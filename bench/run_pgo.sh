#!/usr/bin/env bash
# PGO lane for the sampling hot path: build plain, train an instrumented
# build on the hotpath + fused benches, rebuild with the merged profile,
# and record BOTH snapshots —
#
#   BENCH_hotpath.json      plain  -C opt-level=3 numbers (the baseline)
#   BENCH_hotpath_pgo.json  -Cprofile-use numbers
#
# so the PGO delta on the kernel layer is a recorded, diffable artifact
# (CI uploads both; python/bench_diff.py prints the summary).
#
# Usage:
#   bench/run_pgo.sh [--quick]
#
# Environment:
#   PGO_DIR    profile directory (default: <repo>/target/pgo-profiles)
#   PGO_REUSE  =1 to skip training when $PGO_DIR/merged.profdata exists
#              (CI restores it from cache to keep the job fast)
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

QUICK=()
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=(--quick) ;;
        *) echo "usage: bench/run_pgo.sh [--quick]" >&2; exit 2 ;;
    esac
done

PGO_DIR="${PGO_DIR:-$ROOT/target/pgo-profiles}"
mkdir -p "$PGO_DIR"

echo "==> [1/4] plain build + hotpath snapshot (BENCH_hotpath.json)"
cargo bench --bench hotpath -- --json ${QUICK[@]+"${QUICK[@]}"}
mv "$ROOT/BENCH_hotpath.json" "$ROOT/BENCH_hotpath.plain.json"

if [[ "${PGO_REUSE:-0}" == "1" && -f "$PGO_DIR/merged.profdata" ]]; then
    echo "==> [2/4] PGO_REUSE=1: reusing $PGO_DIR/merged.profdata"
else
    echo "==> [2/4] instrumented build + training runs"
    rm -f "$PGO_DIR"/*.profraw
    # training runs always use --quick (coverage, not timing) and must
    # not fail the lane: instrumentation skews the in-bench speedup
    # gates, which only count on the real builds
    RUSTFLAGS="-Cprofile-generate=$PGO_DIR" \
        cargo bench --bench hotpath -- --quick || true
    RUSTFLAGS="-Cprofile-generate=$PGO_DIR" \
        cargo bench --bench fused -- --quick || true

    PROFDATA="$(command -v llvm-profdata || true)"
    if [[ -z "$PROFDATA" ]]; then
        PROFDATA="$(find "$(rustc --print sysroot)" -name llvm-profdata -type f \
            2>/dev/null | head -n1)"
    fi
    if [[ -z "$PROFDATA" ]]; then
        echo "error: llvm-profdata not found — rustup component add llvm-tools" >&2
        mv "$ROOT/BENCH_hotpath.plain.json" "$ROOT/BENCH_hotpath.json"
        exit 1
    fi
    "$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"/*.profraw
fi

echo "==> [3/4] profile-guided rebuild + hotpath snapshot (BENCH_hotpath_pgo.json)"
RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" \
    cargo bench --bench hotpath -- --json ${QUICK[@]+"${QUICK[@]}"}
mv "$ROOT/BENCH_hotpath.json" "$ROOT/BENCH_hotpath_pgo.json"
mv "$ROOT/BENCH_hotpath.plain.json" "$ROOT/BENCH_hotpath.json"

echo "==> [4/4] plain vs PGO summary (threshold 5%, informational)"
python3 "$ROOT/python/bench_diff.py" \
    "$ROOT/BENCH_hotpath.json" "$ROOT/BENCH_hotpath_pgo.json" --threshold 0.05 || true

echo "done: BENCH_hotpath.json (plain) + BENCH_hotpath_pgo.json (PGO)"
