//! Analytic LM substrate: a deterministic categorical language model pair
//! with *controllable draft-target discrepancy*.
//!
//! The paper's acceptance-rate dynamics depend only on the distributional
//! distance between draft p(.|ctx) and target q(.|ctx) (§3.1, Fig. 1).
//! `SimLm::pair(seed, alpha, ..)` constructs a correlated pair:
//!
//!   target logits(ctx) = z(ctx)
//!   draft  logits(ctx) = alpha * z(ctx) + (1 - alpha) * z'(ctx)
//!
//! with z, z' independent standard-normal vectors derived by hashing the
//! context. `alpha = 1` gives a perfectly aligned draft (acceptance -> 1),
//! `alpha = 0` an independent one. This lets the Exp1/Exp2 sweeps and the
//! Theorem 3.1/3.2 statistical tests run thousands of decode iterations
//! per second with fully reproducible behaviour.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::kvcache::{ColdStore, KvConfig, KvPool, PagedSlots, PoolStatus};
use crate::llm::{EvalNode, Llm, LogitsBatch, PARENT_PREFIX};
use crate::sampling::kernels;
use crate::tree::SessionCore;

/// Markov order of the context hash: only this many trailing tokens
/// shape a conditional, so per-node context builds are O(CTX_ORDER)
/// regardless of prefix length (and the context scratch never grows).
const CTX_ORDER: usize = 8;

/// Stack-buffer width for the chunked logits-row fill: two cache lines
/// of f64, enough for the autovectorizer to fill any lane width.
const ROW_CHUNK: usize = 16;

#[derive(Debug, Clone)]
pub struct SimLm {
    vocab: usize,
    /// Fictitious parameter count, drives MBSU exactly like real models.
    params: usize,
    seed: u64,
    /// Mixing weight towards the shared (target) logits.
    alpha: f64,
    /// Which independent noise stream this model adds (0 = target).
    stream: u64,
    /// Logit scale (sharpness of the conditionals).
    scale: f64,
    cache_len: usize,
    /// Synthetic per-`eval`-call dispatch cost, in splitmix64 rounds
    /// (0 = free). Models the fixed kernel-launch / host-device overhead
    /// a real accelerator pays per forward pass — the cost that fused
    /// [`Llm::eval_batch`] amortizes across requests. Charged once per
    /// `eval` or `eval_batch` call, regardless of row count.
    call_overhead: u64,
    /// Shared paged KV pool (None = dense per-session cache). Each row
    /// of logits already costs O(vocab) real work, so skipping the
    /// prefill of radix-shared prefix tokens is a genuine compute win,
    /// not an accounting trick.
    kv: Option<Arc<KvPool>>,
}

impl SimLm {
    /// A (target, draft) pair with discrepancy `1 - alpha`.
    pub fn pair(seed: u64, alpha: f64, vocab: usize) -> (SimLm, SimLm) {
        let target = SimLm {
            vocab,
            params: 6_900_000, // mirrors the real target/draft ratio (~24x)
            seed,
            alpha: 1.0,
            stream: 0,
            scale: 2.0,
            cache_len: 1 << 20,
            call_overhead: 0,
            kv: None,
        };
        let draft = SimLm { params: 290_000, alpha, stream: 1, ..target.clone() };
        (target, draft)
    }

    /// A (target, draft) pair whose sessions allocate from per-model
    /// paged KV pools ([`crate::kvcache`]): block-granular slots, radix
    /// prefix sharing (when `cfg.share`), LRU eviction, suspend/resume.
    /// Token streams are bit-identical to the dense [`SimLm::pair`] —
    /// the sim's logits depend only on token paths, and sharing only
    /// changes which prefill rows are (re)computed.
    pub fn pair_paged(seed: u64, alpha: f64, vocab: usize, cfg: KvConfig) -> (SimLm, SimLm) {
        let (mut target, mut draft) = Self::pair(seed, alpha, vocab);
        target.cache_len = cfg.num_blocks * cfg.block_size;
        draft.cache_len = target.cache_len;
        target.kv = Some(Arc::new(KvPool::new(cfg)));
        draft.kv = Some(Arc::new(KvPool::new(cfg)));
        (target, draft)
    }

    /// [`SimLm::pair_paged`] plus a persistent cold tier rooted at
    /// `dir`: blocks evicted from each pool's radix index spill to
    /// `<dir>/target` / `<dir>/draft` (separate stores — the two
    /// models' KV contents differ), prefix lookups revive them, and any
    /// radix snapshot a previous process persisted there is loaded
    /// immediately, so hot prefixes survive restarts without
    /// re-prefill. `max_cold_blocks` bounds each store.
    pub fn pair_paged_cold(
        seed: u64,
        alpha: f64,
        vocab: usize,
        cfg: KvConfig,
        dir: impl AsRef<Path>,
        max_cold_blocks: usize,
    ) -> Result<(SimLm, SimLm)> {
        let (target, draft) = Self::pair_paged(seed, alpha, vocab, cfg);
        target.attach_cold(dir.as_ref().join("target"), max_cold_blocks)?;
        draft.attach_cold(dir.as_ref().join("draft"), max_cold_blocks)?;
        Ok((target, draft))
    }

    /// Wire this model's pool to a cold store at `dir` and replay any
    /// radix snapshot persisted there. The exporter/importer closures
    /// capture a pool-less clone of this model: payloads are pure
    /// functions of the token chain, so the hooks never re-enter the
    /// pool they run under.
    pub fn attach_cold(&self, dir: impl AsRef<Path>, max_blocks: usize) -> Result<()> {
        let pool = self.kv.as_ref().context("cold tier needs a paged pool")?;
        let store = ColdStore::open(dir, max_blocks)?;
        let bs = pool.block_size();
        let exporter = SimLm { kv: None, ..self.clone() };
        let importer = exporter.clone();
        pool.set_cold(
            store,
            Box::new(move |chain| exporter.block_payload(chain, bs)),
            Box::new(move |chain, payload| match importer.block_payload(chain, bs) {
                Some(want) => {
                    want.len() == payload.len()
                        && want.iter().zip(payload).all(|(a, b)| a.to_bits() == b.to_bits())
                }
                None => false,
            }),
        );
        pool.load_radix();
        Ok(())
    }

    /// Deterministic pseudo-KV payload for the block closing `chain`:
    /// one f32 per slot, derived from the rolling context hash at that
    /// slot (plus this model's seed/stream identity, so target and
    /// draft payloads differ exactly like their logits do). Bit-exact
    /// reproducible, so import validation is an equality check and any
    /// corruption the file layer misses is still caught.
    fn block_payload(&self, chain: &[u32], block_size: usize) -> Option<Vec<f32>> {
        if chain.len() < block_size || chain.len() % block_size != 0 {
            return None;
        }
        let start = chain.len() - block_size;
        let salt = Self::mix(self.seed ^ self.stream.wrapping_add(0x5eed) ^ self.alpha.to_bits());
        let mut out = Vec::with_capacity(block_size);
        for j in 0..block_size {
            let h = Self::mix(self.ctx_hash(&chain[..start + j + 1]) ^ salt);
            // mantissa bits under a fixed exponent: always finite, never
            // NaN, bitwise comparable
            out.push(f32::from_bits(((h >> 40) as u32 & 0x007F_FFFF) | 0x3F80_0000));
        }
        Some(out)
    }

    /// The model's shared KV pool, when paged.
    pub fn kv_pool(&self) -> Option<&Arc<KvPool>> {
        self.kv.as_ref()
    }

    /// Set the synthetic per-call dispatch cost (see `call_overhead`).
    /// Used by `benches/fused.rs` to make the sim's cost model
    /// launch-dominated like real serving hardware.
    pub fn with_call_overhead(mut self, rounds: u64) -> Self {
        self.call_overhead = rounds;
        self
    }

    /// Burn the fixed per-dispatch cost. Deterministic CPU work (not a
    /// sleep) so bench timings reflect real computation.
    fn spin_dispatch(&self) {
        let mut acc = self.seed | 1;
        for _ in 0..self.call_overhead {
            acc = Self::mix(acc);
        }
        std::hint::black_box(acc);
    }

    /// splitmix64 — fast, well-distributed context hashing.
    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9e3779b97f4a7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
        x ^ (x >> 31)
    }

    fn ctx_hash(&self, ctx: &[u32]) -> u64 {
        // order-sensitive rolling hash over the last CTX_ORDER tokens (a
        // bounded Markov order keeps distinct paths distinct while
        // staying cheap)
        let mut h = Self::mix(self.seed);
        let tail = if ctx.len() > CTX_ORDER { &ctx[ctx.len() - CTX_ORDER..] } else { ctx };
        for &t in tail {
            h = Self::mix(h ^ (t as u64).wrapping_mul(0x100000001b3));
        }
        h
    }

    /// Standard-normal-ish values for (hash, stream, i0..i0+out.len())
    /// via Box-Muller on two splitmix uniforms per index. Two passes so
    /// the float transform vectorizes: the integer hashing (64-bit
    /// multiplies, scalar on most ISAs) stages the uniforms, then one
    /// elementwise pass runs the polynomial `ln`/`cos_2pi` kernels plus
    /// `sqrt` — the row fill's former libm inner loop. `out.len()` must
    /// be <= [`ROW_CHUNK`].
    fn normal_chunk(h: u64, stream: u64, i0: usize, out: &mut [f64]) {
        let mut u2 = [0.0f64; ROW_CHUNK];
        for (j, o) in out.iter_mut().enumerate() {
            let i = i0 + j;
            let a = Self::mix(h ^ Self::mix(stream.wrapping_add(1) ^ (i as u64) << 1));
            let b = Self::mix(a ^ 0xdeadbeefcafef00d);
            *o = ((a >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
            u2[j] = ((b >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        }
        for (o, &v2) in out.iter_mut().zip(&u2) {
            *o = (-2.0 * kernels::ln(*o)).sqrt() * kernels::cos_2pi(v2);
        }
    }

    /// The single row-production path shared by all eval entry points:
    /// append `nodes` to the session core and write one logits row per
    /// node straight into `out`, using the session's bounded context
    /// scratch (the hash only reads the last [`CTX_ORDER`] tokens, so
    /// the context build is O(CTX_ORDER), not O(prefix)).
    fn eval_rows_into(
        &self,
        s: &mut SimSession,
        nodes: &[EvalNode],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let SimSession { core, ctx } = s;
        let range = core.add_pending(nodes)?;
        for i in range {
            core.context_tail_into(i, CTX_ORDER, ctx);
            self.logits_into(ctx, out.push_row());
        }
        Ok(())
    }

    /// Raw logits for a context, written in place (deterministic).
    /// Chunked over [`ROW_CHUNK`]-wide stack buffers so the Box-Muller
    /// transform and the mixture blend run as vectorizable slice passes;
    /// the blend keeps the original per-element operation order, so the
    /// restructuring changes no rounding.
    pub fn logits_into(&self, ctx: &[u32], row: &mut [f32]) {
        debug_assert_eq!(row.len(), self.vocab);
        let h = self.ctx_hash(ctx);
        let blend = self.stream != 0 && self.alpha < 1.0;
        // unit-variance mixture: alpha controls the correlation with the
        // target only, never the draft's sharpness
        let a = self.alpha;
        let norm = (a * a + (1.0 - a) * (1.0 - a)).sqrt();
        let mut shared = [0.0f64; ROW_CHUNK];
        let mut noise = [0.0f64; ROW_CHUNK];
        for (c, chunk) in row.chunks_mut(ROW_CHUNK).enumerate() {
            let i0 = c * ROW_CHUNK;
            let n = chunk.len();
            Self::normal_chunk(h, 0, i0, &mut shared[..n]);
            if blend {
                Self::normal_chunk(h, self.stream, i0, &mut noise[..n]);
                for ((slot, &s), &z) in chunk.iter_mut().zip(&shared[..n]).zip(&noise[..n]) {
                    *slot = (((a * s + (1.0 - a) * z) / norm) * self.scale) as f32;
                }
            } else {
                for (slot, &s) in chunk.iter_mut().zip(&shared[..n]) {
                    *slot = (s * self.scale) as f32;
                }
            }
        }
    }

    /// Raw logits for a context (deterministic; allocating wrapper).
    pub fn logits(&self, ctx: &[u32]) -> Vec<f32> {
        let mut row = vec![0.0; self.vocab];
        self.logits_into(ctx, &mut row);
        row
    }
}

pub struct SimSession {
    pub core: SessionCore,
    /// Bounded context scratch ([`CTX_ORDER`] tokens), reused per row.
    ctx: Vec<u32>,
}

impl Llm for SimLm {
    type Session = SimSession;

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn param_count(&self) -> usize {
        self.params
    }

    fn begin(&self) -> Result<Self::Session> {
        let core = match &self.kv {
            Some(pool) => SessionCore::paged(
                PagedSlots::empty(pool.clone()),
                &[],
                pool.total_slots() as u32,
            ),
            None => SessionCore::new(self.cache_len),
        };
        Ok(SimSession { core, ctx: Vec::with_capacity(CTX_ORDER) })
    }

    /// Paged sessions map the longest radix-cached prefix of the hint as
    /// shared read-only blocks (capped at `hint.len() - 1`, see the
    /// trait docs); the caller skips evaluating those tokens. Dense
    /// sessions ignore the hint.
    fn begin_with_prefix(&self, prefix_hint: &[u32]) -> Result<Self::Session> {
        let max_slots = self.session_capacity();
        self.begin_sized(prefix_hint, max_slots)
    }

    /// [`SimLm::begin_with_prefix`] with a right-sized private-block
    /// table: the session reserves bookkeeping for `max_slots` (clamped
    /// to the pool) instead of the whole pool, fixing the O(sessions x
    /// pool) host-memory footprint while keeping the decode path
    /// allocation-free.
    fn begin_sized(&self, prefix_hint: &[u32], max_slots: usize) -> Result<Self::Session> {
        let Some(pool) = &self.kv else { return self.begin() };
        let m = pool.acquire_prefix(prefix_hint, prefix_hint.len().saturating_sub(1));
        let matched = m.matched;
        let slots = PagedSlots::from_acquire_sized(pool.clone(), m.leases, max_slots);
        Ok(SimSession {
            core: SessionCore::paged(
                slots,
                &prefix_hint[..matched],
                pool.total_slots() as u32,
            ),
            ctx: Vec::with_capacity(CTX_ORDER),
        })
    }

    /// The sim's logits are a pure function of the token path, so a
    /// published prefix is servable immediately — no forward pass needs
    /// to run first (a real paged backend must publish only after the
    /// prefill filled the blocks; see [`crate::kvcache`] module docs).
    fn cache_prefix(&self, tokens: &[u32]) {
        if let Some(pool) = &self.kv {
            pool.publish(tokens);
        }
    }

    fn pool_status(&self) -> Option<PoolStatus> {
        self.kv.as_ref().map(|p| p.status())
    }

    fn set_trace(&self, tracer: &crate::trace::Tracer) {
        if let Some(pool) = &self.kv {
            pool.set_trace(tracer);
        }
    }

    fn session_capacity(&self) -> usize {
        match &self.kv {
            Some(pool) => pool.total_slots(),
            None => self.cache_len - 1,
        }
    }

    fn export_block(&self, chain: &[u32]) -> Option<Vec<f32>> {
        let bs = self.kv.as_ref()?.block_size();
        self.block_payload(chain, bs)
    }

    fn import_block(&self, chain: &[u32], payload: &[f32]) -> bool {
        let Some(pool) = &self.kv else { return false };
        match self.block_payload(chain, pool.block_size()) {
            Some(want) => {
                want.len() == payload.len()
                    && want.iter().zip(payload).all(|(a, b)| a.to_bits() == b.to_bits())
            }
            None => false,
        }
    }

    fn cached_prefix_len(&self, tokens: &[u32]) -> usize {
        self.kv.as_ref().map_or(0, |p| p.peek_prefix(tokens))
    }

    fn persist_cold(&self) {
        if let Some(pool) = &self.kv {
            pool.persist_radix();
        }
    }

    fn eval_into(
        &self,
        s: &mut Self::Session,
        nodes: &[EvalNode],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        self.spin_dispatch();
        self.eval_rows_into(s, nodes, out)
    }

    /// Genuinely vectorized fused pass: one dispatch charge for the whole
    /// cross-request batch and one flat row loop over every group, rather
    /// than N independent eval calls. Rows come from the same single
    /// production path ([`SimLm::eval_rows_into`]), so fused and
    /// per-session results cannot diverge (also property-tested in
    /// tests/fused.rs).
    ///
    /// Upholds the fused atomicity contract ([`Llm::eval_batch_into`]):
    /// every group is validated (capacity, parent topology) before any
    /// session is mutated, so a failing fused call leaves all sessions
    /// untouched and the engine can re-drive per group. Residual: with a
    /// shared paged pool, one group's allocations can starve a later
    /// group mid-call — the engine's admission headroom check prevents
    /// co-scheduling groups that don't jointly fit.
    fn eval_batch_into(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        self.spin_dispatch();
        for (s, nodes) in groups.iter() {
            if nodes.len() > s.core.capacity_left() {
                bail!(
                    "KV cache exhausted: need {} slots, {} free",
                    nodes.len(),
                    s.core.capacity_left()
                );
            }
            let start = s.core.pending.len();
            for (i, n) in nodes.iter().enumerate() {
                if n.parent != PARENT_PREFIX && n.parent as usize >= start + i {
                    bail!(
                        "node {} references parent {} not yet evaluated",
                        start + i,
                        n.parent
                    );
                }
            }
        }
        for (s, nodes) in groups.iter_mut() {
            self.eval_rows_into(s, nodes, out)?;
        }
        Ok(())
    }

    fn commit(&self, s: &mut Self::Session, accepted: &[usize]) -> Result<()> {
        s.core.commit(accepted)
    }

    fn prefix_len(&self, s: &Self::Session) -> usize {
        s.core.prefix_len()
    }

    fn capacity_left(&self, s: &Self::Session) -> usize {
        s.core.capacity_left()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_context() {
        let (t, _) = SimLm::pair(1, 0.5, 32);
        assert_eq!(t.logits(&[1, 2, 3]), t.logits(&[1, 2, 3]));
        assert_ne!(t.logits(&[1, 2, 3]), t.logits(&[1, 2, 4]));
        assert_ne!(t.logits(&[1, 2, 3]), t.logits(&[3, 2, 1])); // order matters
    }

    #[test]
    fn alpha_one_means_identical_models() {
        let (t, d) = SimLm::pair(5, 1.0, 16);
        assert_eq!(t.logits(&[4, 7]), d.logits(&[4, 7]));
    }

    #[test]
    fn alpha_controls_discrepancy_monotonically() {
        use crate::sampling::{process_logits, tv_distance};
        let mut last = 0.0;
        for &alpha in &[0.95, 0.7, 0.3] {
            let (t, d) = SimLm::pair(9, alpha, 64);
            let mut tv = 0.0;
            for c in 0..64u32 {
                let ctx = [c, c.wrapping_mul(7) % 64];
                let q = process_logits(&t.logits(&ctx), 1.0, 1.0).probs();
                let p = process_logits(&d.logits(&ctx), 1.0, 1.0).probs();
                tv += tv_distance(&q, &p);
            }
            tv /= 64.0;
            assert!(tv > last, "alpha={alpha}: tv {tv} should exceed {last}");
            last = tv;
        }
    }

    #[test]
    fn eval_batch_matches_eval_loop() {
        let (t, _) = SimLm::pair(11, 1.0, 24);
        let nodes_a = [EvalNode::root(3), EvalNode::child(5, 0), EvalNode::child(6, 0)];
        let nodes_b = [EvalNode::root(7)];
        let mut sa = t.begin().unwrap();
        let mut sb = t.begin().unwrap();
        let fused = {
            let mut groups: Vec<(&mut SimSession, &[EvalNode])> =
                vec![(&mut sa, &nodes_a[..]), (&mut sb, &nodes_b[..])];
            t.eval_batch(&mut groups).unwrap()
        };
        let mut s1 = t.begin().unwrap();
        let mut s2 = t.begin().unwrap();
        assert_eq!(fused[0], t.eval(&mut s1, &nodes_a).unwrap());
        assert_eq!(fused[1], t.eval(&mut s2, &nodes_b).unwrap());
        // fused sessions hold the same pending state as sequential ones
        assert_eq!(sa.core.pending.len(), 3);
        assert_eq!(sb.core.pending.len(), 1);
    }

    #[test]
    fn eval_uses_path_context() {
        let (t, _) = SimLm::pair(2, 1.0, 16);
        let mut s = t.begin().unwrap();
        // two siblings under the same root: logits must equal direct logits
        let rows = t
            .eval(
                &mut s,
                &[EvalNode::root(3), EvalNode::child(5, 0), EvalNode::child(6, 0)],
            )
            .unwrap();
        assert_eq!(rows[1], t.logits(&[3, 5]));
        assert_eq!(rows[2], t.logits(&[3, 6]));
    }
}
