//! The token-level LM abstraction every decoder runs against.
//!
//! Two implementations exist: [`crate::model::PjrtLm`] (the real
//! AOT-compiled transformer executed via PJRT) and [`crate::sim::SimLm`]
//! (an analytic categorical LM with controllable draft-target
//! discrepancy, used for fast controlled sweeps and property tests).
//! Decoders are generic over this trait, so every algorithm is exercised
//! identically on both substrates.
//!
//! Logits travel in a [`LogitsBatch`]: one flat caller-owned `Vec<f32>`
//! holding every row of a call contiguously, recycled across rounds —
//! the steady-state decode path never allocates a per-row vector (see
//! `rust/README.md` §Hot path). The boxed `Vec<Vec<f32>>` forms survive
//! only as convenience wrappers for tests and examples.

use anyhow::Result;

/// One node to evaluate: a token attached either to the committed prefix
/// tail (`parent == PARENT_PREFIX`) or to an earlier *pending* node of the
/// same session (by pending index). This is how draft trees, prefill
/// chains and single-token decode are all expressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalNode {
    pub token: u32,
    /// `PARENT_PREFIX` or an index into the session's pending list.
    pub parent: i64,
}

pub const PARENT_PREFIX: i64 = -1;

impl EvalNode {
    pub fn root(token: u32) -> Self {
        Self { token, parent: PARENT_PREFIX }
    }

    pub fn child(token: u32, parent: usize) -> Self {
        Self { token, parent: parent as i64 }
    }
}

/// A flat, reusable batch of logits rows: one contiguous buffer, all
/// rows `width` (= vocab) wide. Caller-owned and recycled — `reset`
/// keeps the capacity, so a warm buffer makes every eval call
/// allocation-free. Rows are appended by the `Llm` implementation in
/// node order (and, for fused calls, group-major: group 0's rows, then
/// group 1's, ...).
#[derive(Debug, Clone, Default)]
pub struct LogitsBatch {
    data: Vec<f32>,
    width: usize,
}

impl LogitsBatch {
    /// Drop all rows and fix the row width, keeping capacity.
    pub fn reset(&mut self, width: usize) {
        self.data.clear();
        self.width = width;
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn rows(&self) -> usize {
        if self.width == 0 {
            return 0;
        }
        debug_assert_eq!(self.data.len() % self.width, 0);
        self.data.len() / self.width
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Append one zero-filled row and return it for in-place writing.
    pub fn push_row(&mut self) -> &mut [f32] {
        debug_assert!(self.width > 0, "reset() with the vocab width first");
        let at = self.data.len();
        self.data.resize(at + self.width, 0.0);
        &mut self.data[at..]
    }

    /// Append a row copied from a slice (must be `width` long).
    pub fn push_row_from(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        self.data.extend_from_slice(row);
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    /// Borrow a contiguous row range (e.g. one fused group's rows).
    pub fn view(&self, rows: std::ops::Range<usize>) -> LogitsView<'_> {
        let span = rows.start * self.width..rows.end * self.width;
        LogitsView { data: &self.data[span], width: self.width }
    }

    /// Borrow every row.
    pub fn full(&self) -> LogitsView<'_> {
        LogitsView { data: &self.data, width: self.width }
    }

    /// Copy out as boxed rows (compat / tests only — allocates).
    pub fn to_rows(&self) -> Vec<Vec<f32>> {
        (0..self.rows()).map(|i| self.row(i).to_vec()).collect()
    }
}

/// A borrowed view of consecutive rows in a [`LogitsBatch`].
#[derive(Debug, Clone, Copy)]
pub struct LogitsView<'a> {
    data: &'a [f32],
    width: usize,
}

impl<'a> LogitsView<'a> {
    /// Number of rows.
    pub fn len(&self) -> usize {
        if self.width == 0 {
            return 0;
        }
        self.data.len() / self.width
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn last(&self) -> Option<&'a [f32]> {
        let n = self.len();
        if n == 0 {
            None
        } else {
            Some(self.row(n - 1))
        }
    }
}

/// A language model with tree-structured incremental evaluation.
pub trait Llm {
    type Session;

    fn vocab(&self) -> usize;

    /// Parameter count (drives the MBSU speed ratio, App. C.2).
    fn param_count(&self) -> usize;

    /// Open a fresh session (empty KV cache / empty context).
    fn begin(&self) -> Result<Self::Session>;

    /// Open a session with a *prefix hint*: a pool-backed implementation
    /// may map the longest cached prefix of `prefix_hint` (shared radix
    /// blocks, refcounted) into the session's committed context, so the
    /// caller can skip evaluating those tokens. The match is capped at
    /// `prefix_hint.len() - 1`: at least one hint token is always left
    /// for the caller to evaluate (every decoder needs the logits row at
    /// the prefix tail). Callers read the matched length back through
    /// [`Llm::prefix_len`] and feed only `prefix_hint[matched..]` to
    /// [`Llm::eval_into`]. Default: no sharing, identical to
    /// [`Llm::begin`].
    fn begin_with_prefix(&self, _prefix_hint: &[u32]) -> Result<Self::Session> {
        self.begin()
    }

    /// [`Llm::begin_with_prefix`] plus the session's worst-case KV
    /// footprint in slots (committed + pending high-water mark), so a
    /// pool-backed implementation can right-size per-session
    /// bookkeeping instead of reserving for the whole pool.
    /// `max_slots` is a sizing hint, never a limit — a session may
    /// exceed it at the cost of a reallocation, not an error. Default:
    /// ignores the hint.
    fn begin_sized(&self, prefix_hint: &[u32], _max_slots: usize) -> Result<Self::Session> {
        self.begin_with_prefix(prefix_hint)
    }

    /// Hint that `tokens` is a prompt / persistent prefix worth caching
    /// for future sessions. When — and whether — the blocks become
    /// servable is the implementation's contract: the sim recomputes
    /// logits from tokens, so it publishes immediately; a real paged
    /// backend must wait until the prefill that fills the blocks has
    /// executed. Default: no-op.
    fn cache_prefix(&self, _tokens: &[u32]) {}

    /// Attach a flight-recorder handle ([`crate::trace::Tracer`]) so the
    /// substrate can journal its internal traffic (the paged sim
    /// forwards this to its KV pool: acquire/publish/evict events).
    /// Default: no-op — dense substrates have nothing to record.
    fn set_trace(&self, _tracer: &crate::trace::Tracer) {}

    /// Occupancy and telemetry of the shared KV block pool backing this
    /// model's sessions, when there is one. The engine's admission and
    /// preemption consult this instead of per-session capacity. Default:
    /// `None` (dense per-session caches).
    fn pool_status(&self) -> Option<crate::kvcache::PoolStatus> {
        None
    }

    /// Upper bound on tokens (committed + pending) a fresh session could
    /// ever hold — the admission-time guard against prompts that cannot
    /// fit. Pool-backed implementations report the whole pool (a single
    /// session may use all of it). Default: unbounded.
    fn session_capacity(&self) -> usize {
        usize::MAX
    }

    /// Serialize the KV payload of the cache block that *closes*
    /// `chain`, where `chain` is the full committed token path from the
    /// context start through the block's last token (always a whole
    /// number of blocks). The cold tier ([`crate::kvcache::cold`])
    /// calls this when an evicted radix block spills to disk. `None` =
    /// this substrate cannot serialize KV state (the default; the PJRT
    /// backend's caches are device-resident).
    fn export_block(&self, _chain: &[u32]) -> Option<Vec<f32>> {
        None
    }

    /// Validate a payload previously produced by [`Llm::export_block`]
    /// for `chain`: `true` iff the bytes are exactly what this
    /// substrate would export, i.e. the revived block is servable.
    /// Defense in depth behind the file-level checksum — it also
    /// catches stale or cross-model payloads. Default: reject.
    fn import_block(&self, _chain: &[u32], _payload: &[f32]) -> bool {
        false
    }

    /// How many leading tokens of `tokens` this substrate could serve
    /// without re-prefill right now (hot radix match plus cold-tier
    /// membership). Admission headroom uses this to discount a
    /// candidate's prefill cost; it is a hint, never a reservation.
    /// Default: 0.
    fn cached_prefix_len(&self, _tokens: &[u32]) -> usize {
        0
    }

    /// Flush whatever should survive a restart — the paged sim persists
    /// its radix snapshot and resident blocks to the cold tier. The
    /// engine calls this once on clean shutdown. Default: no-op.
    fn persist_cold(&self) {}

    /// Evaluate `nodes`, appending them to the session's pending set, and
    /// APPEND one raw-logits row per node to `out` (next-token logits
    /// given the node's full path context). The caller owns `out` and
    /// must have `reset` it to the model's vocab width; appending (not
    /// resetting) is what lets the fused default below share one buffer
    /// across groups. Parents must reference earlier pending nodes (from
    /// this or previous eval calls since the last commit).
    fn eval_into(
        &self,
        session: &mut Self::Session,
        nodes: &[EvalNode],
        out: &mut LogitsBatch,
    ) -> Result<()>;

    /// Boxed-rows convenience wrapper over [`Llm::eval_into`]
    /// (allocates; tests/examples only).
    fn eval(&self, session: &mut Self::Session, nodes: &[EvalNode]) -> Result<Vec<Vec<f32>>> {
        let mut batch = LogitsBatch::default();
        batch.reset(self.vocab());
        self.eval_into(session, nodes, &mut batch)?;
        Ok(batch.to_rows())
    }

    /// Evaluate many sessions' node sets in one fused forward pass: the
    /// cross-request batch dimension of the serving engine. `groups[i]`
    /// pairs a session with the nodes to append to it (exactly as one
    /// [`Llm::eval_into`] call would); rows are appended to `out`
    /// group-major (group i's rows are contiguous, in group order), so
    /// the caller slices per-group views from the node counts.
    ///
    /// The default implementation is the per-session fallback loop —
    /// semantically the fused path and the loop MUST be
    /// indistinguishable (same rows, same session state), which the
    /// engine's fused round loop and the equivalence property tests rely
    /// on. Implementations override this to amortize per-call overhead
    /// (one padded device dispatch instead of N).
    ///
    /// Error contract: a fused implementation SHOULD validate every
    /// group up front and fail *before mutating any session* — that is
    /// what lets the engine isolate blast radius by re-driving a failed
    /// phase per group through [`Llm::eval_into`], where only the
    /// poisoned group(s) fail and every other group produces identical
    /// rows ([`crate::sim::SimLm`] and [`crate::chaos::ChaosLm`] uphold
    /// this). This default fallback loop is best-effort only: an
    /// earlier group may already hold its new pending nodes when a
    /// later group fails, so under the default a caller must still
    /// treat participating sessions as suspect. Fused substrates used
    /// by the serving engine override this with the atomic form.
    fn eval_batch_into(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        for (session, nodes) in groups.iter_mut() {
            self.eval_into(session, nodes, out)?;
        }
        Ok(())
    }

    /// Boxed-rows convenience wrapper over [`Llm::eval_batch_into`]
    /// (allocates; tests only).
    fn eval_batch(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let counts: Vec<usize> = groups.iter().map(|(_, nodes)| nodes.len()).collect();
        let mut batch = LogitsBatch::default();
        batch.reset(self.vocab());
        self.eval_batch_into(groups, &mut batch)?;
        let mut out = Vec::with_capacity(counts.len());
        let mut row = 0;
        for n in counts {
            out.push((row..row + n).map(|r| batch.row(r).to_vec()).collect());
            row += n;
        }
        Ok(out)
    }

    /// Commit `accepted` (pending indices forming a rootward chain:
    /// `accepted[0]` has prefix parent, each subsequent entry's parent is
    /// the previous one) into the prefix; discard every other pending
    /// node. The paper's `FilterKVCache` — here a pure index operation,
    /// no cache data moves.
    fn commit(&self, session: &mut Self::Session, accepted: &[usize]) -> Result<()>;

    /// Logical length of the committed context.
    fn prefix_len(&self, session: &Self::Session) -> usize;

    /// How many more tokens (pending + committed) the session can hold.
    fn capacity_left(&self, session: &Self::Session) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_batch_rows_and_views() {
        let mut b = LogitsBatch::default();
        b.reset(3);
        assert_eq!(b.rows(), 0);
        b.push_row().copy_from_slice(&[1.0, 2.0, 3.0]);
        b.push_row_from(&[4.0, 5.0, 6.0]);
        b.push_row_from(&[7.0, 8.0, 9.0]);
        assert_eq!(b.rows(), 3);
        assert_eq!(b.row(1), &[4.0, 5.0, 6.0]);
        let v = b.view(1..3);
        assert_eq!(v.len(), 2);
        assert_eq!(v.row(0), &[4.0, 5.0, 6.0]);
        assert_eq!(v.last().unwrap(), &[7.0, 8.0, 9.0]);
        assert_eq!(b.full().len(), 3);
        assert_eq!(b.to_rows()[2], vec![7.0, 8.0, 9.0]);
        // reset keeps capacity, drops rows
        let cap = 9; // 3 rows x width 3 already in the buffer
        b.reset(3);
        assert_eq!(b.rows(), 0);
        assert!(b.data.capacity() >= cap);
    }
}
