//! The token-level LM abstraction every decoder runs against.
//!
//! Two implementations exist: [`crate::model::PjrtLm`] (the real
//! AOT-compiled transformer executed via PJRT) and [`crate::sim::SimLm`]
//! (an analytic categorical LM with controllable draft-target
//! discrepancy, used for fast controlled sweeps and property tests).
//! Decoders are generic over this trait, so every algorithm is exercised
//! identically on both substrates.

use anyhow::Result;

/// One node to evaluate: a token attached either to the committed prefix
/// tail (`parent == PARENT_PREFIX`) or to an earlier *pending* node of the
/// same session (by pending index). This is how draft trees, prefill
/// chains and single-token decode are all expressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalNode {
    pub token: u32,
    /// `PARENT_PREFIX` or an index into the session's pending list.
    pub parent: i64,
}

pub const PARENT_PREFIX: i64 = -1;

impl EvalNode {
    pub fn root(token: u32) -> Self {
        Self { token, parent: PARENT_PREFIX }
    }

    pub fn child(token: u32, parent: usize) -> Self {
        Self { token, parent: parent as i64 }
    }
}

/// A language model with tree-structured incremental evaluation.
pub trait Llm {
    type Session;

    fn vocab(&self) -> usize;

    /// Parameter count (drives the MBSU speed ratio, App. C.2).
    fn param_count(&self) -> usize;

    /// Open a fresh session (empty KV cache / empty context).
    fn begin(&self) -> Result<Self::Session>;

    /// Evaluate `nodes`, appending them to the session's pending set, and
    /// return one raw-logits row per node (next-token logits given the
    /// node's full path context). Parents must reference earlier pending
    /// nodes (from this or previous `eval` calls since the last commit).
    fn eval(&self, session: &mut Self::Session, nodes: &[EvalNode]) -> Result<Vec<Vec<f32>>>;

    /// Evaluate many sessions' node sets in one fused forward pass: the
    /// cross-request batch dimension of the serving engine. `groups[i]`
    /// pairs a session with the nodes to append to it (exactly as one
    /// [`Llm::eval`] call would); the result carries one row-set per
    /// group, in order.
    ///
    /// The default implementation is the per-session fallback loop —
    /// semantically the fused path and the loop MUST be
    /// indistinguishable (same rows, same session state), which the
    /// engine's fused round loop and the equivalence property tests rely
    /// on. Implementations override this to amortize per-call overhead
    /// (one padded device dispatch instead of N).
    ///
    /// On error, sessions of earlier groups may already hold the new
    /// pending nodes while their rows are lost; callers must treat every
    /// participating session as poisoned (the engine fails all
    /// participating requests).
    fn eval_batch(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let mut out = Vec::with_capacity(groups.len());
        for (session, nodes) in groups.iter_mut() {
            out.push(self.eval(session, nodes)?);
        }
        Ok(out)
    }

    /// Commit `accepted` (pending indices forming a rootward chain:
    /// `accepted[0]` has prefix parent, each subsequent entry's parent is
    /// the previous one) into the prefix; discard every other pending
    /// node. The paper's `FilterKVCache` — here a pure index operation,
    /// no cache data moves.
    fn commit(&self, session: &mut Self::Session, accepted: &[usize]) -> Result<()>;

    /// Logical length of the committed context.
    fn prefix_len(&self, session: &Self::Session) -> usize;

    /// How many more tokens (pending + committed) the session can hold.
    fn capacity_left(&self, session: &Self::Session) -> usize;
}
