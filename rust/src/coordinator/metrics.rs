//! Serving metrics: counters + latency distributions.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub tokens_out: AtomicU64,
    pub decode_rounds: AtomicU64,
    pub draft_calls: AtomicU64,
    /// End-to-end request latencies (seconds).
    latencies: Mutex<Vec<f64>>,
    /// Time-to-first-token latencies (seconds).
    ttft: Mutex<Vec<f64>>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub tokens_out: u64,
    pub decode_rounds: u64,
    pub draft_calls: u64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl Metrics {
    pub fn record_latency(&self, secs: f64) {
        self.latencies.lock().unwrap().push(secs);
    }

    pub fn record_ttft(&self, secs: f64) {
        self.ttft.lock().unwrap().push(secs);
    }

    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lat = self.latencies.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ttft = self.ttft.lock().unwrap().clone();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Snapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            decode_rounds: self.decode_rounds.load(Ordering::Relaxed),
            draft_calls: self.draft_calls.load(Ordering::Relaxed),
            latency_p50: percentile(&lat, 0.50),
            latency_p95: percentile(&lat, 0.95),
            latency_p99: percentile(&lat, 0.99),
            ttft_p50: percentile(&ttft, 0.50),
            ttft_p95: percentile(&ttft, 0.95),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_samples() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i as f64);
        }
        let s = m.snapshot();
        assert!((s.latency_p50 - 50.0).abs() <= 1.0);
        assert!((s.latency_p95 - 95.0).abs() <= 1.0);
        assert!((s.latency_p99 - 99.0).abs() <= 1.0);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.tokens_out, 5);
        m.add(&m.tokens_out, 7);
        assert_eq!(m.snapshot().tokens_out, 12);
    }
}
