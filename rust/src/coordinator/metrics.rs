//! Serving metrics: counters, latency distributions, the adaptive
//! controller's telemetry (per-level acceptance rates, per-round
//! tree-node-budget histogram), the fused-execution telemetry — how
//! many requests each fused [`crate::llm::Llm::eval_batch`] call
//! carried and how full those batches were relative to the round's
//! in-flight request count — the paged KV-cache telemetry: prefix
//! hit rate (plus a per-request hit-ratio decile histogram), blocks in
//! use, copy-on-write copies, evictions, and preemption/resume counts —
//! and the per-phase wall-clock breakdown of the engine round loop
//! (scheduling / draft / verify / sampling), all as bounded
//! log-bucketed histograms.
//!
//! Every distribution here is O(1) memory ([`LogHistogram`]): a
//! long-running server's metrics never grow, and `snapshot()` never
//! clones or sorts sample lists.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::decode::spec::RoundReport;
use crate::kvcache::PoolStatus;
use crate::trace::hist::{HistSummary, LogHistogram};
use crate::trace::{PHASE_DRAFT, PHASE_HOST, PHASE_SCHED, PHASE_VERIFY};
use crate::util::json::Json;

/// Rounds using more nodes than this share the last histogram bucket.
pub const NODE_HIST_MAX: usize = 64;

/// Fused calls batching more requests than this share the last bucket.
pub const FUSED_HIST_MAX: usize = 64;

/// Number of fill-ratio buckets (deciles of participating / in-round).
pub const FILL_BUCKETS: usize = 10;

#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Requests dropped by load/deadline shedding: queue-full rejections
    /// and queued requests whose deadline expired before admission.
    pub shed: AtomicU64,
    /// Engine-side bounded retries of transient faults (suspend +
    /// requeue + resume cycles that were NOT memory-pressure preemptions).
    pub retries: AtomicU64,
    /// Requests aborted by a client `cancel` command.
    pub cancelled: AtomicU64,
    pub tokens_out: AtomicU64,
    pub decode_rounds: AtomicU64,
    pub draft_calls: AtomicU64,
    /// End-to-end request latencies (seconds), measured from arrival
    /// (queue entry), not from admission.
    latencies: LogHistogram,
    /// Time-to-first-token latencies (seconds), measured from arrival —
    /// the number continuous admission exists to shrink.
    ttft: LogHistogram,
    /// Queue waits (seconds): time between entering the waiting queue
    /// and admission (re-waits after preemption count separately).
    queue_wait: LogHistogram,
    /// Wall-clock of one full engine round (begin -> commit).
    round_time: LogHistogram,
    /// Wall-clock per engine phase, keyed by the `PHASE_*` codes:
    /// scheduling overhead (admission/preemption/bookkeeping between
    /// rounds), fused draft levels, the fused target verify, and the
    /// host-side sampling/verification walk.
    phase_sched: LogHistogram,
    phase_draft: LogHistogram,
    phase_verify: LogHistogram,
    phase_host: LogHistogram,
    /// Requests admitted at a mid-round phase boundary (continuous
    /// batching), i.e. while other requests were mid-round — as opposed
    /// to the pre-round admission point.
    pub mid_round_admitted: AtomicU64,
    /// Per-level verification attempts / acceptances across all rounds.
    level_attempts: Mutex<Vec<u64>>,
    level_accepts: Mutex<Vec<u64>>,
    /// Histogram of draft-tree nodes per round (index = node count,
    /// clamped to [`NODE_HIST_MAX`]).
    round_nodes_hist: Mutex<Vec<u64>>,
    /// Total fused model calls (draft + target) issued by the engine's
    /// round loop.
    pub fused_calls: AtomicU64,
    /// Exact sum of group counts across all fused calls (the clamped
    /// histogram below cannot recover the true mean for huge batches).
    pub fused_groups_total: AtomicU64,
    /// Histogram of requests per fused call (index = group count,
    /// clamped to [`FUSED_HIST_MAX`]).
    fused_batch_hist: Mutex<Vec<u64>>,
    /// Fill-ratio deciles: bucket `b` counts fused calls whose
    /// participating/in-round ratio fell in `(b/10, (b+1)/10]`. A draft
    /// call late in a round has low fill (most trees already complete);
    /// the target call always fills the batch.
    fused_fill_hist: Mutex<[u64; FILL_BUCKETS]>,
    /// Requests suspended (KV spilled, requeued at the queue front) by
    /// the engine under pool memory pressure.
    pub preemptions: AtomicU64,
    /// Suspended requests re-admitted and resumed.
    pub resumes: AtomicU64,
    /// Latest target-pool cumulative counters (stored, not summed — the
    /// pool owns the running totals; see [`Metrics::set_kv_pool`]).
    pub kv_hit_tokens: AtomicU64,
    pub kv_lookup_tokens: AtomicU64,
    pub kv_cow_copies: AtomicU64,
    pub kv_evictions: AtomicU64,
    /// Latest target-pool cold-tier cumulative counters (stored like
    /// the hot counters above; all zero when the cold tier is off).
    pub kv_cold_spills: AtomicU64,
    pub kv_cold_hits: AtomicU64,
    pub kv_cold_hit_tokens: AtomicU64,
    pub kv_cold_misses: AtomicU64,
    pub kv_cold_corrupt: AtomicU64,
    /// Latest target-pool occupancy gauges.
    pub kv_blocks_in_use: AtomicU64,
    pub kv_blocks_total: AtomicU64,
    /// Per-request prefix hit-ratio deciles: bucket `b` counts completed
    /// requests whose (hit tokens / prompt tokens) fell in
    /// `[b/10, (b+1)/10)` (full hits land in the last bucket).
    kv_hit_hist: Mutex<[u64; FILL_BUCKETS]>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    /// Queue-full + expired-deadline sheds.
    pub shed: u64,
    /// Transient-fault retry cycles.
    pub retries: u64,
    /// Client-cancelled requests.
    pub cancelled: u64,
    pub tokens_out: u64,
    pub decode_rounds: u64,
    pub draft_calls: u64,
    /// End-to-end latency distribution with its exact sample count
    /// (only completions that recorded a latency are counted). The flat
    /// `latency_*` fields below mirror it.
    pub latency: HistSummary,
    /// Time-to-first-token distribution; its count is the number of
    /// requests that actually streamed a token, which can trail
    /// `completed`.
    pub ttft: HistSummary,
    /// Queue-wait (arrival -> admission) distribution; its count
    /// includes resume-after-preemption re-admissions, so it can exceed
    /// `admitted`.
    pub queue_wait: HistSummary,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    /// Mean end-to-end latency (exact; 0.0 before any completion).
    pub latency_mean: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    /// Mean time-to-first-token (0.0 before any token streamed).
    pub ttft_mean: f64,
    /// Queue-wait (arrival -> admission) percentiles and mean.
    pub queue_wait_p50: f64,
    pub queue_wait_p95: f64,
    pub queue_wait_p99: f64,
    pub queue_wait_mean: f64,
    /// Engine-round wall-clock distribution (seconds).
    pub round_time: HistSummary,
    /// Per-phase wall-clock distributions: scheduling overhead, fused
    /// draft levels, fused target verify, host sampling/verification.
    pub phase_sched: HistSummary,
    pub phase_draft: HistSummary,
    pub phase_verify: HistSummary,
    pub phase_host: HistSummary,
    /// Requests admitted at a mid-round phase boundary.
    pub mid_round_admitted: u64,
    /// Empirical acceptance rate per tree level (accepts / attempts);
    /// empty until a speculative round ran.
    pub accept_rate_by_level: Vec<f64>,
    /// Non-empty buckets of the nodes-per-round histogram, ascending
    /// node count.
    pub round_nodes_hist: Vec<(usize, u64)>,
    /// Total fused model calls issued by the engine round loop.
    pub fused_calls: u64,
    /// Non-empty buckets of the requests-per-fused-call histogram,
    /// ascending group count.
    pub fused_batch_hist: Vec<(usize, u64)>,
    /// Fill-ratio deciles (bucket `b` = ratio in `(b/10, (b+1)/10]`).
    pub fused_fill_hist: [u64; FILL_BUCKETS],
    /// Mean requests per fused call (0.0 before any fused call).
    pub fused_mean_batch: f64,
    /// Engine preemptions (suspend + requeue-front) and resumes.
    pub preemptions: u64,
    pub resumes: u64,
    /// Target-pool cumulative prefix-cache counters (0 when dense).
    pub kv_hit_tokens: u64,
    pub kv_lookup_tokens: u64,
    pub kv_cow_copies: u64,
    pub kv_evictions: u64,
    /// Target-pool cold-tier counters: blocks spilled to disk, cold
    /// fetches that revived a block / missed / failed validation
    /// (corrupt files degrade to re-prefill, counted here), and the
    /// tokens cold hits served (all 0 when the cold tier is off).
    pub kv_cold_spills: u64,
    pub kv_cold_hits: u64,
    pub kv_cold_hit_tokens: u64,
    pub kv_cold_misses: u64,
    pub kv_cold_corrupt: u64,
    /// Target-pool occupancy gauges at snapshot time.
    pub kv_blocks_in_use: u64,
    pub kv_blocks_total: u64,
    /// Cumulative prefix hit rate (hit / looked-up tokens; 0 when no
    /// lookups happened).
    pub kv_hit_rate: f64,
    /// Cold-tier hit rate (hits / consults; 0 when the cold tier never
    /// answered a lookup).
    pub kv_cold_hit_rate: f64,
    /// Per-request hit-ratio deciles (bucket `b` = ratio in
    /// `[b/10, (b+1)/10)`, full hits in the last bucket).
    pub kv_hit_hist: [u64; FILL_BUCKETS],
}

impl Metrics {
    pub fn record_latency(&self, secs: f64) {
        self.latencies.record(secs);
    }

    pub fn record_ttft(&self, secs: f64) {
        self.ttft.record(secs);
    }

    pub fn record_queue_wait(&self, secs: f64) {
        self.queue_wait.record(secs);
    }

    /// Fold one engine round's total wall-clock into the distribution.
    pub fn record_round_time(&self, secs: f64) {
        self.round_time.record(secs);
    }

    /// Fold one phase's wall-clock into the per-phase breakdown.
    /// `code` is one of the `crate::trace::PHASE_*` constants (the same
    /// codes the flight recorder carries); unknown codes are dropped.
    pub fn record_phase(&self, code: u32, secs: f64) {
        match code & 0xff {
            PHASE_SCHED => self.phase_sched.record(secs),
            PHASE_DRAFT => self.phase_draft.record(secs),
            PHASE_VERIFY => self.phase_verify.record(secs),
            PHASE_HOST => self.phase_host.record(secs),
            _ => {}
        }
    }

    /// Fold one speculative round's verification telemetry into the
    /// per-level acceptance and node-budget histograms.
    pub fn record_round(&self, report: &RoundReport) {
        {
            let mut attempts = self.level_attempts.lock().unwrap();
            let mut accepts = self.level_accepts.lock().unwrap();
            for (level, &(_, success)) in report.level_trials.iter().enumerate() {
                if attempts.len() <= level {
                    attempts.resize(level + 1, 0);
                    accepts.resize(level + 1, 0);
                }
                attempts[level] += 1;
                accepts[level] += success as u64;
            }
        }
        let bucket = report.nodes.min(NODE_HIST_MAX);
        let mut hist = self.round_nodes_hist.lock().unwrap();
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }

    /// Record one fused model call: `groups` requests participated out of
    /// `in_round` requests currently running this round.
    pub fn record_fused(&self, groups: usize, in_round: usize) {
        if groups == 0 {
            return;
        }
        self.add(&self.fused_calls, 1);
        self.add(&self.fused_groups_total, groups as u64);
        {
            let bucket = groups.min(FUSED_HIST_MAX);
            let mut hist = self.fused_batch_hist.lock().unwrap();
            if hist.len() <= bucket {
                hist.resize(bucket + 1, 0);
            }
            hist[bucket] += 1;
        }
        let ratio = groups as f64 / in_round.max(groups) as f64;
        // ratio in (0, 1]: bucket b covers (b/10, (b+1)/10]
        let bucket = ((ratio * FILL_BUCKETS as f64).ceil() as usize)
            .clamp(1, FILL_BUCKETS)
            - 1;
        self.fused_fill_hist.lock().unwrap()[bucket] += 1;
    }

    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Mirror the target pool's occupancy + cumulative counters into the
    /// exported gauges (the pool owns the running totals, so this is a
    /// store, not an accumulate — safe to call every round).
    pub fn set_kv_pool(&self, ps: &PoolStatus) {
        let st = Ordering::Relaxed;
        self.kv_hit_tokens.store(ps.stats.hit_tokens, st);
        self.kv_lookup_tokens.store(ps.stats.lookup_tokens, st);
        self.kv_cow_copies.store(ps.stats.cow_copies, st);
        self.kv_evictions.store(ps.stats.evictions, st);
        self.kv_cold_spills.store(ps.stats.cold_spills, st);
        self.kv_cold_hits.store(ps.stats.cold_hits, st);
        self.kv_cold_hit_tokens.store(ps.stats.cold_hit_tokens, st);
        self.kv_cold_misses.store(ps.stats.cold_misses, st);
        self.kv_cold_corrupt.store(ps.stats.cold_corrupt, st);
        self.kv_blocks_in_use.store(ps.blocks_in_use() as u64, st);
        self.kv_blocks_total.store(ps.total_blocks as u64, st);
    }

    /// Fold one completed request's prefix hit ratio (hit tokens over
    /// prompt tokens, clamped to 1 — resumes can push hits past the
    /// prompt length) into the decile histogram.
    pub fn record_kv_hit_ratio(&self, hit_tokens: usize, prompt_tokens: usize) {
        if prompt_tokens == 0 {
            return;
        }
        let ratio = (hit_tokens as f64 / prompt_tokens as f64).min(1.0);
        let bucket = ((ratio * FILL_BUCKETS as f64) as usize).min(FILL_BUCKETS - 1);
        self.kv_hit_hist.lock().unwrap()[bucket] += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let lat = self.latencies.summary();
        let ttft = self.ttft.summary();
        let qwait = self.queue_wait.summary();
        let attempts = self.level_attempts.lock().unwrap();
        let accepts = self.level_accepts.lock().unwrap();
        let accept_rate_by_level = attempts
            .iter()
            .zip(accepts.iter())
            .map(|(&n, &s)| if n == 0 { 0.0 } else { s as f64 / n as f64 })
            .collect();
        let round_nodes_hist = self
            .round_nodes_hist
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(nodes, &c)| (nodes, c))
            .collect();
        let fused_batch_hist: Vec<(usize, u64)> = self
            .fused_batch_hist
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(groups, &c)| (groups, c))
            .collect();
        let fused_fill_hist = *self.fused_fill_hist.lock().unwrap();
        let fused_calls = self.fused_calls.load(Ordering::Relaxed);
        // exact mean from the unclamped counter, not the display histogram
        let fused_mean_batch = if fused_calls == 0 {
            0.0
        } else {
            self.fused_groups_total.load(Ordering::Relaxed) as f64 / fused_calls as f64
        };
        let kv_hit_tokens = self.kv_hit_tokens.load(Ordering::Relaxed);
        let kv_lookup_tokens = self.kv_lookup_tokens.load(Ordering::Relaxed);
        let kv_hit_rate = if kv_lookup_tokens == 0 {
            0.0
        } else {
            kv_hit_tokens as f64 / kv_lookup_tokens as f64
        };
        let kv_cold_hits = self.kv_cold_hits.load(Ordering::Relaxed);
        let kv_cold_misses = self.kv_cold_misses.load(Ordering::Relaxed);
        let kv_cold_corrupt = self.kv_cold_corrupt.load(Ordering::Relaxed);
        let cold_consults = kv_cold_hits + kv_cold_misses + kv_cold_corrupt;
        let kv_cold_hit_rate = if cold_consults == 0 {
            0.0
        } else {
            kv_cold_hits as f64 / cold_consults as f64
        };
        Snapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            decode_rounds: self.decode_rounds.load(Ordering::Relaxed),
            draft_calls: self.draft_calls.load(Ordering::Relaxed),
            latency: lat,
            ttft,
            queue_wait: qwait,
            latency_p50: lat.p50,
            latency_p95: lat.p95,
            latency_p99: lat.p99,
            latency_mean: lat.mean,
            ttft_p50: ttft.p50,
            ttft_p95: ttft.p95,
            ttft_p99: ttft.p99,
            ttft_mean: ttft.mean,
            queue_wait_p50: qwait.p50,
            queue_wait_p95: qwait.p95,
            queue_wait_p99: qwait.p99,
            queue_wait_mean: qwait.mean,
            round_time: self.round_time.summary(),
            phase_sched: self.phase_sched.summary(),
            phase_draft: self.phase_draft.summary(),
            phase_verify: self.phase_verify.summary(),
            phase_host: self.phase_host.summary(),
            mid_round_admitted: self.mid_round_admitted.load(Ordering::Relaxed),
            accept_rate_by_level,
            round_nodes_hist,
            fused_calls,
            fused_batch_hist,
            fused_fill_hist,
            fused_mean_batch,
            preemptions: self.preemptions.load(Ordering::Relaxed),
            resumes: self.resumes.load(Ordering::Relaxed),
            kv_hit_tokens,
            kv_lookup_tokens,
            kv_cow_copies: self.kv_cow_copies.load(Ordering::Relaxed),
            kv_evictions: self.kv_evictions.load(Ordering::Relaxed),
            kv_cold_spills: self.kv_cold_spills.load(Ordering::Relaxed),
            kv_cold_hits,
            kv_cold_hit_tokens: self.kv_cold_hit_tokens.load(Ordering::Relaxed),
            kv_cold_misses,
            kv_cold_corrupt,
            kv_blocks_in_use: self.kv_blocks_in_use.load(Ordering::Relaxed),
            kv_blocks_total: self.kv_blocks_total.load(Ordering::Relaxed),
            kv_hit_rate,
            kv_cold_hit_rate,
            kv_hit_hist: *self.kv_hit_hist.lock().unwrap(),
        }
    }
}

/// One scalar exported by BOTH the snapshot JSON (`json_key`) and the
/// Prometheus exposition (`prom_name`/`prom_kind`). Keeping the two
/// renderings on one table makes key drift structurally impossible —
/// the reflection test in `trace::export` diffs each rendering against
/// this table.
pub struct ScalarExport {
    pub json_key: &'static str,
    pub prom_name: &'static str,
    /// `"counter"` or `"gauge"`.
    pub prom_kind: &'static str,
    pub value: f64,
    /// Render as an integer in JSON (counters and occupancy gauges).
    pub integer: bool,
}

impl Snapshot {
    /// Every scalar this snapshot exports, in exposition order.
    pub fn scalar_exports(&self) -> Vec<ScalarExport> {
        let c = |json_key, prom_name, v: u64| ScalarExport {
            json_key,
            prom_name,
            prom_kind: "counter",
            value: v as f64,
            integer: true,
        };
        let g = |json_key, prom_name, v: f64, integer| ScalarExport {
            json_key,
            prom_name,
            prom_kind: "gauge",
            value: v,
            integer,
        };
        vec![
            c("admitted", "rsd_requests_admitted_total", self.admitted),
            c("rejected", "rsd_requests_rejected_total", self.rejected),
            c("completed", "rsd_requests_completed_total", self.completed),
            c("failed", "rsd_requests_failed_total", self.failed),
            c("shed", "rsd_requests_shed_total", self.shed),
            c("retries", "rsd_retries_total", self.retries),
            c("cancelled", "rsd_requests_cancelled_total", self.cancelled),
            c("tokens_out", "rsd_tokens_out_total", self.tokens_out),
            c("decode_rounds", "rsd_decode_rounds_total", self.decode_rounds),
            c("draft_calls", "rsd_draft_calls_total", self.draft_calls),
            c("fused_calls", "rsd_fused_calls_total", self.fused_calls),
            c("mid_round_admitted", "rsd_mid_round_admitted_total", self.mid_round_admitted),
            c("preemptions", "rsd_preemptions_total", self.preemptions),
            c("resumes", "rsd_resumes_total", self.resumes),
            c("kv_hit_tokens", "rsd_kv_hit_tokens_total", self.kv_hit_tokens),
            c("kv_lookup_tokens", "rsd_kv_lookup_tokens_total", self.kv_lookup_tokens),
            c("kv_cow_copies", "rsd_kv_cow_copies_total", self.kv_cow_copies),
            c("kv_evictions", "rsd_kv_evictions_total", self.kv_evictions),
            c("kv_cold_spills", "rsd_kv_cold_spills_total", self.kv_cold_spills),
            c("kv_cold_hits", "rsd_kv_cold_hits_total", self.kv_cold_hits),
            c("kv_cold_hit_tokens", "rsd_kv_cold_hit_tokens_total", self.kv_cold_hit_tokens),
            c("kv_cold_misses", "rsd_kv_cold_misses_total", self.kv_cold_misses),
            c("kv_cold_corrupt", "rsd_kv_cold_corrupt_total", self.kv_cold_corrupt),
            g("kv_blocks_in_use", "rsd_kv_blocks_in_use", self.kv_blocks_in_use as f64, true),
            g("kv_blocks_total", "rsd_kv_blocks_total", self.kv_blocks_total as f64, true),
            g("kv_hit_rate", "rsd_kv_hit_rate", self.kv_hit_rate, false),
            g("kv_cold_hit_rate", "rsd_kv_cold_hit_rate", self.kv_cold_hit_rate, false),
            g("fused_mean_batch", "rsd_fused_mean_batch", self.fused_mean_batch, false),
        ]
    }
}

fn hist_json(h: &HistSummary) -> Json {
    Json::obj(vec![
        ("count", Json::from(h.count as usize)),
        ("mean", Json::Num(h.mean)),
        ("p50", Json::Num(h.p50)),
        ("p95", Json::Num(h.p95)),
        ("p99", Json::Num(h.p99)),
    ])
}

impl Snapshot {
    /// The full snapshot as JSON — the payload of the `metrics` wire
    /// command. Field names match the struct fields one-to-one.
    pub fn to_json(&self) -> Json {
        let sparse_hist = |h: &[(usize, u64)]| {
            Json::Arr(
                h.iter()
                    .map(|&(k, c)| Json::Arr(vec![Json::from(k), Json::from(c as usize)]))
                    .collect(),
            )
        };
        let deciles = |h: &[u64; FILL_BUCKETS]| {
            Json::Arr(h.iter().map(|&c| Json::from(c as usize)).collect())
        };
        // every shared scalar comes off the export table (keeps JSON
        // and Prometheus key sets in lockstep; see `scalar_exports`)
        let mut pairs: Vec<(&str, Json)> = self
            .scalar_exports()
            .into_iter()
            .map(|e| {
                let v = if e.integer { Json::from(e.value as usize) } else { Json::Num(e.value) };
                (e.json_key, v)
            })
            .collect();
        pairs.extend(vec![
            ("latency", hist_json(&self.latency)),
            ("ttft", hist_json(&self.ttft)),
            ("queue_wait", hist_json(&self.queue_wait)),
            ("latency_p50", Json::Num(self.latency_p50)),
            ("latency_p95", Json::Num(self.latency_p95)),
            ("latency_p99", Json::Num(self.latency_p99)),
            ("latency_mean", Json::Num(self.latency_mean)),
            ("ttft_p50", Json::Num(self.ttft_p50)),
            ("ttft_p95", Json::Num(self.ttft_p95)),
            ("ttft_p99", Json::Num(self.ttft_p99)),
            ("ttft_mean", Json::Num(self.ttft_mean)),
            ("queue_wait_p50", Json::Num(self.queue_wait_p50)),
            ("queue_wait_p95", Json::Num(self.queue_wait_p95)),
            ("queue_wait_p99", Json::Num(self.queue_wait_p99)),
            ("queue_wait_mean", Json::Num(self.queue_wait_mean)),
            ("round_time", hist_json(&self.round_time)),
            ("phase_sched", hist_json(&self.phase_sched)),
            ("phase_draft", hist_json(&self.phase_draft)),
            ("phase_verify", hist_json(&self.phase_verify)),
            ("phase_host", hist_json(&self.phase_host)),
            (
                "accept_rate_by_level",
                Json::Arr(self.accept_rate_by_level.iter().map(|&r| Json::Num(r)).collect()),
            ),
            ("round_nodes_hist", sparse_hist(&self.round_nodes_hist)),
            ("fused_batch_hist", sparse_hist(&self.fused_batch_hist)),
            ("fused_fill_hist", deciles(&self.fused_fill_hist)),
            ("kv_hit_hist", deciles(&self.kv_hit_hist)),
        ]);
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// With log-bucketed histograms the percentiles are exact to within
    /// one bucket ratio (10^(1/16) ≈ 1.155); means stay exact.
    #[test]
    fn percentiles_over_known_samples() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i as f64);
        }
        let s = m.snapshot();
        assert!((s.latency_p50 - 50.0).abs() <= 50.0 * 0.08, "p50 {}", s.latency_p50);
        assert!((s.latency_p95 - 95.0).abs() <= 95.0 * 0.08, "p95 {}", s.latency_p95);
        assert!((s.latency_p99 - 99.0).abs() <= 99.0 * 0.08, "p99 {}", s.latency_p99);
        assert!((s.latency_mean - 50.5).abs() < 1e-9, "mean is exact");
    }

    #[test]
    fn queue_wait_and_ttft_aggregate() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.queue_wait_p50, 0.0);
        assert_eq!(s.ttft_mean, 0.0);
        for i in 1..=10 {
            m.record_queue_wait(i as f64);
            m.record_ttft(2.0 * i as f64);
        }
        m.add(&m.mid_round_admitted, 3);
        let s = m.snapshot();
        assert!((s.queue_wait_mean - 5.5).abs() < 1e-12);
        assert!((s.queue_wait_p50 - 6.0).abs() <= 1.0);
        assert!(s.queue_wait_p95 >= s.queue_wait_p50);
        assert!((s.ttft_mean - 11.0).abs() < 1e-12);
        assert_eq!(s.mid_round_admitted, 3);
    }

    #[test]
    fn phase_timing_aggregates() {
        let m = Metrics::default();
        for _ in 0..4 {
            m.record_phase(crate::trace::PHASE_DRAFT, 0.010);
            m.record_phase(crate::trace::PHASE_VERIFY, 0.020);
        }
        m.record_phase(crate::trace::PHASE_SCHED, 0.001);
        m.record_phase(crate::trace::PHASE_HOST, 0.002);
        // level bits above the low byte must not change the routing
        m.record_phase(crate::trace::PHASE_DRAFT | (3 << 8), 0.010);
        m.record_round_time(0.033);
        let s = m.snapshot();
        assert_eq!(s.phase_draft.count, 5);
        assert_eq!(s.phase_verify.count, 4);
        assert_eq!(s.phase_sched.count, 1);
        assert_eq!(s.phase_host.count, 1);
        assert_eq!(s.round_time.count, 1);
        assert!((s.phase_draft.mean - 0.010).abs() < 1e-12);
        assert!((s.phase_verify.p50 - 0.020).abs() <= 0.020 * 0.08);
        assert!(s.round_time.mean > s.phase_verify.mean);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.tokens_out, 5);
        m.add(&m.tokens_out, 7);
        assert_eq!(m.snapshot().tokens_out, 12);
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::default();
        m.add(&m.completed, 2);
        m.record_latency(0.5);
        m.record_phase(crate::trace::PHASE_VERIFY, 0.02);
        m.record_fused(4, 8);
        let j = m.snapshot().to_json();
        // roundtrips through the parser and keeps the headline fields
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_field("completed").unwrap(), 2);
        assert!(parsed.get("latency_p50").unwrap().as_f64().unwrap() > 0.0);
        // the nested summary carries the exact sample count, not the
        // `completed` counter (one latency was recorded, two completed)
        let lat = parsed.get("latency").unwrap();
        assert_eq!(lat.usize_field("count").unwrap(), 1);
        assert!((lat.get("mean").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        let verify = parsed.get("phase_verify").unwrap();
        assert_eq!(verify.usize_field("count").unwrap(), 1);
        assert_eq!(parsed.usize_field("fused_calls").unwrap(), 1);
    }

    #[test]
    fn round_telemetry_aggregates() {
        let m = Metrics::default();
        // two rounds: level 0 accepted both times, level 1 accepted once
        m.record_round(&RoundReport {
            level_trials: vec![(2, 1), (3, 1)],
            nodes: 6,
            accepted: 2,
            bonus: true,
        });
        m.record_round(&RoundReport {
            level_trials: vec![(1, 1), (3, 0)],
            nodes: 4,
            accepted: 1,
            bonus: false,
        });
        let s = m.snapshot();
        assert_eq!(s.accept_rate_by_level.len(), 2);
        assert!((s.accept_rate_by_level[0] - 1.0).abs() < 1e-12);
        assert!((s.accept_rate_by_level[1] - 0.5).abs() < 1e-12);
        assert_eq!(s.round_nodes_hist, vec![(4, 1), (6, 1)]);
    }

    #[test]
    fn fused_telemetry_aggregates() {
        let m = Metrics::default();
        m.record_fused(8, 8); // full batch -> top decile
        m.record_fused(2, 8); // quarter fill -> (0.2, 0.3]
        m.record_fused(0, 8); // no participants: not a call
        let s = m.snapshot();
        assert_eq!(s.fused_calls, 2);
        assert_eq!(s.fused_batch_hist, vec![(2, 1), (8, 1)]);
        assert_eq!(s.fused_fill_hist[9], 1);
        assert_eq!(s.fused_fill_hist[2], 1);
        assert!((s.fused_mean_batch - 5.0).abs() < 1e-12);
    }

    #[test]
    fn kv_telemetry_stores_and_histograms() {
        use crate::kvcache::{KvConfig, KvPool};
        let m = Metrics::default();
        let pool = KvPool::new(KvConfig { num_blocks: 8, block_size: 4, share: true });
        pool.publish(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let match1 = pool.acquire_prefix(&[1, 2, 3, 4, 5, 6, 7, 8], 7);
        m.set_kv_pool(&pool.status());
        m.add(&m.preemptions, 2);
        m.add(&m.resumes, 2);
        m.record_kv_hit_ratio(7, 8); // 0.875 -> bucket 8
        m.record_kv_hit_ratio(12, 8); // clamped full hit -> bucket 9
        m.record_kv_hit_ratio(0, 8); // miss -> bucket 0
        m.record_kv_hit_ratio(3, 0); // no prompt: ignored
        let s = m.snapshot();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.resumes, 2);
        assert_eq!(s.kv_hit_tokens, 7);
        assert_eq!(s.kv_lookup_tokens, 7);
        assert!((s.kv_hit_rate - 1.0).abs() < 1e-12);
        assert_eq!(s.kv_blocks_total, 8);
        assert!(s.kv_blocks_in_use >= 1, "leased shared blocks count as in use");
        // no cold tier attached: counters and rate stay zero
        assert_eq!(s.kv_cold_spills, 0);
        assert_eq!(s.kv_cold_hits, 0);
        assert_eq!(s.kv_cold_corrupt, 0);
        assert_eq!(s.kv_cold_hit_rate, 0.0);
        assert_eq!(s.kv_hit_hist[8], 1);
        assert_eq!(s.kv_hit_hist[9], 1);
        assert_eq!(s.kv_hit_hist[0], 1);
        assert_eq!(s.kv_hit_hist.iter().sum::<u64>(), 3);
        for l in &match1.leases {
            pool.release_lease(l);
        }
    }

    #[test]
    fn oversized_rounds_share_last_bucket() {
        let m = Metrics::default();
        m.record_round(&RoundReport {
            level_trials: vec![(1, 0)],
            nodes: NODE_HIST_MAX + 40,
            accepted: 0,
            bonus: false,
        });
        assert_eq!(m.snapshot().round_nodes_hist, vec![(NODE_HIST_MAX, 1)]);
    }
}
