//! Serving metrics: counters, latency distributions, the adaptive
//! controller's telemetry (per-level acceptance rates, per-round
//! tree-node-budget histogram), and the fused-execution telemetry —
//! how many requests each fused [`crate::llm::Llm::eval_batch`] call
//! carried and how full those batches were relative to the round's
//! in-flight request count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::decode::spec::RoundReport;

/// Rounds using more nodes than this share the last histogram bucket.
pub const NODE_HIST_MAX: usize = 64;

/// Fused calls batching more requests than this share the last bucket.
pub const FUSED_HIST_MAX: usize = 64;

/// Number of fill-ratio buckets (deciles of participating / in-round).
pub const FILL_BUCKETS: usize = 10;

#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub tokens_out: AtomicU64,
    pub decode_rounds: AtomicU64,
    pub draft_calls: AtomicU64,
    /// End-to-end request latencies (seconds).
    latencies: Mutex<Vec<f64>>,
    /// Time-to-first-token latencies (seconds).
    ttft: Mutex<Vec<f64>>,
    /// Per-level verification attempts / acceptances across all rounds.
    level_attempts: Mutex<Vec<u64>>,
    level_accepts: Mutex<Vec<u64>>,
    /// Histogram of draft-tree nodes per round (index = node count,
    /// clamped to [`NODE_HIST_MAX`]).
    round_nodes_hist: Mutex<Vec<u64>>,
    /// Total fused model calls (draft + target) issued by the engine's
    /// round loop.
    pub fused_calls: AtomicU64,
    /// Exact sum of group counts across all fused calls (the clamped
    /// histogram below cannot recover the true mean for huge batches).
    pub fused_groups_total: AtomicU64,
    /// Histogram of requests per fused call (index = group count,
    /// clamped to [`FUSED_HIST_MAX`]).
    fused_batch_hist: Mutex<Vec<u64>>,
    /// Fill-ratio deciles: bucket `b` counts fused calls whose
    /// participating/in-round ratio fell in `(b/10, (b+1)/10]`. A draft
    /// call late in a round has low fill (most trees already complete);
    /// the target call always fills the batch.
    fused_fill_hist: Mutex<[u64; FILL_BUCKETS]>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub tokens_out: u64,
    pub decode_rounds: u64,
    pub draft_calls: u64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    /// Empirical acceptance rate per tree level (accepts / attempts);
    /// empty until a speculative round ran.
    pub accept_rate_by_level: Vec<f64>,
    /// Non-empty buckets of the nodes-per-round histogram, ascending
    /// node count.
    pub round_nodes_hist: Vec<(usize, u64)>,
    /// Total fused model calls issued by the engine round loop.
    pub fused_calls: u64,
    /// Non-empty buckets of the requests-per-fused-call histogram,
    /// ascending group count.
    pub fused_batch_hist: Vec<(usize, u64)>,
    /// Fill-ratio deciles (bucket `b` = ratio in `(b/10, (b+1)/10]`).
    pub fused_fill_hist: [u64; FILL_BUCKETS],
    /// Mean requests per fused call (0.0 before any fused call).
    pub fused_mean_batch: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl Metrics {
    pub fn record_latency(&self, secs: f64) {
        self.latencies.lock().unwrap().push(secs);
    }

    pub fn record_ttft(&self, secs: f64) {
        self.ttft.lock().unwrap().push(secs);
    }

    /// Fold one speculative round's verification telemetry into the
    /// per-level acceptance and node-budget histograms.
    pub fn record_round(&self, report: &RoundReport) {
        {
            let mut attempts = self.level_attempts.lock().unwrap();
            let mut accepts = self.level_accepts.lock().unwrap();
            for (level, &(_, success)) in report.level_trials.iter().enumerate() {
                if attempts.len() <= level {
                    attempts.resize(level + 1, 0);
                    accepts.resize(level + 1, 0);
                }
                attempts[level] += 1;
                accepts[level] += success as u64;
            }
        }
        let bucket = report.nodes.min(NODE_HIST_MAX);
        let mut hist = self.round_nodes_hist.lock().unwrap();
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }

    /// Record one fused model call: `groups` requests participated out of
    /// `in_round` requests currently running this round.
    pub fn record_fused(&self, groups: usize, in_round: usize) {
        if groups == 0 {
            return;
        }
        self.add(&self.fused_calls, 1);
        self.add(&self.fused_groups_total, groups as u64);
        {
            let bucket = groups.min(FUSED_HIST_MAX);
            let mut hist = self.fused_batch_hist.lock().unwrap();
            if hist.len() <= bucket {
                hist.resize(bucket + 1, 0);
            }
            hist[bucket] += 1;
        }
        let ratio = groups as f64 / in_round.max(groups) as f64;
        // ratio in (0, 1]: bucket b covers (b/10, (b+1)/10]
        let bucket = ((ratio * FILL_BUCKETS as f64).ceil() as usize)
            .clamp(1, FILL_BUCKETS)
            - 1;
        self.fused_fill_hist.lock().unwrap()[bucket] += 1;
    }

    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lat = self.latencies.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ttft = self.ttft.lock().unwrap().clone();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let attempts = self.level_attempts.lock().unwrap();
        let accepts = self.level_accepts.lock().unwrap();
        let accept_rate_by_level = attempts
            .iter()
            .zip(accepts.iter())
            .map(|(&n, &s)| if n == 0 { 0.0 } else { s as f64 / n as f64 })
            .collect();
        let round_nodes_hist = self
            .round_nodes_hist
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(nodes, &c)| (nodes, c))
            .collect();
        let fused_batch_hist: Vec<(usize, u64)> = self
            .fused_batch_hist
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(groups, &c)| (groups, c))
            .collect();
        let fused_fill_hist = *self.fused_fill_hist.lock().unwrap();
        let fused_calls = self.fused_calls.load(Ordering::Relaxed);
        // exact mean from the unclamped counter, not the display histogram
        let fused_mean_batch = if fused_calls == 0 {
            0.0
        } else {
            self.fused_groups_total.load(Ordering::Relaxed) as f64 / fused_calls as f64
        };
        Snapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            decode_rounds: self.decode_rounds.load(Ordering::Relaxed),
            draft_calls: self.draft_calls.load(Ordering::Relaxed),
            latency_p50: percentile(&lat, 0.50),
            latency_p95: percentile(&lat, 0.95),
            latency_p99: percentile(&lat, 0.99),
            ttft_p50: percentile(&ttft, 0.50),
            ttft_p95: percentile(&ttft, 0.95),
            accept_rate_by_level,
            round_nodes_hist,
            fused_calls,
            fused_batch_hist,
            fused_fill_hist,
            fused_mean_batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_samples() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i as f64);
        }
        let s = m.snapshot();
        assert!((s.latency_p50 - 50.0).abs() <= 1.0);
        assert!((s.latency_p95 - 95.0).abs() <= 1.0);
        assert!((s.latency_p99 - 99.0).abs() <= 1.0);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add(&m.tokens_out, 5);
        m.add(&m.tokens_out, 7);
        assert_eq!(m.snapshot().tokens_out, 12);
    }

    #[test]
    fn round_telemetry_aggregates() {
        let m = Metrics::default();
        // two rounds: level 0 accepted both times, level 1 accepted once
        m.record_round(&RoundReport {
            level_trials: vec![(2, 1), (3, 1)],
            nodes: 6,
            accepted: 2,
            bonus: true,
        });
        m.record_round(&RoundReport {
            level_trials: vec![(1, 1), (3, 0)],
            nodes: 4,
            accepted: 1,
            bonus: false,
        });
        let s = m.snapshot();
        assert_eq!(s.accept_rate_by_level.len(), 2);
        assert!((s.accept_rate_by_level[0] - 1.0).abs() < 1e-12);
        assert!((s.accept_rate_by_level[1] - 0.5).abs() < 1e-12);
        assert_eq!(s.round_nodes_hist, vec![(4, 1), (6, 1)]);
    }

    #[test]
    fn fused_telemetry_aggregates() {
        let m = Metrics::default();
        m.record_fused(8, 8); // full batch -> top decile
        m.record_fused(2, 8); // quarter fill -> (0.2, 0.3]
        m.record_fused(0, 8); // no participants: not a call
        let s = m.snapshot();
        assert_eq!(s.fused_calls, 2);
        assert_eq!(s.fused_batch_hist, vec![(2, 1), (8, 1)]);
        assert_eq!(s.fused_fill_hist[9], 1);
        assert_eq!(s.fused_fill_hist[2], 1);
        assert!((s.fused_mean_batch - 5.0).abs() < 1e-12);
    }

    #[test]
    fn oversized_rounds_share_last_bucket() {
        let m = Metrics::default();
        m.record_round(&RoundReport {
            level_trials: vec![(1, 0)],
            nodes: NODE_HIST_MAX + 40,
            accepted: 0,
            bonus: false,
        });
        assert_eq!(m.snapshot().round_nodes_hist, vec![(NODE_HIST_MAX, 1)]);
    }
}
