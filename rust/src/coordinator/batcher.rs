//! Admission queue + scheduling policy: the "dynamic batcher" half of
//! the coordinator. Decides which requests are active (stepped every
//! engine turn) and which wait, with bounded queueing, load shedding,
//! and deadline/priority-aware ordering.
//!
//! Besides the concurrency cap, admission can be *weighted*: each item
//! carries a cost (the engine uses the decoder's per-round node budget)
//! and the summed cost of active items is capped. With heterogeneous
//! per-request budgets (e.g. `adaptive:6` next to `adaptive:30`) this
//! keeps a burst of wide-tree requests from monopolizing the target
//! model's per-iteration compute. An over-cap item is still admitted
//! when nothing else is active, so no request can deadlock the queue.
//!
//! # Scheduling order
//!
//! The next admission candidate is chosen by, in order:
//!
//! 1. **Preempted requests first** ([`Batcher::requeue_front`]):
//!    preemption must never cost a request its turn, so victims re-enter
//!    ahead of every fresh arrival, ordered among themselves by their
//!    original admission age (rank) — a victim of a later preemption
//!    pass can never cut ahead of an older one still waiting.
//! 2. **Effective priority**, descending — the request's declared
//!    priority plus one point per [`AGING_ROUNDS`] engine rounds spent
//!    waiting. The aging term is unbounded, so any queued request
//!    eventually outranks every possible declared priority: a stream of
//!    high-priority arrivals can delay a low-priority request but can
//!    never starve it (regression-tested below).
//! 3. **Deadline**, ascending (`None` = least urgent): among equal
//!    effective priorities the request that declared the tightest
//!    latency budget goes first.
//! 4. **Arrival order** (FIFO): everything else equal, the default
//!    offers behave exactly like the old FIFO queue.
//!
//! The chosen candidate is the *only* one considered against the
//! active-weight cap: a too-heavy best candidate blocks admission
//! rather than letting lighter items sneak past it (no starvation of
//! heavy requests), unless the engine is idle.

use std::collections::VecDeque;
use std::time::Instant;

use crate::trace::{EventKind, Tracer};

/// Engine rounds of queue aging worth one point of effective priority.
pub const AGING_ROUNDS: u64 = 8;

/// Why an offer was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    QueueFull,
}

/// One successful admission: the item, the weight it was charged
/// (pass back to [`Batcher::release_weight`] on completion) and when it
/// entered the queue (for queue-wait / arrival-based latency metrics).
#[derive(Debug)]
pub struct Admitted<T> {
    pub item: T,
    pub weight: usize,
    pub queued_at: Instant,
}

#[derive(Debug)]
struct Entry<T> {
    item: T,
    priority: u8,
    /// Declared latency budget in ms; `u64::MAX` = none declared.
    deadline_ms: u64,
    /// Arrival rank (FIFO tie-break).
    seq: u64,
    /// Engine rounds spent waiting (incremented by [`Batcher::age_tick`]).
    ticks: u64,
    queued_at: Instant,
}

impl<T> Entry<T> {
    fn effective_priority(&self) -> u64 {
        self.priority as u64 + self.ticks / AGING_ROUNDS
    }

    /// Selection key: smaller = admitted sooner.
    fn key(&self) -> (std::cmp::Reverse<u64>, u64, u64) {
        (std::cmp::Reverse(self.effective_priority()), self.deadline_ms, self.seq)
    }
}

/// Deadline/priority-aware admission with a bounded waiting queue, a
/// concurrency cap, and an optional active-weight cap. Generic over the
/// queued item so it is testable without an engine.
#[derive(Debug)]
pub struct Batcher<T> {
    max_concurrency: usize,
    max_queue: usize,
    /// Cap on the summed weight of active items (`usize::MAX` = off).
    max_active_weight: usize,
    /// Preempted requests awaiting resume: admitted before any queued
    /// entry, ascending admission rank among themselves.
    front: VecDeque<(u64, T, Instant)>,
    queue: Vec<Entry<T>>,
    active: usize,
    active_weight: usize,
    next_seq: u64,
    /// Flight recorder: queue-depth samples at every membership change
    /// (off by default).
    tracer: Tracer,
}

impl<T> Batcher<T> {
    pub fn new(max_concurrency: usize, max_queue: usize) -> Self {
        assert!(max_concurrency > 0);
        Self {
            max_concurrency,
            max_queue,
            max_active_weight: usize::MAX,
            front: VecDeque::new(),
            queue: Vec::new(),
            active: 0,
            active_weight: 0,
            next_seq: 0,
            tracer: Tracer::off(),
        }
    }

    /// Enable the active-weight cap (0 disables it).
    pub fn with_max_active_weight(mut self, cap: usize) -> Self {
        self.max_active_weight = if cap == 0 { usize::MAX } else { cap };
        self
    }

    /// Attach a flight-recorder handle: every queue-membership change
    /// (offer, requeue, admit) journals a queue-depth sample.
    pub fn set_trace(&mut self, tracer: &Tracer) {
        self.tracer = tracer.clone();
    }

    fn sample_depth(&self) {
        self.tracer
            .record(EventKind::QueueDepth, 0, self.queued() as u32, self.active as u32);
    }

    /// Offer a new request at default priority with no deadline; reject
    /// when the waiting queue is full (admission control / load
    /// shedding).
    pub fn offer(&mut self, item: T) -> Result<(), (T, Rejected)> {
        self.offer_with(item, 0, None)
    }

    /// Offer a new request with a scheduling class (`priority`, higher
    /// admits first) and an optional declared latency budget
    /// (`deadline_ms`, tighter admits first among equals).
    pub fn offer_with(
        &mut self,
        item: T,
        priority: u8,
        deadline_ms: Option<u64>,
    ) -> Result<(), (T, Rejected)> {
        if self.queued() >= self.max_queue {
            return Err((item, Rejected::QueueFull));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push(Entry {
            item,
            priority,
            deadline_ms: deadline_ms.unwrap_or(u64::MAX),
            seq,
            ticks: 0,
            queued_at: Instant::now(),
        });
        self.sample_depth();
        Ok(())
    }

    /// Re-enqueue a *preempted* request at the FRONT of the waiting
    /// queue, ahead of every fresh arrival — preemption must not cost a
    /// request its turn. `rank` is the request's original admission age
    /// (the engine passes its admission sequence number): victims are
    /// kept in ascending rank, so a younger victim from a later
    /// preemption pass never resumes before an older one still waiting.
    /// Never sheds: the item was already admitted once, so the queue cap
    /// (a guard against new load) does not apply to it.
    pub fn requeue_front(&mut self, item: T, rank: u64) {
        let pos = self
            .front
            .iter()
            .position(|e| e.0 > rank)
            .unwrap_or(self.front.len());
        self.front.insert(pos, (rank, item, Instant::now()));
        self.sample_depth();
    }

    /// One engine round passed: age every waiting request. Aging feeds
    /// the anti-starvation promotion (see module docs).
    pub fn age_tick(&mut self) {
        for e in &mut self.queue {
            e.ticks += 1;
        }
    }

    /// Index of the best queued entry under the scheduling order.
    fn best_idx(&self) -> Option<usize> {
        self.queue
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.key())
            .map(|(i, _)| i)
    }

    /// The request that would be admitted next, if any.
    pub fn peek(&self) -> Option<&T> {
        if let Some((_, item, _)) = self.front.front() {
            return Some(item);
        }
        self.best_idx().map(|i| &self.queue[i].item)
    }

    /// Admit the next waiting request if a concurrency slot is free
    /// (weight-oblivious: every item costs 0).
    pub fn admit(&mut self) -> Option<T> {
        self.admit_by(|_| 0).map(|a| a.item)
    }

    /// Admit the best waiting request if a concurrency slot is free and
    /// its `weight` fits under the active-weight cap. Only the best
    /// candidate is considered: a too-heavy best blocks admission (no
    /// starvation of heavy requests by sneaking light ones past them)
    /// unless the engine is idle, in which case it is admitted
    /// regardless. Pass the returned weight back to
    /// [`Batcher::release_weight`] on completion.
    pub fn admit_by<F: Fn(&T) -> usize>(&mut self, weight: F) -> Option<Admitted<T>> {
        if self.active >= self.max_concurrency {
            return None;
        }
        // candidate: lowest-rank preempted victim, else the best queued
        // entry under the scheduling key
        let from_front = !self.front.is_empty();
        let (w, idx) = if from_front {
            (weight(&self.front[0].1), 0)
        } else {
            let i = self.best_idx()?;
            (weight(&self.queue[i].item), i)
        };
        if self.active > 0 && self.active_weight.saturating_add(w) > self.max_active_weight {
            return None;
        }
        let (item, queued_at) = if from_front {
            let (_, item, queued_at) = self.front.pop_front().expect("checked above");
            (item, queued_at)
        } else {
            // swap_remove is fine: admission order comes from the
            // selection key, never from the backing vector's order
            let e = self.queue.swap_remove(idx);
            (e.item, e.queued_at)
        };
        self.active += 1;
        self.active_weight = self.active_weight.saturating_add(w);
        self.sample_depth();
        Some(Admitted { item, weight: w, queued_at })
    }

    /// A previously admitted request finished; its slot frees up.
    pub fn release(&mut self) {
        self.release_weight(0);
    }

    /// Release a slot, crediting back the weight charged at admission.
    pub fn release_weight(&mut self, weight: usize) {
        debug_assert!(self.active > 0);
        self.active = self.active.saturating_sub(1);
        self.active_weight = self.active_weight.saturating_sub(weight);
    }

    pub fn active(&self) -> usize {
        self.active
    }

    /// Summed weight of currently active items.
    pub fn active_weight(&self) -> usize {
        self.active_weight
    }

    /// Drain queued requests whose declared deadline has already
    /// elapsed while waiting (deadline *enforcement*, as opposed to the
    /// deadline-ordered admission above). Only fresh arrivals are
    /// considered: preempted victims at the front were admitted once and
    /// keep their turn regardless. Returns the shed items for the caller
    /// to report typed errors on.
    pub fn shed_expired(&mut self) -> Vec<T> {
        let mut shed = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let e = &self.queue[i];
            let expired = e.deadline_ms != u64::MAX
                && e.queued_at.elapsed().as_millis() as u64 > e.deadline_ms;
            if expired {
                // swap_remove is fine: admission order comes from the
                // selection key, never from the backing vector's order
                shed.push(self.queue.swap_remove(i).item);
            } else {
                i += 1;
            }
        }
        if !shed.is_empty() {
            self.sample_depth();
        }
        shed
    }

    /// Remove every waiting request (front or queued) matching `pred` —
    /// the cancellation path. Returns the removed items.
    pub fn remove_where<F: Fn(&T) -> bool>(&mut self, pred: F) -> Vec<T> {
        let mut removed = Vec::new();
        let mut i = 0;
        while i < self.front.len() {
            if pred(&self.front[i].1) {
                let (_, item, _) = self.front.remove(i).expect("index checked");
                removed.push(item);
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.queue.len() {
            if pred(&self.queue[i].item) {
                removed.push(self.queue.swap_remove(i).item);
            } else {
                i += 1;
            }
        }
        if !removed.is_empty() {
            self.sample_depth();
        }
        removed
    }

    pub fn queued(&self) -> usize {
        self.front.len() + self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active == 0 && self.queued() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_concurrency() {
        let mut b: Batcher<u32> = Batcher::new(2, 8);
        for i in 0..4 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), None); // cap reached
        b.release();
        assert_eq!(b.admit(), Some(2));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn sheds_load_when_queue_full() {
        let mut b: Batcher<u32> = Batcher::new(1, 2);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        let (item, why) = b.offer(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, Rejected::QueueFull);
    }

    #[test]
    fn weighted_admission_respects_budget_cap() {
        let mut b: Batcher<usize> = Batcher::new(8, 8).with_max_active_weight(30);
        for w in [6usize, 30, 6, 6] {
            b.offer(w).unwrap();
        }
        let weigh = |x: &usize| *x;
        let a = b.admit_by(weigh).unwrap();
        assert_eq!((a.item, a.weight), (6, 6));
        // 6 + 30 > 30: the heavy head must wait, and FIFO holds (the
        // light items behind it do not jump the queue)
        assert!(b.admit_by(weigh).is_none());
        b.release_weight(6);
        // idle engine: the heavy request is admitted despite the cap
        let a = b.admit_by(weigh).unwrap();
        assert_eq!((a.item, a.weight), (30, 30));
        assert!(b.admit_by(weigh).is_none());
        assert_eq!(b.active_weight(), 30);
        b.release_weight(30);
        assert_eq!(b.admit_by(weigh).unwrap().item, 6);
        assert_eq!(b.admit_by(weigh).unwrap().item, 6);
        assert_eq!(b.active_weight(), 12);
    }

    #[test]
    fn unweighted_admit_is_unchanged() {
        let mut b: Batcher<u32> = Batcher::new(2, 8).with_max_active_weight(1);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        // weight 0 per item: the cap never binds
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b: Batcher<u32> = Batcher::new(4, 8);
        for i in 0..3 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
    }

    #[test]
    fn priority_then_deadline_then_fifo() {
        let mut b: Batcher<u32> = Batcher::new(8, 8);
        b.offer_with(1, 0, Some(500)).unwrap();
        b.offer_with(2, 0, Some(100)).unwrap();
        b.offer_with(3, 1, None).unwrap();
        b.offer_with(4, 0, Some(100)).unwrap();
        assert_eq!(b.peek(), Some(&3));
        assert_eq!(b.admit(), Some(3)); // highest priority first
        assert_eq!(b.admit(), Some(2)); // tightest deadline next ...
        assert_eq!(b.admit(), Some(4)); // ... FIFO among equal deadlines
        assert_eq!(b.admit(), Some(1)); // no-deadline equals loosest
    }

    /// Regression (starvation fix): a low-priority request under a
    /// continuous stream of high-priority arrivals must still be
    /// admitted — aging promotes it past any declared priority.
    #[test]
    fn aging_promotes_starved_low_priority() {
        let mut b: Batcher<u64> = Batcher::new(1, 64);
        b.offer_with(999, 0, None).unwrap();
        let mut admitted_at = None;
        for round in 0..2_000u64 {
            b.offer_with(round, 5, None).unwrap();
            let got = b.admit().unwrap();
            b.release();
            if got == 999 {
                admitted_at = Some(round);
                break;
            }
            b.age_tick();
        }
        let round = admitted_at.expect("low-priority request starved");
        // effective priority 0 + round/AGING_ROUNDS must pass 5 first
        assert!(round >= 5 * AGING_ROUNDS, "promoted too early: {round}");
        assert!(round <= 7 * AGING_ROUNDS, "promoted too late: {round}");
    }

    #[test]
    fn requeue_front_precedes_fresh_arrivals() {
        let mut b: Batcher<u32> = Batcher::new(2, 8);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
        b.offer(3).unwrap(); // fresh arrival waits
        // 2 gets preempted: it must re-enter ahead of 3
        b.release();
        b.requeue_front(2, 1);
        assert_eq!(b.peek(), Some(&2));
        assert_eq!(b.admit(), Some(2));
        b.release();
        assert_eq!(b.admit(), Some(3));
    }

    #[test]
    fn requeue_front_outranks_priorities() {
        let mut b: Batcher<u32> = Batcher::new(1, 8);
        b.offer_with(7, 255, Some(1)).unwrap();
        b.requeue_front(1, 0);
        // the preempted request beats even a max-priority fresh arrival
        assert_eq!(b.admit(), Some(1));
        b.release();
        assert_eq!(b.admit(), Some(7));
    }

    #[test]
    fn requeue_front_bypasses_queue_cap_and_keeps_order() {
        let mut b: Batcher<u32> = Batcher::new(1, 2);
        b.offer(10).unwrap();
        b.offer(11).unwrap();
        assert!(b.offer(12).is_err(), "queue full for fresh load");
        // a preempted request still re-enters, ahead of the queue
        b.requeue_front(9, 0);
        assert_eq!(b.queued(), 3);
        assert_eq!(b.admit(), Some(9));
        // multiple victims requeued youngest-first (the engine's
        // preemption order) restore their admission-age order
        let mut c: Batcher<u32> = Batcher::new(2, 8);
        c.offer(99).unwrap();
        c.requeue_front(2, 2);
        c.requeue_front(1, 1);
        assert_eq!(c.admit(), Some(1));
        assert_eq!(c.admit(), Some(2));
    }

    #[test]
    fn shed_expired_drops_only_overdue_fresh_arrivals() {
        let mut b: Batcher<u32> = Batcher::new(1, 8);
        b.offer_with(1, 0, Some(0)).unwrap(); // expires immediately
        b.offer_with(2, 0, None).unwrap(); // no deadline: never shed
        b.offer_with(3, 0, Some(60_000)).unwrap(); // generous: keeps waiting
        b.requeue_front(4, 0); // preempted victim: exempt
        std::thread::sleep(std::time::Duration::from_millis(2));
        let shed = b.shed_expired();
        assert_eq!(shed, vec![1]);
        assert_eq!(b.queued(), 3);
        assert_eq!(b.admit(), Some(4));
    }

    #[test]
    fn remove_where_cancels_front_and_queue() {
        let mut b: Batcher<u32> = Batcher::new(1, 8);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        b.requeue_front(3, 0);
        let mut removed = b.remove_where(|&x| x == 2 || x == 3);
        removed.sort_unstable();
        assert_eq!(removed, vec![2, 3]);
        assert_eq!(b.queued(), 1);
        assert_eq!(b.admit(), Some(1));
    }

    /// Regression: victims of SEPARATE preemption passes still resume
    /// in admission-age order — a younger request preempted later can
    /// never cut ahead of an older one still waiting at the front.
    #[test]
    fn requeued_victims_order_by_admission_age_across_passes() {
        let mut b: Batcher<u32> = Batcher::new(1, 8);
        b.requeue_front(30, 3); // pass 1 parks the rank-3 victim
        b.requeue_front(10, 1); // pass 2 parks an OLDER victim
        b.requeue_front(20, 2); // pass 3 lands between them
        assert_eq!(b.peek(), Some(&10));
        assert_eq!(b.admit(), Some(10));
        b.release();
        assert_eq!(b.admit(), Some(20));
        b.release();
        assert_eq!(b.admit(), Some(30));
    }
}
