//! Admission queue + fairness policy: the "dynamic batcher" half of the
//! coordinator. Decides which requests are active (stepped every engine
//! turn) and which wait, with bounded queueing and load shedding.

use std::collections::VecDeque;

/// Why an offer was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    QueueFull,
}

/// FIFO admission with a bounded waiting queue and a concurrency cap.
/// Generic over the queued item so it is testable without an engine.
#[derive(Debug)]
pub struct Batcher<T> {
    max_concurrency: usize,
    max_queue: usize,
    queue: VecDeque<T>,
    active: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_concurrency: usize, max_queue: usize) -> Self {
        assert!(max_concurrency > 0);
        Self { max_concurrency, max_queue, queue: VecDeque::new(), active: 0 }
    }

    /// Offer a new request; reject when the waiting queue is full
    /// (admission control / load shedding).
    pub fn offer(&mut self, item: T) -> Result<(), (T, Rejected)> {
        if self.queue.len() >= self.max_queue {
            return Err((item, Rejected::QueueFull));
        }
        self.queue.push_back(item);
        Ok(())
    }

    /// Admit the next waiting request if a concurrency slot is free.
    pub fn admit(&mut self) -> Option<T> {
        if self.active < self.max_concurrency {
            if let Some(item) = self.queue.pop_front() {
                self.active += 1;
                return Some(item);
            }
        }
        None
    }

    /// A previously admitted request finished; its slot frees up.
    pub fn release(&mut self) {
        debug_assert!(self.active > 0);
        self.active = self.active.saturating_sub(1);
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active == 0 && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_concurrency() {
        let mut b: Batcher<u32> = Batcher::new(2, 8);
        for i in 0..4 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), None); // cap reached
        b.release();
        assert_eq!(b.admit(), Some(2));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn sheds_load_when_queue_full() {
        let mut b: Batcher<u32> = Batcher::new(1, 2);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        let (item, why) = b.offer(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, Rejected::QueueFull);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b: Batcher<u32> = Batcher::new(4, 8);
        for i in 0..3 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
    }
}
