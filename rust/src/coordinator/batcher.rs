//! Admission queue + fairness policy: the "dynamic batcher" half of the
//! coordinator. Decides which requests are active (stepped every engine
//! turn) and which wait, with bounded queueing and load shedding.
//!
//! Besides the concurrency cap, admission can be *weighted*: each item
//! carries a cost (the engine uses the decoder's per-round node budget)
//! and the summed cost of active items is capped. With heterogeneous
//! per-request budgets (e.g. `adaptive:6` next to `adaptive:30`) this
//! keeps a burst of wide-tree requests from monopolizing the target
//! model's per-iteration compute. An over-cap item is still admitted
//! when nothing else is active, so no request can deadlock the queue.

use std::collections::VecDeque;

/// Why an offer was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    QueueFull,
}

/// FIFO admission with a bounded waiting queue, a concurrency cap, and
/// an optional active-weight cap. Generic over the queued item so it is
/// testable without an engine.
#[derive(Debug)]
pub struct Batcher<T> {
    max_concurrency: usize,
    max_queue: usize,
    /// Cap on the summed weight of active items (`usize::MAX` = off).
    max_active_weight: usize,
    queue: VecDeque<T>,
    active: usize,
    active_weight: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_concurrency: usize, max_queue: usize) -> Self {
        assert!(max_concurrency > 0);
        Self {
            max_concurrency,
            max_queue,
            max_active_weight: usize::MAX,
            queue: VecDeque::new(),
            active: 0,
            active_weight: 0,
        }
    }

    /// Enable the active-weight cap (0 disables it).
    pub fn with_max_active_weight(mut self, cap: usize) -> Self {
        self.max_active_weight = if cap == 0 { usize::MAX } else { cap };
        self
    }

    /// Offer a new request; reject when the waiting queue is full
    /// (admission control / load shedding).
    pub fn offer(&mut self, item: T) -> Result<(), (T, Rejected)> {
        if self.queue.len() >= self.max_queue {
            return Err((item, Rejected::QueueFull));
        }
        self.queue.push_back(item);
        Ok(())
    }

    /// Re-enqueue a *preempted* request at the FRONT of the waiting
    /// queue, ahead of every fresh arrival — preemption must not cost a
    /// request its FIFO position. Never sheds: the item was already
    /// admitted once, so the queue cap (a guard against new load) does
    /// not apply to it.
    pub fn requeue_front(&mut self, item: T) {
        self.queue.push_front(item);
    }

    /// The request that would be admitted next, if any.
    pub fn peek(&self) -> Option<&T> {
        self.queue.front()
    }

    /// Admit the next waiting request if a concurrency slot is free
    /// (weight-oblivious: every item costs 0).
    pub fn admit(&mut self) -> Option<T> {
        self.admit_by(|_| 0).map(|(item, _)| item)
    }

    /// Admit the next waiting request if a concurrency slot is free and
    /// its `weight` fits under the active-weight cap. FIFO order is
    /// preserved: a too-heavy head blocks admission (no starvation of
    /// heavy requests by sneaking light ones past them) unless the
    /// engine is idle, in which case it is admitted regardless. Returns
    /// the item with the weight it was charged; pass that weight back to
    /// [`Batcher::release_weight`] on completion.
    pub fn admit_by<F: Fn(&T) -> usize>(&mut self, weight: F) -> Option<(T, usize)> {
        if self.active >= self.max_concurrency {
            return None;
        }
        let w = weight(self.queue.front()?);
        if self.active > 0 && self.active_weight.saturating_add(w) > self.max_active_weight {
            return None;
        }
        let item = self.queue.pop_front().expect("front checked above");
        self.active += 1;
        self.active_weight = self.active_weight.saturating_add(w);
        Some((item, w))
    }

    /// A previously admitted request finished; its slot frees up.
    pub fn release(&mut self) {
        self.release_weight(0);
    }

    /// Release a slot, crediting back the weight charged at admission.
    pub fn release_weight(&mut self, weight: usize) {
        debug_assert!(self.active > 0);
        self.active = self.active.saturating_sub(1);
        self.active_weight = self.active_weight.saturating_sub(weight);
    }

    pub fn active(&self) -> usize {
        self.active
    }

    /// Summed weight of currently active items.
    pub fn active_weight(&self) -> usize {
        self.active_weight
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active == 0 && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_concurrency() {
        let mut b: Batcher<u32> = Batcher::new(2, 8);
        for i in 0..4 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), None); // cap reached
        b.release();
        assert_eq!(b.admit(), Some(2));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn sheds_load_when_queue_full() {
        let mut b: Batcher<u32> = Batcher::new(1, 2);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        let (item, why) = b.offer(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, Rejected::QueueFull);
    }

    #[test]
    fn weighted_admission_respects_budget_cap() {
        let mut b: Batcher<usize> = Batcher::new(8, 8).with_max_active_weight(30);
        for w in [6usize, 30, 6, 6] {
            b.offer(w).unwrap();
        }
        let weigh = |x: &usize| *x;
        assert_eq!(b.admit_by(weigh), Some((6, 6)));
        // 6 + 30 > 30: the heavy head must wait, and FIFO holds (the
        // light items behind it do not jump the queue)
        assert_eq!(b.admit_by(weigh), None);
        b.release_weight(6);
        // idle engine: the heavy request is admitted despite the cap
        assert_eq!(b.admit_by(weigh), Some((30, 30)));
        assert_eq!(b.admit_by(weigh), None);
        assert_eq!(b.active_weight(), 30);
        b.release_weight(30);
        assert_eq!(b.admit_by(weigh), Some((6, 6)));
        assert_eq!(b.admit_by(weigh), Some((6, 6)));
        assert_eq!(b.active_weight(), 12);
    }

    #[test]
    fn unweighted_admit_is_unchanged() {
        let mut b: Batcher<u32> = Batcher::new(2, 8).with_max_active_weight(1);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        // weight 0 per item: the cap never binds
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b: Batcher<u32> = Batcher::new(4, 8);
        for i in 0..3 {
            b.offer(i).unwrap();
        }
        assert_eq!(b.admit(), Some(0));
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
    }

    #[test]
    fn requeue_front_precedes_fresh_arrivals() {
        let mut b: Batcher<u32> = Batcher::new(2, 8);
        b.offer(1).unwrap();
        b.offer(2).unwrap();
        assert_eq!(b.admit(), Some(1));
        assert_eq!(b.admit(), Some(2));
        b.offer(3).unwrap(); // fresh arrival waits
        // 2 gets preempted: it must re-enter ahead of 3
        b.release();
        b.requeue_front(2);
        assert_eq!(b.peek(), Some(&2));
        assert_eq!(b.admit(), Some(2));
        b.release();
        assert_eq!(b.admit(), Some(3));
    }

    #[test]
    fn requeue_front_bypasses_queue_cap_and_keeps_order() {
        let mut b: Batcher<u32> = Batcher::new(1, 2);
        b.offer(10).unwrap();
        b.offer(11).unwrap();
        assert!(b.offer(12).is_err(), "queue full for fresh load");
        // a preempted request still re-enters, ahead of the queue
        b.requeue_front(9);
        assert_eq!(b.queued(), 3);
        assert_eq!(b.admit(), Some(9));
        // multiple victims requeued newest-first restore their relative
        // order: preempting [a, b] pushes b then a
        let mut c: Batcher<u32> = Batcher::new(2, 8);
        c.offer(99).unwrap();
        c.requeue_front(2);
        c.requeue_front(1);
        assert_eq!(c.admit(), Some(1));
        assert_eq!(c.admit(), Some(2));
    }
}
