//! Typed error taxonomy for the serving engine.
//!
//! Every failure that reaches a request's event stream is an
//! [`EngineError`]: a coarse [`ErrorKind`] (stable wire code), a
//! `retryable` flag the scheduler uses for policy (bounded retry vs
//! immediate terminal error), and a human-readable detail string. The
//! round loop never matches on error *strings* — `classify` maps
//! whatever the substrate returns (including [`crate::kvcache::PoolExhausted`]
//! surfaced through `anyhow`) onto the taxonomy exactly once, at the
//! phase boundary where policy is decided.

use std::fmt;

use crate::util::json::Json;

/// Coarse failure class. The wire code (`code()`) is part of the
/// protocol surface and must stay stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    /// Substrate eval failed but is expected to succeed on retry
    /// (flaky interconnect, transient device fault).
    EvalTransient,
    /// Substrate eval fails deterministically for this request.
    EvalPersistent,
    /// KV block pool could not satisfy an allocation.
    PoolExhausted,
    /// The request's deadline elapsed before admission.
    DeadlineExpired,
    /// Admission queue was full at submit time.
    QueueFull,
    /// The client cancelled the request mid-flight.
    Cancelled,
    /// A transient fault persisted past the per-request retry budget.
    RetriesExhausted,
    /// The request itself was malformed or violated a limit.
    InvalidRequest,
    /// Anything the taxonomy cannot name; never retried.
    Internal,
}

impl ErrorKind {
    /// Stable snake_case wire code.
    pub fn code(self) -> &'static str {
        match self {
            ErrorKind::EvalTransient => "eval_transient",
            ErrorKind::EvalPersistent => "eval_persistent",
            ErrorKind::PoolExhausted => "pool_exhausted",
            ErrorKind::DeadlineExpired => "deadline_expired",
            ErrorKind::QueueFull => "queue_full",
            ErrorKind::Cancelled => "cancelled",
            ErrorKind::RetriesExhausted => "retries_exhausted",
            ErrorKind::InvalidRequest => "invalid_request",
            ErrorKind::Internal => "internal",
        }
    }

    /// Whether a client (or the engine itself) should expect a retry of
    /// the same request to succeed. Engine-side bounded retry only
    /// applies to a subset of these (see the engine's reap loop).
    pub fn default_retryable(self) -> bool {
        matches!(
            self,
            ErrorKind::EvalTransient
                | ErrorKind::PoolExhausted
                | ErrorKind::DeadlineExpired
                | ErrorKind::QueueFull
        )
    }
}

/// The one error type requests terminate with.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineError {
    pub kind: ErrorKind,
    pub retryable: bool,
    pub detail: String,
}

impl EngineError {
    pub fn new(kind: ErrorKind, detail: impl Into<String>) -> Self {
        Self { kind, retryable: kind.default_retryable(), detail: detail.into() }
    }

    pub fn with_retryable(mut self, retryable: bool) -> Self {
        self.retryable = retryable;
        self
    }

    pub fn cancelled() -> Self {
        Self::new(ErrorKind::Cancelled, "cancelled by client")
    }

    /// Map an arbitrary substrate/stepper error onto the taxonomy.
    /// Typed errors pass through unchanged; known substrate failures
    /// (pool exhaustion in either the paged or dense backing) classify
    /// as retryable `PoolExhausted`; everything else is `Internal`.
    pub fn classify(e: &anyhow::Error) -> Self {
        if let Some(ee) = e.downcast_ref::<EngineError>() {
            return ee.clone();
        }
        for cause in e.chain() {
            if cause.downcast_ref::<crate::kvcache::PoolExhausted>().is_some() {
                return Self::new(ErrorKind::PoolExhausted, format!("{e:#}"));
            }
        }
        let msg = format!("{e:#}");
        if msg.contains("KV cache exhausted") || msg.contains("pool exhausted") {
            return Self::new(ErrorKind::PoolExhausted, msg);
        }
        Self::new(ErrorKind::Internal, msg)
    }

    /// Structured wire payload: `{code, retryable, message}`.
    pub fn to_wire(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.kind.code().to_string())),
            ("retryable", Json::Bool(self.retryable)),
            ("message", Json::Str(self.detail.clone())),
        ])
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Detail leads so existing substring checks ("queue full",
        // "prompt too long") keep matching on the rendered form.
        write!(f, "{} [{}]", self.detail, self.kind.code())
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_unique() {
        let kinds = [
            ErrorKind::EvalTransient,
            ErrorKind::EvalPersistent,
            ErrorKind::PoolExhausted,
            ErrorKind::DeadlineExpired,
            ErrorKind::QueueFull,
            ErrorKind::Cancelled,
            ErrorKind::RetriesExhausted,
            ErrorKind::InvalidRequest,
            ErrorKind::Internal,
        ];
        let mut seen = std::collections::HashSet::new();
        for k in kinds {
            assert!(seen.insert(k.code()), "duplicate wire code {}", k.code());
        }
    }

    #[test]
    fn classify_passes_typed_errors_through() {
        let e = EngineError::new(ErrorKind::EvalPersistent, "device poisoned");
        let any = anyhow::Error::from(e.clone());
        assert_eq!(EngineError::classify(&any), e);
    }

    #[test]
    fn classify_maps_pool_exhaustion() {
        let any = anyhow::Error::from(crate::kvcache::PoolExhausted);
        let e = EngineError::classify(&any);
        assert_eq!(e.kind, ErrorKind::PoolExhausted);
        assert!(e.retryable);
        // Dense backing reports exhaustion by message only.
        let any = anyhow::anyhow!("KV cache exhausted: need 3 slots, 1 left");
        assert_eq!(EngineError::classify(&any).kind, ErrorKind::PoolExhausted);
    }

    #[test]
    fn wire_payload_shape() {
        let e = EngineError::new(ErrorKind::QueueFull, "queue full (256 waiting)");
        let w = e.to_wire();
        assert_eq!(w.get("code").and_then(Json::as_str), Some("queue_full"));
        assert_eq!(w.get("retryable").and_then(Json::as_bool), Some(true));
        assert!(w.get("message").and_then(Json::as_str).unwrap().contains("queue full"));
    }

    #[test]
    fn display_keeps_detail_substrings() {
        let e = EngineError::new(ErrorKind::QueueFull, "queue full (256 waiting)");
        assert!(e.to_string().contains("queue full"));
    }
}
