//! The serving engine: owns the (target, draft) model pair and runs
//! *phase-synchronized fused rounds* — continuous batching at iteration
//! granularity, with every model forward batched across requests.
//!
//! # Fused round loop
//!
//! Each engine turn advances every active request by one speculative
//! round, in lockstep phases:
//!
//! 1. **Begin** — every stepper runs its per-round bookkeeping
//!    ([`SpecStepper::begin_round`] / AR sampling) and stages its first
//!    model work. No model call happens here.
//! 2. **Draft** — all staged draft work (one tree level per request) is
//!    executed as ONE fused [`Llm::eval_batch`] call over the draft
//!    model; rows are fed back and each stepper stages its next level.
//!    Repeat until no request has draft work left: requests whose trees
//!    are shallower simply drop out of later fused calls (the fill-ratio
//!    histogram in [`super::metrics::Metrics`] tracks exactly this).
//!    AR requests have no draft phase and never participate.
//! 3. **Verify** — one fused `eval_batch` over the target model covers
//!    every request's verification pass (tail + whole tree; prefill or
//!    single-token decode for AR). Rows are fed back; verification,
//!    commit and emission run on the host per request.
//!
//! Token streams are **identical** to stepping each request alone: every
//! request owns a deterministic RNG stream seeded from
//! `engine_seed ^ request_id`, and model calls never consume RNG, so
//! neither admission order nor batch composition changes any request's
//! output. (Exception: `adaptive:B` requests share the engine-global
//! acceptance estimator by design, so their tree *shapes* — never their
//! distributional correctness — depend on what else ran.)
//! `EngineConfig::fused = false` switches to one `eval` per request for
//! A/B benchmarking; the schedule and output stay the same.
//!
//! The engine core is synchronous (PJRT execution is blocking); it runs
//! on its own thread and talks to front-ends through std channels.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::adaptive::{AdaptiveController, AdaptiveStepper, GlobalEstimator};
use crate::config::{DecoderConfig, EngineConfig, SamplingPatch};
use crate::decode::ar::ArStepper;
use crate::decode::spec::{RoundReport, RoundStart, SpecStepper, StepOutcome};
use crate::decode::{build_parts, DecodeStats};
use crate::llm::{EvalNode, Llm, LogitsBatch};
use crate::util::Rng;

use super::batcher::Batcher;
use super::metrics::Metrics;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request overrides (None = engine defaults).
    pub decoder: Option<DecoderConfig>,
    /// Field-wise sampling overrides; unset fields inherit the engine's
    /// configured sampling.
    pub sampling: Option<SamplingPatch>,
    pub resp: mpsc::Sender<Event>,
}

/// Streamed response events.
#[derive(Debug, Clone)]
pub enum Event {
    /// Newly generated tokens (one speculative round's worth).
    Tokens(Vec<u32>),
    /// Request finished; final stats.
    Done(DecodeStats),
    /// Request failed or was shed.
    Error(String),
}

enum AnyStepper<T: Llm, D: Llm> {
    Ar(ArStepper<T>),
    Spec(SpecStepper<T, D>),
    /// Per-round re-shaped speculative session (`adaptive:B`), sharing
    /// the engine-global acceptance statistics.
    Adaptive(AdaptiveStepper<T, D>),
}

impl<T: Llm, D: Llm> AnyStepper<T, D> {
    fn out(&self) -> &[u32] {
        match self {
            AnyStepper::Ar(s) => &s.out,
            AnyStepper::Spec(s) => &s.out,
            AnyStepper::Adaptive(s) => s.out(),
        }
    }

    fn stats(&self) -> &DecodeStats {
        match self {
            AnyStepper::Ar(s) => &s.stats,
            AnyStepper::Spec(s) => &s.stats,
            AnyStepper::Adaptive(s) => s.stats(),
        }
    }

    fn last_round(&self) -> Option<&RoundReport> {
        match self {
            AnyStepper::Ar(_) => None,
            AnyStepper::Spec(s) => s.last_round(),
            AnyStepper::Adaptive(s) => s.last_round(),
        }
    }
}

/// Where one active request stands within the current fused round.
enum RoundState {
    /// Phase work staged; participating in fused calls.
    InRound,
    /// Round completed, generation continues.
    Progressed,
    /// Request finished (this round or at `begin_round`).
    Done,
    /// Request failed; message to deliver.
    Failed(String),
}

struct Active<T: Llm, D: Llm> {
    req: Request,
    stepper: AnyStepper<T, D>,
    /// This request's own deterministic RNG stream (seeded from
    /// `engine_seed ^ request_id`), making output independent of
    /// admission order and batch composition.
    rng: Rng,
    sent: usize,
    /// Node-budget weight this request was charged at admission.
    weight: usize,
    started: Instant,
    first_token_at: Option<f64>,
}

/// Execute one phase's groups into the shared flat logits buffer and
/// return a per-group outcome (the group's row range in `out`, or an
/// error message), index-aligned with the groups. The buffer is engine-
/// owned and recycled across phases and rounds, so a phase performs no
/// per-row allocation.
///
/// Fused path: one `eval_batch_into` call; on error every participating
/// session may hold half-applied pending state, so ALL groups fail.
/// Sequential fallback (`EngineConfig::fused = false`): one `eval_into`
/// per group, so an error stays confined to the request that hit it —
/// the other sessions were touched by their own calls only.
fn eval_phase<L: Llm>(
    lm: &L,
    fused: bool,
    groups: &mut [(&mut L::Session, &[EvalNode])],
    out: &mut LogitsBatch,
) -> Vec<std::result::Result<std::ops::Range<usize>, String>> {
    out.reset(lm.vocab());
    if fused {
        let counts: Vec<usize> = groups.iter().map(|(_, nodes)| nodes.len()).collect();
        return match lm.eval_batch_into(groups, out) {
            Ok(()) => {
                let mut start = 0;
                counts
                    .into_iter()
                    .map(|n| {
                        let r = start..start + n;
                        start += n;
                        Ok(r)
                    })
                    .collect()
            }
            Err(e) => {
                let msg = e.to_string();
                (0..groups.len()).map(|_| Err(msg.clone())).collect()
            }
        };
    }
    groups
        .iter_mut()
        .map(|(session, nodes)| {
            let start = out.rows();
            lm.eval_into(session, nodes, out)
                .map(|()| start..out.rows())
                .map_err(|e| e.to_string())
        })
        .collect()
}

/// The engine. Generic over the LM implementation so the full coordinator
/// is testable on the sim substrate.
pub struct Engine<T: Llm, D: Llm> {
    target: T,
    draft: D,
    cfg: EngineConfig,
    pub metrics: Arc<Metrics>,
    /// Engine-global decayed acceptance statistics: the prior every new
    /// adaptive request starts from, updated by all of them.
    pub acceptance: Arc<GlobalEstimator>,
}

impl<T: Llm, D: Llm> Engine<T, D> {
    pub fn new(target: T, draft: D, cfg: EngineConfig) -> Self {
        Self {
            target,
            draft,
            cfg,
            metrics: Arc::new(Metrics::default()),
            acceptance: Arc::new(GlobalEstimator::default()),
        }
    }

    /// The per-round node budget a request occupies while active (its
    /// admission weight under `EngineConfig::max_active_budget`).
    fn request_weight(&self, req: &Request) -> usize {
        req.decoder.as_ref().unwrap_or(&self.cfg.decoder).budget().max(1)
    }

    fn make_stepper(&self, req: &Request) -> Result<AnyStepper<T, D>> {
        let decoder = req.decoder.clone().unwrap_or_else(|| self.cfg.decoder.clone());
        let sampling = match &req.sampling {
            Some(patch) => patch.apply(&self.cfg.sampling),
            None => self.cfg.sampling.clone(),
        };
        Ok(match decoder {
            DecoderConfig::Ar => {
                AnyStepper::Ar(ArStepper::new(&self.target, sampling, &req.prompt, req.max_new)?)
            }
            DecoderConfig::Adaptive { budget, family } => {
                let ctl =
                    AdaptiveController::new(budget, family, Some(self.acceptance.clone()));
                AnyStepper::Adaptive(AdaptiveStepper::new(
                    &self.target,
                    &self.draft,
                    ctl,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
            other => {
                let (strategy, rule) = build_parts(&other);
                AnyStepper::Spec(SpecStepper::new(
                    &self.target,
                    &self.draft,
                    strategy,
                    rule,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
        })
    }

    /// Blocking serve loop. Returns when the request channel closes and
    /// all in-flight work drained.
    pub fn run(self, rx: mpsc::Receiver<Request>) -> Arc<Metrics> {
        let mut batcher: Batcher<Request> =
            Batcher::new(self.cfg.max_concurrency, self.cfg.max_queue)
                .with_max_active_weight(self.cfg.max_active_budget);
        let mut active: Vec<Active<T, D>> = Vec::new();
        // the engine-wide flat logits buffer every fused phase writes into
        let mut logits = LogitsBatch::default();
        let mut closed = false;

        loop {
            // ---- intake --------------------------------------------------
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        if let Err((req, _)) = batcher.offer(req) {
                            self.metrics.add(&self.metrics.rejected, 1);
                            let _ = req.resp.send(Event::Error("queue full".into()));
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            // block when idle (nothing active, nothing queued)
            if active.is_empty() && batcher.queued() == 0 {
                if closed {
                    break;
                }
                match rx.recv() {
                    Ok(req) => {
                        if let Err((req, _)) = batcher.offer(req) {
                            self.metrics.add(&self.metrics.rejected, 1);
                            let _ = req.resp.send(Event::Error("queue full".into()));
                        }
                    }
                    Err(_) => break,
                }
            }

            // ---- admission (budget-weighted under heterogeneous
            // per-request decoders) ----------------------------------------
            while let Some((req, weight)) = batcher.admit_by(|r| self.request_weight(r)) {
                self.metrics.add(&self.metrics.admitted, 1);
                match self.make_stepper(&req) {
                    Ok(stepper) => {
                        let rng = Rng::seed_from_u64(self.cfg.seed ^ req.id);
                        active.push(Active {
                            req,
                            stepper,
                            rng,
                            sent: 0,
                            weight,
                            started: Instant::now(),
                            first_token_at: None,
                        });
                    }
                    Err(e) => {
                        self.metrics.add(&self.metrics.failed, 1);
                        let _ = req.resp.send(Event::Error(e.to_string()));
                        batcher.release_weight(weight);
                    }
                }
            }
            if active.is_empty() {
                continue;
            }

            // ---- one fused round over every active request ---------------
            let mut state = self.run_fused_round(&mut active, &mut logits);

            // ---- flush tokens, deliver completions/errors ----------------
            let mut i = 0;
            while i < active.len() {
                // owned disposition so removal below can freely mutate
                let failure: Option<String> = match &state[i] {
                    RoundState::Failed(e) => Some(e.clone()),
                    _ => None,
                };
                let completed = matches!(state[i], RoundState::Done);
                if failure.is_none() {
                    let a = &mut active[i];
                    let out_len = a.stepper.out().len();
                    if out_len > a.sent {
                        if a.first_token_at.is_none() {
                            let t = a.started.elapsed().as_secs_f64();
                            a.first_token_at = Some(t);
                            self.metrics.record_ttft(t);
                        }
                        let new: Vec<u32> = a.stepper.out()[a.sent..].to_vec();
                        self.metrics.add(&self.metrics.tokens_out, new.len() as u64);
                        a.sent = out_len;
                        let _ = a.req.resp.send(Event::Tokens(new));
                    }
                }
                if let Some(e) = failure {
                    self.metrics.add(&self.metrics.failed, 1);
                    let _ = active[i].req.resp.send(Event::Error(e));
                    let weight = active[i].weight;
                    active.swap_remove(i);
                    state.swap_remove(i);
                    batcher.release_weight(weight);
                } else if completed {
                    let stats = active[i].stepper.stats().clone();
                    self.metrics.add(&self.metrics.completed, 1);
                    self.metrics
                        .add(&self.metrics.draft_calls, stats.draft_calls as u64);
                    self.metrics.record_latency(active[i].started.elapsed().as_secs_f64());
                    let _ = active[i].req.resp.send(Event::Done(stats));
                    let weight = active[i].weight;
                    active.swap_remove(i);
                    state.swap_remove(i);
                    batcher.release_weight(weight);
                } else {
                    i += 1;
                }
            }
        }
        self.metrics
    }

    /// Advance every active request by one speculative round, batching
    /// all draft and target forwards across requests (see module docs)
    /// into the shared flat `logits` buffer. Returns each request's
    /// end-of-round state, index-aligned with `active`.
    fn run_fused_round(
        &self,
        active: &mut [Active<T, D>],
        logits: &mut LogitsBatch,
    ) -> Vec<RoundState> {
        let mut state: Vec<RoundState> = Vec::with_capacity(active.len());

        // ---- phase 1: begin rounds (bookkeeping, no model calls) ---------
        for a in active.iter_mut() {
            let start = match &mut a.stepper {
                AnyStepper::Ar(s) => s.begin_round(&self.target, &mut a.rng),
                AnyStepper::Spec(s) => s.begin_round(&self.target, &self.draft),
                AnyStepper::Adaptive(s) => s.begin_round(&self.target, &self.draft),
            };
            state.push(match start {
                Ok(RoundStart::Started) => RoundState::InRound,
                Ok(RoundStart::Finished) => RoundState::Done,
                Err(e) => RoundState::Failed(e.to_string()),
            });
        }
        let in_round =
            state.iter().filter(|s| matches!(s, RoundState::InRound)).count();

        // ---- phase 2: fused draft levels ---------------------------------
        // Requests at different tree depths drop out of later iterations;
        // each iteration is ONE fused draft forward across the rest.
        loop {
            let mut groups: Vec<(&mut D::Session, &[EvalNode])> = Vec::new();
            let mut who: Vec<usize> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                if !matches!(state[i], RoundState::InRound) {
                    continue;
                }
                let g = match &mut a.stepper {
                    AnyStepper::Spec(s) => s.draft_group(),
                    AnyStepper::Adaptive(s) => s.draft_group(),
                    AnyStepper::Ar(_) => None,
                };
                if let Some(g) = g {
                    groups.push(g);
                    who.push(i);
                }
            }
            if groups.is_empty() {
                break;
            }
            let results = eval_phase(&self.draft, self.cfg.fused, &mut groups, logits);
            drop(groups);
            self.metrics.record_fused(who.len(), in_round);
            for (res, &i) in results.into_iter().zip(who.iter()) {
                match res {
                    Ok(range) => {
                        let a = &mut active[i];
                        let rows_i = logits.view(range);
                        let fed = match &mut a.stepper {
                            AnyStepper::Spec(s) => s.feed_draft(rows_i, &mut a.rng),
                            AnyStepper::Adaptive(s) => s.feed_draft(rows_i, &mut a.rng),
                            AnyStepper::Ar(_) => unreachable!("AR stages no draft work"),
                        };
                        if let Err(e) = fed {
                            state[i] = RoundState::Failed(e.to_string());
                        }
                    }
                    Err(e) => state[i] = RoundState::Failed(e),
                }
            }
        }

        // ---- phase 3: one fused target pass (verification) ---------------
        let mut groups: Vec<(&mut T::Session, &[EvalNode])> = Vec::new();
        let mut who: Vec<usize> = Vec::new();
        for (i, a) in active.iter_mut().enumerate() {
            if !matches!(state[i], RoundState::InRound) {
                continue;
            }
            let g = match &mut a.stepper {
                AnyStepper::Ar(s) => s.target_group(),
                AnyStepper::Spec(s) => s.target_group(),
                AnyStepper::Adaptive(s) => s.target_group(),
            };
            match g {
                Some(g) => {
                    groups.push(g);
                    who.push(i);
                }
                None => state[i] = RoundState::Failed("round staged no target work".into()),
            }
        }
        if !groups.is_empty() {
            let results = eval_phase(&self.target, self.cfg.fused, &mut groups, logits);
            drop(groups);
            self.metrics.record_fused(who.len(), in_round);
            for (res, &i) in results.into_iter().zip(who.iter()) {
                let rows_i = match res {
                    Ok(range) => logits.view(range),
                    Err(e) => {
                        state[i] = RoundState::Failed(e);
                        continue;
                    }
                };
                let a = &mut active[i];
                let fed = match &mut a.stepper {
                    AnyStepper::Ar(s) => s.feed_target(&self.target, rows_i),
                    AnyStepper::Spec(s) => {
                        s.feed_target(&self.target, &self.draft, rows_i, &mut a.rng)
                    }
                    AnyStepper::Adaptive(s) => {
                        s.feed_target(&self.target, &self.draft, rows_i, &mut a.rng)
                    }
                };
                state[i] = match fed {
                    Ok(StepOutcome::Progress) => RoundState::Progressed,
                    Ok(StepOutcome::Done) => RoundState::Done,
                    Err(e) => RoundState::Failed(e.to_string()),
                };
                if !matches!(state[i], RoundState::Failed(_)) {
                    self.metrics.add(&self.metrics.decode_rounds, 1);
                    if let Some(report) = active[i].stepper.last_round() {
                        self.metrics.record_round(report);
                    }
                }
            }
        }
        state
    }
}

/// Spawn the engine on a dedicated thread; returns the submission handle
/// and a handle resolving to the final metrics.
pub fn spawn<T, D>(
    engine: Engine<T, D>,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Arc<Metrics>>)
where
    T: Llm + Send + 'static,
    D: Llm + Send + 'static,
    T::Session: Send,
    D::Session: Send,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || engine.run(rx));
    (tx, handle)
}

/// Spawn an engine whose LMs are not `Send` (the PJRT model holds raw
/// FFI handles): the constructor runs *inside* the engine thread, so the
/// models never cross a thread boundary.
pub fn spawn_with<F, T, D>(
    make: F,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Result<Arc<Metrics>>>)
where
    F: FnOnce() -> Result<Engine<T, D>> + Send + 'static,
    T: Llm + 'static,
    D: Llm + 'static,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let engine = make()?;
        Ok(engine.run(rx))
    });
    (tx, handle)
}
