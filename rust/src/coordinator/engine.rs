//! The serving engine: owns the (target, draft) model pair and steps
//! every active request one speculative round per turn — continuous
//! batching at iteration granularity, so long generations never starve
//! newly admitted requests.
//!
//! The engine core is synchronous (PJRT execution is blocking); it runs
//! on its own thread and talks to front-ends through std channels.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::adaptive::{AdaptiveController, AdaptiveStepper, GlobalEstimator};
use crate::config::{DecoderConfig, EngineConfig, SamplingConfig};
use crate::decode::ar::ArStepper;
use crate::decode::spec::{RoundReport, SpecStepper, StepOutcome};
use crate::decode::{build_parts, DecodeStats};
use crate::llm::Llm;
use crate::util::Rng;

use super::batcher::Batcher;
use super::metrics::Metrics;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request overrides (None = engine defaults).
    pub decoder: Option<DecoderConfig>,
    pub sampling: Option<SamplingConfig>,
    pub resp: mpsc::Sender<Event>,
}

/// Streamed response events.
#[derive(Debug, Clone)]
pub enum Event {
    /// Newly generated tokens (one speculative round's worth).
    Tokens(Vec<u32>),
    /// Request finished; final stats.
    Done(DecodeStats),
    /// Request failed or was shed.
    Error(String),
}

enum AnyStepper<T: Llm, D: Llm> {
    Ar(ArStepper<T>),
    Spec(SpecStepper<T, D>),
    /// Per-round re-shaped speculative session (`adaptive:B`), sharing
    /// the engine-global acceptance statistics.
    Adaptive(AdaptiveStepper<T, D>),
}

impl<T: Llm, D: Llm> AnyStepper<T, D> {
    fn out(&self) -> &[u32] {
        match self {
            AnyStepper::Ar(s) => &s.out,
            AnyStepper::Spec(s) => &s.out,
            AnyStepper::Adaptive(s) => s.out(),
        }
    }

    fn stats(&self) -> &DecodeStats {
        match self {
            AnyStepper::Ar(s) => &s.stats,
            AnyStepper::Spec(s) => &s.stats,
            AnyStepper::Adaptive(s) => s.stats(),
        }
    }

    fn last_round(&self) -> Option<&RoundReport> {
        match self {
            AnyStepper::Ar(_) => None,
            AnyStepper::Spec(s) => s.last_round(),
            AnyStepper::Adaptive(s) => s.last_round(),
        }
    }
}

struct Active<T: Llm, D: Llm> {
    req: Request,
    stepper: AnyStepper<T, D>,
    sent: usize,
    /// Node-budget weight this request was charged at admission.
    weight: usize,
    started: Instant,
    first_token_at: Option<f64>,
}

/// The engine. Generic over the LM implementation so the full coordinator
/// is testable on the sim substrate.
pub struct Engine<T: Llm, D: Llm> {
    target: T,
    draft: D,
    cfg: EngineConfig,
    pub metrics: Arc<Metrics>,
    /// Engine-global decayed acceptance statistics: the prior every new
    /// adaptive request starts from, updated by all of them.
    pub acceptance: Arc<GlobalEstimator>,
}

impl<T: Llm, D: Llm> Engine<T, D> {
    pub fn new(target: T, draft: D, cfg: EngineConfig) -> Self {
        Self {
            target,
            draft,
            cfg,
            metrics: Arc::new(Metrics::default()),
            acceptance: Arc::new(GlobalEstimator::default()),
        }
    }

    /// The per-round node budget a request occupies while active (its
    /// admission weight under `EngineConfig::max_active_budget`).
    fn request_weight(&self, req: &Request) -> usize {
        req.decoder.as_ref().unwrap_or(&self.cfg.decoder).budget().max(1)
    }

    fn make_stepper(&self, req: &Request) -> Result<AnyStepper<T, D>> {
        let decoder = req.decoder.clone().unwrap_or_else(|| self.cfg.decoder.clone());
        let sampling = req.sampling.unwrap_or(self.cfg.sampling);
        Ok(match decoder {
            DecoderConfig::Ar => {
                AnyStepper::Ar(ArStepper::new(&self.target, sampling, &req.prompt, req.max_new)?)
            }
            DecoderConfig::Adaptive { budget, family } => {
                let ctl =
                    AdaptiveController::new(budget, family, Some(self.acceptance.clone()));
                AnyStepper::Adaptive(AdaptiveStepper::new(
                    &self.target,
                    &self.draft,
                    ctl,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
            other => {
                let (strategy, rule) = build_parts(&other);
                AnyStepper::Spec(SpecStepper::new(
                    &self.target,
                    &self.draft,
                    strategy,
                    rule,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
        })
    }

    /// Blocking serve loop. Returns when the request channel closes and
    /// all in-flight work drained.
    pub fn run(self, rx: mpsc::Receiver<Request>) -> Arc<Metrics> {
        let mut rng = Rng::seed_from_u64(self.cfg.seed);
        let mut batcher: Batcher<Request> =
            Batcher::new(self.cfg.max_concurrency, self.cfg.max_queue)
                .with_max_active_weight(self.cfg.max_active_budget);
        let mut active: Vec<Active<T, D>> = Vec::new();
        let mut closed = false;

        loop {
            // ---- intake --------------------------------------------------
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        if let Err((req, _)) = batcher.offer(req) {
                            self.metrics.add(&self.metrics.rejected, 1);
                            let _ = req.resp.send(Event::Error("queue full".into()));
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            // block when idle (nothing active, nothing queued)
            if active.is_empty() && batcher.queued() == 0 {
                if closed {
                    break;
                }
                match rx.recv() {
                    Ok(req) => {
                        if let Err((req, _)) = batcher.offer(req) {
                            self.metrics.add(&self.metrics.rejected, 1);
                            let _ = req.resp.send(Event::Error("queue full".into()));
                        }
                    }
                    Err(_) => break,
                }
            }

            // ---- admission (budget-weighted under heterogeneous
            // per-request decoders) ----------------------------------------
            while let Some((req, weight)) = batcher.admit_by(|r| self.request_weight(r)) {
                self.metrics.add(&self.metrics.admitted, 1);
                match self.make_stepper(&req) {
                    Ok(stepper) => active.push(Active {
                        req,
                        stepper,
                        sent: 0,
                        weight,
                        started: Instant::now(),
                        first_token_at: None,
                    }),
                    Err(e) => {
                        self.metrics.add(&self.metrics.failed, 1);
                        let _ = req.resp.send(Event::Error(e.to_string()));
                        batcher.release_weight(weight);
                    }
                }
            }

            // ---- one round per active request (round-robin fairness) -----
            let mut i = 0;
            while i < active.len() {
                let a = &mut active[i];
                let step_result = match &mut a.stepper {
                    AnyStepper::Ar(s) => s.step(&self.target, &mut rng),
                    AnyStepper::Spec(s) => s.step(&self.target, &self.draft, &mut rng),
                    AnyStepper::Adaptive(s) => s.step(&self.target, &self.draft, &mut rng),
                };
                match step_result {
                    Ok(outcome) => {
                        self.metrics.add(&self.metrics.decode_rounds, 1);
                        if let Some(report) = a.stepper.last_round() {
                            self.metrics.record_round(report);
                        }
                        let out_len = a.stepper.out().len();
                        if out_len > a.sent {
                            if a.first_token_at.is_none() {
                                let t = a.started.elapsed().as_secs_f64();
                                a.first_token_at = Some(t);
                                self.metrics.record_ttft(t);
                            }
                            let new: Vec<u32> = a.stepper.out()[a.sent..].to_vec();
                            self.metrics.add(&self.metrics.tokens_out, new.len() as u64);
                            a.sent = out_len;
                            let _ = a.req.resp.send(Event::Tokens(new));
                        }
                        if outcome == StepOutcome::Done {
                            let stats = a.stepper.stats().clone();
                            self.metrics.add(&self.metrics.completed, 1);
                            self.metrics
                                .add(&self.metrics.draft_calls, stats.draft_calls as u64);
                            self.metrics.record_latency(a.started.elapsed().as_secs_f64());
                            let _ = a.req.resp.send(Event::Done(stats));
                            let weight = a.weight;
                            active.swap_remove(i);
                            batcher.release_weight(weight);
                            continue; // don't advance i: swapped element takes this slot
                        }
                    }
                    Err(e) => {
                        self.metrics.add(&self.metrics.failed, 1);
                        let _ = a.req.resp.send(Event::Error(e.to_string()));
                        let weight = a.weight;
                        active.swap_remove(i);
                        batcher.release_weight(weight);
                        continue;
                    }
                }
                i += 1;
            }
        }
        self.metrics
    }
}

/// Spawn the engine on a dedicated thread; returns the submission handle
/// and a handle resolving to the final metrics.
pub fn spawn<T, D>(
    engine: Engine<T, D>,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Arc<Metrics>>)
where
    T: Llm + Send + 'static,
    D: Llm + Send + 'static,
    T::Session: Send,
    D::Session: Send,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || engine.run(rx));
    (tx, handle)
}

/// Spawn an engine whose LMs are not `Send` (the PJRT model holds raw
/// FFI handles): the constructor runs *inside* the engine thread, so the
/// models never cross a thread boundary.
pub fn spawn_with<F, T, D>(
    make: F,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Result<Arc<Metrics>>>)
where
    F: FnOnce() -> Result<Engine<T, D>> + Send + 'static,
    T: Llm + 'static,
    D: Llm + 'static,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let engine = make()?;
        Ok(engine.run(rx))
    });
    (tx, handle)
}
