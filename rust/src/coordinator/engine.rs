//! The serving engine: owns the (target, draft) model pair and runs
//! *phase-synchronized fused rounds* with **continuous batching at phase
//! granularity** — batch membership churns mid-stream: waiting requests
//! join at the next phase boundary (not after a full drain, not even
//! after the current round), completed requests leave — and free their
//! KV blocks — the moment their last commit lands.
//!
//! # Fused round loop
//!
//! Each engine turn advances every active request by one speculative
//! round, in lockstep phases:
//!
//! 1. **Begin** — every stepper runs its per-round bookkeeping
//!    ([`SpecStepper::begin_round`] / AR sampling) and stages its first
//!    model work. No model call happens here. Requests that finish at
//!    this point (length cap, stop token, KV capacity) are delivered and
//!    dropped immediately.
//! 2. **Draft** — all staged draft work (one tree level per request) is
//!    executed as ONE fused [`Llm::eval_batch`] call over the draft
//!    model; rows are fed back and each stepper stages its next level.
//!    Repeat until no request has draft work left: requests whose trees
//!    are shallower simply drop out of later fused calls (the fill-ratio
//!    histogram in [`super::metrics::Metrics`] tracks exactly this).
//!    AR requests have no draft phase and never participate.
//!    **Admission happens at every iteration of this loop**: newly
//!    arrived (or newly unblocked) requests begin their round mid-stream
//!    and their first phase work — the draft-tail prefill, or the AR
//!    prompt prefill — fuses into the very next phase call instead of
//!    waiting for the batch to drain.
//! 3. **Verify** — one fused `eval_batch` over the target model covers
//!    every request's verification pass (tail + whole tree; prefill or
//!    single-token decode for AR). Rows are fed back; verification,
//!    commit and emission run on the host per request. Each request's
//!    newly committed tokens are streamed ([`Event::Tokens`]) the moment
//!    its own commit lands — the stepper's
//!    [`SpecStepper::committed_len`] boundary — and completed requests
//!    (stop token, `max_tokens`) are finalized right here, returning
//!    their KV blocks to the pool before the next admission decision
//!    runs.
//!
//! Scheduling is deadline/priority-aware ([`super::batcher::Batcher`]):
//! per-request `priority` classes admit first, `deadline_ms` breaks
//! ties, and an aging rule promotes any waiting request past every
//! declared priority so nothing starves.
//!
//! Token streams are **identical** to stepping each request alone, and
//! identical across admission schedules: every request owns a
//! deterministic RNG stream seeded from `engine_seed ^ request_id`, and
//! model calls never consume RNG, so neither admission order, nor batch
//! composition, nor mid-round joining changes any request's output.
//! (Exception: `adaptive:B` requests share the engine-global acceptance
//! estimator by design, so their tree *shapes* — never their
//! distributional correctness — depend on what else ran.)
//! `EngineConfig::fused = false` switches to one `eval` per request for
//! A/B benchmarking; `EngineConfig::drain_batching = true` switches to
//! drain-then-refill admission (the `benches/continuous.rs` baseline).
//! The schedule changes, the per-request output never does.
//!
//! The engine core is synchronous (PJRT execution is blocking); it runs
//! on its own thread and talks to front-ends through std channels.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::adaptive::{AdaptiveController, AdaptiveStepper, GlobalEstimator};
use crate::config::{DecoderConfig, EngineConfig, SamplingPatch};
use crate::decode::ar::ArStepper;
use crate::decode::spec::{RoundReport, RoundStart, SpecStepper, StepOutcome};
use crate::decode::{build_parts, DecodeStats};
use crate::llm::{EvalNode, Llm, LogitsBatch};
use crate::trace::watchdog::EngineStatus;
use crate::trace::{EventKind, Tracer, PHASE_DRAFT, PHASE_HOST, PHASE_SCHED, PHASE_VERIFY};
use crate::util::Rng;

use super::batcher::{Admitted, Batcher};
use super::errors::{EngineError, ErrorKind};
use super::metrics::Metrics;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct Request {
    /// Must be unique among in-flight requests: it seeds the request's
    /// RNG stream and keys the engine's preemption state (a duplicate
    /// id would hand a resumed request the wrong spilled stepper). The
    /// server allocates ids from a process-wide counter.
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request overrides (None = engine defaults).
    pub decoder: Option<DecoderConfig>,
    /// Field-wise sampling overrides; unset fields inherit the engine's
    /// configured sampling.
    pub sampling: Option<SamplingPatch>,
    /// Scheduling class: higher admits first (0 = default). Aging in
    /// the batcher guarantees low classes still cannot starve.
    pub priority: u8,
    /// Declared latency budget in milliseconds: among equal effective
    /// priorities, tighter deadlines admit first. None = no preference.
    pub deadline_ms: Option<u64>,
    pub resp: mpsc::Sender<Event>,
}

/// Final per-request accounting, delivered with [`Event::Done`]: the
/// stepper's decode-level statistics plus the request's scheduling
/// timeline. All times are seconds measured from *arrival* (queue
/// entry), so `queue_wait <= ttft <= latency`.
#[derive(Debug, Clone, Default)]
pub struct RequestReport {
    /// The request this report belongs to ([`Request::id`]).
    pub id: u64,
    /// Decode-level statistics (rounds, acceptance, KV telemetry, ...).
    pub stats: DecodeStats,
    /// Queue wait before first admission.
    pub queue_wait: f64,
    /// Time to first streamed token (None when the request finished
    /// without emitting anything, e.g. an immediate stop token).
    pub ttft: Option<f64>,
    /// Total time to completion.
    pub latency: f64,
}

/// Streamed response events.
#[derive(Debug, Clone)]
pub enum Event {
    /// Newly committed tokens, sent at the request's commit boundary
    /// (once per speculative round that emitted anything).
    Tokens(Vec<u32>),
    /// Request finished; final stats + timeline.
    Done(RequestReport),
    /// Request failed, was shed, or was cancelled. Terminal: a request
    /// receives exactly one `Error` OR one `Done`, never both, and the
    /// typed payload carries the scheduler's own retryability verdict.
    Error(EngineError),
}

/// Cross-thread cancellation intake: front-ends mark request ids, the
/// engine drains the set at phase boundaries and aborts the matching
/// in-flight requests — active ones fail at the next reap point (their
/// KV blocks return to the pool with the dropped stepper), queued,
/// parked and retry-delayed ones are removed on the spot. Ids that are
/// not in flight (already completed, never submitted) are ignored, so
/// cancellation is always safe to request. Clone freely; all clones
/// share one set.
#[derive(Debug, Clone, Default)]
pub struct CancelRegistry {
    ids: Arc<Mutex<HashSet<u64>>>,
}

impl CancelRegistry {
    /// Mark a request for cancellation at the engine's next phase
    /// boundary.
    pub fn request(&self, id: u64) {
        self.ids.lock().unwrap().insert(id);
    }

    /// Cheap hot-path pre-check so phase boundaries skip the drain
    /// while nothing is pending (the overwhelmingly common case).
    fn is_empty(&self) -> bool {
        self.ids.lock().unwrap().is_empty()
    }

    fn drain(&self) -> Vec<u64> {
        self.ids.lock().unwrap().drain().collect()
    }
}

enum AnyStepper<T: Llm, D: Llm> {
    Ar(ArStepper<T>),
    Spec(SpecStepper<T, D>),
    /// Per-round re-shaped speculative session (`adaptive:B`), sharing
    /// the engine-global acceptance statistics.
    Adaptive(AdaptiveStepper<T, D>),
}

impl<T: Llm, D: Llm> AnyStepper<T, D> {
    fn out(&self) -> &[u32] {
        match self {
            AnyStepper::Ar(s) => &s.out,
            AnyStepper::Spec(s) => &s.out,
            AnyStepper::Adaptive(s) => s.out(),
        }
    }

    /// Streaming commit boundary: tokens in `out()[..committed()]` are
    /// final (verified + KV-committed) and safe to emit.
    fn committed(&self) -> usize {
        match self {
            AnyStepper::Ar(s) => s.committed_len(),
            AnyStepper::Spec(s) => s.committed_len(),
            AnyStepper::Adaptive(s) => s.committed_len(),
        }
    }

    fn stats(&self) -> &DecodeStats {
        match self {
            AnyStepper::Ar(s) => &s.stats,
            AnyStepper::Spec(s) => &s.stats,
            AnyStepper::Adaptive(s) => s.stats(),
        }
    }

    fn last_round(&self) -> Option<&RoundReport> {
        match self {
            AnyStepper::Ar(_) => None,
            AnyStepper::Spec(s) => s.last_round(),
            AnyStepper::Adaptive(s) => s.last_round(),
        }
    }

    /// Worst-case new KV slots the next round could consume.
    fn round_need(&self) -> usize {
        match self {
            AnyStepper::Ar(s) => s.round_need(),
            AnyStepper::Spec(s) => s.round_need(),
            AnyStepper::Adaptive(s) => s.round_need(),
        }
    }

    /// Spill KV state (preemption); only legal between rounds.
    fn suspend(&mut self, target: &T, draft: &D) -> Result<()> {
        match self {
            AnyStepper::Ar(s) => s.suspend(target),
            AnyStepper::Spec(s) => s.suspend(target, draft),
            AnyStepper::Adaptive(s) => s.suspend(target, draft),
        }
    }

    /// Rebuild KV state after a suspend (shared-prefix hits skip
    /// recompute; the rest re-prefills through the phase machine).
    fn resume(&mut self, target: &T, draft: &D) -> Result<()> {
        match self {
            AnyStepper::Ar(s) => s.resume(target),
            AnyStepper::Spec(s) => s.resume(target, draft),
            AnyStepper::Adaptive(s) => s.resume(target, draft),
        }
    }

    /// Abandon an in-flight round after a mid-round fault (the bounded
    /// retry path): staged phase work is recycled, committed state is
    /// kept, and the stepper suspends as if the round never started.
    /// Legal in any phase, unlike `suspend`.
    fn abort_round(&mut self, target: &T, draft: &D) -> Result<()> {
        match self {
            AnyStepper::Ar(s) => s.abort_round(target),
            AnyStepper::Spec(s) => s.abort_round(target, draft),
            AnyStepper::Adaptive(s) => s.abort_round(target, draft),
        }
    }

    /// Attach the flight recorder: the stepper journals its commit
    /// boundaries under this request's id.
    fn set_trace(&mut self, tracer: &Tracer, id: u64) {
        match self {
            AnyStepper::Ar(s) => s.set_trace(tracer, id),
            AnyStepper::Spec(s) => s.set_trace(tracer, id),
            AnyStepper::Adaptive(s) => s.set_trace(tracer, id),
        }
    }

    /// Attach the speculation analytics: the stepper accrues target
    /// forwards and commit boundaries to `family`'s ledger rows.
    fn set_analytics(&mut self, analytics: &crate::obs::Analytics, family: crate::obs::Family) {
        match self {
            AnyStepper::Ar(s) => s.set_analytics(analytics),
            AnyStepper::Spec(s) => s.set_analytics(analytics, family),
            AnyStepper::Adaptive(s) => s.set_analytics(analytics, family),
        }
    }
}

/// Where one active request stands within the current fused round.
enum RoundState {
    /// Between rounds: the next begin phase will start a round.
    Idle,
    /// Phase work staged; participating in fused calls.
    InRound,
    /// Request finished (this round or at `begin_round`); awaiting
    /// delivery + removal at the next reap point.
    Done,
    /// Request hit a fault; the next reap point decides policy (bounded
    /// retry for retryable kinds, typed terminal error otherwise).
    Failed(EngineError),
}

struct Active<T: Llm, D: Llm> {
    req: Request,
    stepper: AnyStepper<T, D>,
    /// This request's own deterministic RNG stream (seeded from
    /// `engine_seed ^ request_id`), making output independent of
    /// admission order, admission timing, and batch composition.
    rng: Rng,
    sent: usize,
    /// Node-budget weight this request was charged at admission.
    weight: usize,
    /// Admission rank: first-admission order, preserved across
    /// preemption. Victim selection preempts the highest rank (the
    /// youngest), so completion-time `swap_remove` shuffling of the
    /// active list can never cost an older request its KV.
    seq: u64,
    /// Arrival time (queue entry): latency and TTFT are measured from
    /// here, so they include queue wait.
    started: Instant,
    /// Seconds waited before FIRST admission (the [`RequestReport`]
    /// timeline figure; re-admission waits after preemption are
    /// recorded in metrics but do not overwrite it).
    queue_wait: f64,
    first_token_at: Option<f64>,
    /// Mid-decode fault retries already consumed (bounded by
    /// [`EngineConfig::retry_budget`]).
    retries: u32,
    /// RNG stream snapshot taken right after this round's successful
    /// `begin_round`: an aborted round restores it, so the retried
    /// round replays exactly the draws the faulted attempt consumed
    /// and the request's final stream stays bit-identical to a
    /// fault-free run. (AR commits its round token *inside*
    /// `begin_round`, before the snapshot, so a committed draw is
    /// never replayed.)
    round_rng: Option<Rng>,
    state: RoundState,
}

impl<T: Llm, D: Llm> Active<T, D> {
    /// Start this request's round now (used both at the pre-round begin
    /// phase and for mid-round joiners at a phase boundary).
    fn begin(&mut self, target: &T, draft: &D) {
        self.round_rng = None;
        let start = match &mut self.stepper {
            AnyStepper::Ar(s) => s.begin_round(target, &mut self.rng),
            AnyStepper::Spec(s) => s.begin_round(target, draft),
            AnyStepper::Adaptive(s) => s.begin_round(target, draft),
        };
        self.state = match start {
            Ok(RoundStart::Started) => {
                self.round_rng = Some(self.rng.clone());
                RoundState::InRound
            }
            Ok(RoundStart::Finished) => RoundState::Done,
            Err(e) => RoundState::Failed(EngineError::classify(&e)),
        };
    }
}

/// A preempted request's host-side state, parked while its `Request`
/// waits at the front of the batcher queue. The RNG stream is preserved
/// verbatim, so a resumed request's tokens are bit-identical to an
/// uninterrupted run.
struct Parked<T: Llm, D: Llm> {
    stepper: AnyStepper<T, D>,
    rng: Rng,
    sent: usize,
    /// Original admission rank (a resumed request is still its old age).
    seq: u64,
    started: Instant,
    queue_wait: f64,
    first_token_at: Option<f64>,
    /// Fault retries already consumed (preserved across park/resume so
    /// the per-request budget is global, not per-activation).
    retries: u32,
}

/// Everything the serve loop mutates, bundled so every helper sees one
/// coherent picture of queue + active set + bookkeeping.
struct EngineState<T: Llm, D: Llm> {
    batcher: Batcher<Request>,
    active: Vec<Active<T, D>>,
    /// Host-side state of preempted requests, keyed by request id (their
    /// `Request` halves wait at the front of the batcher queue).
    parked: HashMap<u64, Parked<T, D>>,
    /// Ids currently queued/active/parked (duplicate-id guard).
    in_flight: HashSet<u64>,
    /// Fault-retried requests waiting out a deterministic backoff:
    /// `(resume_round, request, rank)`. Entries re-enter the queue
    /// front when `rounds` reaches `resume_round`, or immediately when
    /// the engine would otherwise idle (waiting longer cannot change a
    /// retry's outcome, only stall it).
    delayed: Vec<(u64, Request, u64)>,
    /// Retry counts of requests that faulted before ever activating
    /// (admission-time stepper construction), keyed by id.
    fresh_retries: HashMap<u64, u32>,
    /// Admission-rank source for preemption victim selection.
    next_seq: u64,
    /// The engine-wide flat logits buffer every fused phase writes into.
    logits: LogitsBatch,
    /// Fused rounds completed (trace round ids, watchdog status).
    rounds: u64,
    /// The request channel disconnected; drain and exit.
    closed: bool,
}

/// Execute one phase's groups into the shared flat logits buffer and
/// return a per-group outcome (the group's row range in `out`, or a
/// typed error), index-aligned with the groups. The buffer is engine-
/// owned and recycled across phases and rounds, so a phase performs no
/// per-row allocation.
///
/// Fused path: one `eval_batch_into` call. On error the phase is
/// **re-driven per group** through `eval_into` — blast-radius
/// isolation: the atomicity contract on [`Llm::eval_batch_into`]
/// guarantees a failed fused call mutated no session, so each group can
/// be retried alone and only the request(s) that actually carry the
/// fault fail; every co-batched healthy request produces exactly the
/// rows it would have produced had the poisoned request never shared
/// its batch. Sequential fallback (`EngineConfig::fused = false`) is
/// the same per-group loop from the start.
fn eval_phase<L: Llm>(
    lm: &L,
    fused: bool,
    groups: &mut [(&mut L::Session, &[EvalNode])],
    out: &mut LogitsBatch,
) -> Vec<std::result::Result<std::ops::Range<usize>, EngineError>> {
    out.reset(lm.vocab());
    if fused {
        match lm.eval_batch_into(groups, out) {
            Ok(()) => {
                let mut start = 0;
                return groups
                    .iter()
                    .map(|(_, nodes)| {
                        let r = start..start + nodes.len();
                        start += nodes.len();
                        Ok(r)
                    })
                    .collect();
            }
            // fall through to the per-group re-drive; the failed fused
            // call appended no rows, but reset defensively anyway
            Err(_) => out.reset(lm.vocab()),
        }
    }
    groups
        .iter_mut()
        .map(|(session, nodes)| {
            let start = out.rows();
            lm.eval_into(session, nodes, out)
                .map(|()| start..out.rows())
                .map_err(|e| EngineError::classify(&e))
        })
        .collect()
}

/// The engine. Generic over the LM implementation so the full coordinator
/// is testable on the sim substrate.
pub struct Engine<T: Llm, D: Llm> {
    target: T,
    draft: D,
    cfg: EngineConfig,
    pub metrics: Arc<Metrics>,
    /// Engine-global decayed acceptance statistics: the prior every new
    /// adaptive request starts from, updated by all of them.
    pub acceptance: Arc<GlobalEstimator>,
    /// Flight-recorder handle. Off unless `EngineConfig::trace_events`
    /// is non-zero (or an enabled tracer was passed to
    /// [`Engine::with_telemetry`]). Clone it before [`spawn`] consumes
    /// the engine to dump the journal from outside.
    pub trace: Tracer,
    /// Speculation-analytics handle: the per-family acceptance ledger,
    /// windowed live stats and SLO tracker. On by default
    /// (`EngineConfig::stats_window_rounds`); clone it before [`spawn`]
    /// consumes the engine to read stats from outside.
    pub analytics: crate::obs::Analytics,
    /// Coarse engine state shared with the stall watchdog, refreshed at
    /// round boundaries (only while tracing is enabled).
    status: Arc<Mutex<EngineStatus>>,
    /// Cancellation intake, drained at phase boundaries (empty and
    /// inert unless a front-end was handed a clone via
    /// [`Engine::with_cancels`]).
    cancels: CancelRegistry,
}

impl<T: Llm, D: Llm> Engine<T, D> {
    pub fn new(target: T, draft: D, cfg: EngineConfig) -> Self {
        let trace = Tracer::new(cfg.trace_events);
        Self::with_telemetry(target, draft, cfg, Arc::new(Metrics::default()), trace)
    }

    /// Build an engine around externally owned telemetry: the metrics
    /// registry a server exports and a tracer whose journal outlives
    /// the engine (wire `trace` command, watchdog dumps). Both model
    /// substrates get the tracer attached, so pool-backed KV traffic
    /// (acquire / publish / evict) lands in the same journal.
    pub fn with_telemetry(
        target: T,
        draft: D,
        cfg: EngineConfig,
        metrics: Arc<Metrics>,
        trace: Tracer,
    ) -> Self {
        target.set_trace(&trace);
        draft.set_trace(&trace);
        let analytics = crate::obs::Analytics::from_config(&cfg);
        Self {
            target,
            draft,
            cfg,
            metrics,
            acceptance: Arc::new(GlobalEstimator::default()),
            trace,
            analytics,
            status: Arc::new(Mutex::new(EngineStatus::default())),
            cancels: CancelRegistry::default(),
        }
    }

    /// Share a cancellation registry with front-ends: ids marked through
    /// any clone abort the matching requests at the engine's next phase
    /// boundary (the server's `cancel` wire command feeds this).
    pub fn with_cancels(mut self, cancels: CancelRegistry) -> Self {
        self.cancels = cancels;
        self
    }

    /// Share a speculation-analytics handle with front-ends (the
    /// server's `stats` wire command reads the same ledger the engine
    /// records into). Replaces the config-built handle.
    pub fn with_analytics(mut self, analytics: crate::obs::Analytics) -> Self {
        self.analytics = analytics;
        self
    }

    /// Shared handle to the engine's coarse status, for
    /// [`crate::trace::watchdog::Watchdog::spawn`]. Clone before
    /// [`spawn`] consumes the engine.
    pub fn status_handle(&self) -> Arc<Mutex<EngineStatus>> {
        self.status.clone()
    }

    /// Refresh the watchdog's view of the engine (cheap; round-boundary
    /// cadence; skipped entirely when tracing is off).
    fn update_status(&self, st: &EngineState<T, D>) {
        if !self.trace.enabled() {
            return;
        }
        let mut g = self.status.lock().unwrap();
        g.rounds = st.rounds;
        g.active.clear();
        g.active
            .extend(st.active.iter().map(|a| (a.req.id, a.stepper.committed() as u64)));
        // fault-delayed requests are still in flight: count the ones
        // whose host state is NOT parked (parked covers the rest)
        g.queued = st.batcher.queued()
            + st
                .delayed
                .iter()
                .filter(|(_, r, _)| !st.parked.contains_key(&r.id))
                .count();
        g.parked = st.parked.len();
        g.pool = self.target.pool_status();
    }

    /// The per-round node budget a request occupies while active (its
    /// admission weight under `EngineConfig::max_active_budget`).
    fn request_weight(&self, req: &Request) -> usize {
        req.decoder.as_ref().unwrap_or(&self.cfg.decoder).budget().max(1)
    }

    fn make_stepper(&self, req: &Request) -> Result<AnyStepper<T, D>> {
        let decoder = req.decoder.clone().unwrap_or_else(|| self.cfg.decoder.clone());
        let sampling = match &req.sampling {
            Some(patch) => patch.apply(&self.cfg.sampling),
            None => self.cfg.sampling.clone(),
        };
        Ok(match decoder {
            DecoderConfig::Ar => {
                AnyStepper::Ar(ArStepper::new(&self.target, sampling, &req.prompt, req.max_new)?)
            }
            DecoderConfig::Adaptive { budget, family } => {
                let ctl =
                    AdaptiveController::new(budget, family, Some(self.acceptance.clone()));
                AnyStepper::Adaptive(AdaptiveStepper::new(
                    &self.target,
                    &self.draft,
                    ctl,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
            other => {
                let (strategy, rule) = build_parts(&other);
                AnyStepper::Spec(SpecStepper::new(
                    &self.target,
                    &self.draft,
                    strategy,
                    rule,
                    sampling,
                    &req.prompt,
                    req.max_new,
                )?)
            }
        })
    }

    /// Admission-time guard: a request whose worst-case lifetime
    /// footprint — committed prefix grown to prompt + max_tokens (plus
    /// up to one draft tree of overshoot before truncation) and one
    /// in-flight tree — can never fit a session is answered with a
    /// clean error instead of a mid-decode failure or a silent
    /// truncation. Admitted requests are guaranteed to complete in
    /// full: preemption covers multi-request pressure, and a single
    /// request always fits by this bound. Accepted requests enter the
    /// queue under their declared priority/deadline.
    fn offer_request(&self, st: &mut EngineState<T, D>, req: Request) {
        self.trace.record(
            EventKind::ReqArrive,
            req.id,
            req.prompt.len() as u32,
            st.batcher.queued() as u32,
        );
        // the id keys RNG streams and (crucially) parked preemption
        // state: a duplicate in-flight id could hand one client another
        // request's spilled stepper, so refuse it up front
        if st.in_flight.contains(&req.id) {
            self.metrics.add(&self.metrics.rejected, 1);
            self.trace.record(EventKind::ReqError, req.id, 0, 0);
            let _ = req.resp.send(Event::Error(EngineError::new(
                ErrorKind::InvalidRequest,
                format!("duplicate request id {} (still in flight)", req.id),
            )));
            return;
        }
        let weight = self.request_weight(&req);
        // saturating: a programmatic max_new of usize::MAX must reject
        // cleanly, not overflow
        let need = req
            .prompt
            .len()
            .saturating_add(req.max_new)
            .saturating_add(2 * weight + 4);
        // pool-backed capacity is judged in BLOCKS, charging one extra
        // block for the partial-tail shared-prefix match a session may
        // pin without fully using; dense capacity is raw slots
        let fits = |ps: Option<crate::kvcache::PoolStatus>, cap_slots: usize| match ps {
            Some(p) => need.div_ceil(p.block_size).saturating_add(1) <= p.total_blocks,
            None => need <= cap_slots,
        };
        let target_ok = fits(self.target.pool_status(), self.target.session_capacity());
        // AR requests never open a draft session, so a smaller draft
        // cache must not reject them
        let decoder = req.decoder.as_ref().unwrap_or(&self.cfg.decoder);
        let draft_ok = matches!(decoder, DecoderConfig::Ar)
            || fits(self.draft.pool_status(), self.draft.session_capacity());
        if !(target_ok && draft_ok) {
            self.metrics.add(&self.metrics.rejected, 1);
            self.trace.record(EventKind::ReqError, req.id, 0, 0);
            let _ = req.resp.send(Event::Error(EngineError::new(
                ErrorKind::InvalidRequest,
                format!(
                    "prompt too long or max_tokens too large: {} prompt tokens + {} \
                     max_tokens + {} decode transients exceed session capacity",
                    req.prompt.len(),
                    req.max_new,
                    2 * weight + 4,
                ),
            )));
            return;
        }
        let id = req.id;
        let (priority, deadline_ms) = (req.priority, req.deadline_ms);
        if let Err((req, _)) = st.batcher.offer_with(req, priority, deadline_ms) {
            // load shedding, not a request defect: the typed payload is
            // retryable so clients know to back off and resubmit
            self.metrics.add(&self.metrics.rejected, 1);
            self.metrics.add(&self.metrics.shed, 1);
            self.trace.record(EventKind::ReqError, req.id, 0, 0);
            let _ = req.resp.send(Event::Error(EngineError::new(
                ErrorKind::QueueFull,
                format!("queue full ({} waiting)", st.batcher.queued()),
            )));
        } else {
            st.in_flight.insert(id);
        }
    }

    /// Drain every request currently sitting in the channel into the
    /// queue (non-blocking). Called at every phase boundary, so arrivals
    /// become admissible mid-round.
    fn intake(&self, rx: &mpsc::Receiver<Request>, st: &mut EngineState<T, D>) {
        loop {
            match rx.try_recv() {
                Ok(req) => self.offer_request(st, req),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    st.closed = true;
                    break;
                }
            }
        }
    }

    /// Neither model is pool-backed (dense substrates): every headroom
    /// check is vacuously true.
    fn no_pools(&self) -> bool {
        self.target.pool_status().is_none() && self.draft.pool_status().is_none()
    }

    /// Do both pools hold enough allocatable blocks for one round of
    /// requests with the given per-request `(slots, uses_draft)` needs?
    /// Demand is counted block-granularly (each request may open fresh
    /// partial blocks, so summing raw slots would under-estimate: ten
    /// requests needing one slot each can require ten blocks), plus one
    /// spare block per request; AR requests never open a draft session,
    /// so they charge nothing against the draft pool. Always true on
    /// dense substrates.
    fn pools_fit(&self, needs: &[(usize, bool)]) -> bool {
        let fits = |ps: Option<crate::kvcache::PoolStatus>, draft_side: bool| match ps {
            Some(p) => {
                let want: usize = needs
                    .iter()
                    .filter(|&&(_, uses_draft)| uses_draft || !draft_side)
                    .map(|&(n, _)| n.div_ceil(p.block_size) + 1)
                    .sum();
                p.free_blocks + p.evictable_blocks >= want
            }
            None => true,
        };
        fits(self.target.pool_status(), false) && fits(self.draft.pool_status(), true)
    }

    /// Does this request's decoder drive a draft model?
    fn uses_draft(&self, req: &Request) -> bool {
        !matches!(
            req.decoder.as_ref().unwrap_or(&self.cfg.decoder),
            DecoderConfig::Ar
        )
    }

    /// Would the KV pools still feed every active request's next round
    /// if `cand` were admitted too? (Always true on dense substrates.)
    fn admission_headroom(&self, st: &EngineState<T, D>, cand: &Request) -> bool {
        if self.no_pools() {
            return true;
        }
        let cand_need = match st.parked.get(&cand.id) {
            Some(p) => p.stepper.round_need(),
            None => {
                // discount the prompt prefix already servable from the
                // radix index or the cold tier: a cold hit re-imports
                // blocks instead of re-prefilling, so it consumes pool
                // blocks but no fresh prefill slots beyond the match
                // (capped at len-1 — the tail chain is never empty, and
                // a stale membership answer only costs an extra
                // preemption, never correctness)
                let cached = self
                    .target
                    .cached_prefix_len(&cand.prompt)
                    .min(cand.prompt.len().saturating_sub(1));
                cand.prompt.len() - cached + self.request_weight(cand) + 2
            }
        };
        let mut needs: Vec<(usize, bool)> = st
            .active
            .iter()
            .map(|a| {
                let ar = matches!(a.stepper, AnyStepper::Ar(_));
                (a.stepper.round_need(), !ar)
            })
            .collect();
        needs.push((cand_need, self.uses_draft(cand)));
        self.pools_fit(&needs)
    }

    /// Deterministic exponential backoff, measured in fused rounds:
    /// attempt 1 waits [`EngineConfig::retry_backoff_rounds`], each
    /// further attempt doubles it (shift capped so pathological retry
    /// budgets cannot overflow).
    fn backoff_rounds(&self, attempt: u32) -> u64 {
        (self.cfg.retry_backoff_rounds as u64) << attempt.saturating_sub(1).min(10)
    }

    /// Terminalize a fault that exhausted (or never had) a retry
    /// budget: still-retryable kinds are wrapped as `retries_exhausted`
    /// so the client sees both the policy verdict and the last cause;
    /// terminal kinds pass through unchanged.
    fn exhaust(&self, e: EngineError) -> EngineError {
        if e.retryable {
            EngineError::new(
                ErrorKind::RetriesExhausted,
                format!(
                    "retry budget ({}) exhausted; last error: {e}",
                    self.cfg.retry_budget
                ),
            )
        } else {
            e
        }
    }

    /// Shed queued requests whose declared deadline already expired
    /// (opt-in via [`EngineConfig::enforce_deadlines`]): each receives
    /// one typed, retryable `deadline_expired` error instead of being
    /// admitted into work whose result it can no longer use. Only fresh
    /// arrivals are considered — requeued preemption victims and retry
    /// re-admissions enter at the queue front, which never sheds, so
    /// in-progress work is never thrown away here.
    fn shed_expired(&self, st: &mut EngineState<T, D>) {
        if !self.cfg.enforce_deadlines {
            return;
        }
        for req in st.batcher.shed_expired() {
            self.metrics.add(&self.metrics.shed, 1);
            self.trace.record(EventKind::ReqError, req.id, 0, 0);
            st.in_flight.remove(&req.id);
            st.fresh_retries.remove(&req.id);
            let _ = req.resp.send(Event::Error(EngineError::new(
                ErrorKind::DeadlineExpired,
                format!(
                    "deadline of {}ms expired before admission",
                    req.deadline_ms.unwrap_or(0)
                ),
            )));
        }
    }

    /// Abort every request the cancel registry names, wherever it
    /// stands. Active requests are marked failed and delivered at the
    /// next reap point (dropping the stepper frees their KV blocks);
    /// queued, retry-delayed and parked requests are removed on the
    /// spot. Cancelled requests count in `metrics.cancelled`, not
    /// `failed`, and receive exactly one typed `cancelled` error.
    fn apply_cancels(&self, st: &mut EngineState<T, D>) {
        if self.cancels.is_empty() {
            return;
        }
        for id in self.cancels.drain() {
            if !st.in_flight.contains(&id) {
                continue; // completed, shed, or never submitted
            }
            if let Some(a) = st.active.iter_mut().find(|a| a.req.id == id) {
                a.state = RoundState::Failed(EngineError::cancelled());
                continue;
            }
            let mut reqs: Vec<Request> = st.batcher.remove_where(|r| r.id == id);
            let mut i = 0;
            while i < st.delayed.len() {
                if st.delayed[i].1.id == id {
                    reqs.push(st.delayed.swap_remove(i).1);
                } else {
                    i += 1;
                }
            }
            // a parked stepper holds no KV (suspended), but drop its
            // host state so nothing leaks
            st.parked.remove(&id);
            st.fresh_retries.remove(&id);
            for req in reqs {
                self.metrics.add(&self.metrics.cancelled, 1);
                self.trace.record(EventKind::ReqError, id, 0, 0);
                st.in_flight.remove(&id);
                let _ = req.resp.send(Event::Error(EngineError::cancelled()));
            }
        }
    }

    /// Move every fault-delayed request whose backoff elapsed back to
    /// the front of the queue (rank-ordered: retries resume
    /// oldest-first, like preemption victims). With nothing else to
    /// run, the backoff is cut short — delaying further would idle the
    /// engine without changing any retry's outcome.
    fn release_due_retries(&self, st: &mut EngineState<T, D>) {
        if st.delayed.is_empty() {
            return;
        }
        let force = st.active.is_empty() && st.batcher.queued() == 0;
        let mut i = 0;
        while i < st.delayed.len() {
            if force || st.delayed[i].0 <= st.rounds {
                let (_, req, seq) = st.delayed.swap_remove(i);
                st.batcher.requeue_front(req, seq);
            } else {
                i += 1;
            }
        }
    }

    /// Admit every waiting request the scheduler, the concurrency cap,
    /// the weight cap and the KV headroom allow. This runs at EVERY
    /// phase boundary: `mid_round` joiners begin their round on the spot
    /// so their first phase work fuses into the very next model call.
    /// Under `EngineConfig::drain_batching` admission waits for a full
    /// drain instead (the A/B baseline).
    fn admit_ready(&self, st: &mut EngineState<T, D>, mid_round: bool) {
        self.shed_expired(st);
        self.release_due_retries(st);
        if self.cfg.drain_batching && !st.active.is_empty() {
            return;
        }
        loop {
            let can_admit = match st.batcher.peek() {
                None => false,
                Some(cand) => st.active.is_empty() || self.admission_headroom(st, cand),
            };
            if !can_admit {
                break;
            }
            let Some(adm) = st.batcher.admit_by(|r| self.request_weight(r)) else { break };
            let Admitted { item: req, weight, queued_at } = adm;
            let wait = queued_at.elapsed().as_secs_f64();
            self.metrics.record_queue_wait(wait);
            if let Some(mut p) = st.parked.remove(&req.id) {
                // resume a preempted request: re-acquire whatever
                // prefix is still cached, re-prefill the rest
                let hit_before = p.stepper.stats().kv_hit_tokens;
                match p.stepper.resume(&self.target, &self.draft) {
                    Ok(()) => {
                        self.metrics.add(&self.metrics.resumes, 1);
                        let hit = p.stepper.stats().kv_hit_tokens - hit_before;
                        self.trace.record(EventKind::ReqResume, req.id, hit as u32, 0);
                        st.active.push(Active {
                            req,
                            stepper: p.stepper,
                            rng: p.rng,
                            sent: p.sent,
                            weight,
                            seq: p.seq,
                            started: p.started,
                            queue_wait: p.queue_wait,
                            first_token_at: p.first_token_at,
                            retries: p.retries,
                            round_rng: None,
                            state: RoundState::Idle,
                        });
                    }
                    Err(err) => {
                        // a failed resume leaves the stepper suspended
                        // (sessions are re-acquired first, state is
                        // mutated after), so a retryable fault — pool
                        // exhaustion, a transient device error — parks
                        // the host state again and retries after the
                        // backoff instead of dropping generated work
                        let e = EngineError::classify(&err);
                        if e.retryable && (p.retries as usize) < self.cfg.retry_budget {
                            p.retries += 1;
                            self.metrics.add(&self.metrics.retries, 1);
                            self.trace
                                .record(EventKind::ReqPreempt, req.id, 0, p.retries);
                            let resume_at = st.rounds + self.backoff_rounds(p.retries);
                            let seq = p.seq;
                            st.parked.insert(req.id, p);
                            st.delayed.push((resume_at, req, seq));
                        } else {
                            let e = self.exhaust(e);
                            self.metrics.add(&self.metrics.failed, 1);
                            self.trace.record(EventKind::ReqError, req.id, 0, 0);
                            let _ = req.resp.send(Event::Error(e));
                            st.in_flight.remove(&req.id);
                        }
                        st.batcher.release_weight(weight);
                        continue;
                    }
                }
            } else {
                self.metrics.add(&self.metrics.admitted, 1);
                self.trace.record(
                    EventKind::ReqAdmit,
                    req.id,
                    u32::from(mid_round),
                    weight as u32,
                );
                // publish the prompt as a shareable prefix (the substrate
                // decides if/when the blocks become servable) BEFORE the
                // session opens, so concurrent same-prompt admissions hit;
                // AR requests never open a draft session, so don't spend
                // draft-pool blocks caching a prefix nobody will acquire
                let decoder = req.decoder.as_ref().unwrap_or(&self.cfg.decoder);
                self.target.cache_prefix(&req.prompt);
                if !matches!(decoder, DecoderConfig::Ar) {
                    self.draft.cache_prefix(&req.prompt);
                }
                let family = crate::obs::Family::of(decoder);
                match self.make_stepper(&req) {
                    Ok(mut stepper) => {
                        stepper.set_trace(&self.trace, req.id);
                        stepper.set_analytics(&self.analytics, family);
                        let rng = Rng::seed_from_u64(self.cfg.seed ^ req.id);
                        let seq = st.next_seq;
                        st.next_seq += 1;
                        let retries = st.fresh_retries.remove(&req.id).unwrap_or(0);
                        st.active.push(Active {
                            req,
                            stepper,
                            rng,
                            sent: 0,
                            weight,
                            seq,
                            started: queued_at,
                            queue_wait: wait,
                            first_token_at: None,
                            retries,
                            round_rng: None,
                            state: RoundState::Idle,
                        });
                    }
                    Err(err) => {
                        // stepper construction opens sessions, so this
                        // is where admission meets pool exhaustion /
                        // transient substrate faults: retryable ones
                        // wait out a backoff and try again, bounded by
                        // the same per-request budget
                        let e = EngineError::classify(&err);
                        let tries = st.fresh_retries.get(&req.id).copied().unwrap_or(0);
                        if e.retryable && (tries as usize) < self.cfg.retry_budget {
                            st.fresh_retries.insert(req.id, tries + 1);
                            self.metrics.add(&self.metrics.retries, 1);
                            self.trace
                                .record(EventKind::ReqPreempt, req.id, 0, tries + 1);
                            let resume_at = st.rounds + self.backoff_rounds(tries + 1);
                            let seq = st.next_seq;
                            st.next_seq += 1;
                            st.delayed.push((resume_at, req, seq));
                        } else {
                            let e = self.exhaust(e);
                            st.fresh_retries.remove(&req.id);
                            self.metrics.add(&self.metrics.failed, 1);
                            self.trace.record(EventKind::ReqError, req.id, 0, 0);
                            let _ = req.resp.send(Event::Error(e));
                            st.in_flight.remove(&req.id);
                        }
                        st.batcher.release_weight(weight);
                        continue;
                    }
                }
            }
            if mid_round {
                // a mid-round joiner starts its round NOW; its first
                // staged phase work rides the next fused call. Instant
                // completions/failures are delivered at the caller's
                // next reap point.
                self.metrics.add(&self.metrics.mid_round_admitted, 1);
                let a = st.active.last_mut().expect("just pushed");
                a.begin(&self.target, &self.draft);
            }
        }
    }

    /// Preempt active requests (youngest first, by admission rank) until
    /// the pools can feed every remaining request's next round. Victims
    /// spill their KV state, park their steppers and re-enter the queue
    /// at the FRONT, ahead of every priority class — preemption never
    /// costs a request its turn — and at least one request always keeps
    /// running, so undersized pools degrade to sequential execution
    /// instead of deadlock/rejection. Only legal between rounds.
    fn preempt_for_headroom(&self, st: &mut EngineState<T, D>) {
        if self.no_pools() {
            return;
        }
        while st.active.len() > 1 {
            let needs: Vec<(usize, bool)> = st
                .active
                .iter()
                .map(|a| {
                    let ar = matches!(a.stepper, AnyStepper::Ar(_));
                    (a.stepper.round_need(), !ar)
                })
                .collect();
            if self.pools_fit(&needs) {
                break;
            }
            // victim = the youngest by admission rank (swap_remove at
            // completion shuffles the list, so position is not age)
            let victim = st
                .active
                .iter()
                .enumerate()
                .max_by_key(|(_, a)| a.seq)
                .map(|(i, _)| i)
                .expect("len > 1");
            let mut a = st.active.swap_remove(victim);
            match a.stepper.suspend(&self.target, &self.draft) {
                Ok(()) => {
                    self.metrics.add(&self.metrics.preemptions, 1);
                    self.trace.record(
                        EventKind::ReqPreempt,
                        a.req.id,
                        a.stepper.committed() as u32,
                        0,
                    );
                    st.batcher.release_weight(a.weight);
                    let prev = st.parked.insert(
                        a.req.id,
                        Parked {
                            stepper: a.stepper,
                            rng: a.rng,
                            sent: a.sent,
                            seq: a.seq,
                            started: a.started,
                            queue_wait: a.queue_wait,
                            first_token_at: a.first_token_at,
                            retries: a.retries,
                        },
                    );
                    debug_assert!(prev.is_none(), "duplicate in-flight request id");
                    // rank = original admission age: victims of separate
                    // preemption passes still resume oldest-first
                    st.batcher.requeue_front(a.req, a.seq);
                }
                Err(e) => {
                    self.metrics.add(&self.metrics.failed, 1);
                    self.trace.record(EventKind::ReqError, a.req.id, 0, 0);
                    let _ = a.req.resp.send(Event::Error(EngineError::classify(&e)));
                    st.batcher.release_weight(a.weight);
                    st.in_flight.remove(&a.req.id);
                }
            }
        }
    }

    /// Stream a request's newly committed tokens (commit-boundary
    /// streaming: called right after its `feed_target` / terminal state,
    /// not at the end of the fused round).
    fn flush_tokens(&self, a: &mut Active<T, D>) {
        let committed = a.stepper.committed();
        if committed > a.sent {
            if a.first_token_at.is_none() {
                let t = a.started.elapsed().as_secs_f64();
                a.first_token_at = Some(t);
                self.metrics.record_ttft(t);
            }
            let new: Vec<u32> = a.stepper.out()[a.sent..committed].to_vec();
            self.metrics.add(&self.metrics.tokens_out, new.len() as u64);
            a.sent = committed;
            let _ = a.req.resp.send(Event::Tokens(new));
        }
    }

    /// Deliver a completed request's final report and release its
    /// resources. Dropping `a` here drops the stepper AND its sessions,
    /// which returns every KV block to the pool immediately — waiting
    /// requests see the headroom at the very next admission point.
    fn finish_request(&self, st: &mut EngineState<T, D>, a: Active<T, D>) {
        let mut stats = a.stepper.stats().clone();
        // pool-wide KV telemetry rides along in the done event
        stats.kv_pool = self.target.pool_status();
        if stats.kv_pool.is_some() {
            // hits span both model pools for tree decoders
            let pools = match &a.stepper {
                AnyStepper::Ar(_) => 1,
                _ => 2,
            };
            self.metrics
                .record_kv_hit_ratio(stats.kv_hit_tokens, a.req.prompt.len() * pools);
        }
        self.metrics.add(&self.metrics.completed, 1);
        self.metrics.add(&self.metrics.draft_calls, stats.draft_calls as u64);
        let latency = a.started.elapsed().as_secs_f64();
        self.metrics.record_latency(latency);
        self.analytics.on_done(a.first_token_at, latency, a.req.deadline_ms);
        self.trace.record(
            EventKind::ReqDone,
            a.req.id,
            stats.generated as u32,
            stats.preemptions as u32,
        );
        let report = RequestReport {
            id: a.req.id,
            stats,
            queue_wait: a.queue_wait,
            ttft: a.first_token_at,
            latency,
        };
        let _ = a.req.resp.send(Event::Done(report));
        st.batcher.release_weight(a.weight);
        st.in_flight.remove(&a.req.id);
    }

    /// Remove every request in a terminal state from the active set:
    /// graceful tail handling. Runs at each phase boundary, so a stop
    /// token or `max_tokens` exit mid-round frees its KV blocks and its
    /// concurrency slot before the next admission decision — not after
    /// the batch drains.
    fn reap(&self, st: &mut EngineState<T, D>) {
        let mut i = 0;
        while i < st.active.len() {
            if !matches!(st.active[i].state, RoundState::Done | RoundState::Failed(_)) {
                i += 1;
                continue;
            }
            let mut a = st.active.swap_remove(i);
            match std::mem::replace(&mut a.state, RoundState::Idle) {
                RoundState::Done => {
                    // terminal flush: tokens emitted by a finishing
                    // begin phase (e.g. AR's last sample) still stream
                    self.flush_tokens(&mut a);
                    self.finish_request(st, a);
                }
                RoundState::Failed(e) => self.fail_or_retry(st, a, e),
                _ => unreachable!("terminal state checked above"),
            }
        }
    }

    /// Policy point for every mid-decode fault. Cancellations and
    /// terminal faults deliver exactly one typed error and free the
    /// request's resources; retryable faults under the per-request
    /// budget instead abort the round (recycling its staged work),
    /// restore the round-entry RNG snapshot, park the stepper and
    /// schedule a deterministic-backoff re-admission — the retried
    /// request's final stream is bit-identical to a fault-free run.
    fn fail_or_retry(&self, st: &mut EngineState<T, D>, mut a: Active<T, D>, e: EngineError) {
        if e.kind == ErrorKind::Cancelled {
            self.metrics.add(&self.metrics.cancelled, 1);
            self.trace.record(EventKind::ReqError, a.req.id, 0, 0);
            let _ = a.req.resp.send(Event::Error(e));
            st.batcher.release_weight(a.weight);
            st.in_flight.remove(&a.req.id);
            // dropping `a` releases its KV blocks immediately
            return;
        }
        let mut terminal = e;
        if terminal.retryable && (a.retries as usize) < self.cfg.retry_budget {
            match a.stepper.abort_round(&self.target, &self.draft) {
                Ok(()) => {
                    // replay the aborted round's RNG draws on retry
                    if let Some(rng) = a.round_rng.take() {
                        a.rng = rng;
                    }
                    let attempt = a.retries + 1;
                    self.metrics.add(&self.metrics.retries, 1);
                    self.trace.record(
                        EventKind::ReqPreempt,
                        a.req.id,
                        a.stepper.committed() as u32,
                        attempt,
                    );
                    st.batcher.release_weight(a.weight);
                    let resume_at = st.rounds + self.backoff_rounds(attempt);
                    let prev = st.parked.insert(
                        a.req.id,
                        Parked {
                            stepper: a.stepper,
                            rng: a.rng,
                            sent: a.sent,
                            seq: a.seq,
                            started: a.started,
                            queue_wait: a.queue_wait,
                            first_token_at: a.first_token_at,
                            retries: attempt,
                        },
                    );
                    debug_assert!(prev.is_none(), "duplicate in-flight request id");
                    st.delayed.push((resume_at, a.req, a.seq));
                    return;
                }
                Err(abort_err) => {
                    // the spill itself failed: nothing left to retry
                    terminal = EngineError::new(
                        ErrorKind::Internal,
                        format!("round abort after fault ({terminal}) failed: {abort_err}"),
                    );
                }
            }
        } else {
            terminal = self.exhaust(terminal);
        }
        self.metrics.add(&self.metrics.failed, 1);
        self.trace.record(EventKind::ReqError, a.req.id, 0, 0);
        let _ = a.req.resp.send(Event::Error(terminal));
        st.batcher.release_weight(a.weight);
        st.in_flight.remove(&a.req.id);
    }

    /// Blocking serve loop. Returns when the request channel closes and
    /// all in-flight work drained.
    pub fn run(self, rx: mpsc::Receiver<Request>) -> Arc<Metrics> {
        let mut batcher = Batcher::new(self.cfg.max_concurrency, self.cfg.max_queue)
            .with_max_active_weight(self.cfg.max_active_budget);
        batcher.set_trace(&self.trace);
        let mut st = EngineState {
            batcher,
            active: Vec::new(),
            parked: HashMap::new(),
            in_flight: HashSet::new(),
            next_seq: 0,
            delayed: Vec::new(),
            fresh_retries: HashMap::new(),
            logits: LogitsBatch::default(),
            rounds: 0,
            closed: false,
        };

        loop {
            // ---- intake + idle blocking ----------------------------------
            self.intake(&rx, &mut st);
            self.apply_cancels(&mut st);
            self.update_status(&st);
            if st.active.is_empty() && st.batcher.queued() == 0 {
                if !st.delayed.is_empty() {
                    // only backoff-delayed retries remain: release them
                    // now instead of blocking on the channel (waiting
                    // longer cannot change a retry's outcome)
                    self.release_due_retries(&mut st);
                    continue;
                }
                if st.closed {
                    break;
                }
                match rx.recv() {
                    Ok(req) => self.offer_request(&mut st, req),
                    Err(_) => break,
                }
                continue; // drain any burst before starting a round
            }

            // ---- pre-round admission (deadline/priority scheduler with
            // aging; budget-weighted; KV-headroom-gated when pool-backed)
            st.batcher.age_tick();
            self.admit_ready(&mut st, false);
            if st.active.is_empty() {
                continue;
            }

            // ---- KV memory pressure: suspend + requeue before the round --
            self.preempt_for_headroom(&mut st);

            // ---- one fused round; membership churns at phase boundaries --
            self.run_round(&rx, &mut st);
            self.update_status(&st);
            self.analytics.tick(&self.metrics, st.batcher.queued(), st.active.len());

            // ---- export pool gauges (cheap; stores, not sums) ------------
            if let Some(ps) = self.target.pool_status() {
                self.metrics.set_kv_pool(&ps);
            }
        }
        self.update_status(&st);
        // clean shutdown: spill the still-resident radix index and
        // snapshot the hot prefixes, so a restarted engine pointed at
        // the same cold_dir serves system prompts without re-prefill
        self.target.persist_cold();
        self.draft.persist_cold();
        if let Some(ps) = self.target.pool_status() {
            self.metrics.set_kv_pool(&ps);
        }
        self.metrics
    }

    /// Advance every active request by one speculative round, batching
    /// all draft and target forwards across requests (see module docs)
    /// into the shared flat logits buffer. Between fused calls the
    /// engine reaps terminal requests and admits waiting ones, so batch
    /// membership changes while the round is in flight.
    fn run_round(&self, rx: &mpsc::Receiver<Request>, st: &mut EngineState<T, D>) {
        let round = st.rounds;
        let round_start = Instant::now();
        self.trace.record(
            EventKind::RoundBegin,
            round,
            st.active.len() as u32,
            st.batcher.queued() as u32,
        );
        // Wall-clock breakdown accumulators. "sched" is everything that
        // is neither a model call nor host-side verification: round
        // bookkeeping, reaping, mid-round intake + admission. Draft and
        // verify cover the fused model calls; "host" (exported as the
        // sampling phase) covers the per-request verification / commit /
        // emission work on the target rows.
        let mut sched = 0.0f64;

        // ---- phase 1: begin rounds (bookkeeping, no model calls) ---------
        let t0 = Instant::now();
        self.trace
            .record(EventKind::PhaseBegin, round, PHASE_SCHED, st.active.len() as u32);
        for a in st.active.iter_mut() {
            // non-Idle entries were marked by a cancellation between
            // rounds; leave them for the reap below
            if matches!(a.state, RoundState::Idle) {
                a.begin(&self.target, &self.draft);
            }
        }
        self.reap(st);
        self.trace
            .record(EventKind::PhaseEnd, round, PHASE_SCHED, st.active.len() as u32);
        self.trace.phase_advanced();
        sched += t0.elapsed().as_secs_f64();

        // ---- phase 2: fused draft levels ---------------------------------
        // Requests at different tree depths drop out of later iterations;
        // each iteration is ONE fused draft forward across the rest. New
        // arrivals join at the top of every iteration.
        let mut level: u32 = 0;
        loop {
            let ts = Instant::now();
            if !self.cfg.drain_batching {
                self.intake(rx, st);
                self.admit_ready(st, true);
            }
            self.apply_cancels(st);
            let in_round = st
                .active
                .iter()
                .filter(|a| matches!(a.state, RoundState::InRound))
                .count();
            let EngineState { active, logits, .. } = &mut *st;
            let mut groups: Vec<(&mut D::Session, &[EvalNode])> = Vec::new();
            let mut who: Vec<usize> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                if !matches!(a.state, RoundState::InRound) {
                    continue;
                }
                let g = match &mut a.stepper {
                    AnyStepper::Spec(s) => s.draft_group(),
                    AnyStepper::Adaptive(s) => s.draft_group(),
                    AnyStepper::Ar(_) => None,
                };
                if let Some(g) = g {
                    groups.push(g);
                    who.push(i);
                }
            }
            sched += ts.elapsed().as_secs_f64();
            if groups.is_empty() {
                break;
            }
            // one draft tree level across every participating request;
            // the level rides in the phase code's high bits and the
            // staged node count in the event's `c` payload
            let code = PHASE_DRAFT | (level << 8);
            let staged: u32 = groups.iter().map(|(_, n)| n.len() as u32).sum();
            self.trace
                .record_c(EventKind::PhaseBegin, round, code, who.len() as u32, staged);
            let td = Instant::now();
            let results = eval_phase(&self.draft, self.cfg.fused, &mut groups, logits);
            drop(groups);
            self.metrics.record_fused(who.len(), in_round);
            for (res, &i) in results.into_iter().zip(who.iter()) {
                let a = &mut active[i];
                match res {
                    Ok(range) => {
                        let rows_i = logits.view(range);
                        let fed = match &mut a.stepper {
                            AnyStepper::Spec(s) => s.feed_draft(rows_i, &mut a.rng),
                            AnyStepper::Adaptive(s) => s.feed_draft(rows_i, &mut a.rng),
                            AnyStepper::Ar(_) => unreachable!("AR stages no draft work"),
                        };
                        if let Err(e) = fed {
                            a.state = RoundState::Failed(EngineError::classify(&e));
                        }
                    }
                    Err(e) => a.state = RoundState::Failed(e),
                }
            }
            self.metrics.record_phase(code, td.elapsed().as_secs_f64());
            self.trace
                .record_c(EventKind::PhaseEnd, round, code, who.len() as u32, staged);
            self.trace.phase_advanced();
            let tr = Instant::now();
            self.reap(st);
            sched += tr.elapsed().as_secs_f64();
            level += 1;
        }

        // ---- phase 3: one fused target pass (verification) ---------------
        let ts = Instant::now();
        self.apply_cancels(st);
        let in_round = st
            .active
            .iter()
            .filter(|a| matches!(a.state, RoundState::InRound))
            .count();
        let EngineState { active, logits, .. } = &mut *st;
        let mut groups: Vec<(&mut T::Session, &[EvalNode])> = Vec::new();
        let mut who: Vec<usize> = Vec::new();
        for (i, a) in active.iter_mut().enumerate() {
            if !matches!(a.state, RoundState::InRound) {
                continue;
            }
            let g = match &mut a.stepper {
                AnyStepper::Ar(s) => s.target_group(),
                AnyStepper::Spec(s) => s.target_group(),
                AnyStepper::Adaptive(s) => s.target_group(),
            };
            match g {
                Some(g) => {
                    groups.push(g);
                    who.push(i);
                }
                None => {
                    a.state = RoundState::Failed(EngineError::new(
                        ErrorKind::Internal,
                        "round staged no target work",
                    ))
                }
            }
        }
        sched += ts.elapsed().as_secs_f64();
        if !groups.is_empty() {
            let staged: u32 = groups.iter().map(|(_, n)| n.len() as u32).sum();
            self.trace
                .record_c(EventKind::PhaseBegin, round, PHASE_VERIFY, who.len() as u32, staged);
            let tv = Instant::now();
            let results = eval_phase(&self.target, self.cfg.fused, &mut groups, logits);
            drop(groups);
            self.metrics.record_phase(PHASE_VERIFY, tv.elapsed().as_secs_f64());
            self.trace
                .record_c(EventKind::PhaseEnd, round, PHASE_VERIFY, who.len() as u32, staged);
            self.metrics.record_fused(who.len(), in_round);
            // host-side verification + commit + emission ("sampling")
            self.trace.record(EventKind::PhaseBegin, round, PHASE_HOST, who.len() as u32);
            let th = Instant::now();
            for (res, &i) in results.into_iter().zip(who.iter()) {
                let a = &mut active[i];
                let rows_i = match res {
                    Ok(range) => logits.view(range),
                    Err(e) => {
                        a.state = RoundState::Failed(e);
                        continue;
                    }
                };
                let fed = match &mut a.stepper {
                    AnyStepper::Ar(s) => s.feed_target(&self.target, rows_i),
                    AnyStepper::Spec(s) => {
                        s.feed_target(&self.target, &self.draft, rows_i, &mut a.rng)
                    }
                    AnyStepper::Adaptive(s) => {
                        s.feed_target(&self.target, &self.draft, rows_i, &mut a.rng)
                    }
                };
                a.state = match fed {
                    Ok(StepOutcome::Progress) => RoundState::Idle,
                    Ok(StepOutcome::Done) => RoundState::Done,
                    Err(e) => RoundState::Failed(EngineError::classify(&e)),
                };
                if !matches!(a.state, RoundState::Failed(_)) {
                    self.metrics.add(&self.metrics.decode_rounds, 1);
                    // commit-boundary streaming: this request's tokens go
                    // out NOW, before the rest of the batch is processed
                    self.flush_tokens(a);
                    if let Some(report) = a.stepper.last_round() {
                        self.metrics.record_round(report);
                    }
                }
            }
            self.metrics.record_phase(PHASE_HOST, th.elapsed().as_secs_f64());
            self.trace.record(EventKind::PhaseEnd, round, PHASE_HOST, who.len() as u32);
            self.trace.phase_advanced();
        }
        // graceful tail: stop-token / max_tokens completions free their
        // KV blocks and slots here, before the next admission point
        let tr = Instant::now();
        self.reap(st);
        sched += tr.elapsed().as_secs_f64();
        self.metrics.record_phase(PHASE_SCHED, sched);
        self.metrics.record_round_time(round_start.elapsed().as_secs_f64());
        st.rounds += 1;
    }
}

/// Spawn the engine on a dedicated thread; returns the submission handle
/// and a handle resolving to the final metrics.
pub fn spawn<T, D>(
    engine: Engine<T, D>,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Arc<Metrics>>)
where
    T: Llm + Send + 'static,
    D: Llm + Send + 'static,
    T::Session: Send,
    D::Session: Send,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || engine.run(rx));
    (tx, handle)
}

/// Spawn an engine whose LMs are not `Send` (the PJRT model holds raw
/// FFI handles): the constructor runs *inside* the engine thread, so the
/// models never cross a thread boundary.
pub fn spawn_with<F, T, D>(
    make: F,
) -> (mpsc::Sender<Request>, std::thread::JoinHandle<Result<Arc<Metrics>>>)
where
    F: FnOnce() -> Result<Engine<T, D>> + Send + 'static,
    T: Llm + 'static,
    D: Llm + 'static,
{
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let engine = make()?;
        Ok(engine.run(rx))
    });
    (tx, handle)
}
