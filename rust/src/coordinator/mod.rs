//! The serving coordinator (Layer 3): request admission, continuous
//! batching at phase granularity, per-request decode state, metrics,
//! and the TCP front-end.
//!
//! Structure follows the vLLM router/engine split: [`batcher::Batcher`]
//! owns the admission queue and scheduling policy (priority classes,
//! deadlines, anti-starvation aging, weighted admission);
//! [`engine::Engine`] owns the models and advances every active session
//! one speculative round per turn in lockstep phases, fusing all
//! draft/target forwards across requests into one `eval_batch` call per
//! phase — with batch membership churning at every phase boundary:
//! arrivals join mid-round, completions free their KV immediately, and
//! each request's committed tokens stream at its own commit boundary
//! (so a long request cannot starve others, and the hardware batch
//! dimension never idles); [`server`] is a thin JSON-lines TCP
//! front-end; [`metrics`] aggregates the serving statistics (incl.
//! fused-batch, queue-wait and TTFT telemetry) the benches report.

pub mod batcher;
pub mod engine;
pub mod errors;
pub mod metrics;
pub mod server;

pub use batcher::Batcher;
pub use engine::{CancelRegistry, Engine, Event, Request};
pub use errors::{EngineError, ErrorKind};
pub use metrics::Metrics;
