//! The serving coordinator (Layer 3): request admission, continuous
//! batching at speculative-round granularity, per-request decode state,
//! metrics, and the TCP front-end.
//!
//! Structure follows the vLLM router/engine split: [`batcher::Batcher`]
//! owns the admission queue and fairness policy; [`engine::Engine`] owns
//! the models and advances every active session one speculative round
//! per turn in lockstep phases, fusing all draft/target forwards across
//! requests into one `eval_batch` call per phase (so a long request
//! cannot starve others, and the hardware batch dimension never idles);
//! [`server`] is a thin JSON-lines TCP front-end; [`metrics`] aggregates
//! the serving statistics (incl. fused-batch telemetry) the benches
//! report.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::Batcher;
pub use engine::{Engine, Event, Request};
pub use metrics::Metrics;
