//! JSON-lines TCP front-end over the engine (std::net, thread per
//! connection — the offline build has no async runtime, and the engine
//! core is synchronous anyway).
//!
//! Protocol: one request object per line:
//!   {"prompt": "text", "max_tokens": 32, "decoder": "rsd-s:3x3"?,
//!    "temperature": 0.3?, "top_p": 1.0?, "stop": [10]?,
//!    "priority": 0?, "deadline_ms": 250?, "stream": true?}
//!
//! "stop" is an array of token ids (the tokenizer is byte-level, so an
//! id is a byte value, e.g. 10 = "\n"); generation ends at the first
//! generated occurrence of any of them. The stop token itself is not
//! returned, and accepted draft tokens after it are dropped.
//! "temperature" / "top_p" / "stop" are independent per-field overrides:
//! any field a request leaves out inherits the engine's configured
//! sampling (see [`crate::config::SamplingPatch`]).
//!
//! "priority" (0-255, default 0) picks the scheduling class: higher
//! admits first, with queue aging guaranteeing low classes never starve.
//! "deadline_ms" declares a latency budget: among equal effective
//! priorities the tightest deadline admits first. Both are scheduling
//! hints only — they never change the request's tokens.
//!
//! The optional "decoder" field accepts every spec string of
//! [`crate::config::DecoderConfig`]:
//!   ar | sd:L | spectr:KxL | rsd-c:B-B-.. | rsd-s:WxL
//!   adaptive:B | adaptive:B:rsd-c | adaptive:B:rsd-s
//! `adaptive:B` enables per-request online tree shaping under the hard
//! per-round node budget B ([`crate::adaptive`]); different connections
//! may use different budgets concurrently (the engine's weighted
//! admission keeps them fair, see `EngineConfig::max_active_budget`).
//!
//! Streamed responses, one object per line. Default framing batches each
//! commit boundary into one fragment:
//!   {"tokens": "generated fragment"}
//! With `"stream": true` every committed token is its own event, tagged
//! with its position in the stream:
//!   {"token": "t", "index": 3}
//! Either way events are emitted as the engine commits them (per
//! speculative round), never buffered to the end, and the line stream
//! finishes with exactly one of
//!   {"done": {"id": n, "generated": n, "block_efficiency": x,
//!             "accept_rate_by_level": [..],
//!             "nodes_per_round_hist": {"nodes": rounds, ..}, ...}}
//!   {"error": {"code": "...", "retryable": bool, "message": "..."},
//!    "id": n?}
//! The "done" payload carries the controller telemetry for the request:
//! empirical acceptance rate per tree level, the histogram of
//! draft-tree nodes the target processed per round (always <= B for
//! adaptive decoders), and a "timeline" object with the request's
//! scheduling summary (queue_wait_secs / ttft_secs / latency_secs,
//! all measured from arrival). Error payloads are structured: "code" is
//! a stable snake_case [`crate::coordinator::ErrorKind`] code and
//! "retryable" is the engine's own verdict (e.g. `queue_full` and
//! `deadline_expired` are worth resubmitting, `invalid_request` is not).
//!
//! A request may carry its own "id" (a nonzero integer): it names the
//! request in "done"/"error" events and — the point — makes it
//! addressable by the `cancel` command below, including from another
//! connection. Client-chosen ids live in the upper half of the id
//! space (the server ORs in a high bit) so they can never collide with
//! server-assigned ones; an id already in flight is answered with a
//! typed `invalid_request` error.
//!
//! Admin commands share the line protocol (any object with a "cmd"
//! field is a command, never a generation request):
//!   {"cmd": "metrics"}          → {"metrics": {..full snapshot..}}
//!   {"cmd": "trace"}            → {"trace": {..chrome trace-event json..},
//!                                  "prometheus": "..text exposition.."}
//!   {"cmd": "cancel", "id": n}  → {"cancelled": n}
//! `trace` answers an error object unless the engine was started with
//! tracing enabled ("trace_events" > 0 in the engine config). `cancel`
//! marks the id in the engine's cancel registry; the request itself
//! (wherever it is — queued, mid-round, parked) receives one terminal
//! `cancelled` error at the engine's next phase boundary. Cancelling an
//! unknown or finished id is a harmless no-op (still acknowledged).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{parse_stop_tokens, DecoderConfig, SamplingPatch};
use crate::tokenizer::Tokenizer;
use crate::trace::export::{chrome_trace, prometheus};
use crate::trace::Tracer;
use crate::util::Json;

use super::engine::{CancelRegistry, Event, Request, RequestReport};
use super::errors::{EngineError, ErrorKind};
use super::metrics::Metrics;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Client-chosen request ids are mapped into the upper half of the id
/// space so they can never collide with the server's own counter.
const CLIENT_ID_BIT: u64 = 1 << 63;

/// Server-side telemetry handles, shared by every connection: the
/// metrics registry the engine updates (the `metrics` wire command),
/// the flight-recorder tracer (the `trace` wire command) and the
/// engine's cancellation registry (the `cancel` wire command). All
/// default to absent/off — the matching commands then answer with an
/// error object instead of data.
#[derive(Clone, Default)]
pub struct ServeCtx {
    pub metrics: Option<Arc<Metrics>>,
    pub trace: Tracer,
    pub cancels: Option<CancelRegistry>,
    /// Speculation-analytics handle shared with the engine (the
    /// `stats` wire command; appended to the `trace` exposition).
    pub analytics: crate::obs::Analytics,
}

/// Serve forever. `submit` feeds the engine thread; `ctx` carries the
/// telemetry handles the admin commands expose.
pub fn serve(addr: &str, submit: mpsc::Sender<Request>, ctx: ServeCtx) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("rsd: serving on {addr}");
    serve_listener(listener, submit, ctx)
}

/// Serve an already-bound listener (tests bind port 0 and keep the
/// resolved address; production goes through [`serve`]).
pub fn serve_listener(
    listener: TcpListener,
    submit: mpsc::Sender<Request>,
    ctx: ServeCtx,
) -> Result<()> {
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("rsd: accept error: {e}");
                continue;
            }
        };
        let submit = submit.clone();
        let ctx = ctx.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
            if let Err(e) = handle_conn(stream, submit, ctx) {
                eprintln!("rsd: connection {peer} ended: {e}");
            }
        });
    }
    Ok(())
}

fn send_line(wr: &mut TcpStream, msg: &Json) -> Result<()> {
    wr.write_all(msg.to_string().as_bytes())?;
    wr.write_all(b"\n")?;
    Ok(())
}

/// Structured error envelope: `{"error": {code, retryable, message}}`,
/// optionally tagged with the request's wire id.
fn wire_error(e: &EngineError, id: Option<u64>) -> Json {
    let mut fields = vec![("error", e.to_wire())];
    if let Some(id) = id {
        fields.push(("id", (wire_id(id) as usize).into()));
    }
    Json::obj(fields)
}

/// Protocol-level failures (unparseable line, unknown command, missing
/// capability) as the same structured envelope.
fn err_json(e: impl std::fmt::Display) -> Json {
    wire_error(&EngineError::new(ErrorKind::InvalidRequest, e.to_string()), None)
}

/// The id a client sees: its own id for client-tagged requests,
/// the server counter otherwise.
fn wire_id(internal: u64) -> u64 {
    internal & !CLIENT_ID_BIT
}

/// One parsed wire request (everything the engine's [`Request`] needs,
/// plus connection-local framing preferences). Public so protocol
/// robustness tests can drive the parser directly, without a socket.
#[derive(Debug)]
pub struct WireRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub decoder: Option<DecoderConfig>,
    pub sampling: Option<SamplingPatch>,
    pub priority: u8,
    pub deadline_ms: Option<u64>,
    /// Per-token streaming: one `{"token", "index"}` event per committed
    /// token instead of per-commit `{"tokens"}` fragments.
    pub stream: bool,
    /// Client-chosen id (already mapped into the client id space); the
    /// handle the `cancel` command addresses.
    pub id: Option<u64>,
}

/// Parse one request line. Must never panic, whatever the bytes: the
/// fuzz suite in `tests/protocol.rs` holds it to that.
pub fn parse_wire_request(line: &str, tok: &Tokenizer) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    let prompt_text = j.str_field("prompt")?;
    let prompt = tok.encode(prompt_text);
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_tokens").and_then(Json::as_usize).unwrap_or(64).min(192);
    let decoder = match j.get("decoder").and_then(Json::as_str) {
        Some(s) => Some(s.parse()?),
        None => None,
    };
    let mut patch = SamplingPatch::default();
    if let Some(t) = j.get("temperature").and_then(Json::as_f64) {
        patch.temperature = Some(t as f32);
    }
    if let Some(p) = j.get("top_p").and_then(Json::as_f64) {
        patch.top_p = Some(p as f32);
    }
    if let Some(arr) = j.get("stop").and_then(Json::as_arr) {
        patch.stop = Some(parse_stop_tokens(arr)?);
    }
    let priority = match j.get("priority").and_then(Json::as_usize) {
        Some(p) if p > u8::MAX as usize => {
            anyhow::bail!("priority {p} out of range 0..=255")
        }
        Some(p) => p as u8,
        None => 0,
    };
    let deadline_ms = j.get("deadline_ms").and_then(Json::as_usize).map(|v| v as u64);
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let id = match j.get("id").and_then(Json::as_usize) {
        Some(0) => anyhow::bail!("id must be a nonzero integer"),
        Some(n) => Some(CLIENT_ID_BIT | n as u64),
        None => match j.get("id") {
            Some(_) => anyhow::bail!("id must be a nonzero integer"),
            None => None,
        },
    };
    let sampling = if patch.is_empty() { None } else { Some(patch) };
    Ok(WireRequest { prompt, max_new, decoder, sampling, priority, deadline_ms, stream, id })
}

/// Answer an admin command line (`{"cmd": "..."}`, full object in `j`
/// for argument-carrying commands). Factored out of the connection loop
/// so the protocol is testable without a socket.
pub(crate) fn command_response(cmd: &str, j: &Json, ctx: &ServeCtx) -> Json {
    match cmd {
        // full metrics snapshot (counters, gauges, histogram summaries)
        "metrics" => match &ctx.metrics {
            Some(m) => Json::obj(vec![("metrics", m.snapshot().to_json())]),
            None => err_json("metrics unavailable on this server"),
        },
        // flight-recorder dump: the journal as a Chrome trace-event
        // document (load in chrome://tracing / Perfetto) plus, when
        // metrics are attached, a Prometheus text exposition
        "trace" => {
            if !ctx.trace.enabled() {
                return err_json("tracing disabled (set \"trace_events\" in the engine config)");
            }
            let mut fields = vec![("trace", chrome_trace(&ctx.trace.snapshot()))];
            if let Some(m) = &ctx.metrics {
                let mut text = prometheus(&m.snapshot());
                // the analytics series ride the same exposition
                text.push_str(&ctx.analytics.prometheus());
                fields.push(("prometheus", Json::Str(text)));
            }
            Json::obj(fields)
        }
        // windowed speculation analytics: per-level acceptance,
        // accepted-tokens-per-target-forward, throughput/SLO trends
        // over the last `window` completed stats windows (default 1)
        "stats" => {
            if !ctx.analytics.enabled() {
                return err_json(
                    "analytics disabled (set \"stats_window_rounds\" in the engine config)",
                );
            }
            let window = j.get("window").and_then(Json::as_usize).unwrap_or(1).max(1);
            Json::obj(vec![("stats", ctx.analytics.stats_json(window))])
        }
        // mark a request id for cancellation at the engine's next phase
        // boundary; the addressed request receives its own terminal
        // `cancelled` error on whatever connection submitted it
        "cancel" => {
            let Some(reg) = &ctx.cancels else {
                return err_json("cancellation unavailable on this server");
            };
            match j.get("id").and_then(Json::as_usize) {
                Some(n) if n > 0 => {
                    reg.request(CLIENT_ID_BIT | n as u64);
                    Json::obj(vec![("cancelled", n.into())])
                }
                _ => err_json("cancel requires a nonzero integer \"id\""),
            }
        }
        other => err_json(format!("unknown command '{other}'")),
    }
}

pub(crate) fn done_json(report: &RequestReport) -> Json {
    let stats = &report.stats;
    // controller telemetry: per-level acceptance rates ...
    let accept_rate_by_level = Json::Arr(
        stats
            .level_attempts
            .iter()
            .zip(&stats.level_accepts)
            .map(|(&n, &s)| Json::Num(if n == 0 { 0.0 } else { s as f64 / n as f64 }))
            .collect(),
    );
    // ... and the nodes-per-round histogram (actual budget trajectory)
    let mut hist = std::collections::BTreeMap::new();
    for &nodes in &stats.round_nodes {
        *hist.entry(nodes.to_string()).or_insert(0u64) += 1;
    }
    let nodes_hist =
        Json::Obj(hist.into_iter().map(|(k, v)| (k, Json::Num(v as f64))).collect());
    let mut fields = vec![
        ("id", (wire_id(report.id) as usize).into()),
        ("generated", stats.generated.into()),
        ("block_efficiency", stats.block_efficiency().into()),
        ("decode_calls", stats.decode_calls.into()),
        ("draft_calls", stats.draft_calls.into()),
        ("accepted", stats.accepted_draft_tokens.into()),
        ("bonus_rounds", stats.bonus_tokens.into()),
        ("tree_nodes", stats.tree_nodes.into()),
        ("accept_rate_by_level", accept_rate_by_level),
        ("nodes_per_round_hist", nodes_hist),
        ("kv_hit_tokens", stats.kv_hit_tokens.into()),
        ("preemptions", stats.preemptions.into()),
    ];
    // pool-wide paged-KV telemetry (engine-attached; absent on dense
    // substrates and single-shot decodes)
    if let Some(ps) = &stats.kv_pool {
        fields.push((
            "kv_pool",
            Json::obj(vec![
                ("hit_rate", ps.stats.hit_rate().into()),
                ("hit_tokens", (ps.stats.hit_tokens as usize).into()),
                ("lookup_tokens", (ps.stats.lookup_tokens as usize).into()),
                ("cow_copies", (ps.stats.cow_copies as usize).into()),
                ("evictions", (ps.stats.evictions as usize).into()),
                ("blocks_in_use", ps.blocks_in_use().into()),
                ("blocks_total", ps.total_blocks.into()),
                // cold-tier traffic (all zero when no "cold_dir" is
                // configured): spills to disk, revivals and the tokens
                // of prefill they saved, and corrupt blocks dropped
                ("cold_spills", (ps.stats.cold_spills as usize).into()),
                ("cold_hits", (ps.stats.cold_hits as usize).into()),
                ("cold_hit_tokens", (ps.stats.cold_hit_tokens as usize).into()),
                ("cold_misses", (ps.stats.cold_misses as usize).into()),
                ("cold_corrupt", (ps.stats.cold_corrupt as usize).into()),
                ("cold_hit_rate", ps.stats.cold_hit_rate().into()),
            ]),
        ));
    }
    // compute-budget attribution: what this request cost the target
    // model and what each unit of that budget bought (the paper's
    // accepted-tokens-per-target-forward, per request)
    let per_forward = |n: usize| {
        if stats.decode_calls == 0 {
            0.0
        } else {
            n as f64 / stats.decode_calls as f64
        }
    };
    fields.push((
        "budget",
        Json::obj(vec![
            ("target_forwards", stats.decode_calls.into()),
            ("tree_nodes", stats.tree_nodes.into()),
            ("accepted_per_forward", per_forward(stats.accepted_draft_tokens).into()),
            ("tokens_per_forward", per_forward(stats.generated).into()),
            ("nodes_per_forward", per_forward(stats.tree_nodes).into()),
        ]),
    ));
    fields.push(("wall_secs", stats.wall.as_secs_f64().into()));
    // per-request scheduling timeline (queue → first token → done), all
    // seconds from arrival
    fields.push((
        "timeline",
        Json::obj(vec![
            ("queue_wait_secs", report.queue_wait.into()),
            ("ttft_secs", report.ttft.map_or(Json::Null, Json::Num)),
            ("latency_secs", report.latency.into()),
        ]),
    ));
    Json::obj(vec![("done", Json::obj(fields))])
}

/// One per-token streaming event (`"stream": true` framing).
pub(crate) fn token_json(tok: &Tokenizer, token: u32, index: usize) -> Json {
    Json::obj(vec![
        ("token", Json::Str(tok.decode(&[token]))),
        ("index", index.into()),
    ])
}

fn handle_conn(stream: TcpStream, submit: mpsc::Sender<Request>, ctx: ServeCtx) -> Result<()> {
    let mut wr = stream.try_clone()?;
    let rd = BufReader::new(stream);
    let tok = Tokenizer::new();
    for line in rd.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // admin commands share the line protocol with generation
        // requests: {"cmd": "metrics"} / {"cmd": "trace"} /
        // {"cmd": "cancel", "id": n}
        if let Ok(j) = Json::parse(&line) {
            if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
                send_line(&mut wr, &command_response(cmd, &j, &ctx))?;
                continue;
            }
        }
        let wire = match parse_wire_request(&line, &tok) {
            Ok(x) => x,
            Err(e) => {
                send_line(&mut wr, &err_json(format!("bad request: {e}")))?;
                continue;
            }
        };
        let per_token = wire.stream;
        let (tx, rx) = mpsc::channel();
        let id = wire.id.unwrap_or_else(|| NEXT_ID.fetch_add(1, Ordering::Relaxed));
        let req = Request {
            id,
            prompt: wire.prompt,
            max_new: wire.max_new,
            decoder: wire.decoder,
            sampling: wire.sampling,
            priority: wire.priority,
            deadline_ms: wire.deadline_ms,
            resp: tx,
        };
        if submit.send(req).is_err() {
            let e = EngineError::new(ErrorKind::Internal, "engine stopped");
            send_line(&mut wr, &wire_error(&e, Some(id)))?;
            return Ok(());
        }
        let mut emitted = 0usize;
        while let Ok(ev) = rx.recv() {
            match ev {
                Event::Tokens(ts) => {
                    if per_token {
                        for &t in &ts {
                            send_line(&mut wr, &token_json(&tok, t, emitted))?;
                            emitted += 1;
                        }
                    } else {
                        emitted += ts.len();
                        let msg = Json::obj(vec![("tokens", Json::Str(tok.decode(&ts)))]);
                        send_line(&mut wr, &msg)?;
                    }
                }
                Event::Done(report) => {
                    send_line(&mut wr, &done_json(&report))?;
                    break;
                }
                Event::Error(e) => {
                    send_line(&mut wr, &wire_error(&e, Some(id)))?;
                    break;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_request_parses_full_form() {
        let tok = Tokenizer::new();
        let w = parse_wire_request(
            r#"{"prompt": "hello", "max_tokens": 9, "decoder": "rsd-c:2-2", "temperature": 0.5}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(w.prompt.len(), 5);
        assert_eq!(w.max_new, 9);
        assert_eq!(w.decoder, Some(crate::config::DecoderConfig::RsdC { branches: vec![2, 2] }));
        let samp = w.sampling.unwrap();
        assert!((samp.temperature.unwrap() - 0.5).abs() < 1e-6);
        // unset fields stay None: they inherit the engine's sampling
        assert!(samp.top_p.is_none());
        assert!(samp.stop.is_none());
    }

    #[test]
    fn wire_request_defaults() {
        let tok = Tokenizer::new();
        let w = parse_wire_request(r#"{"prompt": "hi"}"#, &tok).unwrap();
        assert_eq!(w.max_new, 64);
        assert!(w.decoder.is_none());
        assert!(w.sampling.is_none());
        assert_eq!(w.priority, 0);
        assert!(w.deadline_ms.is_none());
        assert!(!w.stream, "per-commit fragments are the default framing");
    }

    #[test]
    fn wire_request_parses_scheduling_and_streaming_fields() {
        let tok = Tokenizer::new();
        let w = parse_wire_request(
            r#"{"prompt": "hi", "priority": 7, "deadline_ms": 250, "stream": true}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(w.priority, 7);
        assert_eq!(w.deadline_ms, Some(250));
        assert!(w.stream);
        // scheduling hints never touch sampling
        assert!(w.sampling.is_none());
        // out-of-range priority is a clean parse error, not a lossy cast
        assert!(parse_wire_request(r#"{"prompt": "hi", "priority": 300}"#, &tok).is_err());
    }

    #[test]
    fn wire_request_parses_stop_tokens() {
        let tok = Tokenizer::new();
        let w = parse_wire_request(r#"{"prompt": "hi", "stop": [10, 0]}"#, &tok).unwrap();
        let samp = w.sampling.unwrap();
        assert_eq!(samp.stop, Some(vec![10, 0]));
        // only "stop" was set: temperature/top_p inherit the engine's
        assert!(samp.temperature.is_none());
        assert!(samp.top_p.is_none());
        // invalid stop entries are rejected
        assert!(parse_wire_request(r#"{"prompt": "hi", "stop": ["x"]}"#, &tok).is_err());
    }

    #[test]
    fn wire_request_rejects_bad() {
        let tok = Tokenizer::new();
        assert!(parse_wire_request(r#"{"max_tokens": 2}"#, &tok).is_err());
        assert!(parse_wire_request("not json", &tok).is_err());
    }

    #[test]
    fn wire_request_parses_adaptive_decoder() {
        let tok = Tokenizer::new();
        let w =
            parse_wire_request(r#"{"prompt": "hi", "decoder": "adaptive:30"}"#, &tok).unwrap();
        assert_eq!(
            w.decoder,
            Some(crate::config::DecoderConfig::Adaptive {
                budget: 30,
                family: crate::config::AdaptiveFamily::Auto,
            })
        );
        assert!(parse_wire_request(r#"{"prompt": "hi", "decoder": "adaptive:0"}"#, &tok).is_err());
    }

    #[test]
    fn token_events_carry_text_and_index() {
        let tok = Tokenizer::new();
        let j = token_json(&tok, b'a' as u32, 4);
        assert_eq!(j.get("token").and_then(Json::as_str), Some("a"));
        assert_eq!(j.get("index").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn metrics_command_returns_full_snapshot() {
        let metrics = Arc::new(Metrics::default());
        metrics.add(&metrics.admitted, 3);
        metrics.add(&metrics.completed, 2);
        metrics.record_latency(0.25);
        let ctx =
            ServeCtx { metrics: Some(metrics), trace: Tracer::off(), ..Default::default() };
        let j = command_response("metrics", &Json::Null, &ctx);
        // the reply must parse back and carry the full snapshot
        let j = Json::parse(&j.to_string()).unwrap();
        let m = j.get("metrics").expect("metrics object");
        assert_eq!(m.get("admitted").and_then(Json::as_usize), Some(3));
        assert_eq!(m.get("completed").and_then(Json::as_usize), Some(2));
        let lat = m.get("latency").expect("latency summary");
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(1));
        // no metrics attached → an error object, not a panic
        let none = command_response("metrics", &Json::Null, &ServeCtx::default());
        assert!(none.get("error").is_some());
    }

    #[test]
    fn trace_command_dumps_journal_and_prometheus() {
        let trace = Tracer::new(64);
        trace.record(crate::trace::EventKind::ReqArrive, 1, 5, 0);
        trace.record(crate::trace::EventKind::ReqDone, 1, 8, 0);
        let ctx = ServeCtx {
            metrics: Some(Arc::new(Metrics::default())),
            trace,
            ..Default::default()
        };
        let j = command_response("trace", &Json::Null, &ctx);
        let j = Json::parse(&j.to_string()).unwrap();
        let events =
            j.get("trace").and_then(|t| t.get("traceEvents")).and_then(Json::as_arr).unwrap();
        // metadata event + the two recorded events
        assert_eq!(events.len(), 3);
        let prom = j.get("prometheus").and_then(Json::as_str).unwrap();
        assert!(prom.contains("rsd_requests_completed_total"));
        // tracing off → an error object
        let off = command_response("trace", &Json::Null, &ServeCtx::default());
        assert!(off.get("error").is_some());
        // unknown commands answer cleanly too
        assert!(command_response("bogus", &Json::Null, &ctx).get("error").is_some());
    }

    #[test]
    fn cancel_command_marks_the_mapped_id() {
        let reg = CancelRegistry::default();
        let ctx = ServeCtx {
            metrics: None,
            trace: Tracer::off(),
            cancels: Some(reg.clone()),
            ..Default::default()
        };
        let line = Json::parse(r#"{"cmd": "cancel", "id": 7}"#).unwrap();
        let j = command_response("cancel", &line, &ctx);
        assert_eq!(j.get("cancelled").and_then(Json::as_usize), Some(7));
        // no id / zero id / no registry are clean error objects
        let noid = Json::parse(r#"{"cmd": "cancel"}"#).unwrap();
        assert!(command_response("cancel", &noid, &ctx).get("error").is_some());
        let zero = Json::parse(r#"{"cmd": "cancel", "id": 0}"#).unwrap();
        assert!(command_response("cancel", &zero, &ctx).get("error").is_some());
        assert!(command_response("cancel", &line, &ServeCtx::default())
            .get("error")
            .is_some());
    }

    #[test]
    fn client_ids_map_into_the_upper_half_and_back() {
        let tok = Tokenizer::new();
        let w = parse_wire_request(r#"{"prompt": "hi", "id": 42}"#, &tok).unwrap();
        let internal = w.id.unwrap();
        assert_eq!(internal, CLIENT_ID_BIT | 42);
        assert_eq!(wire_id(internal), 42);
        // zero and non-numeric ids are clean parse errors
        assert!(parse_wire_request(r#"{"prompt": "hi", "id": 0}"#, &tok).is_err());
        assert!(parse_wire_request(r#"{"prompt": "hi", "id": "x"}"#, &tok).is_err());
    }

    #[test]
    fn error_envelope_is_structured() {
        let e = EngineError::new(ErrorKind::QueueFull, "queue full (9 waiting)");
        let j = wire_error(&e, Some(CLIENT_ID_BIT | 3));
        let inner = j.get("error").expect("error object");
        assert_eq!(inner.get("code").and_then(Json::as_str), Some("queue_full"));
        assert_eq!(inner.get("retryable").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(3));
        // protocol-level failures use the same envelope
        let pe = err_json("bad request: not json");
        let inner = pe.get("error").expect("error object");
        assert_eq!(inner.get("code").and_then(Json::as_str), Some("invalid_request"));
    }

    #[test]
    fn done_event_carries_controller_telemetry() {
        let report = RequestReport {
            id: 9,
            stats: crate::decode::DecodeStats {
                generated: 10,
                decode_calls: 4,
                level_attempts: vec![4, 3],
                level_accepts: vec![3, 1],
                round_nodes: vec![6, 6, 4, 6],
                ..Default::default()
            },
            queue_wait: 0.05,
            ttft: Some(0.2),
            latency: 1.5,
        };
        let j = done_json(&report);
        let done = j.get("done").unwrap();
        assert_eq!(done.get("id").and_then(Json::as_usize), Some(9));
        let rates = done.get("accept_rate_by_level").and_then(Json::as_arr).unwrap();
        assert_eq!(rates.len(), 2);
        assert!((rates[0].as_f64().unwrap() - 0.75).abs() < 1e-12);
        let hist = done.get("nodes_per_round_hist").and_then(Json::as_obj).unwrap();
        assert_eq!(hist.get("6").and_then(Json::as_usize), Some(3));
        assert_eq!(hist.get("4").and_then(Json::as_usize), Some(1));
        // the scheduling timeline rides along
        let tl = done.get("timeline").expect("timeline object");
        assert!((tl.get("queue_wait_secs").and_then(Json::as_f64).unwrap() - 0.05).abs() < 1e-12);
        assert!((tl.get("ttft_secs").and_then(Json::as_f64).unwrap() - 0.2).abs() < 1e-12);
        assert!((tl.get("latency_secs").and_then(Json::as_f64).unwrap() - 1.5).abs() < 1e-12);
    }
}
