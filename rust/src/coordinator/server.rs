//! JSON-lines TCP front-end over the engine (std::net, thread per
//! connection — the offline build has no async runtime, and the engine
//! core is synchronous anyway).
//!
//! Protocol: one request object per line:
//!   {"prompt": "text", "max_tokens": 32, "decoder": "rsd-s:3x3"?,
//!    "temperature": 0.3?, "top_p": 1.0?}
//! Streamed responses, one object per line:
//!   {"tokens": "generated fragment"}
//!   {"done": {"generated": n, "block_efficiency": x, ...}}
//!   {"error": "..."}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;

use anyhow::Result;

use crate::config::SamplingConfig;
use crate::tokenizer::Tokenizer;
use crate::util::Json;

use super::engine::{Event, Request};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Serve forever. `submit` feeds the engine thread.
pub fn serve(addr: &str, submit: mpsc::Sender<Request>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("rsd: serving on {addr}");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("rsd: accept error: {e}");
                continue;
            }
        };
        let submit = submit.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
            if let Err(e) = handle_conn(stream, submit) {
                eprintln!("rsd: connection {peer} ended: {e}");
            }
        });
    }
    Ok(())
}

fn send_line(wr: &mut TcpStream, msg: &Json) -> Result<()> {
    wr.write_all(msg.to_string().as_bytes())?;
    wr.write_all(b"\n")?;
    Ok(())
}

fn err_json(e: impl std::fmt::Display) -> Json {
    Json::obj(vec![("error", Json::Str(e.to_string()))])
}

pub(crate) fn parse_wire_request(
    line: &str,
    tok: &Tokenizer,
) -> Result<(Vec<u32>, usize, Option<crate::config::DecoderConfig>, Option<SamplingConfig>)> {
    let j = Json::parse(line)?;
    let prompt_text = j.str_field("prompt")?;
    let prompt = tok.encode(prompt_text);
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_tokens").and_then(Json::as_usize).unwrap_or(64).min(192);
    let decoder = match j.get("decoder").and_then(Json::as_str) {
        Some(s) => Some(s.parse()?),
        None => None,
    };
    let sampling = match (
        j.get("temperature").and_then(Json::as_f64),
        j.get("top_p").and_then(Json::as_f64),
    ) {
        (None, None) => None,
        (t, p) => Some(SamplingConfig {
            temperature: t.unwrap_or(0.3) as f32,
            top_p: p.unwrap_or(1.0) as f32,
        }),
    };
    Ok((prompt, max_new, decoder, sampling))
}

pub(crate) fn done_json(stats: &crate::decode::DecodeStats) -> Json {
    Json::obj(vec![(
        "done",
        Json::obj(vec![
            ("generated", stats.generated.into()),
            ("block_efficiency", stats.block_efficiency().into()),
            ("decode_calls", stats.decode_calls.into()),
            ("draft_calls", stats.draft_calls.into()),
            ("accepted", stats.accepted_draft_tokens.into()),
            ("wall_secs", stats.wall.as_secs_f64().into()),
        ]),
    )])
}

fn handle_conn(stream: TcpStream, submit: mpsc::Sender<Request>) -> Result<()> {
    let mut wr = stream.try_clone()?;
    let rd = BufReader::new(stream);
    let tok = Tokenizer::new();
    for line in rd.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (prompt, max_new, decoder, sampling) = match parse_wire_request(&line, &tok) {
            Ok(x) => x,
            Err(e) => {
                send_line(&mut wr, &err_json(format!("bad request: {e}")))?;
                continue;
            }
        };
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new,
            decoder,
            sampling,
            resp: tx,
        };
        if submit.send(req).is_err() {
            send_line(&mut wr, &err_json("engine stopped"))?;
            return Ok(());
        }
        while let Ok(ev) = rx.recv() {
            match ev {
                Event::Tokens(ts) => {
                    let msg = Json::obj(vec![("tokens", Json::Str(tok.decode(&ts)))]);
                    send_line(&mut wr, &msg)?;
                }
                Event::Done(stats) => {
                    send_line(&mut wr, &done_json(&stats))?;
                    break;
                }
                Event::Error(e) => {
                    send_line(&mut wr, &err_json(e))?;
                    break;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_request_parses_full_form() {
        let tok = Tokenizer::new();
        let (prompt, max_new, dec, samp) = parse_wire_request(
            r#"{"prompt": "hello", "max_tokens": 9, "decoder": "rsd-c:2-2", "temperature": 0.5}"#,
            &tok,
        )
        .unwrap();
        assert_eq!(prompt.len(), 5);
        assert_eq!(max_new, 9);
        assert_eq!(dec, Some(crate::config::DecoderConfig::RsdC { branches: vec![2, 2] }));
        assert!((samp.unwrap().temperature - 0.5).abs() < 1e-6);
    }

    #[test]
    fn wire_request_defaults() {
        let tok = Tokenizer::new();
        let (_, max_new, dec, samp) =
            parse_wire_request(r#"{"prompt": "hi"}"#, &tok).unwrap();
        assert_eq!(max_new, 64);
        assert!(dec.is_none());
        assert!(samp.is_none());
    }

    #[test]
    fn wire_request_rejects_bad() {
        let tok = Tokenizer::new();
        assert!(parse_wire_request(r#"{"max_tokens": 2}"#, &tok).is_err());
        assert!(parse_wire_request("not json", &tok).is_err());
    }
}
