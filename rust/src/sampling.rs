//! Sampling substrate: logits processing (temperature / nucleus), Gumbel
//! machinery for sampling *without replacement* (Gumbel-Top-k and the
//! truncated-Gumbel recursion of Stochastic Beam Search), categorical
//! sampling and residual distributions.
//!
//! All verification math runs in f64 probability space: the distributions
//! involved (vocab ≤ a few hundred here) are small, and the acceptance
//! thresholds of recursive rejection sampling are exact identities — f32
//! drift would show up directly as distribution-recovery error.

use crate::util::Rng;

pub const NEG_INF: f64 = f64::NEG_INFINITY;

/// A processed, normalized categorical distribution in log space.
/// Filtered-out tokens carry `-inf` (paper Alg. 4 line 6: filtered tokens
/// are excluded from Gumbel-Top-k and from residuals).
#[derive(Debug, Clone)]
pub struct LogProbs(pub Vec<f64>);

impl LogProbs {
    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Probabilities (exact exp; -inf -> 0).
    pub fn probs(&self) -> Vec<f64> {
        self.0.iter().map(|&l| l.exp()).collect()
    }
}

/// Convert raw model logits to a processed log-distribution:
/// logits/temperature -> log_softmax -> nucleus(top_p) -> renormalize.
pub fn process_logits(logits: &[f32], temperature: f32, top_p: f32) -> LogProbs {
    assert!(temperature > 0.0, "temperature must be > 0 (greedy not supported)");
    let inv_t = 1.0 / temperature as f64;
    let mut lp: Vec<f64> = logits.iter().map(|&x| x as f64 * inv_t).collect();
    log_normalize(&mut lp);
    if top_p < 1.0 {
        nucleus_filter(&mut lp, top_p as f64);
        log_normalize(&mut lp);
    }
    LogProbs(lp)
}

/// In-place log-softmax (stable). `-inf` entries stay `-inf`.
pub fn log_normalize(lp: &mut [f64]) {
    let m = lp.iter().cloned().fold(NEG_INF, f64::max);
    if m == NEG_INF {
        return; // fully masked; caller's bug, keep as-is
    }
    let z: f64 = lp.iter().map(|&l| (l - m).exp()).sum();
    let lz = m + z.ln();
    for l in lp.iter_mut() {
        if *l != NEG_INF {
            *l -= lz;
        }
    }
}

/// Nucleus filter: keep the smallest prob-sorted prefix with mass >= top_p,
/// set the rest to -inf. Ties broken by index for determinism.
fn nucleus_filter(lp: &mut [f64], top_p: f64) {
    let mut idx: Vec<usize> = (0..lp.len()).collect();
    idx.sort_by(|&a, &b| lp[b].partial_cmp(&lp[a]).unwrap().then(a.cmp(&b)));
    let mut mass = 0.0;
    let mut keep = lp.len();
    for (rank, &i) in idx.iter().enumerate() {
        mass += lp[i].exp();
        if mass >= top_p {
            keep = rank + 1;
            break;
        }
    }
    for &i in &idx[keep..] {
        lp[i] = NEG_INF;
    }
}

/// Standard Gumbel(0,1) sample.
pub fn gumbel(rng: &mut Rng) -> f64 {
    let u: f64 = rng.gen_f64_open();
    -(-u.ln()).ln()
}

/// Gumbel-Top-k trick (Vieira 2014): returns up to `k` indices sampled
/// *without replacement* from the categorical `lp`, in decreasing order of
/// perturbed log-prob, together with the perturbed values. `-inf` entries
/// are never returned (paper Alg. 4 line 6).
pub fn gumbel_top_k(lp: &LogProbs, k: usize, rng: &mut Rng) -> Vec<(usize, f64)> {
    let mut perturbed: Vec<(usize, f64)> = lp
        .0
        .iter()
        .enumerate()
        .filter(|(_, &l)| l != NEG_INF)
        .map(|(i, &l)| (i, l + gumbel(rng)))
        .collect();
    perturbed.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    perturbed.truncate(k);
    perturbed
}

/// Numerically-stable truncated Gumbel (Kool et al. 2019, App. B.3):
/// given the parent's truncated value `u`, the child perturbed values
/// `phi_tilde` and their max `z`, returns values distributed as the
/// perturbed ones conditioned on max == u:
///   g_hat = -log(exp(-u) - exp(-z) + exp(-phi))
pub fn truncated_gumbel(u: f64, z: f64, phi_tilde: &[f64]) -> Vec<f64> {
    phi_tilde
        .iter()
        .map(|&g| {
            if g == NEG_INF {
                return NEG_INF;
            }
            let v = u - g + ln_1m_exp(g - z);
            u - v.max(0.0) - ln_1p_exp(-v.abs())
        })
        .collect()
}

/// log(1 - exp(x)) for x <= 0, stable near 0 and -inf.
fn ln_1m_exp(x: f64) -> f64 {
    if x >= 0.0 {
        // x == 0 => log(0) = -inf (happens exactly at the argmax child)
        return NEG_INF;
    }
    if x > -f64::ln(2.0) {
        (-f64::exp_m1(x)).ln()
    } else {
        f64::ln_1p(-x.exp())
    }
}

/// log(1 + exp(x)), stable.
fn ln_1p_exp(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        f64::ln_1p(x.exp())
    }
}

/// Sample an index from probabilities `p` (need not be normalized).
pub fn sample_categorical(p: &[f64], rng: &mut Rng) -> usize {
    let total: f64 = p.iter().sum();
    assert!(total > 0.0, "cannot sample from zero distribution");
    let mut u: f64 = rng.gen_f64() * total;
    for (i, &pi) in p.iter().enumerate() {
        u -= pi;
        if u <= 0.0 {
            return i;
        }
    }
    // floating point slack: return the last strictly-positive entry
    p.iter().rposition(|&x| x > 0.0).expect("nonzero entry exists")
}

/// Residual distribution Norm[[q - p]^+] in probability space. Returns
/// None when q <= p pointwise (residual mass ~ 0), which happens when the
/// draft already covers the target.
pub fn residual(q: &[f64], p: &[f64]) -> Option<Vec<f64>> {
    let mut r: Vec<f64> = q.iter().zip(p).map(|(&qi, &pi)| (qi - pi).max(0.0)).collect();
    let z: f64 = r.iter().sum();
    if z <= 1e-300 {
        return None;
    }
    for x in &mut r {
        *x /= z;
    }
    Some(r)
}

/// Total-variation distance between two probability vectors.
pub fn tv_distance(a: &[f64], b: &[f64]) -> f64 {
    0.5 * a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn process_logits_normalizes() {
        let lp = process_logits(&[1.0, 2.0, 3.0], 0.7, 1.0);
        let s: f64 = lp.probs().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn temperature_sharpens() {
        let hot = process_logits(&[1.0, 2.0], 1.0, 1.0);
        let cold = process_logits(&[1.0, 2.0], 0.25, 1.0);
        assert!(cold.probs()[1] > hot.probs()[1]);
    }

    #[test]
    fn nucleus_drops_tail_and_renormalizes() {
        // probs ~ [0.6, 0.3, 0.1]; top_p = 0.8 keeps two tokens
        let logits = [0.6f32.ln(), 0.3f32.ln(), 0.1f32.ln()];
        let lp = process_logits(&logits, 1.0, 0.8);
        assert_eq!(lp.0[2], NEG_INF);
        let p = lp.probs();
        assert!((p[0] + p[1] - 1.0).abs() < 1e-9);
        assert!((p[0] / p[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn gumbel_top_k_skips_filtered_and_orders() {
        let lp = LogProbs(vec![-0.5, NEG_INF, -1.5, -0.7]);
        let mut r = rng(0);
        for _ in 0..50 {
            let out = gumbel_top_k(&lp, 3, &mut r);
            assert_eq!(out.len(), 3);
            assert!(out.iter().all(|&(i, _)| i != 1));
            assert!(out.windows(2).all(|w| w[0].1 >= w[1].1));
        }
    }

    /// Gumbel-Top-1 must sample from the categorical itself.
    #[test]
    fn gumbel_top_one_matches_categorical() {
        let probs = [0.5, 0.2, 0.25, 0.05];
        let lp = LogProbs(probs.iter().map(|p| (*p as f64).ln()).collect());
        let mut r = rng(7);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[gumbel_top_k(&lp, 1, &mut r)[0].0] += 1;
        }
        for i in 0..4 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - probs[i]).abs() < 0.005, "{i}: {emp} vs {}", probs[i]);
        }
    }

    /// The first TWO Gumbel-Top-k outputs must follow sampling without
    /// replacement: P(first=i, second=j) = p_i * p_j / (1 - p_i).
    #[test]
    fn gumbel_top_two_is_without_replacement() {
        let probs = [0.5, 0.3, 0.2];
        let lp = LogProbs(probs.iter().map(|p| (*p as f64).ln()).collect());
        let mut r = rng(42);
        let n = 300_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..n {
            let out = gumbel_top_k(&lp, 2, &mut r);
            *counts.entry((out[0].0, out[1].0)).or_insert(0usize) += 1;
        }
        for i in 0..3 {
            for j in 0..3 {
                if i == j {
                    continue;
                }
                let expect = probs[i] * probs[j] / (1.0 - probs[i]);
                let emp = *counts.get(&(i, j)).unwrap_or(&0) as f64 / n as f64;
                assert!((emp - expect).abs() < 0.01, "({i},{j}): {emp} vs {expect}");
            }
        }
    }

    #[test]
    fn truncated_gumbel_bounded_and_monotone() {
        let phi = vec![-1.0, 0.5, -3.0, 0.2];
        let z = phi.iter().cloned().fold(NEG_INF, f64::max);
        let u = -0.3;
        let out = truncated_gumbel(u, z, &phi);
        for &o in &out {
            assert!(o <= u + 1e-12, "{o} > {u}");
        }
        // monotone in phi
        let mut pairs: Vec<(f64, f64)> = phi.iter().cloned().zip(out.iter().cloned()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(pairs.windows(2).all(|w| w[0].1 <= w[1].1));
        // the argmax child attains exactly u
        let imax = phi.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((out[imax] - u).abs() < 1e-9);
    }

    #[test]
    fn residual_basic() {
        let q = [0.5, 0.5];
        let p = [0.8, 0.2];
        let r = residual(&q, &p).unwrap();
        assert!((r[0] - 0.0).abs() < 1e-12);
        assert!((r[1] - 1.0).abs() < 1e-12);
        assert!(residual(&q, &q).is_none());
    }

    #[test]
    fn categorical_empirical() {
        let p = [0.1, 0.6, 0.3];
        let mut r = rng(3);
        let n = 100_000;
        let mut c = [0usize; 3];
        for _ in 0..n {
            c[sample_categorical(&p, &mut r)] += 1;
        }
        for i in 0..3 {
            assert!((c[i] as f64 / n as f64 - p[i]).abs() < 0.01);
        }
    }
}
