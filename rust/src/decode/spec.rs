//! Shared machinery for all tree-based speculative decoders: the round
//! stepper (draft -> parallel target evaluation -> verification ->
//! zero-copy KV commit), the draft-tree bookkeeping, and the verification
//! walk.
//!
//! A decoder = a [`TreeStrategy`] (how the draft tree is grown: chain,
//! i.i.d. paths, Gumbel-Top-k, Stochastic Beam Search) + a
//! [`VerifyRule`](super::rrs::VerifyRule) (how a sibling set is accepted:
//! RRS, K-SEQ, multi-round). This mirrors the paper's structure: Figure 2
//! is one [`SpecStepper`] round, Alg. 3/8 are strategies, Alg. 6 is the
//! rule.
//!
//! # Phase machine
//!
//! The stepper does not own the model calls. A round is a resumable
//! *phase machine* that stages work and consumes rows:
//!
//! 1. [`SpecStepper::begin_round`] — capacity/length checks, stages the
//!    draft-tail chain ([`RoundStart::Started`]) or finishes the request
//!    without a round ([`RoundStart::Finished`]).
//! 2. While [`SpecStepper::draft_group`] is `Some((session, nodes))`:
//!    run the *draft* model on them, hand the rows back through
//!    [`SpecStepper::feed_draft`] (which expands the next tree level —
//!    one draft phase per non-leaf level).
//! 3. [`SpecStepper::target_group`] then stages the whole tree (plus the
//!    target tail) for one parallel *target* pass;
//!    [`SpecStepper::feed_target`] verifies, commits the accepted path
//!    into both KV caches and emits tokens.
//!
//! [`SpecStepper::step`] drives the machine with direct per-session
//! `eval_into` calls (the single-request path). The serving engine
//! instead advances *every* active request's machine in lockstep and
//! executes each phase as one fused [`crate::llm::Llm::eval_batch_into`]
//! call across requests; because model calls never consume the
//! per-request RNG, the fused schedule is token-for-token identical to
//! sequential stepping.
//!
//! # Allocation discipline
//!
//! The steady-state round is allocation-free: logits arrive in a flat
//! recycled [`LogitsBatch`], per-node log-distributions come from the
//! stepper's [`RoundScratch`] arena (a [`LogProbs`] buffer pool), the
//! tree/phase/verification vectors are pooled across rounds, and all
//! probability work happens in caller-owned scratch. The hot-path bench
//! (`benches/hotpath.rs`) proves 0 heap allocations per round with a
//! counting global allocator.
//!
//! Decoding stays *resumable at round granularity*, which is what lets
//! the coordinator interleave many requests over one model (continuous
//! batching at the iteration level, vLLM-style).

use std::mem;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::SamplingConfig;
use crate::llm::{EvalNode, Llm, LogitsBatch, LogitsView};
use crate::sampling::{
    process_logits_into, sample_categorical, LogProbs, SelectScratch, VerifyScratch,
};
use crate::util::Rng;

use super::rrs::{LevelOutcome, VerifyRule};
use super::{DecodeRun, DecodeStats};

/// One draft-tree node.
#[derive(Debug, Clone)]
pub struct TreeNode {
    pub token: u32,
    /// Parent node id; `None` = the round's root context.
    pub parent: Option<usize>,
    pub level: usize,
    /// Multiplicity: how many i.i.d. draft paths merged into this node
    /// (only > 1 for SpecTr's trie merge).
    pub mult: usize,
    /// Index in the draft session's pending list (None for leaf levels,
    /// which are never evaluated by the draft model).
    pub draft_pending: Option<usize>,
    /// Processed draft distribution AT this node (context ending here).
    pub draft_lp: Option<LogProbs>,
}

/// The draft-token tree of one round.
#[derive(Debug, Default)]
pub struct DraftTree {
    pub nodes: Vec<TreeNode>,
    /// Node ids per level, construction order (= verification order).
    pub levels: Vec<Vec<usize>>,
    /// Processed draft distribution at the root context.
    pub root_draft_lp: LogProbs,
}

impl DraftTree {
    /// Ordered children of `parent` at `level`, expanded by multiplicity:
    /// (node_id, token) per draft path. Allocating wrapper over
    /// [`DraftTree::sibling_candidates_into`].
    pub fn sibling_candidates(&self, level: usize, parent: Option<usize>) -> Vec<(usize, u32)> {
        let mut out = Vec::new();
        self.sibling_candidates_into(level, parent, &mut out);
        out
    }

    /// [`DraftTree::sibling_candidates`] into a caller-owned buffer.
    pub fn sibling_candidates_into(
        &self,
        level: usize,
        parent: Option<usize>,
        out: &mut Vec<(usize, u32)>,
    ) {
        out.clear();
        if level >= self.levels.len() {
            return;
        }
        for &id in &self.levels[level] {
            let n = &self.nodes[id];
            if n.parent == parent {
                for _ in 0..n.mult {
                    out.push((id, n.token));
                }
            }
        }
    }
}

/// A child proposed by a strategy during level expansion.
#[derive(Debug, Clone, Copy)]
pub struct Child {
    pub parent: Option<usize>,
    pub token: u32,
}

/// How the draft-token tree is grown, level by level. Object-safe so the
/// coordinator can hold heterogeneous deciders.
pub trait TreeStrategy: Send {
    fn depth(&self) -> usize;

    /// Upper bound on tree nodes per round (the target budget).
    fn max_nodes(&self) -> usize;

    /// Reset per-round state.
    fn begin_round(&mut self);

    /// Propose children for `level` (0-based), appending them to `out`
    /// (cleared by the caller; strategies own any further scratch, so
    /// expansion is allocation-free once warm). Parents must be nodes of
    /// `level - 1` (or `None` = root for level 0). The appended order is
    /// the *verification order* (e.g. decreasing perturbed log-prob for
    /// sampling without replacement).
    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng, out: &mut Vec<Child>);

    /// Post-creation hook: `node_ids[i]` is the id of the i-th *distinct*
    /// created node, in construction order (duplicates merged for
    /// i.i.d. strategies; without-replacement strategies never merge).
    fn on_created(&mut self, _tree: &DraftTree, _level: usize, _node_ids: &[usize]) {}
}

/// Per-request scratch arena recycled across rounds: the [`LogProbs`]
/// buffer pool (no per-node `Vec<f64>` in steady state) plus the shared
/// selection / verification / probability scratch every hot-path kernel
/// writes into.
#[derive(Debug, Default)]
pub struct RoundScratch {
    /// Recycled log-distribution buffers, handed out by
    /// [`RoundScratch::process_into`] and returned by
    /// [`RoundScratch::recycle`].
    lp_pool: Vec<Vec<f64>>,
    /// Nucleus partial-selection index scratch.
    pub sel: SelectScratch,
    /// Verification-rule probability scratch (q / p / residual).
    pub verify: VerifyScratch,
    /// Generic probability buffer (bonus-token sampling).
    pub probs: Vec<f64>,
    /// Verification-walk sibling candidates.
    cands: Vec<(usize, u32)>,
    /// Verification-walk sibling tokens.
    tokens: Vec<u32>,
}

impl RoundScratch {
    /// Process raw logits into a pooled [`LogProbs`] (allocation-free
    /// once the pool is warm). Return it with [`RoundScratch::recycle`].
    pub fn process_into(&mut self, logits: &[f32], temperature: f32, top_p: f32) -> LogProbs {
        let mut buf = self.lp_pool.pop().unwrap_or_default();
        process_logits_into(logits, temperature, top_p, &mut self.sel, &mut buf);
        LogProbs(buf)
    }

    /// Return a pooled distribution's buffer to the arena.
    pub fn recycle(&mut self, lp: LogProbs) {
        let mut v = lp.0;
        v.clear();
        self.lp_pool.push(v);
    }
}

/// Walk result of [`verify_tree`].
#[derive(Debug, Default)]
pub struct VerifyResult {
    /// Accepted node ids, root-ward order.
    pub accepted: Vec<usize>,
    /// The round's final token: residual sample on rejection, or a bonus
    /// token from the target distribution when the walk exits the tree.
    pub final_token: u32,
    pub bonus: bool,
    /// Per walked level: (sibling candidates examined, 1 if one of them
    /// was accepted else 0). Each examined candidate is one rejection
    /// trial of the verification rule, so these are the sufficient
    /// statistics for estimating per-candidate acceptance rates
    /// ([`crate::adaptive::AcceptanceEstimator`]).
    pub level_trials: Vec<(usize, usize)>,
}

/// What one speculative round observed — the telemetry consumed by the
/// adaptive controller ([`crate::adaptive`]) and the serving metrics.
#[derive(Debug, Clone, Default)]
pub struct RoundReport {
    /// Per walked level: (candidates examined, accepted 0/1).
    pub level_trials: Vec<(usize, usize)>,
    /// Draft-tree nodes the target processed this round (actual budget).
    pub nodes: usize,
    /// Accepted draft tokens this round.
    pub accepted: usize,
    /// Whether the walk exited the tree (bonus token drawn from target).
    pub bonus: bool,
}

/// Verify a draft tree level by level (paper §3.2.2): at each level run
/// the rule over the accepted parent's ordered children; on rejection the
/// rule's residual sample ends the round; if the walk leaves the tree a
/// bonus token is drawn from the target distribution at the last accepted
/// context. Allocating wrapper over [`verify_tree_into`].
pub fn verify_tree(
    tree: &DraftTree,
    rule: &dyn VerifyRule,
    root_target_lp: &LogProbs,
    node_target_lp: &[LogProbs],
    rng: &mut Rng,
) -> VerifyResult {
    let mut scratch = RoundScratch::default();
    let mut out = VerifyResult::default();
    verify_tree_into(tree, rule, root_target_lp, node_target_lp, rng, &mut scratch, &mut out);
    out
}

/// [`verify_tree`] into caller-owned scratch and result buffers — the
/// allocation-free verification chain the stepper runs every round.
pub fn verify_tree_into(
    tree: &DraftTree,
    rule: &dyn VerifyRule,
    root_target_lp: &LogProbs,
    node_target_lp: &[LogProbs],
    rng: &mut Rng,
    scratch: &mut RoundScratch,
    out: &mut VerifyResult,
) {
    out.accepted.clear();
    out.level_trials.clear();
    let mut cur: Option<usize> = None;
    for level in 0..tree.levels.len() {
        tree.sibling_candidates_into(level, cur, &mut scratch.cands);
        if scratch.cands.is_empty() {
            break; // branch truncated (RSD-S early truncation)
        }
        scratch.tokens.clear();
        scratch.tokens.extend(scratch.cands.iter().map(|&(_, t)| t));
        let draft_lp = match cur {
            None => &tree.root_draft_lp,
            Some(id) => tree.nodes[id]
                .draft_lp
                .as_ref()
                .expect("non-leaf parent must carry a draft distribution"),
        };
        let target_lp = match cur {
            None => root_target_lp,
            Some(id) => &node_target_lp[id],
        };
        match rule.verify_with(&scratch.tokens, draft_lp, target_lp, &mut scratch.verify, rng) {
            LevelOutcome::Accept { pos } => {
                // `pos` earlier siblings were each rejected before this one
                out.level_trials.push((pos + 1, 1));
                let id = scratch.cands[pos].0;
                out.accepted.push(id);
                cur = Some(id);
            }
            LevelOutcome::Reject { token } => {
                out.level_trials.push((scratch.tokens.len(), 0));
                out.final_token = token;
                out.bonus = false;
                return;
            }
        }
    }
    // all levels accepted (or branch ended): bonus token from q(.|cur)
    let lp = match cur {
        None => root_target_lp,
        Some(id) => &node_target_lp[id],
    };
    lp.probs_into(&mut scratch.probs);
    out.final_token = sample_categorical(&scratch.probs, rng) as u32;
    out.bonus = true;
}

fn chain_nodes_into(tokens: &[u32], out: &mut Vec<EvalNode>) {
    out.clear();
    out.extend(tokens.iter().enumerate().map(|(i, &t)| {
        if i == 0 {
            EvalNode::root(t)
        } else {
            EvalNode { token: t, parent: i as i64 - 1 }
        }
    }));
}

/// What one [`SpecStepper::step`] produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Round completed, generation continues.
    Progress,
    /// Request finished (max tokens reached, stop token generated, or
    /// capacity exhausted).
    Done,
}

/// Did `begin_round` stage model work, or finish the request instead?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundStart {
    /// A round is in progress: drive the draft phases, then the target
    /// phase.
    Started,
    /// No round: the stepper is done (`is_done()`), no phase work is
    /// staged, and the caller should treat this as [`StepOutcome::Done`].
    Finished,
}

/// Where a round stands between model calls.
enum Phase {
    /// No round in progress.
    Idle,
    /// Waiting for draft rows for `nodes`. `level` is the tree level the
    /// rows will describe (`None` = the tail chain whose last row yields
    /// the round's root draft distribution).
    AwaitDraft { nodes: Vec<EvalNode>, level: Option<usize> },
    /// Tree built; waiting for target rows for `nodes` (tail + tree).
    AwaitTarget { nodes: Vec<EvalNode> },
}

/// Per-round working state carried across phases. Recycled whole (tree
/// buffers, pooled level vectors) between rounds via `SpecStepper::spare`.
struct RoundCtx {
    tree: DraftTree,
    /// `strategy.depth()` captured at round start.
    depth: usize,
    /// Length of the draft tail chain evaluated at round start.
    dtail_len: usize,
    /// Next free index in the draft session's pending list.
    draft_pending_count: usize,
}

impl RoundCtx {
    fn empty() -> Self {
        Self { tree: DraftTree::default(), depth: 0, dtail_len: 0, draft_pending_count: 0 }
    }
}

/// Resumable speculative decoding session over a (target, draft) pair.
pub struct SpecStepper<T: Llm, D: Llm> {
    strategy: Box<dyn TreeStrategy>,
    rule: Box<dyn VerifyRule>,
    sampling: SamplingConfig,
    dsess: D::Session,
    tsess: T::Session,
    /// Tokens of the logical sequence not yet in the draft's KV cache
    /// (leaf-level accepts are never draft-evaluated + the final token).
    tail_draft: Vec<u32>,
    /// Tokens not yet in the target's KV cache (only the final token of
    /// the previous round; the whole prompt on round 1).
    tail_target: Vec<u32>,
    phase: Phase,
    round: Option<RoundCtx>,
    /// Recycled round context from the previous round (warm tree bufs).
    spare: Option<RoundCtx>,
    /// The per-request scratch arena (LogProbs pool + kernel scratch).
    scratch: RoundScratch,
    /// Pooled `EvalNode` vectors cycled through the phases.
    node_pool: Vec<Vec<EvalNode>>,
    /// Pooled per-level node-id vectors for the tree.
    level_pool: Vec<Vec<usize>>,
    /// Strategy expansion output, reused each level.
    children: Vec<Child>,
    /// Reusable verification-walk result.
    vr: VerifyResult,
    /// Reusable per-node target distributions (pooled buffers inside).
    node_target_lp: Vec<LogProbs>,
    /// Reusable emission staging for one round.
    emit: Vec<u32>,
    /// Reusable commit chains.
    tchain: Vec<usize>,
    dchain: Vec<usize>,
    /// Reusable accepted-but-uncached token staging (becomes next
    /// round's `tail_draft` by swap).
    uncached: Vec<u32>,
    /// Flat logits buffer for the single-request `step` path.
    logits: LogitsBatch,
    /// Telemetry of the most recent round (reused buffer; valid when
    /// `has_report`).
    report: RoundReport,
    has_report: bool,
    /// Flight-recorder handle (default off) and the id this request's
    /// commit-boundary events carry. Recording allocates nothing.
    tracer: crate::trace::Tracer,
    trace_id: u64,
    /// Speculation-analytics handle (default off) and the decoder
    /// family this session's ledger rows accrue under.
    analytics: crate::obs::Analytics,
    family: crate::obs::Family,
    /// The original prompt (immutable): with `out` it reconstructs the
    /// full logical sequence, which is all suspend/resume needs to spill
    /// and rebuild KV state losslessly.
    prompt: Vec<u32>,
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    max_new: usize,
    started: Instant,
    done: bool,
}

impl<T: Llm, D: Llm> SpecStepper<T, D> {
    pub fn new(
        target: &T,
        draft: &D,
        strategy: Box<dyn TreeStrategy>,
        rule: Box<dyn VerifyRule>,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        if prompt.is_empty() {
            bail!("prompt must be non-empty");
        }
        // pre-size every per-round growth vector so the steady-state
        // round never reallocates (the zero-allocation contract the
        // hot-path bench enforces); reserves are clamped for huge
        // max_new values
        let max_nodes = strategy.max_nodes().max(1);
        let depth = strategy.depth().max(1);
        let reserve_rounds = max_new.min(1 << 20);
        let out = Vec::with_capacity(reserve_rounds + max_nodes + 2);
        // prefix-hinted sessions: a pool-backed substrate maps whatever
        // radix-cached prefix of the prompt it holds; those tokens are
        // already committed, so the tails start at the first uncached one
        // (cap at len-1 guaranteed by the trait contract — the tail chain
        // is never empty). Sized to the session's worst case — committed
        // sequence plus one full tree plus residual/bonus margin — so a
        // paged substrate reserves per-session footprint, not the pool.
        let max_slots = prompt.len() + max_new.min(1 << 20) + max_nodes + 2;
        let tsess = target.begin_sized(prompt, max_slots)?;
        let dsess = draft.begin_sized(prompt, max_slots)?;
        let tm = target.prefix_len(&tsess);
        let dm = draft.prefix_len(&dsess);
        debug_assert!(tm < prompt.len() && dm < prompt.len());
        let mut stats = DecodeStats { kv_hit_tokens: tm + dm, ..Default::default() };
        stats.round_nodes.reserve(reserve_rounds + 1);
        stats.level_attempts.reserve(depth);
        stats.level_accepts.reserve(depth);
        let mut vr = VerifyResult::default();
        vr.accepted.reserve(depth);
        vr.level_trials.reserve(depth);
        let mut report = RoundReport::default();
        report.level_trials.reserve(depth);
        let tail_cap = prompt.len() + max_nodes + 2;
        let mut tail_draft = Vec::with_capacity(tail_cap);
        tail_draft.extend_from_slice(&prompt[dm..]);
        let mut tail_target = Vec::with_capacity(tail_cap);
        tail_target.extend_from_slice(&prompt[tm..]);
        Ok(Self {
            strategy,
            rule,
            sampling,
            dsess,
            tsess,
            tail_draft,
            tail_target,
            phase: Phase::Idle,
            round: None,
            spare: None,
            scratch: RoundScratch::default(),
            node_pool: Vec::new(),
            level_pool: Vec::new(),
            children: Vec::with_capacity(max_nodes),
            vr,
            node_target_lp: Vec::with_capacity(max_nodes),
            emit: Vec::with_capacity(max_nodes + 2),
            tchain: Vec::with_capacity(tail_cap),
            dchain: Vec::with_capacity(tail_cap),
            uncached: Vec::with_capacity(tail_cap),
            logits: LogitsBatch::default(),
            report,
            has_report: false,
            tracer: crate::trace::Tracer::off(),
            trace_id: 0,
            analytics: crate::obs::Analytics::off(),
            family: crate::obs::Family::RsdS,
            prompt: prompt.to_vec(),
            out,
            stats,
            max_new,
            started: Instant::now(),
            done: false,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// The streaming commit boundary: every token in
    /// `out[..committed_len()]` has been verified AND committed into the
    /// KV caches, so it is final and safe to emit — nothing before this
    /// index can ever be retracted. Advances exactly once per round (at
    /// [`SpecStepper::feed_target`]), which is the granularity at which
    /// the serving engine streams `token` events.
    pub fn committed_len(&self) -> usize {
        self.out.len()
    }

    /// Telemetry of the most recent completed round.
    pub fn last_round(&self) -> Option<&RoundReport> {
        if self.has_report {
            Some(&self.report)
        } else {
            None
        }
    }

    /// Attach a flight-recorder handle; this request's commit
    /// boundaries are journaled under `id` from the next round on.
    pub fn set_trace(&mut self, tracer: &crate::trace::Tracer, id: u64) {
        self.tracer = tracer.clone();
        self.trace_id = id;
    }

    /// Attach a speculation-analytics handle; this session's target
    /// forwards and commit boundaries accrue to `family`'s ledger.
    pub fn set_analytics(&mut self, analytics: &crate::obs::Analytics, family: crate::obs::Family) {
        self.analytics = analytics.clone();
        self.family = family;
    }

    /// Swap the tree strategy before the next round (adaptive tree
    /// re-shaping). Safe at round granularity: every per-round strategy
    /// state is reset by `begin_round`, and the KV sessions only depend
    /// on the committed chain, never on how past trees were shaped.
    pub fn set_strategy(&mut self, strategy: Box<dyn TreeStrategy>) {
        self.strategy = strategy;
    }

    /// Worst-case new KV slots the next round could consume (tail chain
    /// + a full draft tree + residual/bonus margin) — what the engine's
    /// pre-round preemption check sums across active requests.
    pub fn round_need(&self) -> usize {
        self.round_need_with_budget(self.strategy.max_nodes())
    }

    /// [`SpecStepper::round_need`] under a caller-supplied tree budget —
    /// for wrappers that may swap the strategy before the round starts
    /// (the adaptive controller reports its hard budget, since the
    /// current strategy's size is only last round's choice).
    pub fn round_need_with_budget(&self, max_nodes: usize) -> usize {
        self.tail_draft.len().max(self.tail_target.len()) + max_nodes + 2
    }

    /// Spill this request's KV state (engine preemption): both sessions
    /// are dropped — releasing every pool block they lease — and the
    /// per-model tails are rebuilt as the *full* logical sequence
    /// (prompt + generated), so the next round's ordinary tail-chain
    /// prefill restores the cache. Only legal between rounds. Consumes
    /// no RNG, so a preempted request's token stream is bit-identical to
    /// an uninterrupted one.
    pub fn suspend(&mut self, target: &T, draft: &D) -> Result<()> {
        if !matches!(self.phase, Phase::Idle) || self.round.is_some() {
            bail!("suspend mid-round");
        }
        if self.done {
            bail!("suspend after completion");
        }
        self.tail_target.clear();
        self.tail_target.extend_from_slice(&self.prompt);
        self.tail_target.extend_from_slice(&self.out);
        self.tail_draft.clear();
        self.tail_draft.extend_from_slice(&self.prompt);
        self.tail_draft.extend_from_slice(&self.out);
        // empty placeholder sessions: a paged `begin` leases nothing
        self.tsess = target.begin()?;
        self.dsess = draft.begin()?;
        self.stats.preemptions += 1;
        Ok(())
    }

    /// Re-admit a suspended request: re-open both sessions with the
    /// spilled sequence as prefix hint — whatever prefix is still
    /// radix-cached is mapped back without recompute, the rest stays in
    /// the tails and is re-prefilled by the next round's phase machine.
    pub fn resume(&mut self, target: &T, draft: &D) -> Result<()> {
        let max_slots =
            self.prompt.len() + self.max_new.min(1 << 20) + self.strategy.max_nodes() + 2;
        self.tsess = target.begin_sized(&self.tail_target, max_slots)?;
        let tm = target.prefix_len(&self.tsess);
        self.tail_target.drain(..tm);
        self.dsess = draft.begin_sized(&self.tail_draft, max_slots)?;
        let dm = draft.prefix_len(&self.dsess);
        self.tail_draft.drain(..dm);
        self.stats.kv_hit_tokens += tm + dm;
        Ok(())
    }

    /// Abandon an in-flight round after a mid-round fault (the engine's
    /// bounded-retry path): recycle every staged phase/round buffer back
    /// to its pool, then suspend as if the round had never started.
    /// Dropping the sessions discards whatever pending KV a partial
    /// phase already appended; the tails are rebuilt as prompt +
    /// generated, so the resumed request replays from its last commit
    /// boundary. Consumes no RNG — with the engine's round-start RNG
    /// snapshot restored, the retried round redraws identically.
    pub fn abort_round(&mut self, target: &T, draft: &D) -> Result<()> {
        match mem::replace(&mut self.phase, Phase::Idle) {
            Phase::Idle => {}
            Phase::AwaitDraft { mut nodes, .. } | Phase::AwaitTarget { mut nodes } => {
                nodes.clear();
                self.node_pool.push(nodes);
            }
        }
        if let Some(mut ctx) = self.round.take() {
            // mirror feed_target's recycle block: every pooled buffer
            // the aborted round holds goes back to its pool
            self.scratch.recycle(mem::take(&mut ctx.tree.root_draft_lp));
            for n in ctx.tree.nodes.drain(..) {
                if let Some(lp) = n.draft_lp {
                    self.scratch.recycle(lp);
                }
            }
            for mut lvl in ctx.tree.levels.drain(..) {
                lvl.clear();
                self.level_pool.push(lvl);
            }
            for lp in self.node_target_lp.drain(..) {
                self.scratch.recycle(lp);
            }
            self.spare = Some(ctx);
        }
        self.suspend(target, draft)
    }

    fn finish(&mut self) -> StepOutcome {
        self.out.truncate(self.max_new);
        self.stats.generated = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
        StepOutcome::Done
    }

    /// Start a round: length/capacity checks, then stage the draft tail.
    /// [`RoundStart::Finished`] means the stepper finished *without* a
    /// round (already done, `max_new` reached, or out of KV capacity) and
    /// no phase work exists.
    pub fn begin_round(&mut self, target: &T, draft: &D) -> Result<RoundStart> {
        debug_assert!(matches!(self.phase, Phase::Idle), "begin_round mid-round");
        self.has_report = false;
        if self.done {
            return Ok(RoundStart::Finished);
        }
        if self.out.len() >= self.max_new {
            self.finish();
            return Ok(RoundStart::Finished);
        }
        // capacity guard: tail + a full tree + bonus token
        let need = self.tail_draft.len().max(self.tail_target.len())
            + self.strategy.max_nodes()
            + 2;
        if target.capacity_left(&self.tsess) < need || draft.capacity_left(&self.dsess) < need {
            self.finish();
            return Ok(RoundStart::Finished);
        }
        let mut nodes = self.node_pool.pop().unwrap_or_default();
        chain_nodes_into(&self.tail_draft, &mut nodes);
        self.phase = Phase::AwaitDraft { nodes, level: None };
        Ok(RoundStart::Started)
    }

    /// The pending draft work: the draft session and the staged nodes.
    /// `None` once the tree is fully built (or no round is in progress).
    pub fn draft_group(&mut self) -> Option<(&mut D::Session, &[EvalNode])> {
        match &self.phase {
            Phase::AwaitDraft { nodes, .. } => Some((&mut self.dsess, nodes.as_slice())),
            _ => None,
        }
    }

    /// Consume the draft rows for the staged nodes and grow the tree
    /// until the next draft evaluation is needed (another `draft_group`)
    /// or the tree is complete (`target_group` becomes available).
    pub fn feed_draft(&mut self, rows: LogitsView<'_>, rng: &mut Rng) -> Result<()> {
        let phase = mem::replace(&mut self.phase, Phase::Idle);
        let Phase::AwaitDraft { nodes, level } = phase else {
            bail!("feed_draft outside the draft phase");
        };
        if rows.len() != nodes.len() {
            bail!("feed_draft: {} rows for {} staged nodes", rows.len(), nodes.len());
        }
        self.stats.draft_calls += 1;
        let (temp, top_p) = (self.sampling.temperature, self.sampling.top_p);
        let next_level = match level {
            None => {
                // tail chain: the last row is the root draft distribution
                let root_draft_lp =
                    self.scratch.process_into(rows.last().expect("tail non-empty"), temp, top_p);
                let mut ctx = self.spare.take().unwrap_or_else(RoundCtx::empty);
                debug_assert!(ctx.tree.nodes.is_empty() && ctx.tree.levels.is_empty());
                ctx.tree.root_draft_lp = root_draft_lp;
                ctx.depth = self.strategy.depth();
                ctx.dtail_len = nodes.len();
                ctx.draft_pending_count = nodes.len();
                self.round = Some(ctx);
                self.strategy.begin_round();
                0
            }
            Some(level) => {
                let ctx = self.round.as_mut().context("feed_draft without a round")?;
                for (i, &id) in ctx.tree.levels[level].iter().enumerate() {
                    ctx.tree.nodes[id].draft_pending = Some(ctx.draft_pending_count + i);
                    ctx.tree.nodes[id].draft_lp =
                        Some(self.scratch.process_into(rows.row(i), temp, top_p));
                }
                ctx.draft_pending_count += ctx.tree.levels[level].len();
                level + 1
            }
        };
        let mut nodes = nodes;
        nodes.clear();
        self.node_pool.push(nodes);
        self.advance_draft(next_level, rng);
        Ok(())
    }

    /// Grow the tree from `level`: expand levels (no model needed) until
    /// one requires draft distributions (stages the next draft phase) or
    /// the tree is complete (stages the target phase).
    fn advance_draft(&mut self, mut level: usize, rng: &mut Rng) {
        loop {
            let ctx = self.round.as_mut().expect("round in progress");
            if level >= ctx.depth {
                break;
            }
            self.children.clear();
            self.strategy.expand(&ctx.tree, level, rng, &mut self.children);
            if self.children.is_empty() {
                break;
            }
            // merge duplicates (same parent + token): i.i.d. strategies
            // produce them; without-replacement strategies cannot.
            let mut created = self.level_pool.pop().unwrap_or_default();
            created.clear();
            for c in &self.children {
                if let Some(&id) = created.iter().find(|&&id| {
                    ctx.tree.nodes[id].parent == c.parent && ctx.tree.nodes[id].token == c.token
                }) {
                    ctx.tree.nodes[id].mult += 1;
                    continue;
                }
                let id = ctx.tree.nodes.len();
                ctx.tree.nodes.push(TreeNode {
                    token: c.token,
                    parent: c.parent,
                    level,
                    mult: 1,
                    draft_pending: None,
                    draft_lp: None,
                });
                created.push(id);
            }
            ctx.tree.levels.push(created);
            self.strategy.on_created(&ctx.tree, level, &ctx.tree.levels[level]);

            // evaluate this level with the draft model unless it is the
            // leaf level (leaf distributions are never used for drafting)
            if level + 1 < ctx.depth {
                let dtail_len = ctx.dtail_len;
                let mut nodes = self.node_pool.pop().unwrap_or_default();
                nodes.clear();
                nodes.extend(ctx.tree.levels[level].iter().map(|&id| {
                    let parent_pending = match ctx.tree.nodes[id].parent {
                        None => dtail_len as i64 - 1,
                        Some(p) => ctx.tree.nodes[p]
                            .draft_pending
                            .expect("parent evaluated at previous level")
                            as i64,
                    };
                    EvalNode { token: ctx.tree.nodes[id].token, parent: parent_pending }
                }));
                self.phase = Phase::AwaitDraft { nodes, level: Some(level) };
                return;
            }
            level += 1;
        }
        self.stage_target();
    }

    /// Tree complete: stage the target pass (tail + whole tree, one
    /// parallel evaluation).
    fn stage_target(&mut self) {
        let ctx = self.round.as_ref().expect("round in progress");
        let ttail_len = self.tail_target.len();
        let mut tnodes = self.node_pool.pop().unwrap_or_default();
        chain_nodes_into(&self.tail_target, &mut tnodes);
        for (id, n) in ctx.tree.nodes.iter().enumerate() {
            let parent = match n.parent {
                None => (ttail_len - 1) as i64,
                Some(p) => (ttail_len + p) as i64,
            };
            debug_assert_eq!(id + ttail_len, tnodes.len());
            tnodes.push(EvalNode { token: n.token, parent });
        }
        self.phase = Phase::AwaitTarget { nodes: tnodes };
    }

    /// The pending target (verification) work, once every draft phase has
    /// been fed.
    pub fn target_group(&mut self) -> Option<(&mut T::Session, &[EvalNode])> {
        match &self.phase {
            Phase::AwaitTarget { nodes } => Some((&mut self.tsess, nodes.as_slice())),
            _ => None,
        }
    }

    /// Consume the target rows: verify the tree (recursive rejection
    /// sampling per level), commit the accepted path into both KV caches
    /// (zero-copy `FilterKVCache`), emit tokens (honoring stop tokens)
    /// and close the round.
    pub fn feed_target(
        &mut self,
        target: &T,
        draft: &D,
        rows: LogitsView<'_>,
        rng: &mut Rng,
    ) -> Result<StepOutcome> {
        let phase = mem::replace(&mut self.phase, Phase::Idle);
        let Phase::AwaitTarget { nodes } = phase else {
            bail!("feed_target outside the verify phase");
        };
        let mut ctx = self.round.take().context("feed_target without a round")?;
        let dtail_len = ctx.dtail_len;
        let ttail_len = self.tail_target.len();
        if rows.len() != nodes.len() {
            bail!("feed_target: {} rows for {} staged nodes", rows.len(), nodes.len());
        }
        debug_assert_eq!(nodes.len(), ttail_len + ctx.tree.nodes.len());
        {
            let mut nodes = nodes;
            nodes.clear();
            self.node_pool.push(nodes);
        }
        let (temp, top_p) = (self.sampling.temperature, self.sampling.top_p);
        self.stats.decode_calls += 1;
        self.stats.tree_nodes += ctx.tree.nodes.len();
        self.analytics.record_forward(self.family, ctx.tree.nodes.len() as u32);
        let root_target_lp = self.scratch.process_into(rows.row(ttail_len - 1), temp, top_p);
        // normally a no-op (drained when the round closed); after a
        // mid-round commit error the stale distributions must not shift
        // this round's node indexing
        for lp in self.node_target_lp.drain(..) {
            self.scratch.recycle(lp);
        }
        for r in ttail_len..rows.len() {
            let lp = self.scratch.process_into(rows.row(r), temp, top_p);
            self.node_target_lp.push(lp);
        }

        // ---- verification (recursive rejection sampling per level) -------
        let mut vr = mem::take(&mut self.vr);
        verify_tree_into(
            &ctx.tree,
            self.rule.as_ref(),
            &root_target_lp,
            &self.node_target_lp,
            rng,
            &mut self.scratch,
            &mut vr,
        );

        // ---- stop-token truncation ---------------------------------------
        // This round's emission is the accepted draft tokens plus the
        // final (residual or bonus) token; the first stop token ends the
        // request, is not emitted, and drops everything after it.
        self.emit.clear();
        self.emit.extend(vr.accepted.iter().map(|&id| ctx.tree.nodes[id].token));
        self.emit.push(vr.final_token);
        let cut = if self.sampling.stop.is_empty() {
            None
        } else {
            self.emit.iter().position(|&t| self.sampling.is_stop(t))
        };
        let kept = cut.unwrap_or(self.emit.len());
        // effective counts keep stats consistent with the truncated
        // stream: dropped tokens contribute neither to acceptance counts
        // nor to per-level trial telemetry (level k's trial produced
        // accepted token k; the trial of the level that produced the
        // dropped final token is cut as well)
        let eff_accepted = vr.accepted.len().min(kept);
        let eff_bonus = vr.bonus && cut.is_none();
        if cut.is_some() {
            vr.level_trials.truncate(eff_accepted);
        }

        self.stats.accepted_draft_tokens += eff_accepted;
        if eff_bonus {
            self.stats.bonus_tokens += 1;
        }
        for (lvl, &(_, success)) in vr.level_trials.iter().enumerate() {
            if self.stats.level_attempts.len() <= lvl {
                self.stats.level_attempts.resize(lvl + 1, 0);
                self.stats.level_accepts.resize(lvl + 1, 0);
            }
            self.stats.level_attempts[lvl] += 1;
            self.stats.level_accepts[lvl] += success as u64;
        }
        self.stats.round_nodes.push(ctx.tree.nodes.len() as u32);
        self.report.level_trials.clear();
        self.report.level_trials.extend_from_slice(&vr.level_trials);
        self.report.nodes = ctx.tree.nodes.len();
        self.report.accepted = eff_accepted;
        self.report.bonus = eff_bonus;
        self.has_report = true;
        self.tracer.record(
            crate::trace::EventKind::Commit,
            self.trace_id,
            eff_accepted as u32,
            u32::from(eff_bonus),
        );
        self.analytics.record_commit(
            self.family,
            eff_accepted,
            usize::from(eff_bonus),
            &vr.level_trials,
        );

        // ---- zero-copy KV commit (FilterKVCache) --------------------------
        self.tchain.clear();
        self.tchain.extend(0..ttail_len);
        self.tchain.extend(vr.accepted.iter().map(|&id| ttail_len + id));
        target.commit(&mut self.tsess, &self.tchain)?;

        self.dchain.clear();
        self.dchain.extend(0..dtail_len);
        self.uncached.clear();
        for &id in &vr.accepted {
            match ctx.tree.nodes[id].draft_pending {
                Some(p) if self.uncached.is_empty() => self.dchain.push(p),
                _ => self.uncached.push(ctx.tree.nodes[id].token),
            }
        }
        draft.commit(&mut self.dsess, &self.dchain)?;

        let final_token = vr.final_token;

        // ---- recycle round buffers ---------------------------------------
        self.scratch.recycle(mem::take(&mut ctx.tree.root_draft_lp));
        for n in ctx.tree.nodes.drain(..) {
            if let Some(lp) = n.draft_lp {
                self.scratch.recycle(lp);
            }
        }
        for mut lvl in ctx.tree.levels.drain(..) {
            lvl.clear();
            self.level_pool.push(lvl);
        }
        for lp in self.node_target_lp.drain(..) {
            self.scratch.recycle(lp);
        }
        self.scratch.recycle(root_target_lp);
        self.vr = vr;
        self.spare = Some(ctx);

        // ---- emit tokens ---------------------------------------------------
        self.out.extend_from_slice(&self.emit[..kept]);
        if cut.is_some() {
            return Ok(self.finish());
        }
        // next round's per-session tails: the target already holds every
        // accepted node's KV (only the final token is new to it); the
        // draft additionally misses leaf-level accepts it never evaluated.
        self.uncached.push(final_token);
        mem::swap(&mut self.tail_draft, &mut self.uncached);
        self.tail_target.clear();
        self.tail_target.push(final_token);

        if self.out.len() >= self.max_new {
            return Ok(self.finish());
        }
        Ok(StepOutcome::Progress)
    }

    /// Run one full speculative round (Figure 2 of the paper) by driving
    /// the phase machine with direct per-session model calls — the
    /// single-request path. The serving engine drives many steppers'
    /// phases in lockstep instead and fuses the model calls. The flat
    /// logits buffer is owned by the stepper and recycled round to round.
    pub fn step(&mut self, target: &T, draft: &D, rng: &mut Rng) -> Result<StepOutcome> {
        if self.begin_round(target, draft)? == RoundStart::Finished {
            return Ok(StepOutcome::Done);
        }
        let mut batch = mem::take(&mut self.logits);
        loop {
            batch.reset(draft.vocab());
            match self.draft_group() {
                Some((sess, nodes)) => draft.eval_into(sess, nodes, &mut batch)?,
                None => break,
            }
            self.feed_draft(batch.full(), rng)?;
        }
        batch.reset(target.vocab());
        match self.target_group() {
            Some((sess, nodes)) => target.eval_into(sess, nodes, &mut batch)?,
            None => bail!("round staged no target work"),
        }
        let outcome = self.feed_target(target, draft, batch.full(), rng);
        self.logits = batch;
        outcome
    }
}

/// The full decoding loop shared by SD / SpecTr / RSD-C / RSD-S.
#[allow(clippy::too_many_arguments)]
pub fn run_spec<T, D>(
    target: &T,
    draft: &D,
    strategy: Box<dyn TreeStrategy>,
    rule: Box<dyn VerifyRule>,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun>
where
    T: Llm,
    D: Llm,
{
    let mut stepper =
        SpecStepper::new(target, draft, strategy, rule, sampling.clone(), prompt, max_new)?;
    while stepper.step(target, draft, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out.clone(), stats: stepper.stats.clone() })
}
