//! Shared machinery for all tree-based speculative decoders: the round
//! stepper (draft -> parallel target evaluation -> verification ->
//! zero-copy KV commit), the draft-tree bookkeeping, and the verification
//! walk.
//!
//! A decoder = a [`TreeStrategy`] (how the draft tree is grown: chain,
//! i.i.d. paths, Gumbel-Top-k, Stochastic Beam Search) + a
//! [`VerifyRule`](super::rrs::VerifyRule) (how a sibling set is accepted:
//! RRS, K-SEQ, multi-round). This mirrors the paper's structure: Figure 2
//! is [`SpecStepper::step`], Alg. 3/8 are strategies, Alg. 6 is the rule.
//!
//! Decoding is *resumable at round granularity* ([`SpecStepper`]), which
//! is what lets the coordinator interleave many requests over one model
//! (continuous batching at the iteration level, vLLM-style).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::SamplingConfig;
use crate::llm::{EvalNode, Llm};
use crate::sampling::{process_logits, sample_categorical, LogProbs};
use crate::util::Rng;

use super::rrs::{LevelOutcome, VerifyRule};
use super::{DecodeRun, DecodeStats};

/// One draft-tree node.
#[derive(Debug, Clone)]
pub struct TreeNode {
    pub token: u32,
    /// Parent node id; `None` = the round's root context.
    pub parent: Option<usize>,
    pub level: usize,
    /// Multiplicity: how many i.i.d. draft paths merged into this node
    /// (only > 1 for SpecTr's trie merge).
    pub mult: usize,
    /// Index in the draft session's pending list (None for leaf levels,
    /// which are never evaluated by the draft model).
    pub draft_pending: Option<usize>,
    /// Processed draft distribution AT this node (context ending here).
    pub draft_lp: Option<LogProbs>,
}

/// The draft-token tree of one round.
#[derive(Debug)]
pub struct DraftTree {
    pub nodes: Vec<TreeNode>,
    /// Node ids per level, construction order (= verification order).
    pub levels: Vec<Vec<usize>>,
    /// Processed draft distribution at the root context.
    pub root_draft_lp: LogProbs,
}

impl DraftTree {
    /// Ordered children of `parent` at `level`, expanded by multiplicity:
    /// (node_id, token) per draft path.
    pub fn sibling_candidates(&self, level: usize, parent: Option<usize>) -> Vec<(usize, u32)> {
        let mut out = Vec::new();
        if level >= self.levels.len() {
            return out;
        }
        for &id in &self.levels[level] {
            let n = &self.nodes[id];
            if n.parent == parent {
                for _ in 0..n.mult {
                    out.push((id, n.token));
                }
            }
        }
        out
    }
}

/// A child proposed by a strategy during level expansion.
#[derive(Debug, Clone, Copy)]
pub struct Child {
    pub parent: Option<usize>,
    pub token: u32,
}

/// How the draft-token tree is grown, level by level. Object-safe so the
/// coordinator can hold heterogeneous deciders.
pub trait TreeStrategy: Send {
    fn depth(&self) -> usize;

    /// Upper bound on tree nodes per round (the target budget).
    fn max_nodes(&self) -> usize;

    /// Reset per-round state.
    fn begin_round(&mut self);

    /// Propose children for `level` (0-based). Parents must be nodes of
    /// `level - 1` (or `None` = root for level 0). The returned order is
    /// the *verification order* (e.g. decreasing perturbed log-prob for
    /// sampling without replacement).
    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child>;

    /// Post-creation hook: `node_ids[i]` is the id of the i-th *distinct*
    /// created node, in construction order (duplicates merged for
    /// i.i.d. strategies; without-replacement strategies never merge).
    fn on_created(&mut self, _tree: &DraftTree, _level: usize, _node_ids: &[usize]) {}
}

/// Walk result of [`verify_tree`].
#[derive(Debug)]
pub struct VerifyResult {
    /// Accepted node ids, root-ward order.
    pub accepted: Vec<usize>,
    /// The round's final token: residual sample on rejection, or a bonus
    /// token from the target distribution when the walk exits the tree.
    pub final_token: u32,
    pub bonus: bool,
    /// Per walked level: (sibling candidates examined, 1 if one of them
    /// was accepted else 0). Each examined candidate is one rejection
    /// trial of the verification rule, so these are the sufficient
    /// statistics for estimating per-candidate acceptance rates
    /// ([`crate::adaptive::AcceptanceEstimator`]).
    pub level_trials: Vec<(usize, usize)>,
}

/// What one speculative round observed — the telemetry consumed by the
/// adaptive controller ([`crate::adaptive`]) and the serving metrics.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// Per walked level: (candidates examined, accepted 0/1).
    pub level_trials: Vec<(usize, usize)>,
    /// Draft-tree nodes the target processed this round (actual budget).
    pub nodes: usize,
    /// Accepted draft tokens this round.
    pub accepted: usize,
    /// Whether the walk exited the tree (bonus token drawn from target).
    pub bonus: bool,
}

/// Verify a draft tree level by level (paper §3.2.2): at each level run
/// the rule over the accepted parent's ordered children; on rejection the
/// rule's residual sample ends the round; if the walk leaves the tree a
/// bonus token is drawn from the target distribution at the last accepted
/// context.
pub fn verify_tree(
    tree: &DraftTree,
    rule: &dyn VerifyRule,
    root_target_lp: &LogProbs,
    node_target_lp: &[LogProbs],
    rng: &mut Rng,
) -> VerifyResult {
    let mut cur: Option<usize> = None;
    let mut accepted = Vec::new();
    let mut level_trials = Vec::new();
    for level in 0..tree.levels.len() {
        let cands = tree.sibling_candidates(level, cur);
        if cands.is_empty() {
            break; // branch truncated (RSD-S early truncation)
        }
        let tokens: Vec<u32> = cands.iter().map(|&(_, t)| t).collect();
        let draft_lp = match cur {
            None => &tree.root_draft_lp,
            Some(id) => tree.nodes[id]
                .draft_lp
                .as_ref()
                .expect("non-leaf parent must carry a draft distribution"),
        };
        let target_lp = match cur {
            None => root_target_lp,
            Some(id) => &node_target_lp[id],
        };
        match rule.verify(&tokens, draft_lp, target_lp, rng) {
            LevelOutcome::Accept { pos } => {
                // `pos` earlier siblings were each rejected before this one
                level_trials.push((pos + 1, 1));
                let id = cands[pos].0;
                accepted.push(id);
                cur = Some(id);
            }
            LevelOutcome::Reject { token } => {
                level_trials.push((tokens.len(), 0));
                return VerifyResult { accepted, final_token: token, bonus: false, level_trials };
            }
        }
    }
    // all levels accepted (or branch ended): bonus token from q(.|cur)
    let lp = match cur {
        None => root_target_lp,
        Some(id) => &node_target_lp[id],
    };
    let token = sample_categorical(&lp.probs(), rng) as u32;
    VerifyResult { accepted, final_token: token, bonus: true, level_trials }
}

fn chain_nodes(tokens: &[u32]) -> Vec<EvalNode> {
    tokens
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            if i == 0 {
                EvalNode::root(t)
            } else {
                EvalNode { token: t, parent: i as i64 - 1 }
            }
        })
        .collect()
}

/// What one [`SpecStepper::step`] produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Round completed, generation continues.
    Progress,
    /// Request finished (max tokens reached or capacity exhausted).
    Done,
}

/// Resumable speculative decoding session over a (target, draft) pair.
pub struct SpecStepper<T: Llm, D: Llm> {
    strategy: Box<dyn TreeStrategy>,
    rule: Box<dyn VerifyRule>,
    sampling: SamplingConfig,
    dsess: D::Session,
    tsess: T::Session,
    /// Tokens of the logical sequence not yet in the draft's KV cache
    /// (leaf-level accepts are never draft-evaluated + the final token).
    tail_draft: Vec<u32>,
    /// Tokens not yet in the target's KV cache (only the final token of
    /// the previous round; the whole prompt on round 1).
    tail_target: Vec<u32>,
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    /// Telemetry of the most recent round; `None` when the last `step`
    /// did not run a round (finished / capacity-stopped).
    last_round: Option<RoundReport>,
    max_new: usize,
    started: Instant,
    done: bool,
}

impl<T: Llm, D: Llm> SpecStepper<T, D> {
    pub fn new(
        target: &T,
        draft: &D,
        strategy: Box<dyn TreeStrategy>,
        rule: Box<dyn VerifyRule>,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        if prompt.is_empty() {
            bail!("prompt must be non-empty");
        }
        Ok(Self {
            strategy,
            rule,
            sampling,
            dsess: draft.begin()?,
            tsess: target.begin()?,
            tail_draft: prompt.to_vec(),
            tail_target: prompt.to_vec(),
            out: Vec::new(),
            stats: DecodeStats::default(),
            last_round: None,
            max_new,
            started: Instant::now(),
            done: false,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Telemetry of the most recent completed round.
    pub fn last_round(&self) -> Option<&RoundReport> {
        self.last_round.as_ref()
    }

    /// Swap the tree strategy before the next round (adaptive tree
    /// re-shaping). Safe at round granularity: every per-round strategy
    /// state is reset by `begin_round`, and the KV sessions only depend
    /// on the committed chain, never on how past trees were shaped.
    pub fn set_strategy(&mut self, strategy: Box<dyn TreeStrategy>) {
        self.strategy = strategy;
    }

    fn finish(&mut self) -> StepOutcome {
        self.out.truncate(self.max_new);
        self.stats.generated = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
        StepOutcome::Done
    }

    /// Run one speculative round (Figure 2 of the paper).
    pub fn step(&mut self, target: &T, draft: &D, rng: &mut Rng) -> Result<StepOutcome> {
        self.last_round = None;
        if self.done {
            return Ok(StepOutcome::Done);
        }
        if self.out.len() >= self.max_new {
            return Ok(self.finish());
        }
        let depth = self.strategy.depth();
        // capacity guard: tail + a full tree + bonus token
        let need = self.tail_draft.len().max(self.tail_target.len())
            + self.strategy.max_nodes()
            + 2;
        if target.capacity_left(&self.tsess) < need || draft.capacity_left(&self.dsess) < need {
            return Ok(self.finish());
        }
        let sampling = self.sampling;
        let dtail_len = self.tail_draft.len();

        // ---- draft phase -------------------------------------------------
        let tail_nodes = chain_nodes(&self.tail_draft);
        let drows = draft.eval(&mut self.dsess, &tail_nodes)?;
        self.stats.draft_calls += 1;
        let root_draft_lp = process_logits(
            drows.last().expect("tail non-empty"),
            sampling.temperature,
            sampling.top_p,
        );
        let mut tree = DraftTree { nodes: Vec::new(), levels: Vec::new(), root_draft_lp };
        self.strategy.begin_round();
        let mut draft_pending_count = dtail_len;
        for level in 0..depth {
            let children = self.strategy.expand(&tree, level, rng);
            if children.is_empty() {
                break;
            }
            // merge duplicates (same parent + token): i.i.d. strategies
            // produce them; without-replacement strategies cannot.
            let mut created: Vec<usize> = Vec::new();
            for c in &children {
                if let Some(&id) = created.iter().find(|&&id| {
                    tree.nodes[id].parent == c.parent && tree.nodes[id].token == c.token
                }) {
                    tree.nodes[id].mult += 1;
                    continue;
                }
                let id = tree.nodes.len();
                tree.nodes.push(TreeNode {
                    token: c.token,
                    parent: c.parent,
                    level,
                    mult: 1,
                    draft_pending: None,
                    draft_lp: None,
                });
                created.push(id);
            }
            tree.levels.push(created.clone());
            self.strategy.on_created(&tree, level, &created);

            // evaluate this level with the draft model unless it is the
            // leaf level (leaf distributions are never used for drafting).
            if level + 1 < depth {
                let nodes: Vec<EvalNode> = created
                    .iter()
                    .map(|&id| {
                        let parent_pending = match tree.nodes[id].parent {
                            None => dtail_len as i64 - 1,
                            Some(p) => tree.nodes[p]
                                .draft_pending
                                .expect("parent evaluated at previous level")
                                as i64,
                        };
                        EvalNode { token: tree.nodes[id].token, parent: parent_pending }
                    })
                    .collect();
                let rows = draft.eval(&mut self.dsess, &nodes)?;
                self.stats.draft_calls += 1;
                for (i, &id) in created.iter().enumerate() {
                    tree.nodes[id].draft_pending = Some(draft_pending_count + i);
                    tree.nodes[id].draft_lp =
                        Some(process_logits(&rows[i], sampling.temperature, sampling.top_p));
                }
                draft_pending_count += created.len();
            }
        }

        // ---- target phase: tail + whole tree in one parallel pass --------
        let ttail_len = self.tail_target.len();
        let mut tnodes = chain_nodes(&self.tail_target);
        for (id, n) in tree.nodes.iter().enumerate() {
            let parent = match n.parent {
                None => (ttail_len - 1) as i64,
                Some(p) => (ttail_len + p) as i64,
            };
            debug_assert_eq!(id + ttail_len, tnodes.len());
            tnodes.push(EvalNode { token: n.token, parent });
        }
        let trows = target.eval(&mut self.tsess, &tnodes)?;
        self.stats.decode_calls += 1;
        self.stats.tree_nodes += tree.nodes.len();
        let root_target_lp =
            process_logits(&trows[ttail_len - 1], sampling.temperature, sampling.top_p);
        let node_target_lp: Vec<LogProbs> = trows[ttail_len..]
            .iter()
            .map(|r| process_logits(r, sampling.temperature, sampling.top_p))
            .collect();

        // ---- verification (recursive rejection sampling per level) -------
        let vr = verify_tree(&tree, self.rule.as_ref(), &root_target_lp, &node_target_lp, rng);
        self.stats.accepted_draft_tokens += vr.accepted.len();
        if vr.bonus {
            self.stats.bonus_tokens += 1;
        }
        for (lvl, &(_, success)) in vr.level_trials.iter().enumerate() {
            if self.stats.level_attempts.len() <= lvl {
                self.stats.level_attempts.resize(lvl + 1, 0);
                self.stats.level_accepts.resize(lvl + 1, 0);
            }
            self.stats.level_attempts[lvl] += 1;
            self.stats.level_accepts[lvl] += success as u64;
        }
        self.stats.round_nodes.push(tree.nodes.len() as u32);
        self.last_round = Some(RoundReport {
            level_trials: vr.level_trials.clone(),
            nodes: tree.nodes.len(),
            accepted: vr.accepted.len(),
            bonus: vr.bonus,
        });

        // ---- zero-copy KV commit (FilterKVCache) --------------------------
        let mut tchain: Vec<usize> = (0..ttail_len).collect();
        tchain.extend(vr.accepted.iter().map(|&id| ttail_len + id));
        target.commit(&mut self.tsess, &tchain)?;

        let mut dchain: Vec<usize> = (0..dtail_len).collect();
        let mut uncached: Vec<u32> = Vec::new();
        for &id in &vr.accepted {
            match tree.nodes[id].draft_pending {
                Some(p) if uncached.is_empty() => dchain.push(p),
                _ => uncached.push(tree.nodes[id].token),
            }
        }
        draft.commit(&mut self.dsess, &dchain)?;

        // ---- emit tokens ---------------------------------------------------
        for &id in &vr.accepted {
            self.out.push(tree.nodes[id].token);
        }
        self.out.push(vr.final_token);
        // next round's per-session tails: the target already holds every
        // accepted node's KV (only the final token is new to it); the
        // draft additionally misses leaf-level accepts it never evaluated.
        uncached.push(vr.final_token);
        self.tail_draft = uncached;
        self.tail_target = vec![vr.final_token];

        if self.out.len() >= self.max_new {
            return Ok(self.finish());
        }
        Ok(StepOutcome::Progress)
    }
}

/// The full decoding loop shared by SD / SpecTr / RSD-C / RSD-S.
#[allow(clippy::too_many_arguments)]
pub fn run_spec<T, D>(
    target: &T,
    draft: &D,
    strategy: Box<dyn TreeStrategy>,
    rule: Box<dyn VerifyRule>,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun>
where
    T: Llm,
    D: Llm,
{
    let mut stepper =
        SpecStepper::new(target, draft, strategy, rule, *sampling, prompt, max_new)?;
    while stepper.step(target, draft, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out.clone(), stats: stepper.stats.clone() })
}
