//! Verification rules for one level of the draft-token tree.
//!
//! The paper's theoretical contribution, *recursive rejection sampling*
//! (Alg. 1 / Alg. 6), generalizes multi-round rejection sampling to draft
//! distributions with dependencies — here, sampling **without
//! replacement**: after the k-th sibling is rejected, the next sibling is
//! distributed as p conditioned on not being any of the previous ones, so
//! the draft distribution must be renormalized with tried tokens removed,
//! while the target residual shrinks by the (renormalized) draft mass.
//!
//! Baselines implemented under the same interface:
//! * [`MultiRound`] — SpecInfer-style (sampling *with* replacement: the
//!   draft distribution never changes between siblings);
//! * [`KSeq`] — SpecTr's K-sequential selection with its γ-scaled
//!   acceptance and closed-form residual.
//!
//! All rules recover the target distribution exactly; RRS additionally
//! achieves the highest acceptance rate for without-replacement siblings
//! (Theorem 3.1, tested statistically in rust/tests/props.rs).

use crate::sampling::{kernels, residual_in_place, sample_categorical, LogProbs, VerifyScratch};
use crate::util::Rng;

/// Outcome of verifying one sibling set.
#[derive(Debug, Clone, PartialEq)]
pub enum LevelOutcome {
    /// `siblings[pos]` accepted; continue to its children.
    Accept { pos: usize },
    /// All siblings rejected; `token` sampled from the final residual —
    /// it terminates the round.
    Reject { token: u32 },
}

pub trait VerifyRule: Send {
    /// Verify an ordered sibling set `siblings` (construction order = the
    /// without-replacement order for RSD) whose parent context has
    /// processed draft distribution `draft` and target distribution
    /// `target`. All probability-space working state lives in `scratch`
    /// (caller-owned, reused across levels and rounds), so verification
    /// is allocation-free.
    fn verify_with(
        &self,
        siblings: &[u32],
        draft: &LogProbs,
        target: &LogProbs,
        scratch: &mut VerifyScratch,
        rng: &mut Rng,
    ) -> LevelOutcome;

    /// Convenience wrapper allocating a throwaway scratch
    /// (tests/benches only).
    fn verify(
        &self,
        siblings: &[u32],
        draft: &LogProbs,
        target: &LogProbs,
        rng: &mut Rng,
    ) -> LevelOutcome {
        let mut scratch = VerifyScratch::default();
        self.verify_with(siblings, draft, target, &mut scratch, rng)
    }
}

/// Recursive rejection sampling (the paper's Alg. 6).
#[derive(Debug, Clone, Copy, Default)]
pub struct Rrs;

impl VerifyRule for Rrs {
    fn verify_with(
        &self,
        siblings: &[u32],
        draft: &LogProbs,
        target: &LogProbs,
        scratch: &mut VerifyScratch,
        rng: &mut Rng,
    ) -> LevelOutcome {
        let q = &mut scratch.q;
        let p = &mut scratch.p;
        target.probs_into(q);
        draft.probs_into(p);
        for (pos, &x) in siblings.iter().enumerate() {
            let xi = x as usize;
            let (qx, px) = (q[xi], p[xi]);
            // accept with min(1, q^{(k)}(x) / p^{(k)}(x))
            if px > 0.0 && rng.gen_f64() < (qx / px).min(1.0) {
                return LevelOutcome::Accept { pos };
            }
            // q^{(k+1)} = Norm[[q^{(k)} - p^{(k)}]^+]; when the residual
            // mass vanished the draft's remaining support covers q
            // exactly — fall back to sampling q directly.
            if !residual_in_place(q, p) {
                break;
            }
            // p^{(k+1)} = p^{(k)} conditioned on not drawing x (sampling
            // without replacement): zero the tried token, renormalize.
            // The mass fold is chunked (kernels ULP contract); the
            // division pass is elementwise and vectorizes as-is.
            p[xi] = 0.0;
            let z = kernels::sum(p);
            if z <= 0.0 {
                break;
            }
            for v in p.iter_mut() {
                *v /= z;
            }
        }
        LevelOutcome::Reject { token: sample_categorical(q, rng) as u32 }
    }
}

/// SpecInfer-style multi-round rejection sampling (sampling WITH
/// replacement: p is never renormalized between siblings).
#[derive(Debug, Clone, Copy, Default)]
pub struct MultiRound;

impl VerifyRule for MultiRound {
    fn verify_with(
        &self,
        siblings: &[u32],
        draft: &LogProbs,
        target: &LogProbs,
        scratch: &mut VerifyScratch,
        rng: &mut Rng,
    ) -> LevelOutcome {
        let q = &mut scratch.q;
        let p = &mut scratch.p;
        target.probs_into(q);
        draft.probs_into(p);
        for (pos, &x) in siblings.iter().enumerate() {
            let xi = x as usize;
            if p[xi] > 0.0 && rng.gen_f64() < (q[xi] / p[xi]).min(1.0) {
                return LevelOutcome::Accept { pos };
            }
            if !residual_in_place(q, p) {
                break;
            }
        }
        LevelOutcome::Reject { token: sample_categorical(q, rng) as u32 }
    }
}

/// SpecTr K-SEQ (Sun et al. 2023): siblings are i.i.d. draws from p;
/// accept each with min(1, q(x) / (γ p(x))); on total rejection sample
/// from the closed-form residual
///   Norm[ q - min(p, q/γ) (1-(1-β)^K)/β ],  β = Σ min(p, q/γ).
#[derive(Debug, Clone, Copy)]
pub struct KSeq {
    /// γ ∈ [1, K]; `None` tunes γ per level: the smallest *valid* γ
    /// (pointwise non-negative residual) maximizes acceptance, matching
    /// the paper's tuned K-SEQ baseline. γ depends only on (p, q, K), so
    /// exactness is preserved.
    pub gamma: Option<f64>,
}

impl KSeq {
    fn tune_gamma(p: &[f64], q: &[f64], k: usize) -> f64 {
        let kf = k as f64;
        let steps = 16;
        for i in 0..=steps {
            let gamma = 1.0 + (kf - 1.0) * i as f64 / steps as f64;
            let beta: f64 = p.iter().zip(q).map(|(&pi, &qi)| pi.min(qi / gamma)).sum();
            if beta <= 0.0 {
                return gamma;
            }
            let scale = (1.0 - (1.0 - beta).powf(kf)) / beta;
            let valid = p
                .iter()
                .zip(q)
                .all(|(&pi, &qi)| qi - pi.min(qi / gamma) * scale >= -1e-12);
            if valid {
                return gamma;
            }
        }
        kf
    }
}

impl VerifyRule for KSeq {
    fn verify_with(
        &self,
        siblings: &[u32],
        draft: &LogProbs,
        target: &LogProbs,
        scratch: &mut VerifyScratch,
        rng: &mut Rng,
    ) -> LevelOutcome {
        let q = &mut scratch.q;
        let p = &mut scratch.p;
        target.probs_into(q);
        draft.probs_into(p);
        let kf = siblings.len() as f64;
        let gamma = self
            .gamma
            .unwrap_or_else(|| Self::tune_gamma(p, q, siblings.len()))
            .clamp(1.0, kf.max(1.0));
        for (pos, &x) in siblings.iter().enumerate() {
            let xi = x as usize;
            if p[xi] > 0.0 && rng.gen_f64() < (q[xi] / (gamma * p[xi])).min(1.0) {
                return LevelOutcome::Accept { pos };
            }
        }
        let beta: f64 = q
            .iter()
            .zip(p.iter())
            .map(|(&qi, &pi)| pi.min(qi / gamma))
            .sum();
        let scale = if beta > 0.0 {
            (1.0 - (1.0 - beta).powf(kf)) / beta
        } else {
            0.0
        };
        let res = &mut scratch.aux;
        res.clear();
        res.extend(
            q.iter()
                .zip(p.iter())
                .map(|(&qi, &pi)| (qi - pi.min(qi / gamma) * scale).max(0.0)),
        );
        let z: f64 = res.iter().sum();
        let token = if z > 1e-300 {
            sample_categorical(res, rng) as u32
        } else {
            sample_categorical(q, rng) as u32
        };
        LevelOutcome::Reject { token }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{gumbel_top_k, tv_distance};

    fn lp(probs: &[f64]) -> LogProbs {
        LogProbs(probs.iter().map(|&p| if p > 0.0 { p.ln() } else { f64::NEG_INFINITY }).collect())
    }

    /// End-to-end distributional check: siblings drawn WITHOUT replacement
    /// via Gumbel-Top-k, verified with RRS -> outcome token ~ q exactly
    /// (Theorem 3.1 for the sampling-without-replacement instance).
    #[test]
    fn rrs_recovers_target_distribution() {
        let p = lp(&[0.5, 0.3, 0.15, 0.05]);
        let q = lp(&[0.1, 0.2, 0.3, 0.4]); // adversarially different
        let mut rng = Rng::seed_from_u64(0);
        let n = 200_000;
        for k in 1..=3usize {
            let mut hist = vec![0f64; 4];
            for _ in 0..n {
                let sib: Vec<u32> =
                    gumbel_top_k(&p, k, &mut rng).iter().map(|&(i, _)| i as u32).collect();
                let tok = match Rrs.verify(&sib, &p, &q, &mut rng) {
                    LevelOutcome::Accept { pos } => sib[pos],
                    LevelOutcome::Reject { token } => token,
                };
                hist[tok as usize] += 1.0;
            }
            for h in &mut hist {
                *h /= n as f64;
            }
            let tv = tv_distance(&hist, &q.probs());
            assert!(tv < 0.01, "K={k}: TV {tv} too large: {hist:?}");
        }
    }

    /// The Bernoulli toy of Fig. 1: with K=2 (the whole binary vocab
    /// drafted without replacement), RRS accepts with probability 1.
    #[test]
    fn rrs_toy_always_accepts_full_support() {
        let p = lp(&[0.9, 0.1]);
        let q = lp(&[0.05, 0.95]);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..20_000 {
            let sib: Vec<u32> =
                gumbel_top_k(&p, 2, &mut rng).iter().map(|&(i, _)| i as u32).collect();
            match Rrs.verify(&sib, &p, &q, &mut rng) {
                LevelOutcome::Accept { .. } => {}
                LevelOutcome::Reject { .. } => panic!("RRS must accept when siblings cover X"),
            }
        }
    }

    /// Multi-round with i.i.d. siblings also recovers q (sanity for the
    /// baseline), but accepts less often than RRS on the toy pair.
    #[test]
    fn multiround_recovers_target_and_accepts_less() {
        let p = lp(&[0.9, 0.1]);
        let q = lp(&[0.05, 0.95]);
        let mut rng = Rng::seed_from_u64(2);
        let n = 100_000;
        let mut hist = vec![0f64; 2];
        let mut accepts = 0usize;
        for _ in 0..n {
            // i.i.d. siblings (with replacement) — multi-round's regime
            let sib: Vec<u32> = (0..2)
                .map(|_| sample_categorical(&p.probs(), &mut rng) as u32)
                .collect();
            match MultiRound.verify(&sib, &p, &q, &mut rng) {
                LevelOutcome::Accept { pos } => {
                    accepts += 1;
                    hist[sib[pos] as usize] += 1.0;
                }
                LevelOutcome::Reject { token } => hist[token as usize] += 1.0,
            }
        }
        for h in &mut hist {
            *h /= n as f64;
        }
        assert!(tv_distance(&hist, &q.probs()) < 0.01, "{hist:?}");
        let rate = accepts as f64 / n as f64;
        assert!(rate < 0.9, "multi-round acceptance {rate} suspiciously high");
    }

    /// K-SEQ with i.i.d. siblings recovers q.
    #[test]
    fn kseq_recovers_target() {
        let p = lp(&[0.4, 0.35, 0.15, 0.1]);
        let q = lp(&[0.1, 0.15, 0.35, 0.4]);
        let mut rng = Rng::seed_from_u64(3);
        let n = 200_000;
        for k in [2usize, 3] {
            let mut hist = vec![0f64; 4];
            for _ in 0..n {
                let sib: Vec<u32> = (0..k)
                    .map(|_| sample_categorical(&p.probs(), &mut rng) as u32)
                    .collect();
                let tok = match (KSeq { gamma: None }).verify(&sib, &p, &q, &mut rng) {
                    LevelOutcome::Accept { pos } => sib[pos],
                    LevelOutcome::Reject { token } => token,
                };
                hist[tok as usize] += 1.0;
            }
            for h in &mut hist {
                *h /= n as f64;
            }
            let tv = tv_distance(&hist, &q.probs());
            assert!(tv < 0.01, "K={k}: TV {tv}: {hist:?}");
        }
    }

    /// Single sibling: RRS degenerates to classic speculative-decoding
    /// rejection sampling, multi-round and RRS coincide.
    #[test]
    fn single_sibling_reduces_to_classic() {
        let p = lp(&[0.7, 0.3]);
        let q = lp(&[0.4, 0.6]);
        let n = 100_000;
        let mut r1 = Rng::seed_from_u64(4);
        let mut r2 = Rng::seed_from_u64(4);
        let mut acc_a = 0;
        let mut acc_b = 0;
        for _ in 0..n {
            let x = sample_categorical(&p.probs(), &mut r1) as u32;
            let y = sample_categorical(&p.probs(), &mut r2) as u32;
            assert_eq!(x, y);
            if matches!(Rrs.verify(&[x], &p, &q, &mut r1), LevelOutcome::Accept { .. }) {
                acc_a += 1;
            }
            if matches!(MultiRound.verify(&[y], &p, &q, &mut r2), LevelOutcome::Accept { .. }) {
                acc_b += 1;
            }
        }
        // identical RNG streams => identical decisions
        assert_eq!(acc_a, acc_b);
        // theoretical acceptance = sum min(p, q) = 0.4 + 0.3 = 0.7
        let rate = acc_a as f64 / n as f64;
        assert!((rate - 0.7).abs() < 0.01, "rate {rate}");
    }

    /// RRS must accept strictly more than K-SEQ and multi-round when the
    /// siblings come without replacement and discrepancy is high (Fig. 1).
    #[test]
    fn rrs_dominates_baselines_on_toy() {
        let p = lp(&[0.8, 0.2]);
        let q = lp(&[0.2, 0.8]);
        let mut rng = Rng::seed_from_u64(5);
        let n = 50_000;
        let mut acc = [0usize; 3];
        for _ in 0..n {
            let wor: Vec<u32> =
                gumbel_top_k(&p, 2, &mut rng).iter().map(|&(i, _)| i as u32).collect();
            let iid: Vec<u32> = (0..2)
                .map(|_| sample_categorical(&p.probs(), &mut rng) as u32)
                .collect();
            if matches!(Rrs.verify(&wor, &p, &q, &mut rng), LevelOutcome::Accept { .. }) {
                acc[0] += 1;
            }
            if matches!(MultiRound.verify(&iid, &p, &q, &mut rng), LevelOutcome::Accept { .. }) {
                acc[1] += 1;
            }
            if matches!(
                (KSeq { gamma: None }).verify(&iid, &p, &q, &mut rng),
                LevelOutcome::Accept { .. }
            ) {
                acc[2] += 1;
            }
        }
        assert!(acc[0] > acc[1] && acc[0] > acc[2], "{acc:?}");
        assert_eq!(acc[0], n, "RRS accepts always on full-support toy");
    }
}
