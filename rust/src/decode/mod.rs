//! Decoding algorithms: the paper's RSD-C and RSD-S plus every baseline
//! it evaluates against (AR, SD, SpecTr K-SEQ) and the SpecInfer-style
//! multi-round rule used in Figure 1.
//!
//! All decoders are generic over [`crate::llm::Llm`], so they run
//! unchanged on the AOT-compiled PJRT model and on the analytic sim.

pub mod ar;
pub mod rrs;
pub mod spec;
pub mod strategies;
pub mod toy;

use std::time::Duration;

use anyhow::Result;

use crate::config::{DecoderConfig, SamplingConfig};
use crate::llm::Llm;
use crate::util::Rng;

use rrs::{KSeq, MultiRound, Rrs};
use strategies::{Chain, GumbelTopK, IidPaths, StochasticBeam};

/// Counters for one decode run.
#[derive(Debug, Clone, Default)]
pub struct DecodeStats {
    /// Target-model iterations (block-efficiency denominator; the prompt
    /// pass counts as the first iteration, as in the paper).
    pub decode_calls: usize,
    /// Draft-model invocations.
    pub draft_calls: usize,
    /// Total draft-tree nodes processed by the target (actual budget).
    pub tree_nodes: usize,
    /// Draft tokens accepted by verification.
    pub accepted_draft_tokens: usize,
    /// Rounds where the walk exited the tree (all levels accepted).
    pub bonus_tokens: usize,
    pub generated: usize,
    pub wall: Duration,
    /// Per-level verification attempts (index = tree level); with
    /// `level_accepts` this is the per-request acceptance-rate telemetry
    /// surfaced in the server's `done` event and consumed by the
    /// adaptive controller.
    pub level_attempts: Vec<u64>,
    /// Per-level accepted verifications (walk survived that level).
    pub level_accepts: Vec<u64>,
    /// Draft-tree nodes the target processed in each round, in round
    /// order — the actual per-round budget trajectory (histogrammed by
    /// the serving metrics; hard-capped by `DecoderConfig::Adaptive`).
    pub round_nodes: Vec<u32>,
    /// Prompt/prefix tokens served from the shared KV cache instead of
    /// being (re)computed — summed over both models and over resumes
    /// after preemption. 0 on dense substrates.
    pub kv_hit_tokens: usize,
    /// Times this request was preempted (suspended + later resumed) by
    /// the engine under KV memory pressure.
    pub preemptions: usize,
    /// Pool-wide KV occupancy/telemetry at completion, attached by the
    /// serving engine when the substrate is pool-backed (None for
    /// single-shot decodes and dense substrates).
    pub kv_pool: Option<crate::kvcache::PoolStatus>,
}

impl DecodeStats {
    /// Block efficiency η (Leviathan et al.): tokens per target call.
    pub fn block_efficiency(&self) -> f64 {
        if self.decode_calls == 0 {
            return 0.0;
        }
        self.generated as f64 / self.decode_calls as f64
    }

    /// Memory-Bound Speed-Up (App. C.2): η / (L·r + 1) with r the
    /// draft/target size ratio and L the draft depth.
    pub fn mbsu(&self, depth: usize, draft_params: usize, target_params: usize) -> f64 {
        let r = draft_params as f64 / target_params as f64;
        self.block_efficiency() / (depth as f64 * r + 1.0)
    }

    /// Measured tokens per second.
    pub fn token_rate(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.generated as f64 / self.wall.as_secs_f64()
    }
}

/// Result of one decode run.
#[derive(Debug, Clone)]
pub struct DecodeRun {
    pub tokens: Vec<u32>,
    pub stats: DecodeStats,
}

/// Run `decoder` on (target, draft) for `prompt`, generating up to
/// `max_new` tokens. The single entry point used by the engine, the
/// benches and the examples.
pub fn generate<T: Llm, D: Llm>(
    decoder: &DecoderConfig,
    sampling: &SamplingConfig,
    target: &T,
    draft: &D,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun> {
    match decoder {
        DecoderConfig::Ar => ar::run_ar(target, sampling, prompt, max_new, rng),
        DecoderConfig::Adaptive { budget, family } => crate::adaptive::run_adaptive(
            target, draft, *budget, *family, sampling, prompt, max_new, rng,
        ),
        _ => {
            let (strategy, rule) = build_parts(decoder);
            spec::run_spec(target, draft, strategy, rule, sampling, prompt, max_new, rng)
        }
    }
}

/// Instantiate the (strategy, rule) pair for a tree-based decoder config.
/// Panics on `Ar` (which has no tree). For `Adaptive` this returns the
/// uniform-prior *initial* shape; callers wanting per-round re-shaping
/// use [`crate::adaptive::AdaptiveStepper`] instead.
pub fn build_parts(
    decoder: &DecoderConfig,
) -> (Box<dyn spec::TreeStrategy>, Box<dyn rrs::VerifyRule>) {
    use crate::adaptive::allocator::{initial_shape, DEFAULT_PHI_GAP};
    match decoder {
        DecoderConfig::Ar => unreachable!("AR has no tree strategy"),
        DecoderConfig::Adaptive { budget, family } => {
            (initial_shape(*budget, *family).build(DEFAULT_PHI_GAP), Box::new(Rrs))
        }
        DecoderConfig::Sd { l } => (Box::new(Chain { depth: *l }), Box::new(Rrs)),
        DecoderConfig::SpecTr { k, l } => {
            (Box::new(IidPaths { k: *k, depth: *l }), Box::new(KSeq { gamma: None }))
        }
        DecoderConfig::RsdC { branches } => {
            (Box::new(GumbelTopK::new(branches.clone())), Box::new(Rrs))
        }
        DecoderConfig::RsdCMultiRound { branches } => {
            (Box::new(GumbelTopK::new(branches.clone())), Box::new(MultiRound))
        }
        DecoderConfig::RsdS { w, l } => (Box::new(StochasticBeam::new(*w, *l)), Box::new(Rrs)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimLm;

    fn all_decoders() -> Vec<DecoderConfig> {
        vec![
            DecoderConfig::Ar,
            DecoderConfig::Sd { l: 3 },
            DecoderConfig::SpecTr { k: 3, l: 3 },
            DecoderConfig::RsdC { branches: vec![2, 2, 1] },
            DecoderConfig::RsdS { w: 3, l: 3 },
            DecoderConfig::Adaptive {
                budget: 6,
                family: crate::config::AdaptiveFamily::Auto,
            },
        ]
    }

    #[test]
    fn every_decoder_generates_exactly_max_new() {
        let (target, draft) = SimLm::pair(3, 0.7, 48);
        let mut rng = Rng::seed_from_u64(0);
        let sampling = SamplingConfig::default();
        for cfg in all_decoders() {
            let run =
                generate(&cfg, &sampling, &target, &draft, &[1, 2, 3], 24, &mut rng).unwrap();
            assert_eq!(run.tokens.len(), 24, "{cfg:?}");
            assert_eq!(run.stats.generated, 24);
            assert!(run.tokens.iter().all(|&t| t < 48));
        }
    }

    #[test]
    fn spec_decoders_beat_ar_block_efficiency_when_aligned() {
        let (target, draft) = SimLm::pair(5, 0.97, 48);
        let mut rng = Rng::seed_from_u64(1);
        let sampling = SamplingConfig::new(0.5, 1.0);
        for cfg in all_decoders().into_iter().skip(1) {
            let run =
                generate(&cfg, &sampling, &target, &draft, &[7, 8], 64, &mut rng).unwrap();
            assert!(
                run.stats.block_efficiency() > 1.3,
                "{cfg:?}: eff {}",
                run.stats.block_efficiency()
            );
        }
    }

    #[test]
    fn rsd_s_beats_sd_on_efficiency_misaligned() {
        // high discrepancy: the without-replacement tree must help
        let (target, draft) = SimLm::pair(9, 0.4, 48);
        let sampling = SamplingConfig::new(0.7, 1.0);
        let mut eff_sd = 0.0;
        let mut eff_rsds = 0.0;
        for seed in 0..8 {
            let mut rng = Rng::seed_from_u64(seed);
            eff_sd += generate(
                &DecoderConfig::Sd { l: 3 },
                &sampling,
                &target,
                &draft,
                &[1],
                64,
                &mut rng,
            )
            .unwrap()
            .stats
            .block_efficiency();
            let mut rng = Rng::seed_from_u64(seed);
            eff_rsds += generate(
                &DecoderConfig::RsdS { w: 4, l: 3 },
                &sampling,
                &target,
                &draft,
                &[1],
                64,
                &mut rng,
            )
            .unwrap()
            .stats
            .block_efficiency();
        }
        assert!(eff_rsds > eff_sd, "RSD-S {eff_rsds} vs SD {eff_sd}");
    }

    #[test]
    fn stats_are_consistent() {
        let (target, draft) = SimLm::pair(2, 0.8, 32);
        let mut rng = Rng::seed_from_u64(4);
        let run = generate(
            &DecoderConfig::RsdC { branches: vec![2, 2] },
            &SamplingConfig::default(),
            &target,
            &draft,
            &[3, 4, 5],
            40,
            &mut rng,
        )
        .unwrap();
        let s = &run.stats;
        assert!(s.decode_calls > 0);
        assert!(s.tree_nodes >= s.decode_calls * 2); // budget 6 per round... >= 2
        assert!(s.accepted_draft_tokens + s.decode_calls >= s.generated);
        assert!(s.block_efficiency() >= 1.0);
    }

    #[test]
    fn mbsu_normalizes_like_paper() {
        let mut s = DecodeStats { decode_calls: 10, generated: 25, ..Default::default() };
        s.accepted_draft_tokens = 15;
        // eff 2.5, r = 0.05, L = 4 -> mbsu = 2.5 / 1.2
        let m = s.mbsu(4, 50, 1000);
        assert!((m - 2.5 / 1.2).abs() < 1e-12);
    }
}
