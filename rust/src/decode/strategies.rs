//! Draft-tree construction strategies: one per decoding algorithm.
//!
//! * [`Chain`] — single sampled path (classic speculative decoding).
//! * [`IidPaths`] — K i.i.d. draft paths merged into a trie (SpecTr).
//! * [`GumbelTopK`] — RSD-C: per-parent Gumbel-Top-b sampling *without
//!   replacement* (paper Alg. 3/4).
//! * [`StochasticBeam`] — RSD-S: Stochastic Beam Search over sequences
//!   (paper Alg. 8/9, Kool et al. 2019), which samples *sequences*
//!   without replacement and early-truncates unlikely branches.
//!
//! Every strategy owns its expansion scratch (perturbation buffers,
//! selection heaps) and writes proposed children into the caller's
//! buffer, so steady-state tree growth is allocation-free. Chain and
//! IidPaths sample in log space (Gumbel-max) — no probability
//! materialization per expand; RSD selection goes through the bounded
//! partial-selection kernels of [`crate::sampling`].

use crate::sampling::{
    bounded_heap_offer, gumbel_max, gumbel_top_k_into, kernels, truncated_gumbel_one, LogProbs,
    NEG_INF,
};
use crate::util::Rng;

use super::spec::{Child, DraftTree, TreeStrategy};

fn parent_lp<'t>(tree: &'t DraftTree, parent: Option<usize>) -> &'t LogProbs {
    match parent {
        None => &tree.root_draft_lp,
        Some(p) => tree.nodes[p].draft_lp.as_ref().expect("parent evaluated"),
    }
}

/// Classic SD: one sampled token per level.
pub struct Chain {
    pub depth: usize,
}

impl TreeStrategy for Chain {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.depth
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng, out: &mut Vec<Child>) {
        let parent = if level == 0 {
            None
        } else {
            // a truncated/empty previous level ends the chain instead of
            // panicking (possible when strategies are swapped mid-stream)
            match tree.levels.get(level - 1).and_then(|l| l.last()) {
                Some(&id) => Some(id),
                None => return,
            }
        };
        let lp = parent_lp(tree, parent);
        if let Some(token) = gumbel_max(&lp.0, rng) {
            out.push(Child { parent, token: token as u32 });
        }
    }
}

/// SpecTr: K i.i.d. draft paths. Duplicate (parent, token) pairs merge
/// into trie nodes carrying multiplicity; a node with multiplicity m
/// spawns m i.i.d. children at the next level.
pub struct IidPaths {
    pub k: usize,
    pub depth: usize,
}

impl TreeStrategy for IidPaths {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.k * self.depth
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng, out: &mut Vec<Child>) {
        if level == 0 {
            for _ in 0..self.k {
                if let Some(t) = gumbel_max(&tree.root_draft_lp.0, rng) {
                    out.push(Child { parent: None, token: t as u32 });
                }
            }
        } else {
            for &id in &tree.levels[level - 1] {
                let lp = parent_lp(tree, Some(id));
                for _ in 0..tree.nodes[id].mult {
                    if let Some(t) = gumbel_max(&lp.0, rng) {
                        out.push(Child { parent: Some(id), token: t as u32 });
                    }
                }
            }
        }
    }
}

/// RSD-C: constant branching factors b = (b_0, .., b_{L-1}); each parent
/// draws its b_l children via the Gumbel-Top-k trick — sampling without
/// replacement, returned in decreasing perturbed-log-prob order (the
/// verification order required by recursive rejection sampling).
pub struct GumbelTopK {
    pub branches: Vec<usize>,
    /// Bounded-heap scratch for [`gumbel_top_k_into`], reused per parent.
    topk: Vec<(usize, f64)>,
}

impl GumbelTopK {
    pub fn new(branches: Vec<usize>) -> Self {
        Self { branches, topk: Vec::new() }
    }
}

impl TreeStrategy for GumbelTopK {
    fn depth(&self) -> usize {
        self.branches.len()
    }

    fn max_nodes(&self) -> usize {
        crate::config::rsd_c_budget(&self.branches)
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng, out: &mut Vec<Child>) {
        let b = self.branches[level];
        if level == 0 {
            gumbel_top_k_into(parent_lp(tree, None), b, rng, &mut self.topk);
            for &(idx, _) in self.topk.iter() {
                out.push(Child { parent: None, token: idx as u32 });
            }
        } else {
            for &id in &tree.levels[level - 1] {
                gumbel_top_k_into(parent_lp(tree, Some(id)), b, rng, &mut self.topk);
                for &(idx, _) in self.topk.iter() {
                    out.push(Child { parent: Some(id), token: idx as u32 });
                }
            }
        }
    }
}

/// One beam candidate held by the bounded top-W heap.
#[derive(Debug, Clone, Copy)]
struct BeamCand {
    /// i64 encoding of `Option<usize>` (-1 = root) keeps the struct Copy.
    parent: i64,
    token: u32,
    /// Candidate emission order — the tie-break that reproduces the
    /// stable full-sort's ordering byte for byte.
    order: u32,
    phi: f64,
    psi: f64,
}

/// RSD-S: Stochastic Beam Search with beamwidth W. Maintains per-node
/// cumulative sequence log-probs φ and truncated perturbed values ψ
/// (paper eq. 10-12); each level keeps the global top-W (parent, token)
/// pairs by ψ, which is equivalent to sampling the top-W length-(l+1)
/// sequences without replacement (Kool et al. 2019) and early-truncates
/// branches whose continuation mass collapsed.
pub struct StochasticBeam {
    pub w: usize,
    pub depth: usize,
    /// Early-truncation threshold: after the top-W selection, candidates
    /// whose cumulative sequence log-prob φ trails the level's best by
    /// more than this gap are dropped before drafting. Exactness is
    /// unaffected (verification sees fewer siblings, never wrong ones);
    /// the saved nodes shrink the actual per-round budget. `INFINITY`
    /// (the [`StochasticBeam::new`] default) disables truncation.
    pub max_phi_gap: f64,
    /// φ, ψ per created node id.
    state: Vec<(f64, f64)>,
    /// (φ, ψ) of the candidates proposed by the last `expand`, in the
    /// same order, consumed by `on_created`.
    staged: Vec<(f64, f64)>,
    /// Per-parent perturbed sequence log-probs φ~, reused across parents.
    phi_tilde: Vec<f64>,
    /// Bounded min-heap holding the current top-W candidates.
    heap: Vec<BeamCand>,
}

impl StochasticBeam {
    pub fn new(w: usize, depth: usize) -> Self {
        Self::with_gap(w, depth, f64::INFINITY)
    }

    /// Beam with early truncation at `max_phi_gap` log-prob units below
    /// the per-level best sequence (the adaptive controller's setting).
    pub fn with_gap(w: usize, depth: usize, max_phi_gap: f64) -> Self {
        assert!(max_phi_gap >= 0.0, "phi gap must be non-negative");
        Self {
            w,
            depth,
            max_phi_gap,
            state: Vec::new(),
            staged: Vec::new(),
            phi_tilde: Vec::new(),
            heap: Vec::new(),
        }
    }

    /// Worst-first ranking for the bounded heap: lower ψ is worse; equal
    /// ψ (NaN-safe via `total_cmp`) breaks toward the LATER candidate,
    /// reproducing the stable sort the full-sort selection used.
    #[inline]
    fn worse(a: &BeamCand, b: &BeamCand) -> bool {
        match a.psi.total_cmp(&b.psi) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => a.order > b.order,
        }
    }

    /// Stream one parent's children into the heap: draw the perturbed
    /// sequence log-probs (one Gumbel per unfiltered token, ascending
    /// index — the RNG order contract), condition them on the parent's
    /// truncated value, and offer each candidate.
    fn offer_parent(
        &mut self,
        lp: &LogProbs,
        parent: Option<usize>,
        phi_p: f64,
        psi_p: f64,
        order: &mut u32,
        rng: &mut Rng,
    ) {
        let mut phi_tilde = std::mem::take(&mut self.phi_tilde);
        phi_tilde.clear();
        // batched Gumbel perturbation: stage the serial uniform draws
        // (one per unfiltered token, ascending index — the RNG-order
        // contract), then run the double-log transform as one
        // vectorizable slice map. Bit-identical to the scalar
        // draw-per-token form, which shares the same transform.
        kernels::with_uniform_scratch(|us| {
            us.clear();
            for &l in &lp.0 {
                if l != NEG_INF {
                    us.push(rng.gen_f64_open());
                }
            }
            kernels::gumbel_map_in_place(us);
            let mut j = 0;
            phi_tilde.extend(lp.0.iter().map(|&l| {
                if l == NEG_INF {
                    NEG_INF
                } else {
                    let g = us[j];
                    j += 1;
                    phi_p + l + g
                }
            }));
        });
        let z = kernels::max(&phi_tilde);
        let parent_enc = parent.map_or(-1, |p| p as i64);
        for (x, &g) in phi_tilde.iter().enumerate() {
            let f = if lp.0[x] == NEG_INF { NEG_INF } else { phi_p + lp.0[x] };
            let s = truncated_gumbel_one(psi_p, z, g);
            // drop NaN φ/ψ (degenerate distributions) outright: the
            // NaN-safe ranking would otherwise hand the beam to a broken
            // branch
            if f != NEG_INF && s != NEG_INF && !f.is_nan() && !s.is_nan() {
                let cand =
                    BeamCand { parent: parent_enc, token: x as u32, order: *order, phi: f, psi: s };
                *order += 1;
                bounded_heap_offer(&mut self.heap, self.w, cand, Self::worse);
            }
        }
        self.phi_tilde = phi_tilde;
    }
}

impl TreeStrategy for StochasticBeam {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.w * self.depth
    }

    fn begin_round(&mut self) {
        self.state.clear();
        self.staged.clear();
    }

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng, out: &mut Vec<Child>) {
        self.heap.clear();
        let mut order = 0u32;
        if level == 0 {
            // φ_{-1} = ψ_{-1} = 0 (paper footnote 1)
            self.offer_parent(&tree.root_draft_lp, None, 0.0, 0.0, &mut order, rng);
        } else {
            for &id in &tree.levels[level - 1] {
                let (phi, psi) = self.state[id];
                let lp = parent_lp(tree, Some(id));
                self.offer_parent(lp, Some(id), phi, psi, &mut order, rng);
            }
        }
        // global top-W by ψ, decreasing (= verification order); the
        // order tie-break reproduces the stable sort exactly.
        let mut heap = std::mem::take(&mut self.heap);
        heap.sort_unstable_by(|a, b| b.psi.total_cmp(&a.psi).then(a.order.cmp(&b.order)));
        // early truncation: drop branches whose sequence mass collapsed
        // relative to the level's best (the φ-max candidate always stays)
        if self.max_phi_gap.is_finite() && !heap.is_empty() {
            let best_phi = heap.iter().map(|c| c.phi).fold(NEG_INF, f64::max);
            heap.retain(|c| c.phi >= best_phi - self.max_phi_gap);
        }
        self.staged.clear();
        self.staged.extend(heap.iter().map(|c| (c.phi, c.psi)));
        out.extend(heap.iter().map(|c| Child {
            parent: if c.parent < 0 { None } else { Some(c.parent as usize) },
            token: c.token,
        }));
        self.heap = heap;
    }

    fn on_created(&mut self, _tree: &DraftTree, _level: usize, node_ids: &[usize]) {
        // without-replacement candidates never merge: 1:1 with staged
        debug_assert_eq!(node_ids.len(), self.staged.len());
        for (&id, &st) in node_ids.iter().zip(&self.staged) {
            if self.state.len() <= id {
                self.state.resize(id + 1, (0.0, 0.0));
            }
            self.state[id] = st;
        }
        self.staged.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::process_logits;

    fn tree_with_root(logits: &[f32]) -> DraftTree {
        DraftTree {
            nodes: Vec::new(),
            levels: Vec::new(),
            root_draft_lp: process_logits(logits, 1.0, 1.0),
        }
    }

    fn expand(s: &mut dyn TreeStrategy, t: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child> {
        let mut out = Vec::new();
        s.expand(t, level, rng, &mut out);
        out
    }

    #[test]
    fn chain_proposes_single_path() {
        let t = tree_with_root(&[0.0, 1.0, 2.0]);
        let mut s = Chain { depth: 3 };
        let mut rng = Rng::seed_from_u64(0);
        let c = expand(&mut s, &t, 0, &mut rng);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].parent, None);
    }

    /// SATELLITE: the log-space Gumbel-max expand samples from the
    /// parent categorical (distribution equivalence with the dropped
    /// probs() materialization path).
    #[test]
    fn chain_expand_matches_parent_distribution() {
        let probs = [0.5, 0.05, 0.25, 0.2];
        let logits: Vec<f32> = probs.iter().map(|p| (*p as f32).ln()).collect();
        let t = tree_with_root(&logits);
        let mut s = Chain { depth: 1 };
        let mut rng = Rng::seed_from_u64(9);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let c = expand(&mut s, &t, 0, &mut rng);
            counts[c[0].token as usize] += 1;
        }
        for i in 0..4 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - probs[i]).abs() < 0.005, "{i}: {emp} vs {}", probs[i]);
        }
    }

    /// SATELLITE: i.i.d. path expansion stays i.i.d. under Gumbel-max —
    /// each of the K root draws follows the categorical independently.
    #[test]
    fn iid_expand_matches_parent_distribution() {
        let probs = [0.4, 0.1, 0.2, 0.3];
        let logits: Vec<f32> = probs.iter().map(|p| (*p as f32).ln()).collect();
        let t = tree_with_root(&logits);
        let mut s = IidPaths { k: 3, depth: 2 };
        let mut rng = Rng::seed_from_u64(17);
        let n = 100_000;
        let mut counts = [0usize; 4];
        let mut pairs = std::collections::HashMap::new();
        for _ in 0..n {
            let c = expand(&mut s, &t, 0, &mut rng);
            assert_eq!(c.len(), 3);
            for ch in &c {
                counts[ch.token as usize] += 1;
            }
            *pairs.entry((c[0].token, c[1].token)).or_insert(0usize) += 1;
        }
        for i in 0..4 {
            let emp = counts[i] as f64 / (3 * n) as f64;
            assert!((emp - probs[i]).abs() < 0.005, "{i}: {emp} vs {}", probs[i]);
        }
        // independence spot check: P(first = a, second = a) = p_a^2
        let emp = *pairs.get(&(0, 0)).unwrap_or(&0) as f64 / n as f64;
        assert!((emp - probs[0] * probs[0]).abs() < 0.01, "{emp}");
    }

    #[test]
    fn gumbel_topk_children_distinct_per_parent() {
        let t = tree_with_root(&[0.0, 0.5, 1.0, 1.5]);
        let mut s = GumbelTopK::new(vec![3]);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..100 {
            let c = expand(&mut s, &t, 0, &mut rng);
            assert_eq!(c.len(), 3);
            let mut toks: Vec<u32> = c.iter().map(|x| x.token).collect();
            toks.sort();
            toks.dedup();
            assert_eq!(toks.len(), 3, "without replacement => distinct");
        }
    }

    #[test]
    fn stochastic_beam_keeps_w_and_orders_by_psi() {
        let t = tree_with_root(&[0.1, 0.9, 0.3, 0.7, 0.5]);
        let mut s = StochasticBeam::new(3, 2);
        s.begin_round();
        let mut rng = Rng::seed_from_u64(2);
        let mut c = Vec::new();
        s.expand(&t, 0, &mut rng, &mut c);
        assert_eq!(c.len(), 3);
        // staged psi decreasing
        assert!(s.staged.windows(2).all(|w| w[0].1 >= w[1].1));
        // all from root
        assert!(c.iter().all(|x| x.parent.is_none()));
        // distinct tokens (without replacement at the root)
        let mut toks: Vec<u32> = c.iter().map(|x| x.token).collect();
        toks.sort();
        toks.dedup();
        assert_eq!(toks.len(), 3);
    }

    #[test]
    fn phi_gap_truncates_but_keeps_best() {
        let t = tree_with_root(&[0.0, 2.0, 4.0, 6.0]);
        let mut rng = Rng::seed_from_u64(3);
        let mut full = StochasticBeam::new(3, 2);
        full.begin_round();
        assert_eq!(expand(&mut full, &t, 0, &mut rng).len(), 3, "infinite gap keeps the beam");
        // gap 0: only candidates tied with the best sequence log-prob
        // survive — at least one always does
        let mut tight = StochasticBeam::with_gap(3, 2, 0.0);
        tight.begin_round();
        let c = expand(&mut tight, &t, 0, &mut rng);
        assert!(!c.is_empty() && c.len() <= 3);
    }

    #[test]
    fn max_nodes_matches_budget_definitions() {
        assert_eq!(Chain { depth: 4 }.max_nodes(), 4);
        assert_eq!(IidPaths { k: 3, depth: 7 }.max_nodes(), 21);
        assert_eq!(GumbelTopK::new(vec![2, 2, 2]).max_nodes(), 14);
        assert_eq!(StochasticBeam::new(6, 5).max_nodes(), 30);
    }
}
