//! Draft-tree construction strategies: one per decoding algorithm.
//!
//! * [`Chain`] — single sampled path (classic speculative decoding).
//! * [`IidPaths`] — K i.i.d. draft paths merged into a trie (SpecTr).
//! * [`GumbelTopK`] — RSD-C: per-parent Gumbel-Top-b sampling *without
//!   replacement* (paper Alg. 3/4).
//! * [`StochasticBeam`] — RSD-S: Stochastic Beam Search over sequences
//!   (paper Alg. 8/9, Kool et al. 2019), which samples *sequences*
//!   without replacement and early-truncates unlikely branches.


use crate::sampling::{gumbel, gumbel_top_k, sample_categorical, truncated_gumbel, LogProbs, NEG_INF};
use crate::util::Rng;

use super::spec::{Child, DraftTree, TreeStrategy};

fn parent_lp<'t>(tree: &'t DraftTree, parent: Option<usize>) -> &'t LogProbs {
    match parent {
        None => &tree.root_draft_lp,
        Some(p) => tree.nodes[p].draft_lp.as_ref().expect("parent evaluated"),
    }
}

/// Classic SD: one sampled token per level.
pub struct Chain {
    pub depth: usize,
}

impl TreeStrategy for Chain {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.depth
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child> {
        let parent = if level == 0 {
            None
        } else {
            // a truncated/empty previous level ends the chain instead of
            // panicking (possible when strategies are swapped mid-stream)
            match tree.levels.get(level - 1).and_then(|l| l.last()) {
                Some(&id) => Some(id),
                None => return Vec::new(),
            }
        };
        let lp = parent_lp(tree, parent);
        let token = sample_categorical(&lp.probs(), rng) as u32;
        vec![Child { parent, token }]
    }
}

/// SpecTr: K i.i.d. draft paths. Duplicate (parent, token) pairs merge
/// into trie nodes carrying multiplicity; a node with multiplicity m
/// spawns m i.i.d. children at the next level.
pub struct IidPaths {
    pub k: usize,
    pub depth: usize,
}

impl TreeStrategy for IidPaths {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.k * self.depth
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child> {
        let mut out = Vec::new();
        if level == 0 {
            let probs = tree.root_draft_lp.probs();
            for _ in 0..self.k {
                out.push(Child { parent: None, token: sample_categorical(&probs, rng) as u32 });
            }
        } else {
            for &id in &tree.levels[level - 1] {
                let probs = parent_lp(tree, Some(id)).probs();
                for _ in 0..tree.nodes[id].mult {
                    out.push(Child {
                        parent: Some(id),
                        token: sample_categorical(&probs, rng) as u32,
                    });
                }
            }
        }
        out
    }
}

/// RSD-C: constant branching factors b = (b_0, .., b_{L-1}); each parent
/// draws its b_l children via the Gumbel-Top-k trick — sampling without
/// replacement, returned in decreasing perturbed-log-prob order (the
/// verification order required by recursive rejection sampling).
pub struct GumbelTopK {
    pub branches: Vec<usize>,
}

impl TreeStrategy for GumbelTopK {
    fn depth(&self) -> usize {
        self.branches.len()
    }

    fn max_nodes(&self) -> usize {
        crate::config::rsd_c_budget(&self.branches)
    }

    fn begin_round(&mut self) {}

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child> {
        let b = self.branches[level];
        let parents: Vec<Option<usize>> = if level == 0 {
            vec![None]
        } else {
            tree.levels[level - 1].iter().map(|&id| Some(id)).collect()
        };
        let mut out = Vec::new();
        for parent in parents {
            let lp = parent_lp(tree, parent);
            for (idx, _) in gumbel_top_k(lp, b, rng) {
                out.push(Child { parent, token: idx as u32 });
            }
        }
        out
    }
}

/// RSD-S: Stochastic Beam Search with beamwidth W. Maintains per-node
/// cumulative sequence log-probs φ and truncated perturbed values ψ
/// (paper eq. 10-12); each level keeps the global top-W (parent, token)
/// pairs by ψ, which is equivalent to sampling the top-W length-(l+1)
/// sequences without replacement (Kool et al. 2019) and early-truncates
/// branches whose continuation mass collapsed.
pub struct StochasticBeam {
    pub w: usize,
    pub depth: usize,
    /// Early-truncation threshold: after the top-W selection, candidates
    /// whose cumulative sequence log-prob φ trails the level's best by
    /// more than this gap are dropped before drafting. Exactness is
    /// unaffected (verification sees fewer siblings, never wrong ones);
    /// the saved nodes shrink the actual per-round budget. `INFINITY`
    /// (the [`StochasticBeam::new`] default) disables truncation.
    pub max_phi_gap: f64,
    /// φ, ψ per created node id.
    state: Vec<(f64, f64)>,
    /// (φ, ψ) of the candidates proposed by the last `expand`, in the
    /// same order, consumed by `on_created`.
    staged: Vec<(f64, f64)>,
}

impl StochasticBeam {
    pub fn new(w: usize, depth: usize) -> Self {
        Self::with_gap(w, depth, f64::INFINITY)
    }

    /// Beam with early truncation at `max_phi_gap` log-prob units below
    /// the per-level best sequence (the adaptive controller's setting).
    pub fn with_gap(w: usize, depth: usize, max_phi_gap: f64) -> Self {
        assert!(max_phi_gap >= 0.0, "phi gap must be non-negative");
        Self { w, depth, max_phi_gap, state: Vec::new(), staged: Vec::new() }
    }
}

impl TreeStrategy for StochasticBeam {
    fn depth(&self) -> usize {
        self.depth
    }

    fn max_nodes(&self) -> usize {
        self.w * self.depth
    }

    fn begin_round(&mut self) {
        self.state.clear();
        self.staged.clear();
    }

    fn expand(&mut self, tree: &DraftTree, level: usize, rng: &mut Rng) -> Vec<Child> {
        // beam = previous level's nodes (or the root)
        let beam: Vec<(Option<usize>, f64, f64)> = if level == 0 {
            vec![(None, 0.0, 0.0)] // φ_{-1} = ψ_{-1} = 0 (paper footnote 1)
        } else {
            tree.levels[level - 1]
                .iter()
                .map(|&id| {
                    let (phi, psi) = self.state[id];
                    (Some(id), phi, psi)
                })
                .collect()
        };

        // candidates across the whole beam: (parent, token, φ_child, ψ_child)
        let mut cands: Vec<(Option<usize>, u32, f64, f64)> = Vec::new();
        for (parent, phi_p, psi_p) in beam {
            let lp = parent_lp(tree, parent);
            let phi_child: Vec<f64> =
                lp.0.iter().map(|&l| if l == NEG_INF { NEG_INF } else { phi_p + l }).collect();
            let phi_tilde: Vec<f64> = phi_child
                .iter()
                .map(|&f| if f == NEG_INF { NEG_INF } else { f + gumbel(rng) })
                .collect();
            let z = phi_tilde.iter().cloned().fold(NEG_INF, f64::max);
            let psi = truncated_gumbel(psi_p, z, &phi_tilde);
            for (x, (&f, &s)) in phi_child.iter().zip(&psi).enumerate() {
                // drop NaN φ/ψ (degenerate distributions) outright: the
                // NaN-safe sort below would rank +NaN above every real
                // candidate, handing the beam to a broken branch
                if f != NEG_INF && s != NEG_INF && !f.is_nan() && !s.is_nan() {
                    cands.push((parent, x as u32, f, s));
                }
            }
        }
        // global top-W by ψ, decreasing (= verification order).
        // total_cmp: NaN-safe — a NaN ψ (degenerate distribution) must
        // not panic the serving engine mid-round.
        cands.sort_by(|a, b| b.3.total_cmp(&a.3));
        cands.truncate(self.w);
        // early truncation: drop branches whose sequence mass collapsed
        // relative to the level's best (the φ-max candidate always stays)
        if self.max_phi_gap.is_finite() && !cands.is_empty() {
            let best_phi = cands.iter().map(|c| c.2).fold(NEG_INF, f64::max);
            cands.retain(|c| c.2 >= best_phi - self.max_phi_gap);
        }
        self.staged = cands.iter().map(|&(_, _, f, s)| (f, s)).collect();
        cands
            .into_iter()
            .map(|(parent, token, _, _)| Child { parent, token })
            .collect()
    }

    fn on_created(&mut self, _tree: &DraftTree, _level: usize, node_ids: &[usize]) {
        // without-replacement candidates never merge: 1:1 with staged
        debug_assert_eq!(node_ids.len(), self.staged.len());
        for (&id, &st) in node_ids.iter().zip(&self.staged) {
            if self.state.len() <= id {
                self.state.resize(id + 1, (0.0, 0.0));
            }
            self.state[id] = st;
        }
        self.staged.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::process_logits;

    fn tree_with_root(logits: &[f32]) -> DraftTree {
        DraftTree {
            nodes: Vec::new(),
            levels: Vec::new(),
            root_draft_lp: process_logits(logits, 1.0, 1.0),
        }
    }

    #[test]
    fn chain_proposes_single_path() {
        let t = tree_with_root(&[0.0, 1.0, 2.0]);
        let mut s = Chain { depth: 3 };
        let mut rng = Rng::seed_from_u64(0);
        let c = s.expand(&t, 0, &mut rng);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].parent, None);
    }

    #[test]
    fn gumbel_topk_children_distinct_per_parent() {
        let t = tree_with_root(&[0.0, 0.5, 1.0, 1.5]);
        let mut s = GumbelTopK { branches: vec![3] };
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..100 {
            let c = s.expand(&t, 0, &mut rng);
            assert_eq!(c.len(), 3);
            let mut toks: Vec<u32> = c.iter().map(|x| x.token).collect();
            toks.sort();
            toks.dedup();
            assert_eq!(toks.len(), 3, "without replacement => distinct");
        }
    }

    #[test]
    fn stochastic_beam_keeps_w_and_orders_by_psi() {
        let t = tree_with_root(&[0.1, 0.9, 0.3, 0.7, 0.5]);
        let mut s = StochasticBeam::new(3, 2);
        s.begin_round();
        let mut rng = Rng::seed_from_u64(2);
        let c = s.expand(&t, 0, &mut rng);
        assert_eq!(c.len(), 3);
        // staged psi decreasing
        assert!(s.staged.windows(2).all(|w| w[0].1 >= w[1].1));
        // all from root
        assert!(c.iter().all(|x| x.parent.is_none()));
        // distinct tokens (without replacement at the root)
        let mut toks: Vec<u32> = c.iter().map(|x| x.token).collect();
        toks.sort();
        toks.dedup();
        assert_eq!(toks.len(), 3);
    }

    #[test]
    fn phi_gap_truncates_but_keeps_best() {
        let t = tree_with_root(&[0.0, 2.0, 4.0, 6.0]);
        let mut rng = Rng::seed_from_u64(3);
        let mut full = StochasticBeam::new(3, 2);
        full.begin_round();
        assert_eq!(full.expand(&t, 0, &mut rng).len(), 3, "infinite gap keeps the beam");
        // gap 0: only candidates tied with the best sequence log-prob
        // survive — at least one always does
        let mut tight = StochasticBeam::with_gap(3, 2, 0.0);
        tight.begin_round();
        let c = tight.expand(&t, 0, &mut rng);
        assert!(!c.is_empty() && c.len() <= 3);
    }

    #[test]
    fn max_nodes_matches_budget_definitions() {
        assert_eq!(Chain { depth: 4 }.max_nodes(), 4);
        assert_eq!(IidPaths { k: 3, depth: 7 }.max_nodes(), 21);
        assert_eq!(GumbelTopK { branches: vec![2, 2, 2] }.max_nodes(), 14);
        assert_eq!(StochasticBeam::new(6, 5).max_nodes(), 30);
    }
}
