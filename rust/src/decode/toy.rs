//! The didactic Bernoulli example of the paper's Figure 1: acceptance
//! rates of multi-round rejection sampling, K-SEQ, OTM and recursive
//! rejection sampling when draft = Ber(p), target = Ber(q) and K = 2
//! tokens are proposed.
//!
//! Everything here is exact (closed form), matching how Figure 1 is a
//! property of the rules themselves, not of any model.

/// Acceptance probability of multi-round rejection sampling (SpecInfer)
/// with K i.i.d. draft tokens from Ber(p), target Ber(q).
/// Closed form via the recursion: round k rejects with prob
/// 1 - sum_x min(q_k(x), p(x)) and q_{k+1} = Norm[[q_k - p]^+].
pub fn multiround_accept(p: f64, q: f64, k: usize) -> f64 {
    let pv = [1.0 - p, p];
    let mut qv = [1.0 - q, q];
    let mut reject_mass = 1.0;
    let mut accepted = 0.0;
    for _ in 0..k {
        let overlap: f64 = (0..2).map(|i| pv[i].min(qv[i])).sum();
        accepted += reject_mass * overlap;
        if overlap >= 1.0 - 1e-15 {
            break;
        }
        let mut res = [0.0, 0.0];
        let mut z = 0.0;
        for i in 0..2 {
            res[i] = (qv[i] - pv[i]).max(0.0);
            z += res[i];
        }
        if z <= 0.0 {
            break;
        }
        qv = [res[0] / z, res[1] / z];
        reject_mass *= 1.0 - overlap;
    }
    accepted
}

/// Acceptance probability of K-SEQ (SpecTr) with parameter gamma:
/// each of the K i.i.d. tokens accepted with min(1, q(x)/(gamma p(x)));
/// overall accept = 1 - (1 - beta)^K with beta = sum_x min(p(x), q(x)/gamma).
pub fn kseq_accept(p: f64, q: f64, k: usize, gamma: f64) -> f64 {
    let pv = [1.0 - p, p];
    let qv = [1.0 - q, q];
    let beta: f64 = (0..2).map(|i| pv[i].min(qv[i] / gamma)).sum();
    1.0 - (1.0 - beta).powi(k as i32)
}

/// Is gamma *valid* for (p, q, K)? K-SEQ recovers q exactly only when its
/// residual q - min(p, q/γ)·(1-(1-β)^K)/β is pointwise non-negative;
/// small γ over-accepts and cannot be compensated. (This is why the
/// paper's γ search is over a restricted range.)
pub fn kseq_gamma_valid(p: f64, q: f64, k: usize, gamma: f64) -> bool {
    let pv = [1.0 - p, p];
    let qv = [1.0 - q, q];
    let beta: f64 = (0..2).map(|i| pv[i].min(qv[i] / gamma)).sum();
    if beta <= 0.0 {
        return true;
    }
    let scale = (1.0 - (1.0 - beta).powi(k as i32)) / beta;
    (0..2).all(|i| qv[i] - pv[i].min(qv[i] / gamma) * scale >= -1e-12)
}

/// K-SEQ with gamma numerically optimized over the *valid* subset of
/// [1, K] (the paper plots the tuned variant).
pub fn kseq_accept_best_gamma(p: f64, q: f64, k: usize) -> f64 {
    let mut best: f64 = 0.0;
    let steps = 400;
    for i in 0..=steps {
        let gamma = 1.0 + (k as f64 - 1.0) * i as f64 / steps as f64;
        if kseq_gamma_valid(p, q, k, gamma) {
            best = best.max(kseq_accept(p, q, k, gamma));
        }
    }
    best
}

/// Optimal-transport acceptance upper bound with membership cost (OTM,
/// SpecTr Thm. 3): accept_opt = sum_x min(q(x), 1 - (1 - p(x))^K).
/// For |X| = 2 the bound is achieved, so this is exact for the toy.
pub fn otm_accept(p: f64, q: f64, k: usize) -> f64 {
    let pv = [1.0 - p, p];
    let qv = [1.0 - q, q];
    (0..2)
        .map(|i| qv[i].min(1.0 - (1.0 - pv[i]).powi(k as i32)))
        .sum::<f64>()
        .min(1.0)
}

/// Acceptance probability of recursive rejection sampling with K = 2
/// tokens drawn WITHOUT replacement from Ber(p). With a binary vocabulary
/// the two draft tokens cover X, so the second round's draft distribution
/// is a point mass on the untried token and RRS accepts with probability
/// 1 whenever the residual is supported there — which it always is:
///   round 1: accept mass sum_x min(p, q);
///   round 2: draft = delta_{x'}, residual q_2 supported on x' only.
pub fn rrs_accept_k2(p: f64, q: f64) -> f64 {
    let _ = (p, q);
    1.0
}

/// Exact RRS acceptance for general categorical p, q and siblings drawn
/// without replacement via Gumbel-Top-k, K = 2, by enumeration.
pub fn rrs_accept_k2_categorical(pv: &[f64], qv: &[f64]) -> f64 {
    let n = pv.len();
    let mut acc = 0.0;
    for first in 0..n {
        if pv[first] <= 0.0 {
            continue;
        }
        // P(first) = p_first; accept round 1 with min(1, q/p)
        let a1 = (qv[first] / pv[first]).min(1.0);
        acc += pv[first] * a1;
        // rejected: residual q2 = Norm[[q - p]^+], draft2 = p w/o first
        let rej = pv[first] * (1.0 - a1);
        if rej <= 0.0 {
            continue;
        }
        let mut q2: Vec<f64> = qv.iter().zip(pv).map(|(&q, &p)| (q - p).max(0.0)).collect();
        let z: f64 = q2.iter().sum();
        if z <= 0.0 {
            // residual empty: outcome still exact, counts as acceptance of
            // the resample — but for the *acceptance-rate* metric we count
            // only draft-token acceptance; skip.
            continue;
        }
        for v in &mut q2 {
            *v /= z;
        }
        let pz: f64 = 1.0 - pv[first];
        if pz <= 0.0 {
            continue;
        }
        for second in 0..n {
            if second == first || pv[second] <= 0.0 {
                continue;
            }
            let p2 = pv[second] / pz; // sampling w/o replacement conditional
            let a2 = if p2 > 0.0 { (q2[second] / p2).min(1.0) } else { 0.0 };
            acc += rej * p2 * a2;
        }
    }
    acc.min(1.0)
}

/// One row of Figure 1: acceptance rates for all four methods at (p, q).
#[derive(Debug, Clone, Copy)]
pub struct ToyRow {
    pub p: f64,
    pub q: f64,
    pub multiround: f64,
    pub kseq: f64,
    pub otm: f64,
    pub rrs: f64,
}

pub fn figure1_row(p: f64, q: f64) -> ToyRow {
    ToyRow {
        p,
        q,
        multiround: multiround_accept(p, q, 2),
        kseq: kseq_accept_best_gamma(p, q, 2),
        otm: otm_accept(p, q, 2),
        rrs: rrs_accept_k2_categorical(&[1.0 - p, p], &[1.0 - q, q]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rrs_always_one_on_binary() {
        for &(p, q) in &[(0.1, 0.9), (0.5, 0.5), (0.9, 0.1), (0.3, 0.7)] {
            let a = rrs_accept_k2_categorical(&[1.0 - p, p], &[1.0 - q, q]);
            assert!((a - 1.0).abs() < 1e-9, "p={p} q={q}: {a}");
        }
    }

    #[test]
    fn ordering_matches_figure1() {
        // RRS >= OTM >= tuned K-SEQ >= multi-round, strict at high
        // discrepancy (paper Fig. 1)
        for &(p, q) in &[(0.9, 0.1), (0.8, 0.2), (0.7, 0.1)] {
            let r = figure1_row(p, q);
            assert!(r.rrs >= r.otm - 1e-9, "{r:?}");
            assert!(r.otm >= r.kseq - 1e-9, "{r:?}");
            assert!(r.kseq >= r.multiround - 1e-9, "{r:?}");
            assert!(r.rrs > r.multiround + 0.05, "strict at high discrepancy: {r:?}");
        }
    }

    #[test]
    fn identical_distributions_accept_everywhere() {
        let r = figure1_row(0.4, 0.4);
        assert!(r.multiround > 0.99);
        assert!(r.kseq > 0.99);
        assert!(r.otm > 0.99);
        assert!((r.rrs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn closed_forms_match_monte_carlo() {
        use crate::decode::rrs::{KSeq, LevelOutcome, MultiRound, VerifyRule};
        use crate::sampling::{sample_categorical, LogProbs};
        use crate::util::Rng;
        let (p, q) = (0.75, 0.25);
        let plp = LogProbs(vec![(1.0f64 - p).ln(), p.ln()]);
        let qlp = LogProbs(vec![(1.0f64 - q).ln(), q.ln()]);
        let mut rng = Rng::seed_from_u64(11);
        let n = 100_000;
        let mut mr = 0usize;
        let mut ks = 0usize;
        let gamma = 1.5;
        for _ in 0..n {
            let sib: Vec<u32> = (0..2)
                .map(|_| sample_categorical(&plp.probs(), &mut rng) as u32)
                .collect();
            if matches!(MultiRound.verify(&sib, &plp, &qlp, &mut rng), LevelOutcome::Accept { .. })
            {
                mr += 1;
            }
            if matches!(
                (KSeq { gamma: Some(gamma) }).verify(&sib, &plp, &qlp, &mut rng),
                LevelOutcome::Accept { .. }
            ) {
                ks += 1;
            }
        }
        let mr_emp = mr as f64 / n as f64;
        let ks_emp = ks as f64 / n as f64;
        assert!((mr_emp - multiround_accept(p, q, 2)).abs() < 0.01, "{mr_emp}");
        assert!((ks_emp - kseq_accept(p, q, 2, gamma)).abs() < 0.01, "{ks_emp}");
    }
}
