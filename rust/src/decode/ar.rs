//! Auto-regressive baseline: one target call per generated token.
//! Resumable ([`ArStepper`]) so the coordinator can interleave AR
//! requests with speculative ones.
//!
//! Like [`super::spec::SpecStepper`], the stepper is a phase machine
//! that never calls the model itself: [`ArStepper::begin_round`] samples
//! the next token (or stages the prompt prefill on the first round) and
//! stages a target evaluation; the caller runs it — fused with every
//! other active request in the serving engine — and hands the rows back
//! through [`ArStepper::feed_target`]. AR rounds have no draft phase, so
//! AR requests simply contribute nothing to the engine's fused draft
//! calls.
//!
//! The steady-state AR round is allocation-free like the speculative
//! one: the next-token distribution, the probability scratch, the phase
//! node vectors and the commit chain are all reused across rounds.

use std::mem;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::SamplingConfig;
use crate::llm::{EvalNode, Llm, LogitsBatch, LogitsView};
use crate::sampling::{process_logits_into, sample_categorical, LogProbs, SelectScratch};
use crate::util::Rng;

use super::spec::{RoundStart, StepOutcome};
use super::{DecodeRun, DecodeStats};

/// Target work staged for the current AR round.
enum Phase {
    Idle,
    /// Prompt prefill (first round only; emits no token).
    AwaitPrefill { nodes: Vec<EvalNode> },
    /// Single-token decode for the token sampled at `begin_round`.
    AwaitDecode { nodes: Vec<EvalNode> },
}

pub struct ArStepper<T: Llm> {
    sampling: SamplingConfig,
    sess: T::Session,
    /// Distribution for the next token (None until prefill ran; the
    /// inner buffer is reused across rounds).
    lp: Option<LogProbs>,
    /// Nucleus-selection scratch for logits processing.
    sel: SelectScratch,
    /// Probability scratch for next-token sampling.
    probs: Vec<f64>,
    /// Pooled phase node vectors.
    node_pool: Vec<Vec<EvalNode>>,
    /// Reusable commit chain.
    chain: Vec<usize>,
    /// Flat logits buffer for the single-request `step` path.
    logits: LogitsBatch,
    phase: Phase,
    prompt: Vec<u32>,
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    max_new: usize,
    started: Instant,
    done: bool,
}

impl<T: Llm> ArStepper<T> {
    pub fn new(
        target: &T,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        if prompt.is_empty() {
            bail!("prompt must be non-empty");
        }
        Ok(Self {
            sampling,
            sess: target.begin()?,
            lp: None,
            sel: SelectScratch::default(),
            probs: Vec::new(),
            node_pool: Vec::new(),
            chain: Vec::new(),
            logits: LogitsBatch::default(),
            phase: Phase::Idle,
            prompt: prompt.to_vec(),
            // clamped like SpecStepper::new: a programmatic max_new of
            // usize::MAX must not abort on the reservation
            out: Vec::with_capacity(max_new.min(1 << 20)),
            stats: DecodeStats::default(),
            max_new,
            started: Instant::now(),
            done: false,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    fn finish(&mut self) -> StepOutcome {
        self.stats.generated = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
        StepOutcome::Done
    }

    /// Start a round: sample the next token from the current distribution
    /// and stage its evaluation, or stage the prompt prefill on round 1.
    /// [`RoundStart::Finished`] means the request just finished without
    /// model work (length cap, stop token, or KV capacity) — any token
    /// sampled this call is already in `out`.
    pub fn begin_round(&mut self, target: &T, rng: &mut Rng) -> Result<RoundStart> {
        debug_assert!(matches!(self.phase, Phase::Idle), "begin_round mid-round");
        if self.done {
            return Ok(RoundStart::Finished);
        }
        let Some(lp) = &self.lp else {
            // prefill round: evaluate the whole prompt chain
            let mut nodes = self.node_pool.pop().unwrap_or_default();
            nodes.clear();
            nodes.extend(self.prompt.iter().enumerate().map(|(i, &t)| {
                if i == 0 {
                    EvalNode::root(t)
                } else {
                    EvalNode::child(t, i - 1)
                }
            }));
            self.phase = Phase::AwaitPrefill { nodes };
            return Ok(RoundStart::Started);
        };
        lp.probs_into(&mut self.probs);
        let token = sample_categorical(&self.probs, rng) as u32;
        if self.sampling.is_stop(token) {
            // stop token: finish without emitting it
            self.finish();
            return Ok(RoundStart::Finished);
        }
        self.out.push(token);
        if self.out.len() >= self.max_new || target.capacity_left(&self.sess) < 2 {
            self.finish();
            return Ok(RoundStart::Finished);
        }
        let mut nodes = self.node_pool.pop().unwrap_or_default();
        nodes.clear();
        nodes.push(EvalNode::root(token));
        self.phase = Phase::AwaitDecode { nodes };
        Ok(RoundStart::Started)
    }

    /// The staged target work (AR rounds always have exactly one target
    /// phase and no draft phase).
    pub fn target_group(&mut self) -> Option<(&mut T::Session, &[EvalNode])> {
        match &self.phase {
            Phase::AwaitPrefill { nodes } | Phase::AwaitDecode { nodes } => {
                Some((&mut self.sess, nodes.as_slice()))
            }
            Phase::Idle => None,
        }
    }

    /// Consume the target rows: commit the evaluated chain and refresh
    /// the next-token distribution (into the reused buffer).
    pub fn feed_target(&mut self, target: &T, rows: LogitsView<'_>) -> Result<StepOutcome> {
        let phase = mem::replace(&mut self.phase, Phase::Idle);
        let nodes = match phase {
            Phase::AwaitPrefill { nodes } | Phase::AwaitDecode { nodes } => nodes,
            Phase::Idle => bail!("feed_target outside a round"),
        };
        let nodes_len = nodes.len();
        {
            let mut nodes = nodes;
            nodes.clear();
            self.node_pool.push(nodes);
        }
        if rows.len() != nodes_len {
            bail!("feed_target: {} rows for {} staged nodes", rows.len(), nodes_len);
        }
        self.stats.decode_calls += 1;
        self.chain.clear();
        self.chain.extend(0..nodes_len);
        target.commit(&mut self.sess, &self.chain)?;
        let mut buf = match self.lp.take() {
            Some(lp) => lp.0,
            None => Vec::new(),
        };
        process_logits_into(
            rows.last().expect("staged nodes non-empty"),
            self.sampling.temperature,
            self.sampling.top_p,
            &mut self.sel,
            &mut buf,
        );
        self.lp = Some(LogProbs(buf));
        Ok(StepOutcome::Progress)
    }

    /// One iteration: sample a token and evaluate it (runs the prefill
    /// first when needed, so each successful `step` emits exactly one
    /// token, as before the phase split).
    pub fn step(&mut self, target: &T, rng: &mut Rng) -> Result<StepOutcome> {
        loop {
            let was_prefill = self.lp.is_none();
            if self.begin_round(target, rng)? == RoundStart::Finished {
                return Ok(StepOutcome::Done);
            }
            let mut batch = mem::take(&mut self.logits);
            batch.reset(target.vocab());
            match self.target_group() {
                Some((sess, nodes)) => target.eval_into(sess, nodes, &mut batch)?,
                None => bail!("round staged no target work"),
            }
            let outcome = self.feed_target(target, batch.full())?;
            self.logits = batch;
            if !was_prefill {
                return Ok(outcome);
            }
        }
    }
}

pub fn run_ar<T: Llm>(
    target: &T,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun> {
    let mut stepper = ArStepper::new(target, sampling.clone(), prompt, max_new)?;
    while stepper.step(target, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out.clone(), stats: stepper.stats.clone() })
}
