//! Auto-regressive baseline: one target call per generated token.
//! Resumable ([`ArStepper`]) so the coordinator can interleave AR
//! requests with speculative ones.
//!
//! Like [`super::spec::SpecStepper`], the stepper is a phase machine
//! that never calls the model itself: [`ArStepper::begin_round`] samples
//! the next token (or stages the prompt prefill on the first round) and
//! stages a target evaluation; the caller runs it — fused with every
//! other active request in the serving engine — and hands the rows back
//! through [`ArStepper::feed_target`]. AR rounds have no draft phase, so
//! AR requests simply contribute nothing to the engine's fused draft
//! calls.
//!
//! The steady-state AR round is allocation-free like the speculative
//! one: the next-token distribution, the probability scratch, the phase
//! node vectors and the commit chain are all reused across rounds.

use std::mem;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::SamplingConfig;
use crate::llm::{EvalNode, Llm, LogitsBatch, LogitsView};
use crate::sampling::{process_logits_into, sample_categorical, LogProbs, SelectScratch};
use crate::util::Rng;

use super::spec::{RoundStart, StepOutcome};
use super::{DecodeRun, DecodeStats};

/// Target work staged for the current AR round.
enum Phase {
    Idle,
    /// Prompt prefill (first round only; emits no token).
    AwaitPrefill { nodes: Vec<EvalNode> },
    /// Single-token decode for the token sampled at `begin_round`.
    AwaitDecode { nodes: Vec<EvalNode> },
}

pub struct ArStepper<T: Llm> {
    sampling: SamplingConfig,
    sess: T::Session,
    /// Distribution for the next token (None until prefill ran; the
    /// inner buffer is reused across rounds).
    lp: Option<LogProbs>,
    /// Nucleus-selection scratch for logits processing.
    sel: SelectScratch,
    /// Probability scratch for next-token sampling.
    probs: Vec<f64>,
    /// Pooled phase node vectors.
    node_pool: Vec<Vec<EvalNode>>,
    /// Reusable commit chain.
    chain: Vec<usize>,
    /// Flat logits buffer for the single-request `step` path.
    logits: LogitsBatch,
    phase: Phase,
    /// The original prompt (immutable; with `out` it reconstructs the
    /// full logical sequence for suspend/resume).
    prompt: Vec<u32>,
    /// The chain the next prefill evaluates: the prompt initially, the
    /// whole logical sequence after a suspend.
    prefill: Vec<u32>,
    /// Tokens of `prefill` already committed via a shared KV prefix
    /// (skipped by the prefill chain).
    prefill_start: usize,
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    max_new: usize,
    started: Instant,
    done: bool,
    /// Flight-recorder handle (default off) and the id this request's
    /// commit events carry.
    tracer: crate::trace::Tracer,
    trace_id: u64,
    /// Speculation-analytics handle (default off); AR forwards carry a
    /// zero node budget and each committed token counts as bonus.
    analytics: crate::obs::Analytics,
}

impl<T: Llm> ArStepper<T> {
    pub fn new(
        target: &T,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        if prompt.is_empty() {
            bail!("prompt must be non-empty");
        }
        // right-sized session: an AR request never holds more than the
        // committed sequence plus a one-token decode margin
        let sess = target.begin_sized(prompt, prompt.len() + max_new.min(1 << 20) + 2)?;
        let prefill_start = target.prefix_len(&sess);
        debug_assert!(prefill_start < prompt.len());
        let stats = DecodeStats { kv_hit_tokens: prefill_start, ..Default::default() };
        Ok(Self {
            sampling,
            sess,
            lp: None,
            sel: SelectScratch::default(),
            probs: Vec::new(),
            node_pool: Vec::new(),
            chain: Vec::new(),
            logits: LogitsBatch::default(),
            phase: Phase::Idle,
            prompt: prompt.to_vec(),
            prefill: prompt.to_vec(),
            prefill_start,
            // clamped like SpecStepper::new: a programmatic max_new of
            // usize::MAX must not abort on the reservation
            out: Vec::with_capacity(max_new.min(1 << 20)),
            stats,
            max_new,
            started: Instant::now(),
            done: false,
            tracer: crate::trace::Tracer::off(),
            trace_id: 0,
            analytics: crate::obs::Analytics::off(),
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Attach a flight-recorder handle; this request's commit
    /// boundaries are journaled under `id` from the next round on.
    pub fn set_trace(&mut self, tracer: &crate::trace::Tracer, id: u64) {
        self.tracer = tracer.clone();
        self.trace_id = id;
    }

    /// Attach a speculation-analytics handle; AR always accrues to the
    /// `ar` family ledger.
    pub fn set_analytics(&mut self, analytics: &crate::obs::Analytics) {
        self.analytics = analytics.clone();
    }

    /// The streaming commit boundary (see
    /// [`super::spec::SpecStepper::committed_len`]): AR tokens are final
    /// the moment they are sampled at `begin_round`, one per round.
    pub fn committed_len(&self) -> usize {
        self.out.len()
    }

    fn finish(&mut self) -> StepOutcome {
        self.stats.generated = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
        StepOutcome::Done
    }

    /// Worst-case new KV slots the next round could consume (see
    /// [`super::spec::SpecStepper::round_need`]).
    pub fn round_need(&self) -> usize {
        if self.lp.is_none() {
            self.prefill.len() - self.prefill_start + 2
        } else {
            2
        }
    }

    /// Spill KV state (engine preemption): the session is dropped and
    /// the prefill chain becomes the full logical sequence; the next
    /// round re-prefills it and re-derives the next-token distribution
    /// from the same context (bit-identical, no RNG consumed). Only
    /// legal between rounds.
    pub fn suspend(&mut self, target: &T) -> Result<()> {
        if !matches!(self.phase, Phase::Idle) {
            bail!("suspend mid-round");
        }
        if self.done {
            bail!("suspend after completion");
        }
        self.prefill.clear();
        self.prefill.extend_from_slice(&self.prompt);
        self.prefill.extend_from_slice(&self.out);
        self.prefill_start = 0;
        self.lp = None;
        self.sess = target.begin()?;
        self.stats.preemptions += 1;
        Ok(())
    }

    /// Re-admit after a suspend: whatever prefix of the spilled sequence
    /// is still radix-cached is mapped back without recompute.
    pub fn resume(&mut self, target: &T) -> Result<()> {
        let max_slots = self.prompt.len() + self.max_new.min(1 << 20) + 2;
        self.sess = target.begin_sized(&self.prefill, max_slots)?;
        self.prefill_start = target.prefix_len(&self.sess);
        self.stats.kv_hit_tokens += self.prefill_start;
        Ok(())
    }

    /// Abandon an in-flight round after a mid-round fault (the engine's
    /// bounded-retry path): recycle the staged nodes, then suspend as if
    /// the round had never started. Any token this round's `begin_round`
    /// sampled is already committed in `out` (AR tokens are final at
    /// sampling), so the rebuilt prefill includes it and the retried
    /// request never replays an RNG draw.
    pub fn abort_round(&mut self, target: &T) -> Result<()> {
        match mem::replace(&mut self.phase, Phase::Idle) {
            Phase::Idle => {}
            Phase::AwaitPrefill { mut nodes } | Phase::AwaitDecode { mut nodes } => {
                nodes.clear();
                self.node_pool.push(nodes);
            }
        }
        self.suspend(target)
    }

    /// Start a round: sample the next token from the current distribution
    /// and stage its evaluation, or stage the prompt prefill on round 1.
    /// [`RoundStart::Finished`] means the request just finished without
    /// model work (length cap, stop token, or KV capacity) — any token
    /// sampled this call is already in `out`.
    pub fn begin_round(&mut self, target: &T, rng: &mut Rng) -> Result<RoundStart> {
        debug_assert!(matches!(self.phase, Phase::Idle), "begin_round mid-round");
        if self.done {
            return Ok(RoundStart::Finished);
        }
        let Some(lp) = &self.lp else {
            // prefill round: evaluate the not-yet-cached tail of the
            // prefill chain (the whole prompt unless a shared KV prefix
            // or a pre-suspend commit already covers the head)
            let mut nodes = self.node_pool.pop().unwrap_or_default();
            nodes.clear();
            nodes.extend(self.prefill[self.prefill_start..].iter().enumerate().map(
                |(i, &t)| {
                    if i == 0 {
                        EvalNode::root(t)
                    } else {
                        EvalNode::child(t, i - 1)
                    }
                },
            ));
            self.phase = Phase::AwaitPrefill { nodes };
            return Ok(RoundStart::Started);
        };
        lp.probs_into(&mut self.probs);
        let token = sample_categorical(&self.probs, rng) as u32;
        if self.sampling.is_stop(token) {
            // stop token: finish without emitting it
            self.finish();
            return Ok(RoundStart::Finished);
        }
        self.out.push(token);
        // AR's commit boundary: the sampled token is final immediately
        self.tracer.record(crate::trace::EventKind::Commit, self.trace_id, 0, 1);
        self.analytics.record_commit(crate::obs::Family::Ar, 0, 1, &[]);
        if self.out.len() >= self.max_new || target.capacity_left(&self.sess) < 2 {
            self.finish();
            return Ok(RoundStart::Finished);
        }
        let mut nodes = self.node_pool.pop().unwrap_or_default();
        nodes.clear();
        nodes.push(EvalNode::root(token));
        self.phase = Phase::AwaitDecode { nodes };
        Ok(RoundStart::Started)
    }

    /// The staged target work (AR rounds always have exactly one target
    /// phase and no draft phase).
    pub fn target_group(&mut self) -> Option<(&mut T::Session, &[EvalNode])> {
        match &self.phase {
            Phase::AwaitPrefill { nodes } | Phase::AwaitDecode { nodes } => {
                Some((&mut self.sess, nodes.as_slice()))
            }
            Phase::Idle => None,
        }
    }

    /// Consume the target rows: commit the evaluated chain and refresh
    /// the next-token distribution (into the reused buffer).
    pub fn feed_target(&mut self, target: &T, rows: LogitsView<'_>) -> Result<StepOutcome> {
        let phase = mem::replace(&mut self.phase, Phase::Idle);
        let nodes = match phase {
            Phase::AwaitPrefill { nodes } | Phase::AwaitDecode { nodes } => nodes,
            Phase::Idle => bail!("feed_target outside a round"),
        };
        let nodes_len = nodes.len();
        {
            let mut nodes = nodes;
            nodes.clear();
            self.node_pool.push(nodes);
        }
        if rows.len() != nodes_len {
            bail!("feed_target: {} rows for {} staged nodes", rows.len(), nodes_len);
        }
        self.stats.decode_calls += 1;
        self.analytics.record_forward(crate::obs::Family::Ar, 0);
        self.chain.clear();
        self.chain.extend(0..nodes_len);
        target.commit(&mut self.sess, &self.chain)?;
        let mut buf = match self.lp.take() {
            Some(lp) => lp.0,
            None => Vec::new(),
        };
        process_logits_into(
            rows.last().expect("staged nodes non-empty"),
            self.sampling.temperature,
            self.sampling.top_p,
            &mut self.sel,
            &mut buf,
        );
        self.lp = Some(LogProbs(buf));
        Ok(StepOutcome::Progress)
    }

    /// One iteration: sample a token and evaluate it (runs the prefill
    /// first when needed, so each successful `step` emits exactly one
    /// token, as before the phase split).
    pub fn step(&mut self, target: &T, rng: &mut Rng) -> Result<StepOutcome> {
        loop {
            let was_prefill = self.lp.is_none();
            if self.begin_round(target, rng)? == RoundStart::Finished {
                return Ok(StepOutcome::Done);
            }
            let mut batch = mem::take(&mut self.logits);
            batch.reset(target.vocab());
            match self.target_group() {
                Some((sess, nodes)) => target.eval_into(sess, nodes, &mut batch)?,
                None => bail!("round staged no target work"),
            }
            let outcome = self.feed_target(target, batch.full())?;
            self.logits = batch;
            if !was_prefill {
                return Ok(outcome);
            }
        }
    }
}

pub fn run_ar<T: Llm>(
    target: &T,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun> {
    let mut stepper = ArStepper::new(target, sampling.clone(), prompt, max_new)?;
    while stepper.step(target, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out.clone(), stats: stepper.stats.clone() })
}
