//! Auto-regressive baseline: one target call per generated token.
//! Resumable ([`ArStepper`]) so the coordinator can interleave AR
//! requests with speculative ones.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::SamplingConfig;
use crate::llm::{EvalNode, Llm};
use crate::sampling::{process_logits, sample_categorical, LogProbs};
use crate::util::Rng;

use super::spec::StepOutcome;
use super::{DecodeRun, DecodeStats};

pub struct ArStepper<T: Llm> {
    sampling: SamplingConfig,
    sess: T::Session,
    /// Distribution for the next token (None until prefill ran).
    lp: Option<LogProbs>,
    prompt: Vec<u32>,
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    max_new: usize,
    started: Instant,
    done: bool,
}

impl<T: Llm> ArStepper<T> {
    pub fn new(
        target: &T,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        if prompt.is_empty() {
            bail!("prompt must be non-empty");
        }
        Ok(Self {
            sampling,
            sess: target.begin()?,
            lp: None,
            prompt: prompt.to_vec(),
            out: Vec::new(),
            stats: DecodeStats::default(),
            max_new,
            started: Instant::now(),
            done: false,
        })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    fn finish(&mut self) -> StepOutcome {
        self.stats.generated = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
        StepOutcome::Done
    }

    /// One iteration: sample from the current distribution and (unless
    /// finished) evaluate the sampled token to obtain the next one.
    pub fn step(&mut self, target: &T, rng: &mut Rng) -> Result<StepOutcome> {
        if self.done {
            return Ok(StepOutcome::Done);
        }
        if self.lp.is_none() {
            // prefill round
            let nodes: Vec<EvalNode> = self
                .prompt
                .iter()
                .enumerate()
                .map(|(i, &t)| {
                    if i == 0 {
                        EvalNode::root(t)
                    } else {
                        EvalNode::child(t, i - 1)
                    }
                })
                .collect();
            let rows = target.eval(&mut self.sess, &nodes)?;
            self.stats.decode_calls += 1;
            let chain: Vec<usize> = (0..self.prompt.len()).collect();
            target.commit(&mut self.sess, &chain)?;
            self.lp = Some(process_logits(
                rows.last().unwrap(),
                self.sampling.temperature,
                self.sampling.top_p,
            ));
        }
        let token =
            sample_categorical(&self.lp.as_ref().unwrap().probs(), rng) as u32;
        self.out.push(token);
        if self.out.len() >= self.max_new || target.capacity_left(&self.sess) < 2 {
            return Ok(self.finish());
        }
        let rows = target.eval(&mut self.sess, &[EvalNode::root(token)])?;
        self.stats.decode_calls += 1;
        target.commit(&mut self.sess, &[0])?;
        self.lp = Some(process_logits(
            &rows[0],
            self.sampling.temperature,
            self.sampling.top_p,
        ));
        Ok(StepOutcome::Progress)
    }
}

pub fn run_ar<T: Llm>(
    target: &T,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun> {
    let mut stepper = ArStepper::new(target, *sampling, prompt, max_new)?;
    while stepper.step(target, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out.clone(), stats: stepper.stats.clone() })
}
