//! Speculation analytics: the per-(decoder-family, tree-level)
//! acceptance ledger, compute-budget accounting, windowed live stats
//! and SLO tracking for the serving engine.
//!
//! The paper's central empirical claim is about *fixed target
//! computational budgets* — accepted tokens per target forward — so
//! this module accounts target compute at its source: every target
//! forward a stepper issues ([`Analytics::record_forward`], charged
//! with the draft-tree nodes it verified) and every commit boundary
//! ([`Analytics::record_commit`], carrying the accepted/bonus token
//! counts and the per-level verification trials). From those two
//! record points the ledger yields live accepted-tokens-per-target-
//! forward and per-level acceptance curves, per decoder family.
//!
//! Three layers, all behind one cloneable handle ([`Analytics`],
//! mirroring [`crate::trace::Tracer`]: a disabled handle is a `None`
//! and every record call is one branch):
//!
//! 1. **Acceptance ledger** — fixed-size per-(family, level) atomics.
//!    Recording is zero-allocation and lock-free (relaxed atomic adds
//!    into preallocated arrays); the hot-path 0-alloc gate in
//!    `benches/hotpath.rs` runs with analytics enabled.
//! 2. **Windowed aggregator** — a preallocated ring of cumulative
//!    boundary snapshots ([`Cume`], `Copy`, no heap), rotated every
//!    `stats_window_rounds` engine rounds by [`Analytics::tick`].
//!    A window's aggregate is the delta between two boundaries, so
//!    ticks never sum anything retroactively and never allocate.
//! 3. **SLO tracker** — TTFT/latency objective attainment and
//!    deadline-hit counters fed by [`Analytics::on_done`], reported
//!    per window with error-budget burn against a 99% objective.
//!
//! Analytics never consumes RNG and never changes control flow, so
//! token streams are bit-identical analytics-on vs analytics-off (the
//! soak suite asserts this). Export is cold-path: the `stats` wire
//! command renders [`Analytics::stats_json`], Prometheus series come
//! from [`Analytics::prometheus`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::DecoderConfig;
use crate::coordinator::metrics::Metrics;
use crate::util::json::Json;

/// Tree levels tracked per family; deeper levels fold into the last
/// slot (deeper than any shipped decoder: `ADAPTIVE_MAX_DEPTH` is 8).
pub const MAX_LEVELS: usize = 16;

/// Number of [`Family`] variants (ledger array size).
pub const NUM_FAMILIES: usize = 7;

/// SLO attainment objective the error-budget burn is measured against.
pub const SLO_OBJECTIVE: f64 = 0.99;

/// Decoder family a ledger row is keyed by — the decoder *kind*, not
/// its shape parameters, so per-request width/depth variations of one
/// algorithm aggregate into one comparable row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Family {
    Ar = 0,
    Sd = 1,
    SpecTr = 2,
    RsdC = 3,
    RsdCMr = 4,
    RsdS = 5,
    Adaptive = 6,
}

impl Family {
    pub const ALL: [Family; NUM_FAMILIES] = [
        Family::Ar,
        Family::Sd,
        Family::SpecTr,
        Family::RsdC,
        Family::RsdCMr,
        Family::RsdS,
        Family::Adaptive,
    ];

    /// The family of a decoder config (adaptive requests stay
    /// `Adaptive` whichever shape family the controller picks).
    pub fn of(cfg: &DecoderConfig) -> Family {
        match cfg {
            DecoderConfig::Ar => Family::Ar,
            DecoderConfig::Sd { .. } => Family::Sd,
            DecoderConfig::SpecTr { .. } => Family::SpecTr,
            DecoderConfig::RsdC { .. } => Family::RsdC,
            DecoderConfig::RsdCMultiRound { .. } => Family::RsdCMr,
            DecoderConfig::RsdS { .. } => Family::RsdS,
            DecoderConfig::Adaptive { .. } => Family::Adaptive,
        }
    }

    /// Stable label (Prometheus `family` label, stats JSON key).
    pub fn name(self) -> &'static str {
        match self {
            Family::Ar => "ar",
            Family::Sd => "sd",
            Family::SpecTr => "spectr",
            Family::RsdC => "rsd-c",
            Family::RsdCMr => "rsd-c-mr",
            Family::RsdS => "rsd-s",
            Family::Adaptive => "adaptive",
        }
    }
}

/// One family's ledger row: fixed-size, all-atomic, preallocated.
#[derive(Default)]
struct FamilyLedger {
    /// Target-model forwards issued (the budget denominator; prefill
    /// rounds count, matching `DecodeStats::decode_calls`).
    target_forwards: AtomicU64,
    /// Draft-tree nodes the target verified across those forwards.
    tree_nodes: AtomicU64,
    /// Draft tokens accepted by verification.
    accepted: AtomicU64,
    /// Bonus tokens (walk exited the tree / AR round tokens).
    bonus: AtomicU64,
    /// Tokens committed to the output stream
    /// (= accepted + bonus + resamples).
    committed: AtomicU64,
    /// Residual-resample events (one per rejected level trial: the
    /// committed token came from the residual, not the tree).
    resamples: AtomicU64,
    /// Commit boundaries recorded.
    commits: AtomicU64,
    /// Per-level verification attempts (index = tree level, clamped
    /// to [`MAX_LEVELS`]).
    level_attempts: [AtomicU64; MAX_LEVELS],
    /// Per-level accepted verifications.
    level_accepts: [AtomicU64; MAX_LEVELS],
}

/// Plain-data copy of one ledger row (or a sum of rows) for tests and
/// export. Cold path.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LedgerTotals {
    pub target_forwards: u64,
    pub tree_nodes: u64,
    pub accepted: u64,
    pub bonus: u64,
    pub committed: u64,
    pub resamples: u64,
    pub commits: u64,
    pub level_attempts: Vec<u64>,
    pub level_accepts: Vec<u64>,
}

impl LedgerTotals {
    /// Accepted draft tokens per target forward — the paper's
    /// fixed-budget headline metric (0 before any forward).
    pub fn accepted_per_target_forward(&self) -> f64 {
        if self.target_forwards == 0 {
            0.0
        } else {
            self.accepted as f64 / self.target_forwards as f64
        }
    }

    /// Committed tokens per target forward (block efficiency).
    pub fn tokens_per_target_forward(&self) -> f64 {
        if self.target_forwards == 0 {
            0.0
        } else {
            self.committed as f64 / self.target_forwards as f64
        }
    }

    /// Per-level acceptance rates, trimmed to attempted levels.
    pub fn acceptance_by_level(&self) -> Vec<f64> {
        self.level_attempts
            .iter()
            .zip(&self.level_accepts)
            .map(|(&n, &s)| if n == 0 { 0.0 } else { s as f64 / n as f64 })
            .collect()
    }

    fn add_assign(&mut self, o: &LedgerTotals) {
        self.target_forwards += o.target_forwards;
        self.tree_nodes += o.tree_nodes;
        self.accepted += o.accepted;
        self.bonus += o.bonus;
        self.committed += o.committed;
        self.resamples += o.resamples;
        self.commits += o.commits;
        if self.level_attempts.len() < o.level_attempts.len() {
            self.level_attempts.resize(o.level_attempts.len(), 0);
            self.level_accepts.resize(o.level_accepts.len(), 0);
        }
        for (i, (&a, &s)) in o.level_attempts.iter().zip(&o.level_accepts).enumerate() {
            self.level_attempts[i] += a;
            self.level_accepts[i] += s;
        }
    }
}

/// One cumulative boundary snapshot: everything a window aggregate is
/// a delta of. `Copy`, fixed-size, no heap — ring rotation is a slot
/// write.
#[derive(Debug, Clone, Copy, Default)]
struct Cume {
    /// Microseconds since the analytics epoch.
    t_us: u64,
    /// Engine rounds ticked.
    rounds: u64,
    // engine/metrics counters
    tokens_out: u64,
    completed: u64,
    failed: u64,
    shed: u64,
    retries: u64,
    preemptions: u64,
    kv_hit_tokens: u64,
    kv_lookup_tokens: u64,
    kv_cold_hits: u64,
    /// Cold-tier consults: hits + misses + corrupt drops.
    kv_cold_consults: u64,
    // ledger sums (all families)
    target_forwards: u64,
    tree_nodes: u64,
    accepted: u64,
    bonus: u64,
    committed: u64,
    resamples: u64,
    level_attempts: [u64; MAX_LEVELS],
    level_accepts: [u64; MAX_LEVELS],
    // SLO counters
    ttft_hits: u64,
    ttft_total: u64,
    latency_hits: u64,
    latency_total: u64,
    deadline_hits: u64,
    deadline_total: u64,
    // gauges at the boundary
    queue_depth: u64,
    active: u64,
}

impl Cume {
    fn delta(&self, start: &Cume) -> Cume {
        let mut d = *self;
        d.t_us -= start.t_us;
        d.rounds -= start.rounds;
        d.tokens_out -= start.tokens_out;
        d.completed -= start.completed;
        d.failed -= start.failed;
        d.shed -= start.shed;
        d.retries -= start.retries;
        d.preemptions -= start.preemptions;
        d.kv_hit_tokens -= start.kv_hit_tokens;
        d.kv_lookup_tokens -= start.kv_lookup_tokens;
        d.kv_cold_hits -= start.kv_cold_hits;
        d.kv_cold_consults -= start.kv_cold_consults;
        d.target_forwards -= start.target_forwards;
        d.tree_nodes -= start.tree_nodes;
        d.accepted -= start.accepted;
        d.bonus -= start.bonus;
        d.committed -= start.committed;
        d.resamples -= start.resamples;
        for i in 0..MAX_LEVELS {
            d.level_attempts[i] -= start.level_attempts[i];
            d.level_accepts[i] -= start.level_accepts[i];
        }
        d.ttft_hits -= start.ttft_hits;
        d.ttft_total -= start.ttft_total;
        d.latency_hits -= start.latency_hits;
        d.latency_total -= start.latency_total;
        d.deadline_hits -= start.deadline_hits;
        d.deadline_total -= start.deadline_total;
        // queue_depth/active stay the END-of-window gauges (deltas of
        // gauges are meaningless)
        d
    }
}

struct Ring {
    /// Boundary `j` (1-based rotation count) lives at slot
    /// `(j - 1) % capacity`; preallocated, never grows.
    buf: Vec<Cume>,
    /// Boundaries pushed over the ring's lifetime.
    pushed: u64,
}

/// The analytics state behind an enabled handle.
pub struct AnalyticsInner {
    epoch: Instant,
    /// Rotation period in engine rounds.
    window_rounds: usize,
    slo_ttft_ms: u64,
    slo_latency_ms: u64,
    ledger: [FamilyLedger; NUM_FAMILIES],
    ticks: AtomicU64,
    ttft_hits: AtomicU64,
    ttft_total: AtomicU64,
    latency_hits: AtomicU64,
    latency_total: AtomicU64,
    deadline_hits: AtomicU64,
    deadline_total: AtomicU64,
    /// Cumulative state at the latest tick (stats read this instead of
    /// taking a `Metrics` handle; at most one round stale).
    latest: Mutex<Cume>,
    ring: Mutex<Ring>,
}

impl AnalyticsInner {
    fn new(window_rounds: usize, windows: usize, slo_ttft_ms: u64, slo_latency_ms: u64) -> Self {
        AnalyticsInner {
            epoch: Instant::now(),
            window_rounds: window_rounds.max(1),
            slo_ttft_ms,
            slo_latency_ms,
            ledger: std::array::from_fn(|_| FamilyLedger::default()),
            ticks: AtomicU64::new(0),
            ttft_hits: AtomicU64::new(0),
            ttft_total: AtomicU64::new(0),
            latency_hits: AtomicU64::new(0),
            latency_total: AtomicU64::new(0),
            deadline_hits: AtomicU64::new(0),
            deadline_total: AtomicU64::new(0),
            latest: Mutex::new(Cume::default()),
            ring: Mutex::new(Ring { buf: vec![Cume::default(); windows.max(1)], pushed: 0 }),
        }
    }

    /// Gather the cumulative state NOW (atomic loads only; no alloc).
    fn collect(&self, m: &Metrics, queued: usize, active: usize, rounds: u64) -> Cume {
        let ld = Ordering::Relaxed;
        let mut c = Cume {
            t_us: self.epoch.elapsed().as_micros() as u64,
            rounds,
            tokens_out: m.tokens_out.load(ld),
            completed: m.completed.load(ld),
            failed: m.failed.load(ld),
            shed: m.shed.load(ld),
            retries: m.retries.load(ld),
            preemptions: m.preemptions.load(ld),
            kv_hit_tokens: m.kv_hit_tokens.load(ld),
            kv_lookup_tokens: m.kv_lookup_tokens.load(ld),
            kv_cold_hits: m.kv_cold_hits.load(ld),
            kv_cold_consults: m.kv_cold_hits.load(ld)
                + m.kv_cold_misses.load(ld)
                + m.kv_cold_corrupt.load(ld),
            ttft_hits: self.ttft_hits.load(ld),
            ttft_total: self.ttft_total.load(ld),
            latency_hits: self.latency_hits.load(ld),
            latency_total: self.latency_total.load(ld),
            deadline_hits: self.deadline_hits.load(ld),
            deadline_total: self.deadline_total.load(ld),
            queue_depth: queued as u64,
            active: active as u64,
            ..Cume::default()
        };
        for row in &self.ledger {
            c.target_forwards += row.target_forwards.load(ld);
            c.tree_nodes += row.tree_nodes.load(ld);
            c.accepted += row.accepted.load(ld);
            c.bonus += row.bonus.load(ld);
            c.committed += row.committed.load(ld);
            c.resamples += row.resamples.load(ld);
            for i in 0..MAX_LEVELS {
                c.level_attempts[i] += row.level_attempts[i].load(ld);
                c.level_accepts[i] += row.level_accepts[i].load(ld);
            }
        }
        c
    }

    fn family_totals(&self, fam: Family) -> LedgerTotals {
        let ld = Ordering::Relaxed;
        let row = &self.ledger[fam as usize];
        LedgerTotals {
            target_forwards: row.target_forwards.load(ld),
            tree_nodes: row.tree_nodes.load(ld),
            accepted: row.accepted.load(ld),
            bonus: row.bonus.load(ld),
            committed: row.committed.load(ld),
            resamples: row.resamples.load(ld),
            commits: row.commits.load(ld),
            level_attempts: row.level_attempts.iter().map(|a| a.load(ld)).collect(),
            level_accepts: row.level_accepts.iter().map(|a| a.load(ld)).collect(),
        }
    }
}

/// The recording handle: a clone-cheap `Option<Arc<AnalyticsInner>>`.
/// The default ([`Analytics::off`]) records nothing and holds nothing.
#[derive(Clone, Default)]
pub struct Analytics {
    inner: Option<Arc<AnalyticsInner>>,
}

impl std::fmt::Debug for Analytics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Analytics").field("enabled", &self.enabled()).finish()
    }
}

impl Analytics {
    /// Disabled analytics: nothing allocated, every record one branch.
    pub fn off() -> Self {
        Analytics { inner: None }
    }

    /// Enabled analytics rotating a window every `window_rounds`
    /// engine rounds over a ring of `windows` boundaries
    /// (`window_rounds == 0` = disabled). `slo_*_ms` of 0 disable the
    /// matching objective.
    pub fn new(window_rounds: usize, windows: usize, slo_ttft_ms: u64, slo_latency_ms: u64) -> Self {
        if window_rounds == 0 {
            return Self::off();
        }
        Analytics {
            inner: Some(Arc::new(AnalyticsInner::new(
                window_rounds,
                windows,
                slo_ttft_ms,
                slo_latency_ms,
            ))),
        }
    }

    /// Build from the engine config's analytics knobs.
    pub fn from_config(cfg: &crate::config::EngineConfig) -> Self {
        Self::new(cfg.stats_window_rounds, cfg.stats_windows, cfg.slo_ttft_ms, cfg.slo_latency_ms)
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Record one target forward that verified `nodes` draft-tree
    /// nodes (0 for AR). Zero-alloc, lock-free.
    #[inline]
    pub fn record_forward(&self, fam: Family, nodes: u32) {
        if let Some(i) = &self.inner {
            let row = &i.ledger[fam as usize];
            row.target_forwards.fetch_add(1, Ordering::Relaxed);
            row.tree_nodes.fetch_add(nodes as u64, Ordering::Relaxed);
        }
    }

    /// Record one commit boundary: `accepted` draft tokens survived
    /// verification, `bonus` extra committed tokens (the tree-exit
    /// bonus draw, or AR's round token), and the per-level trials
    /// (`(nodes, success)` per attempted level, stop-truncated the
    /// same way the stream was). A failed trial is one residual-
    /// resample event whose drawn token was committed, so the
    /// committed-token count is `accepted + bonus + failed trials` —
    /// exactly the tokens the round emitted. Zero-alloc, lock-free.
    #[inline]
    pub fn record_commit(&self, fam: Family, accepted: usize, bonus: usize, trials: &[(usize, usize)]) {
        if let Some(i) = &self.inner {
            let row = &i.ledger[fam as usize];
            let mut resamples = 0u64;
            for (level, &(_, success)) in trials.iter().enumerate() {
                let slot = level.min(MAX_LEVELS - 1);
                row.level_attempts[slot].fetch_add(1, Ordering::Relaxed);
                row.level_accepts[slot].fetch_add(success as u64, Ordering::Relaxed);
                if success == 0 {
                    resamples += 1;
                }
            }
            row.commits.fetch_add(1, Ordering::Relaxed);
            row.accepted.fetch_add(accepted as u64, Ordering::Relaxed);
            row.bonus.fetch_add(bonus as u64, Ordering::Relaxed);
            row.resamples.fetch_add(resamples, Ordering::Relaxed);
            row.committed.fetch_add(accepted as u64 + bonus as u64 + resamples, Ordering::Relaxed);
        }
    }

    /// Engine-round tick: refresh the live cumulative snapshot and
    /// rotate a window boundary every `window_rounds` ticks. Allocates
    /// nothing (two short mutex holds over preallocated `Copy` state).
    #[inline]
    pub fn tick(&self, m: &Metrics, queued: usize, active: usize) {
        let Some(i) = &self.inner else { return };
        let rounds = i.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        let cume = i.collect(m, queued, active, rounds);
        *i.latest.lock().unwrap() = cume;
        if rounds % i.window_rounds as u64 == 0 {
            let mut r = i.ring.lock().unwrap();
            let cap = r.buf.len() as u64;
            let slot = (r.pushed % cap) as usize;
            r.buf[slot] = cume;
            r.pushed += 1;
        }
    }

    /// Record one completed request's SLO outcomes. Zero-alloc.
    #[inline]
    pub fn on_done(&self, ttft: Option<f64>, latency: f64, deadline_ms: Option<u64>) {
        let Some(i) = &self.inner else { return };
        if i.slo_ttft_ms > 0 {
            if let Some(t) = ttft {
                i.ttft_total.fetch_add(1, Ordering::Relaxed);
                if t * 1e3 <= i.slo_ttft_ms as f64 {
                    i.ttft_hits.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if i.slo_latency_ms > 0 {
            i.latency_total.fetch_add(1, Ordering::Relaxed);
            if latency * 1e3 <= i.slo_latency_ms as f64 {
                i.latency_hits.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(d) = deadline_ms {
            i.deadline_total.fetch_add(1, Ordering::Relaxed);
            if latency * 1e3 <= d as f64 {
                i.deadline_hits.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Ledger totals summed over every family (cold path; tests and
    /// export).
    pub fn totals(&self) -> LedgerTotals {
        let mut sum = LedgerTotals::default();
        if self.inner.is_some() {
            for fam in Family::ALL {
                sum.add_assign(&self.family_totals(fam));
            }
        }
        sum
    }

    /// One family's ledger totals (all-zero when disabled).
    pub fn family_totals(&self, fam: Family) -> LedgerTotals {
        match &self.inner {
            Some(i) => i.family_totals(fam),
            None => LedgerTotals::default(),
        }
    }

    /// The `{"cmd": "stats"}` payload: aggregate over the current
    /// (partial) window plus the last `window` completed windows, a
    /// per-window trend series, the cumulative per-family ledger and
    /// the SLO/config echo. `Json::Null` when disabled. Cold path.
    pub fn stats_json(&self, window: usize) -> Json {
        let Some(i) = &self.inner else { return Json::Null };
        let k = window.max(1) as u64;
        let latest = *i.latest.lock().unwrap();
        let (complete, start, trend_cumes, ring_cap) = {
            let r = i.ring.lock().unwrap();
            let cap = r.buf.len() as u64;
            let oldest_retained = r.pushed.saturating_sub(cap) + 1;
            // aggregate start = state at boundary (pushed - k): the
            // zero state when k covers all history, the oldest
            // retained boundary when the requested one was evicted
            let j0 = match r.pushed.saturating_sub(k) {
                0 => 0,
                j if j >= oldest_retained => j,
                _ => oldest_retained,
            };
            let back = r.pushed - j0;
            let start = if j0 == 0 {
                Cume::default()
            } else {
                r.buf[((j0 - 1) % cap) as usize]
            };
            // trend: the last `back` complete windows (both boundaries
            // still retained), oldest first
            let mut trend = Vec::new();
            for j in (j0 + 1)..=r.pushed {
                if j < oldest_retained {
                    continue; // end boundary evicted
                }
                let end = r.buf[((j - 1) % cap) as usize];
                let s = if j == 1 {
                    Cume::default()
                } else if j - 1 >= oldest_retained {
                    r.buf[((j - 2) % cap) as usize]
                } else {
                    continue; // start boundary evicted
                };
                trend.push(end.delta(&s));
            }
            (back, start, trend, r.buf.len())
        };
        let agg = latest.delta(&start);
        let cfg = Json::obj(vec![
            ("window_rounds", Json::from(i.window_rounds)),
            ("windows", Json::from(ring_cap)),
            ("slo_ttft_ms", Json::from(i.slo_ttft_ms as usize)),
            ("slo_latency_ms", Json::from(i.slo_latency_ms as usize)),
        ]);
        let now = Json::obj(vec![
            ("rounds", Json::from(latest.rounds as usize)),
            ("uptime_secs", Json::Num(i.epoch.elapsed().as_secs_f64())),
            ("queue_depth", Json::from(latest.queue_depth as usize)),
            ("active", Json::from(latest.active as usize)),
        ]);
        let mut families = Vec::new();
        for fam in Family::ALL {
            let t = i.family_totals(fam);
            if t.target_forwards == 0 && t.commits == 0 {
                continue;
            }
            families.push((fam.name(), ledger_json(&t)));
        }
        let trend = Json::Arr(
            trend_cumes
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("rounds", Json::from(w.rounds as usize)),
                        ("span_secs", Json::Num(w.t_us as f64 / 1e6)),
                        ("tokens_per_sec", Json::Num(rate(w.tokens_out, w.t_us))),
                        (
                            "accepted_per_target_forward",
                            Json::Num(ratio(w.accepted, w.target_forwards)),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("config", cfg),
            ("now", now),
            ("window", window_json(&agg, complete)),
            ("trend", trend),
            (
                "cumulative",
                Json::obj(vec![
                    ("families", Json::obj(families)),
                    ("slo", slo_json(&latest, i.slo_ttft_ms, i.slo_latency_ms)),
                ]),
            ),
        ])
    }

    /// Prometheus text exposition of the analytics series (empty when
    /// disabled). Appended to the metrics exposition by the `trace`
    /// wire command; names are stable (README Observability).
    pub fn prometheus(&self) -> String {
        let Some(i) = &self.inner else { return String::new() };
        let mut o = String::new();
        let typed = |o: &mut String, name: &str, kind: &str| {
            o.push_str(&format!("# TYPE {name} {kind}\n"));
        };
        for (metric, get) in [
            ("rsd_spec_target_forwards_total", 0usize),
            ("rsd_spec_tree_nodes_total", 1),
            ("rsd_spec_accepted_total", 2),
            ("rsd_spec_bonus_total", 3),
            ("rsd_spec_committed_total", 4),
            ("rsd_spec_resamples_total", 5),
        ] {
            typed(&mut o, metric, "counter");
            for fam in Family::ALL {
                let t = i.family_totals(fam);
                if t.target_forwards == 0 && t.commits == 0 {
                    continue;
                }
                let v = match get {
                    0 => t.target_forwards,
                    1 => t.tree_nodes,
                    2 => t.accepted,
                    3 => t.bonus,
                    4 => t.committed,
                    _ => t.resamples,
                };
                o.push_str(&format!("{metric}{{family=\"{}\"}} {v}\n", fam.name()));
            }
        }
        typed(&mut o, "rsd_spec_accepted_per_forward", "gauge");
        typed(&mut o, "rsd_spec_accept_rate", "gauge");
        for fam in Family::ALL {
            let t = i.family_totals(fam);
            if t.target_forwards == 0 && t.commits == 0 {
                continue;
            }
            o.push_str(&format!(
                "rsd_spec_accepted_per_forward{{family=\"{}\"}} {}\n",
                fam.name(),
                t.accepted_per_target_forward()
            ));
            let rates = t.acceptance_by_level();
            for (level, r) in rates.iter().enumerate() {
                if t.level_attempts[level] == 0 {
                    continue;
                }
                o.push_str(&format!(
                    "rsd_spec_accept_rate{{family=\"{}\",level=\"{level}\"}} {r}\n",
                    fam.name()
                ));
            }
        }
        let ld = Ordering::Relaxed;
        for (name, hits, total) in [
            ("rsd_slo_ttft_attainment", i.ttft_hits.load(ld), i.ttft_total.load(ld)),
            ("rsd_slo_latency_attainment", i.latency_hits.load(ld), i.latency_total.load(ld)),
            ("rsd_slo_deadline_hit_rate", i.deadline_hits.load(ld), i.deadline_total.load(ld)),
        ] {
            typed(&mut o, name, "gauge");
            o.push_str(&format!("{name} {}\n", ratio(hits, total)));
        }
        o
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

fn rate(count: u64, span_us: u64) -> f64 {
    if span_us == 0 {
        0.0
    } else {
        count as f64 / (span_us as f64 / 1e6)
    }
}

fn ledger_json(t: &LedgerTotals) -> Json {
    Json::obj(vec![
        ("target_forwards", Json::from(t.target_forwards as usize)),
        ("tree_nodes", Json::from(t.tree_nodes as usize)),
        ("accepted", Json::from(t.accepted as usize)),
        ("bonus", Json::from(t.bonus as usize)),
        ("committed", Json::from(t.committed as usize)),
        ("resamples", Json::from(t.resamples as usize)),
        ("commits", Json::from(t.commits as usize)),
        ("accepted_per_target_forward", Json::Num(t.accepted_per_target_forward())),
        ("tokens_per_target_forward", Json::Num(t.tokens_per_target_forward())),
        (
            "acceptance_by_level",
            Json::Arr(trim_levels(&t.acceptance_by_level(), &t.level_attempts)),
        ),
    ])
}

/// Drop trailing never-attempted levels from an acceptance curve.
fn trim_levels(rates: &[f64], attempts: &[u64]) -> Vec<Json> {
    let used = attempts.iter().rposition(|&a| a > 0).map_or(0, |p| p + 1);
    rates[..used.min(rates.len())].iter().map(|&r| Json::Num(r)).collect()
}

fn window_json(w: &Cume, complete_windows: u64) -> Json {
    let attempts: Vec<u64> = w.level_attempts.to_vec();
    let rates: Vec<f64> = w
        .level_attempts
        .iter()
        .zip(&w.level_accepts)
        .map(|(&n, &s)| ratio(s, n))
        .collect();
    Json::obj(vec![
        ("complete_windows", Json::from(complete_windows as usize)),
        ("rounds", Json::from(w.rounds as usize)),
        ("span_secs", Json::Num(w.t_us as f64 / 1e6)),
        ("tokens_out", Json::from(w.tokens_out as usize)),
        ("tokens_per_sec", Json::Num(rate(w.tokens_out, w.t_us))),
        ("target_forwards", Json::from(w.target_forwards as usize)),
        ("tree_nodes", Json::from(w.tree_nodes as usize)),
        ("accepted", Json::from(w.accepted as usize)),
        ("bonus", Json::from(w.bonus as usize)),
        ("committed", Json::from(w.committed as usize)),
        ("resamples", Json::from(w.resamples as usize)),
        ("accepted_per_target_forward", Json::Num(ratio(w.accepted, w.target_forwards))),
        ("tokens_per_target_forward", Json::Num(ratio(w.committed, w.target_forwards))),
        ("nodes_per_target_forward", Json::Num(ratio(w.tree_nodes, w.target_forwards))),
        ("acceptance_by_level", Json::Arr(trim_levels(&rates, &attempts))),
        ("kv_hit_rate", Json::Num(ratio(w.kv_hit_tokens, w.kv_lookup_tokens))),
        ("kv_cold_hit_rate", Json::Num(ratio(w.kv_cold_hits, w.kv_cold_consults))),
        ("completed", Json::from(w.completed as usize)),
        ("failed", Json::from(w.failed as usize)),
        ("shed", Json::from(w.shed as usize)),
        ("retries", Json::from(w.retries as usize)),
        ("preemptions", Json::from(w.preemptions as usize)),
        ("queue_depth", Json::from(w.queue_depth as usize)),
        ("active", Json::from(w.active as usize)),
        ("slo", slo_json(w, 1, 1)),
    ])
}

/// SLO attainment block: `null` attainment for objectives that never
/// counted (disabled, or nothing completed yet); burn = worst enabled
/// miss rate over the error budget `1 - SLO_OBJECTIVE`.
fn slo_json(w: &Cume, slo_ttft_ms: u64, slo_latency_ms: u64) -> Json {
    let att = |hits: u64, total: u64, enabled: bool| {
        if !enabled || total == 0 {
            Json::Null
        } else {
            Json::Num(ratio(hits, total))
        }
    };
    let mut burn: f64 = 0.0;
    for (hits, total) in [
        (w.ttft_hits, w.ttft_total),
        (w.latency_hits, w.latency_total),
        (w.deadline_hits, w.deadline_total),
    ] {
        if total > 0 {
            let miss = 1.0 - ratio(hits, total);
            burn = burn.max(miss / (1.0 - SLO_OBJECTIVE));
        }
    }
    Json::obj(vec![
        ("objective", Json::Num(SLO_OBJECTIVE)),
        ("ttft_attainment", att(w.ttft_hits, w.ttft_total, slo_ttft_ms > 0)),
        ("latency_attainment", att(w.latency_hits, w.latency_total, slo_latency_ms > 0)),
        ("deadline_hit_rate", att(w.deadline_hits, w.deadline_total, true)),
        ("error_budget_burn", Json::Num(burn)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled(window_rounds: usize, windows: usize) -> Analytics {
        Analytics::new(window_rounds, windows, 100, 1000)
    }

    #[test]
    fn off_handle_is_inert() {
        let a = Analytics::off();
        assert!(!a.enabled());
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 2, 1, &[(3, 1), (2, 0)]);
        a.on_done(Some(0.01), 0.5, Some(100));
        a.tick(&Metrics::default(), 0, 0);
        assert_eq!(a.totals(), LedgerTotals::default());
        assert!(matches!(a.stats_json(1), Json::Null));
        assert!(a.prometheus().is_empty());
        // window_rounds 0 is the documented "disabled" spelling
        assert!(!Analytics::new(0, 8, 0, 0).enabled());
    }

    #[test]
    fn ledger_accumulates_per_family_and_level() {
        let a = enabled(4, 8);
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 2, 1, &[(3, 1), (3, 1)]);
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 0, 0, &[(3, 0)]);
        a.record_forward(Family::Ar, 0);
        a.record_commit(Family::Ar, 0, 1, &[]);
        let rsds = a.family_totals(Family::RsdS);
        assert_eq!(rsds.target_forwards, 2);
        assert_eq!(rsds.tree_nodes, 12);
        assert_eq!(rsds.accepted, 2);
        assert_eq!(rsds.bonus, 1);
        // round 1 committed its 2 accepts + the bonus draw; round 2
        // committed the residual-resample token of its rejected trial
        assert_eq!(rsds.committed, 4);
        assert_eq!(rsds.resamples, 1, "a rejected trial is one residual resample");
        assert_eq!(rsds.level_attempts[0], 2);
        assert_eq!(rsds.level_accepts[0], 1);
        assert_eq!(rsds.level_attempts[1], 1);
        assert!((rsds.accepted_per_target_forward() - 1.0).abs() < 1e-12);
        assert!((rsds.tokens_per_target_forward() - 2.0).abs() < 1e-12);
        let ar = a.family_totals(Family::Ar);
        assert_eq!(ar.committed, 1);
        assert_eq!(ar.tree_nodes, 0);
        let sum = a.totals();
        assert_eq!(sum.target_forwards, 3);
        assert_eq!(sum.committed, 5);
    }

    #[test]
    fn family_of_maps_every_decoder() {
        use crate::config::AdaptiveFamily;
        let cases = [
            (DecoderConfig::Ar, Family::Ar),
            (DecoderConfig::Sd { l: 3 }, Family::Sd),
            (DecoderConfig::SpecTr { k: 2, l: 2 }, Family::SpecTr),
            (DecoderConfig::RsdC { branches: vec![2, 2] }, Family::RsdC),
            (DecoderConfig::RsdCMultiRound { branches: vec![2] }, Family::RsdCMr),
            (DecoderConfig::RsdS { w: 3, l: 2 }, Family::RsdS),
            (
                DecoderConfig::Adaptive { budget: 6, family: AdaptiveFamily::Auto },
                Family::Adaptive,
            ),
        ];
        for (cfg, fam) in cases {
            assert_eq!(Family::of(&cfg), fam, "{cfg:?}");
        }
    }

    #[test]
    fn deep_trials_clamp_into_the_last_level_slot() {
        let a = enabled(4, 8);
        let trials: Vec<(usize, usize)> = (0..MAX_LEVELS + 4).map(|_| (1, 1)).collect();
        a.record_commit(Family::Sd, trials.len(), 0, &trials);
        let t = a.family_totals(Family::Sd);
        assert_eq!(t.level_attempts[MAX_LEVELS - 1], 5);
        assert_eq!(t.level_attempts.iter().sum::<u64>(), (MAX_LEVELS + 4) as u64);
    }

    #[test]
    fn windows_aggregate_deltas_not_lifetime_sums() {
        let a = enabled(2, 8);
        let m = Metrics::default();
        // window 1: two rounds, 10 tokens
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 3, 1, &[(3, 1)]);
        m.add(&m.tokens_out, 10);
        a.tick(&m, 1, 2);
        a.tick(&m, 1, 2); // rotates
        // window 2: 5 more tokens, one more forward
        m.add(&m.tokens_out, 5);
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 1, 0, &[(3, 1)]);
        a.tick(&m, 0, 1);
        a.tick(&m, 0, 1); // rotates
        let j = a.stats_json(1);
        let w = j.get("window").unwrap();
        // last complete window + empty partial: only window 2's deltas
        assert_eq!(w.usize_field("tokens_out").unwrap(), 5);
        assert_eq!(w.usize_field("target_forwards").unwrap(), 1);
        assert_eq!(w.usize_field("accepted").unwrap(), 1);
        assert_eq!(w.usize_field("rounds").unwrap(), 2);
        // widening the window to 2 covers everything
        let j = a.stats_json(2);
        let w = j.get("window").unwrap();
        assert_eq!(w.usize_field("tokens_out").unwrap(), 15);
        assert_eq!(w.usize_field("target_forwards").unwrap(), 2);
        let trend = j.get("trend").unwrap().as_arr().unwrap();
        assert_eq!(trend.len(), 2);
        assert_eq!(trend[0].usize_field("rounds").unwrap(), 2);
    }

    #[test]
    fn ring_wraparound_clamps_to_oldest_retained_boundary() {
        let a = enabled(1, 3); // every tick is a boundary; ring holds 3
        let m = Metrics::default();
        for _ in 0..10u64 {
            m.add(&m.tokens_out, 1);
            a.tick(&m, 0, 0);
        }
        // a window request covering all history aggregates from zero
        // (the cumulative state needs no evicted boundary)
        let j = a.stats_json(100);
        let w = j.get("window").unwrap();
        assert_eq!(w.usize_field("complete_windows").unwrap(), 10);
        assert_eq!(w.usize_field("tokens_out").unwrap(), 10);
        assert_eq!(w.usize_field("rounds").unwrap(), 10);
        let trend = j.get("trend").unwrap().as_arr().unwrap();
        // per-window deltas need BOTH boundaries retained: only the
        // windows ending at boundaries 9 and 10 render
        assert_eq!(trend.len(), 2);
        for t in trend {
            assert_eq!(t.usize_field("rounds").unwrap(), 1);
        }
        // a mid-size request whose start boundary was evicted clamps
        // to the oldest retained boundary (8): 2 windows, 2 tokens
        let j = a.stats_json(5);
        let w = j.get("window").unwrap();
        assert_eq!(w.usize_field("complete_windows").unwrap(), 2);
        assert_eq!(w.usize_field("tokens_out").unwrap(), 2);
    }

    #[test]
    fn empty_window_yields_zeroes_not_nans() {
        let a = enabled(4, 4);
        let j = a.stats_json(1);
        let w = j.get("window").unwrap();
        assert_eq!(w.usize_field("rounds").unwrap(), 0);
        assert_eq!(w.get("tokens_per_sec").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            w.get("accepted_per_target_forward").unwrap().as_f64().unwrap(),
            0.0
        );
        assert_eq!(w.get("kv_hit_rate").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(w.get("kv_cold_hit_rate").unwrap().as_f64().unwrap(), 0.0);
        assert!(w.get("acceptance_by_level").unwrap().as_arr().unwrap().is_empty());
        // the whole document round-trips through the parser (no NaN —
        // NaN would not serialize to valid JSON)
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn slo_counters_and_burn() {
        let a = Analytics::new(4, 4, 100, 1000);
        // ttft 50ms hit, latency 0.5s hit, deadline 200ms hit
        a.on_done(Some(0.05), 0.5, Some(200));
        // ttft miss, latency miss, deadline miss
        a.on_done(Some(0.5), 2.0, Some(100));
        // no first token: ttft not counted, latency hit, no deadline
        a.on_done(None, 0.9, None);
        let m = Metrics::default();
        a.tick(&m, 0, 0);
        let j = a.stats_json(1);
        let slo = j.get("window").unwrap().get("slo").unwrap();
        assert!((slo.get("ttft_attainment").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        let lat = slo.get("latency_attainment").unwrap().as_f64().unwrap();
        assert!((lat - 2.0 / 3.0).abs() < 1e-12);
        assert!((slo.get("deadline_hit_rate").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        // worst miss rate 0.5 over a 1% budget = 50x burn
        assert!((slo.get("error_budget_burn").unwrap().as_f64().unwrap() - 50.0).abs() < 1e-9);
        // disabled objectives answer null, not 0
        let b = Analytics::new(4, 4, 0, 0);
        b.on_done(Some(0.05), 0.5, None);
        b.tick(&m, 0, 0);
        let j = b.stats_json(1);
        let slo = j.get("window").unwrap().get("slo").unwrap();
        assert!(matches!(slo.get("ttft_attainment"), Some(Json::Null)));
        assert!(matches!(slo.get("latency_attainment"), Some(Json::Null)));
        assert!(matches!(slo.get("deadline_hit_rate"), Some(Json::Null)));
    }

    #[test]
    fn prometheus_exposition_has_stable_names() {
        let a = enabled(4, 4);
        a.record_forward(Family::RsdS, 6);
        a.record_commit(Family::RsdS, 2, 1, &[(3, 1), (3, 0)]);
        a.on_done(Some(0.01), 0.1, Some(500));
        let text = a.prometheus();
        for needle in [
            "# TYPE rsd_spec_target_forwards_total counter",
            "rsd_spec_target_forwards_total{family=\"rsd-s\"} 1",
            "rsd_spec_accepted_total{family=\"rsd-s\"} 2",
            "rsd_spec_committed_total{family=\"rsd-s\"} 4",
            "rsd_spec_resamples_total{family=\"rsd-s\"} 1",
            "rsd_spec_accepted_per_forward{family=\"rsd-s\"} 2",
            "rsd_spec_accept_rate{family=\"rsd-s\",level=\"0\"} 1",
            "rsd_spec_accept_rate{family=\"rsd-s\",level=\"1\"} 0",
            "# TYPE rsd_slo_ttft_attainment gauge",
            "rsd_slo_deadline_hit_rate 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // families that never recorded are omitted entirely
        assert!(!text.contains("family=\"spectr\""));
    }

    #[test]
    fn stats_json_roundtrips_and_carries_headline_metrics() {
        let a = enabled(2, 4);
        let m = Metrics::default();
        a.record_forward(Family::Adaptive, 8);
        a.record_commit(Family::Adaptive, 3, 1, &[(4, 1), (4, 1), (2, 0)]);
        m.add(&m.tokens_out, 4);
        a.tick(&m, 2, 1);
        a.tick(&m, 2, 1);
        let j = Json::parse(&a.stats_json(1).to_string()).unwrap();
        let w = j.get("window").unwrap();
        assert!((w.get("accepted_per_target_forward").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12);
        let curve = w.get("acceptance_by_level").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 3);
        assert!((curve[0].as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(curve[2].as_f64().unwrap(), 0.0);
        let fam = j
            .get("cumulative")
            .unwrap()
            .get("families")
            .unwrap()
            .get("adaptive")
            .unwrap();
        assert_eq!(fam.usize_field("target_forwards").unwrap(), 1);
        assert_eq!(fam.usize_field("resamples").unwrap(), 1);
        assert_eq!(j.get("now").unwrap().usize_field("queue_depth").unwrap(), 2);
        assert_eq!(
            j.get("config").unwrap().usize_field("window_rounds").unwrap(),
            2
        );
    }
}
