//! The per-request controller: estimator + allocator + re-shaping loop.
//!
//! [`AdaptiveController`] owns a request's acceptance statistics and
//! turns them into one [`TreeShape`] per speculative round.
//! [`AdaptiveStepper`] binds a controller to a resumable
//! [`SpecStepper`], swapping the tree strategy between rounds — the
//! engine steps it exactly like a static speculative session, so
//! continuous batching, streaming and fairness are untouched.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{AdaptiveFamily, SamplingConfig, ADAPTIVE_MAX_DEPTH};
use crate::decode::rrs::Rrs;
use crate::decode::spec::{RoundReport, RoundStart, SpecStepper, StepOutcome};
use crate::decode::{DecodeRun, DecodeStats};
use crate::llm::{EvalNode, Llm, LogitsView};
use crate::util::Rng;

use super::allocator::{self, TreeShape, DEFAULT_PHI_GAP, DEFAULT_RATE};
use super::estimator::{AcceptanceEstimator, GlobalEstimator};

/// Pseudo-trials backing the engine-global prior when blending it into a
/// request's local estimate.
const GLOBAL_PRIOR_STRENGTH: f64 = 4.0;
/// Pseudo-trials the local estimate needs before it dominates the prior.
const LOCAL_PRIOR_STRENGTH: f64 = 8.0;

/// Chooses a draft-tree shape each round under a hard node budget.
pub struct AdaptiveController {
    budget: usize,
    /// Candidate shapes, enumerated once: the space depends only on
    /// (budget, family), so each round only re-scores it.
    shapes: Vec<TreeShape>,
    local: AcceptanceEstimator,
    global: Option<Arc<GlobalEstimator>>,
}

impl AdaptiveController {
    /// `global` carries engine-wide decayed statistics (None outside the
    /// serving engine, e.g. in single-shot [`crate::decode::generate`]).
    /// Programmatic budgets are clamped to the parser's accepted range
    /// `[1, ADAPTIVE_MAX_BUDGET]`.
    pub fn new(
        budget: usize,
        family: AdaptiveFamily,
        global: Option<Arc<GlobalEstimator>>,
    ) -> Self {
        let budget = budget.clamp(1, allocator::MAX_SEARCH_BUDGET);
        Self {
            budget,
            shapes: allocator::enumerate_shapes(budget, family),
            local: AcceptanceEstimator::default(),
            global,
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Blended per-level acceptance rates: the global estimator acts as
    /// the prior mean, local evidence takes over as it accumulates.
    /// Clamped away from {0, 1} so no shape's score ever degenerates.
    pub fn rates(&self) -> Vec<f64> {
        (0..ADAPTIVE_MAX_DEPTH)
            .map(|level| {
                let prior = match &self.global {
                    Some(g) => g.rate(level, DEFAULT_RATE, GLOBAL_PRIOR_STRENGTH),
                    None => DEFAULT_RATE,
                };
                self.local.rate(level, prior, LOCAL_PRIOR_STRENGTH).clamp(0.02, 0.98)
            })
            .collect()
    }

    /// The shape to run next round. Guaranteed `budget() <= self.budget`.
    pub fn next_shape(&self) -> TreeShape {
        allocator::best_shape_from(&self.shapes, &self.rates())
    }

    /// Fold one round's verification telemetry into both scopes.
    pub fn observe(&mut self, report: &RoundReport) {
        self.local.observe(report);
        if let Some(g) = &self.global {
            g.observe(report);
        }
    }
}

/// A resumable adaptive decoding session: one speculative round per
/// `step`, with the tree re-shaped from live acceptance estimates before
/// every round.
pub struct AdaptiveStepper<T: Llm, D: Llm> {
    inner: SpecStepper<T, D>,
    ctl: AdaptiveController,
    /// Shape the inner stepper currently holds: re-building the boxed
    /// strategy is skipped while the controller's choice is stable
    /// (the steady state once estimates converge).
    current: TreeShape,
}

impl<T: Llm, D: Llm> AdaptiveStepper<T, D> {
    pub fn new(
        target: &T,
        draft: &D,
        ctl: AdaptiveController,
        sampling: SamplingConfig,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Self> {
        let shape = ctl.next_shape();
        let inner = SpecStepper::new(
            target,
            draft,
            shape.build(DEFAULT_PHI_GAP),
            Box::new(Rrs),
            sampling,
            prompt,
            max_new,
        )?;
        Ok(Self { inner, ctl, current: shape })
    }

    /// Swap the inner stepper's tree strategy to the controller's current
    /// pick. Safe at round granularity only (see
    /// [`SpecStepper::set_strategy`]).
    fn reshape(&mut self) {
        if !self.inner.is_done() {
            let shape = self.ctl.next_shape();
            debug_assert!(shape.budget() <= self.ctl.budget());
            if shape != self.current {
                self.inner.set_strategy(shape.build(DEFAULT_PHI_GAP));
                self.current = shape;
            }
        }
    }

    /// Fold the just-finished round's telemetry into the controller.
    fn observe_round(&mut self) {
        if let Some(report) = self.inner.last_round() {
            // clone keeps the report available for the engine's metrics
            let report = report.clone();
            self.ctl.observe(&report);
        }
    }

    /// Phase machine (see [`SpecStepper`]): re-shape, then start a round.
    pub fn begin_round(&mut self, target: &T, draft: &D) -> Result<RoundStart> {
        self.reshape();
        self.inner.begin_round(target, draft)
    }

    /// Pending draft work of the current phase (delegates to the inner
    /// stepper).
    pub fn draft_group(&mut self) -> Option<(&mut D::Session, &[EvalNode])> {
        self.inner.draft_group()
    }

    pub fn feed_draft(&mut self, rows: LogitsView<'_>, rng: &mut Rng) -> Result<()> {
        self.inner.feed_draft(rows, rng)
    }

    /// Pending target (verification) work of the current phase.
    pub fn target_group(&mut self) -> Option<(&mut T::Session, &[EvalNode])> {
        self.inner.target_group()
    }

    /// Verify + commit + learn from the round's outcome.
    pub fn feed_target(
        &mut self,
        target: &T,
        draft: &D,
        rows: LogitsView<'_>,
        rng: &mut Rng,
    ) -> Result<StepOutcome> {
        let outcome = self.inner.feed_target(target, draft, rows, rng)?;
        self.observe_round();
        Ok(outcome)
    }

    /// Re-shape, run one speculative round, learn from its outcome.
    pub fn step(&mut self, target: &T, draft: &D, rng: &mut Rng) -> Result<StepOutcome> {
        self.reshape();
        let outcome = self.inner.step(target, draft, rng)?;
        self.observe_round();
        Ok(outcome)
    }

    pub fn is_done(&self) -> bool {
        self.inner.is_done()
    }

    /// Worst-case new KV slots next round (engine preemption math).
    /// Uses the controller's hard node budget, not the current
    /// strategy's size: `begin_round` may re-shape to any tree within
    /// the budget before the round's allocations happen.
    pub fn round_need(&self) -> usize {
        self.inner.round_need_with_budget(self.ctl.budget())
    }

    /// Spill KV state between rounds (see [`SpecStepper::suspend`]).
    /// The controller's acceptance statistics live host-side and are
    /// untouched.
    pub fn suspend(&mut self, target: &T, draft: &D) -> Result<()> {
        self.inner.suspend(target, draft)
    }

    /// Attach a flight-recorder handle (delegates to the wrapped
    /// [`SpecStepper`]).
    pub fn set_trace(&mut self, tracer: &crate::trace::Tracer, id: u64) {
        self.inner.set_trace(tracer, id);
    }

    /// Attach a speculation-analytics handle (delegates to the wrapped
    /// [`SpecStepper`], so rounds are recorded exactly once — under
    /// `family`, whatever tree shape the controller picks per round).
    pub fn set_analytics(&mut self, analytics: &crate::obs::Analytics, family: crate::obs::Family) {
        self.inner.set_analytics(analytics, family);
    }

    /// Re-admit after a suspend (see [`SpecStepper::resume`]).
    pub fn resume(&mut self, target: &T, draft: &D) -> Result<()> {
        self.inner.resume(target, draft)
    }

    /// Abandon an in-flight round after a mid-round fault (see
    /// [`SpecStepper::abort_round`]). The aborted round never reached
    /// `feed_target`, so the acceptance estimator observed nothing and
    /// the retried round reshapes from the same statistics.
    pub fn abort_round(&mut self, target: &T, draft: &D) -> Result<()> {
        self.inner.abort_round(target, draft)
    }

    pub fn out(&self) -> &[u32] {
        &self.inner.out
    }

    /// The streaming commit boundary (see
    /// [`SpecStepper::committed_len`]).
    pub fn committed_len(&self) -> usize {
        self.inner.committed_len()
    }

    pub fn stats(&self) -> &DecodeStats {
        &self.inner.stats
    }

    pub fn last_round(&self) -> Option<&RoundReport> {
        self.inner.last_round()
    }

    pub fn controller(&self) -> &AdaptiveController {
        &self.ctl
    }
}

/// Full adaptive decoding loop (the [`crate::decode::generate`] path —
/// no engine, so no global statistics are shared).
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive<T: Llm, D: Llm>(
    target: &T,
    draft: &D,
    budget: usize,
    family: AdaptiveFamily,
    sampling: &SamplingConfig,
    prompt: &[u32],
    max_new: usize,
    rng: &mut Rng,
) -> Result<DecodeRun> {
    let ctl = AdaptiveController::new(budget, family, None);
    let mut stepper =
        AdaptiveStepper::new(target, draft, ctl, sampling.clone(), prompt, max_new)?;
    while stepper.step(target, draft, rng)? == StepOutcome::Progress {}
    Ok(DecodeRun { tokens: stepper.out().to_vec(), stats: stepper.stats().clone() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimLm;

    #[test]
    fn controller_adapts_shape_to_evidence() {
        let mut ctl = AdaptiveController::new(6, AdaptiveFamily::Auto, None);
        // feed rounds where level 0 always accepts instantly: the shape
        // should move towards depth
        for _ in 0..100 {
            let depth = ctl.next_shape().depth().min(3);
            let trials = (0..depth).map(|_| (1, 1)).collect();
            ctl.observe(&RoundReport {
                level_trials: trials,
                nodes: 6,
                accepted: depth,
                bonus: true,
            });
        }
        let deep = ctl.next_shape();
        assert!(deep.depth() >= 3, "{deep:?}");
        // now feed heavy rejection: the shape should widen / shallow out
        let mut ctl = AdaptiveController::new(6, AdaptiveFamily::Auto, None);
        for _ in 0..100 {
            let b = match ctl.next_shape() {
                TreeShape::RsdC { branches } => branches[0],
                TreeShape::RsdS { w, .. } => w,
            };
            ctl.observe(&RoundReport {
                level_trials: vec![(b, 0)],
                nodes: 6,
                accepted: 0,
                bonus: false,
            });
        }
        let wide = ctl.next_shape();
        assert!(wide.depth() <= 2, "{wide:?}");
    }

    #[test]
    fn every_chosen_shape_respects_budget() {
        for budget in [1usize, 3, 6, 10, 30] {
            let ctl = AdaptiveController::new(budget, AdaptiveFamily::Auto, None);
            assert!(ctl.next_shape().budget() <= budget);
        }
    }

    #[test]
    fn adaptive_generation_is_exact_length_and_budget_bounded() {
        let (target, draft) = SimLm::pair(3, 0.7, 48);
        let mut rng = Rng::seed_from_u64(0);
        let sampling = SamplingConfig::default();
        for budget in [6usize, 30] {
            let run = run_adaptive(
                &target,
                &draft,
                budget,
                AdaptiveFamily::Auto,
                &sampling,
                &[1, 2, 3],
                32,
                &mut rng,
            )
            .unwrap();
            assert_eq!(run.tokens.len(), 32);
            assert!(run
                .stats
                .round_nodes
                .iter()
                .all(|&n| n as usize <= budget), "budget {budget}: {:?}", run.stats.round_nodes);
        }
    }
}
