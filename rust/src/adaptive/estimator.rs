//! Online acceptance estimation from verification telemetry.
//!
//! Every walked level of a verified draft tree yields Bernoulli evidence
//! about the *per-candidate* acceptance rate at that depth: an accepted
//! level at sibling position `k` is `k` rejections followed by one
//! acceptance (`k + 1` trials, 1 success); a rejected level with `b`
//! candidates is `b` trials, 0 successes ([`RoundReport::level_trials`]).
//! The estimator keeps exponentially decayed trial/success counts per
//! level, so shifts in draft-target alignment along a generation (topic
//! drift, code vs. prose, ...) are tracked within a few dozen rounds.
//!
//! Two scopes exist in the serving engine: a per-request
//! [`AcceptanceEstimator`] (fast, noisy) and an engine-global
//! [`GlobalEstimator`] shared across requests (slow, smooth) that serves
//! as the prior for freshly admitted requests.

use std::sync::Mutex;

use crate::decode::spec::RoundReport;

/// Exponentially decayed per-level acceptance statistics.
#[derive(Debug, Clone)]
pub struct AcceptanceEstimator {
    /// Multiplicative decay applied to a level's counts on each new
    /// observation of that level.
    decay: f64,
    trials: Vec<f64>,
    successes: Vec<f64>,
}

impl Default for AcceptanceEstimator {
    fn default() -> Self {
        Self::new(0.97)
    }
}

impl AcceptanceEstimator {
    pub fn new(decay: f64) -> Self {
        assert!((0.0..=1.0).contains(&decay));
        Self { decay, trials: Vec::new(), successes: Vec::new() }
    }

    /// Fold one round's verification walk into the statistics.
    pub fn observe(&mut self, report: &RoundReport) {
        for (level, &(trials, successes)) in report.level_trials.iter().enumerate() {
            if self.trials.len() <= level {
                self.trials.resize(level + 1, 0.0);
                self.successes.resize(level + 1, 0.0);
            }
            self.trials[level] = self.trials[level] * self.decay + trials as f64;
            self.successes[level] = self.successes[level] * self.decay + successes as f64;
        }
    }

    /// Posterior-mean per-candidate acceptance rate at `level`, shrunk
    /// towards `prior_mean` with `prior_strength` pseudo-trials. Levels
    /// never observed return the prior mean exactly.
    pub fn rate(&self, level: usize, prior_mean: f64, prior_strength: f64) -> f64 {
        let (n, s) = match self.trials.get(level) {
            Some(&n) => (n, self.successes[level]),
            None => (0.0, 0.0),
        };
        (s + prior_mean * prior_strength) / (n + prior_strength)
    }

    /// Decayed trial mass at `level` (how much evidence the rate rests on).
    pub fn evidence(&self, level: usize) -> f64 {
        self.trials.get(level).copied().unwrap_or(0.0)
    }

    /// Deepest level with any evidence.
    pub fn levels(&self) -> usize {
        self.trials.len()
    }
}

/// Engine-global decayed acceptance statistics, shared (via `Arc`) by
/// every adaptive request of one engine. Interior mutability keeps the
/// engine loop borrow-free; the engine is single-threaded so the lock is
/// uncontended.
#[derive(Debug, Default)]
pub struct GlobalEstimator {
    inner: Mutex<AcceptanceEstimator>,
}

impl GlobalEstimator {
    pub fn observe(&self, report: &RoundReport) {
        self.inner.lock().unwrap().observe(report);
    }

    pub fn rate(&self, level: usize, prior_mean: f64, prior_strength: f64) -> f64 {
        self.inner.lock().unwrap().rate(level, prior_mean, prior_strength)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(level_trials: Vec<(usize, usize)>) -> RoundReport {
        let accepted = level_trials.iter().filter(|&&(_, s)| s == 1).count();
        RoundReport { level_trials, nodes: 4, accepted, bonus: false }
    }

    #[test]
    fn unobserved_levels_return_prior() {
        let e = AcceptanceEstimator::default();
        assert!((e.rate(0, 0.6, 8.0) - 0.6).abs() < 1e-12);
        assert!((e.rate(5, 0.3, 8.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn rates_converge_to_observed_frequency() {
        let mut e = AcceptanceEstimator::new(1.0); // no decay: plain counts
        // level 0: accept on the first candidate every time -> rate ~1
        // level 1: 3 candidates all rejected every time -> rate ~0
        for _ in 0..200 {
            e.observe(&report(vec![(1, 1), (3, 0)]));
        }
        assert!(e.rate(0, 0.5, 1.0) > 0.95);
        assert!(e.rate(1, 0.5, 1.0) < 0.05);
        assert_eq!(e.levels(), 2);
    }

    #[test]
    fn decay_tracks_regime_change() {
        let mut e = AcceptanceEstimator::new(0.9);
        for _ in 0..100 {
            e.observe(&report(vec![(1, 1)]));
        }
        assert!(e.rate(0, 0.5, 1.0) > 0.9);
        for _ in 0..100 {
            e.observe(&report(vec![(4, 0)]));
        }
        assert!(e.rate(0, 0.5, 1.0) < 0.1, "decayed stats must follow the new regime");
    }

    #[test]
    fn global_estimator_accumulates_across_observers() {
        let g = GlobalEstimator::default();
        for _ in 0..50 {
            g.observe(&report(vec![(2, 1)]));
        }
        // 2 trials, 1 success per round -> rate near 0.5
        let r = g.rate(0, 0.9, 1.0);
        assert!((r - 0.5).abs() < 0.1, "{r}");
    }
}
