//! Adaptive draft-budget control: per-request online tree shaping under
//! a fixed target-compute budget.
//!
//! The paper's Exp2 fixes the *target computational budget* B — the
//! draft-tree nodes the target model processes per iteration — and asks
//! which tree shape maximizes block efficiency. Statically the answer
//! depends on draft-target alignment the server cannot know up front:
//! well-aligned requests want deep narrow trees, misaligned ones want
//! their budget spent on sibling width at shallow levels. This module
//! closes the loop at runtime, per request:
//!
//! * [`estimator`] — decayed per-level acceptance statistics harvested
//!   from every verification walk ([`crate::decode::spec::RoundReport`]),
//!   kept per-request and engine-global (the prior for new requests);
//! * [`allocator`] — exhaustive scoring of RSD-C branch vectors and
//!   RSD-S `(w, l)` beams under the hard node budget, maximizing the
//!   expected accepted tokens per round under the paper's acceptance
//!   model `1 - (1 - a_l)^{b_l}`;
//! * [`controller`] — the per-round loop: choose shape, run one
//!   speculative round via [`crate::decode::spec::SpecStepper`]
//!   (re-shaped in place between rounds), observe, repeat.
//!
//! Selected by [`crate::config::DecoderConfig::Adaptive`] (spec strings
//! `adaptive:B`, `adaptive:B:rsd-c`, `adaptive:B:rsd-s`), per request
//! over the serving protocol via `"decoder": "adaptive:30"`.

pub mod allocator;
pub mod controller;
pub mod estimator;

pub use allocator::TreeShape;
pub use controller::{run_adaptive, AdaptiveController, AdaptiveStepper};
pub use estimator::{AcceptanceEstimator, GlobalEstimator};
