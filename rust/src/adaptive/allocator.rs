//! Budget-constrained tree-shape allocation.
//!
//! Given per-level per-candidate acceptance rates `a_l` (estimated
//! online) and a hard per-round node budget `B`, pick the draft-tree
//! shape maximizing the expected accepted tokens per round under the
//! paper's acceptance model: a level offering `b` without-replacement
//! candidates is accepted with probability ≈ `1 - (1 - a_l)^b`, and the
//! verification walk survives to level `l` with the product of the
//! preceding level probabilities. Every round additionally emits one
//! final token (residual or bonus), hence the `1 +` in the objective.
//!
//! The search space is the paper's two families (App. C.3.2) under the
//! budget: non-increasing RSD-C branch vectors with
//! `sum_l prod_{j<=l} b_j <= B`, and RSD-S beams `(w, l)` with
//! `w * l <= B`, both capped at [`ADAPTIVE_MAX_DEPTH`]. The spaces are
//! tiny (tens of shapes for B = 30), so exhaustive scoring per round is
//! cheaper than a single draft-model call.

use crate::config::{
    rsd_c_budget, AdaptiveFamily, DecoderConfig, ADAPTIVE_MAX_BUDGET, ADAPTIVE_MAX_DEPTH,
};
use crate::decode::spec::TreeStrategy;
use crate::decode::strategies::{GumbelTopK, StochasticBeam};

/// Acceptance rate assumed for levels with no evidence at all (also the
/// prior mean the estimators shrink towards).
pub const DEFAULT_RATE: f64 = 0.6;

/// Early-truncation gap (log-prob units) applied to adaptive RSD-S
/// shapes: beam branches trailing the level-best sequence by more than
/// this are not drafted, returning unused nodes to the budget.
pub const DEFAULT_PHI_GAP: f64 = 10.0;

/// Shape search is capped here even when a programmatic budget is
/// larger: beyond a few hundred nodes per round no additional shape can
/// raise expected tokens (depth is capped and width saturates
/// `1 - (1-a)^b`), while the search space would grow without bound.
/// Kept equal to the parser's [`ADAPTIVE_MAX_BUDGET`], so a request's
/// declared budget (its admission weight) is never above what a round
/// can actually use.
pub const MAX_SEARCH_BUDGET: usize = ADAPTIVE_MAX_BUDGET;

/// A concrete draft-tree shape the controller can run for one round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TreeShape {
    RsdC { branches: Vec<usize> },
    RsdS { w: usize, l: usize },
}

impl TreeShape {
    /// Worst-case draft-tree nodes per round (the shape's budget).
    pub fn budget(&self) -> usize {
        match self {
            TreeShape::RsdC { branches } => rsd_c_budget(branches),
            TreeShape::RsdS { w, l } => w * l,
        }
    }

    pub fn depth(&self) -> usize {
        match self {
            TreeShape::RsdC { branches } => branches.len(),
            TreeShape::RsdS { l, .. } => *l,
        }
    }

    /// The equivalent static decoder config (labels, budget math).
    pub fn to_decoder(&self) -> DecoderConfig {
        match self {
            TreeShape::RsdC { branches } => DecoderConfig::RsdC { branches: branches.clone() },
            TreeShape::RsdS { w, l } => DecoderConfig::RsdS { w: *w, l: *l },
        }
    }

    /// Instantiate the drafting strategy for one round.
    pub fn build(&self, phi_gap: f64) -> Box<dyn TreeStrategy> {
        match self {
            TreeShape::RsdC { branches } => {
                Box::new(GumbelTopK::new(branches.clone()))
            }
            TreeShape::RsdS { w, l } => Box::new(StochasticBeam::with_gap(*w, *l, phi_gap)),
        }
    }

    pub fn label(&self) -> String {
        self.to_decoder().label()
    }
}

/// Per-level survival probability of a sibling set with `b` candidates.
fn level_accept(rate: f64, b: usize) -> f64 {
    1.0 - (1.0 - rate.clamp(0.0, 1.0)).powi(b as i32)
}

/// Expected tokens emitted per round by `shape` under rates `a` (indexed
/// by level; levels beyond `a.len()` reuse the last known rate).
pub fn expected_tokens(shape: &TreeShape, a: &[f64]) -> f64 {
    let rate_at = |l: usize| -> f64 {
        a.get(l).copied().or_else(|| a.last().copied()).unwrap_or(DEFAULT_RATE)
    };
    let mut survive = 1.0;
    let mut expected = 1.0; // the round's final token (residual or bonus)
    match shape {
        TreeShape::RsdC { branches } => {
            for (l, &b) in branches.iter().enumerate() {
                survive *= level_accept(rate_at(l), b);
                expected += survive;
            }
        }
        TreeShape::RsdS { w, l } => {
            for lvl in 0..*l {
                survive *= level_accept(rate_at(lvl), *w);
                expected += survive;
            }
        }
    }
    expected
}

/// All non-increasing RSD-C branch vectors within `budget` nodes and
/// [`ADAPTIVE_MAX_DEPTH`] levels.
fn rsdc_shapes(budget: usize) -> Vec<TreeShape> {
    fn rec(
        out: &mut Vec<TreeShape>,
        cur: &mut Vec<usize>,
        level_nodes: usize,
        used: usize,
        max_branch: usize,
        budget: usize,
    ) {
        if !cur.is_empty() {
            out.push(TreeShape::RsdC { branches: cur.clone() });
        }
        if cur.len() >= ADAPTIVE_MAX_DEPTH {
            return;
        }
        for b in 1..=max_branch {
            let Some(nodes) = level_nodes.checked_mul(b) else { break };
            if used + nodes > budget {
                break; // nodes grows with b
            }
            cur.push(b);
            rec(out, cur, nodes, used + nodes, b, budget);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(&mut out, &mut Vec::new(), 1, 0, budget, budget);
    out
}

/// All RSD-S beams with `w * l <= budget` and depth within the cap.
fn rsds_shapes(budget: usize) -> Vec<TreeShape> {
    let mut out = Vec::new();
    for l in 1..=budget.min(ADAPTIVE_MAX_DEPTH) {
        for w in 1..=(budget / l) {
            out.push(TreeShape::RsdS { w, l });
        }
    }
    out
}

/// Candidate shapes for `family` under `budget`. Never empty: budget is
/// clamped to [1, [`MAX_SEARCH_BUDGET`]] before searching (a zero budget
/// is meaningless for a speculative decoder — the spec-string parser
/// rejects it — so programmatic callers get the single-node chain
/// instead of a panic), and emitted shapes always respect the caller's
/// budget for `budget >= 1`, whatever its magnitude.
pub fn enumerate_shapes(budget: usize, family: AdaptiveFamily) -> Vec<TreeShape> {
    let budget = budget.clamp(1, MAX_SEARCH_BUDGET);
    let mut out = Vec::new();
    if matches!(family, AdaptiveFamily::Auto | AdaptiveFamily::RsdC) {
        out.extend(rsdc_shapes(budget));
    }
    if matches!(family, AdaptiveFamily::Auto | AdaptiveFamily::RsdS) {
        out.extend(rsds_shapes(budget));
    }
    out
}

/// The shape in `shapes` maximizing [`expected_tokens`]. Ties break
/// towards the cheaper shape (fewer nodes), then first-listed, so the
/// choice is deterministic. The per-round hot path: the controller
/// enumerates its shape space once and re-scores it here every round.
pub fn best_shape_from(shapes: &[TreeShape], rates: &[f64]) -> TreeShape {
    let mut best: Option<(&TreeShape, usize, f64)> = None;
    for shape in shapes {
        let nodes = shape.budget();
        let score = expected_tokens(shape, rates);
        let better = match &best {
            None => true,
            Some((_, cur_nodes, cur_score)) => {
                score > cur_score + 1e-9
                    || ((score - cur_score).abs() <= 1e-9 && nodes < *cur_nodes)
            }
        };
        if better {
            best = Some((shape, nodes, score));
        }
    }
    best.expect("shape list must be non-empty").0.clone()
}

/// Convenience: enumerate + select in one call (tests, `build_parts`).
pub fn best_shape(budget: usize, family: AdaptiveFamily, rates: &[f64]) -> TreeShape {
    best_shape_from(&enumerate_shapes(budget, family), rates)
}

/// The shape used before any acceptance evidence exists (uniform prior).
pub fn initial_shape(budget: usize, family: AdaptiveFamily) -> TreeShape {
    best_shape(budget, family, &[DEFAULT_RATE])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration_respects_budget_and_monotonicity() {
        for b in [1usize, 2, 6, 14, 30] {
            let shapes = enumerate_shapes(b, AdaptiveFamily::Auto);
            assert!(!shapes.is_empty());
            for s in &shapes {
                assert!(s.budget() <= b, "{s:?} exceeds budget {b}");
                assert!(s.depth() <= ADAPTIVE_MAX_DEPTH);
                if let TreeShape::RsdC { branches } = s {
                    assert!(branches.windows(2).all(|w| w[0] >= w[1]), "{branches:?}");
                }
            }
        }
    }

    #[test]
    fn high_acceptance_prefers_depth_low_prefers_width() {
        // near-perfect draft: deep chains dominate (every level survives)
        let deep = best_shape(6, AdaptiveFamily::Auto, &[0.97]);
        assert!(deep.depth() >= 4, "{deep:?}");
        // poor draft: spend the budget on sibling width up front
        let wide = best_shape(6, AdaptiveFamily::Auto, &[0.25]);
        assert!(wide.depth() <= 3, "{wide:?}");
        let first_width = match &wide {
            TreeShape::RsdC { branches } => branches[0],
            TreeShape::RsdS { w, .. } => *w,
        };
        assert!(first_width >= 2, "{wide:?}");
    }

    #[test]
    fn expected_tokens_matches_closed_form() {
        // chain of depth 2 at rate a: 1 + a + a^2
        let e = expected_tokens(&TreeShape::RsdC { branches: vec![1, 1] }, &[0.5]);
        assert!((e - (1.0 + 0.5 + 0.25)).abs() < 1e-12);
        // width-2 single level: 1 + (1 - 0.25)
        let e = expected_tokens(&TreeShape::RsdC { branches: vec![2] }, &[0.5]);
        assert!((e - 1.75).abs() < 1e-12);
    }

    #[test]
    fn family_restriction_is_honoured() {
        for b in [6usize, 30] {
            for s in enumerate_shapes(b, AdaptiveFamily::RsdC) {
                assert!(matches!(s, TreeShape::RsdC { .. }));
            }
            for s in enumerate_shapes(b, AdaptiveFamily::RsdS) {
                assert!(matches!(s, TreeShape::RsdS { .. }));
            }
        }
    }

    #[test]
    fn initial_shape_fits_every_budget() {
        for b in 1..=40 {
            for fam in [AdaptiveFamily::Auto, AdaptiveFamily::RsdC, AdaptiveFamily::RsdS] {
                assert!(initial_shape(b, fam).budget() <= b);
            }
        }
    }
}
