//! Per-session logical block table: the session-side half of the paged
//! KV cache. A [`PagedSlots`] owns two kinds of references into the
//! shared [`super::KvPool`]:
//!
//! * **shared leases** — read-only radix blocks covering a deduplicated
//!   committed prefix (mapped at [`crate::llm::Llm::begin_with_prefix`]
//!   time, never written);
//! * **private blocks** — exclusively leased blocks whose individual
//!   slots back the session's own committed tokens and pending
//!   draft-tree nodes, tracked with a per-block occupancy bitmask.
//!
//! Slot ids are `block * block_size + offset`, so every slot a session
//! hands to the attention mask is globally unique and pool-addressable.
//! Draft-tree branches all attend the same shared/committed blocks and
//! diverge into fresh private slots, so branching never copies a block
//! (the copy-on-write cost only materializes when a divergent prefix is
//! *published*, see [`super::KvPool::publish`]).
//!
//! Allocation discipline: `alloc_slot`/`free_slot` are allocation-free
//! in steady state — the block vector is capacity-reserved, a freed
//! block returns to the pool immediately (so pool headroom stays
//! accurate for admission/preemption decisions), and re-leasing pops it
//! straight back from the pool's free list.

use std::sync::Arc;

use super::pool::{KvPool, PoolExhausted, SharedLease};

#[derive(Debug, Clone, Copy)]
struct PrivateBlock {
    id: u32,
    /// Bit `i` set = slot `i` of the block is live.
    mask: u64,
}

/// A session's lease on the shared pool (see module docs). Dropping it
/// releases every shared lease and private block.
#[derive(Debug)]
pub struct PagedSlots {
    pool: Arc<KvPool>,
    shared: Vec<SharedLease>,
    blocks: Vec<PrivateBlock>,
    /// Every block below this index is full — committed-prefix blocks
    /// fill once and never reopen, so allocation scans from here
    /// instead of rescanning the whole (mostly full) block list;
    /// `free_slot` rewinds it when an earlier block reopens.
    cursor: usize,
    /// All-live mask for one block (low `block_size` bits).
    full_mask: u64,
}

impl PagedSlots {
    /// A fresh lease with no blocks, reserved for the whole pool (a
    /// session *may* lease every block). Callers that know the
    /// session's worst-case footprint should use
    /// [`PagedSlots::sized`] instead — reserving the full pool for
    /// every session is O(sessions x pool) host memory.
    pub fn empty(pool: Arc<KvPool>) -> Self {
        let slots = pool.total_slots();
        Self::sized(pool, slots)
    }

    /// A fresh lease sized for a session that will hold at most
    /// `max_slots` private slots: the block vector reserves exactly the
    /// worst-case block count (plus one for cursor/partial-block
    /// slack), clamped to the pool size — so `alloc_slot`'s push never
    /// regrows it, which is part of the zero-allocation contract
    /// `benches/hotpath.rs` gates on. `max_slots` is a sizing hint, not
    /// a limit: exceeding it costs a reallocation, not an error.
    pub fn sized(pool: Arc<KvPool>, max_slots: usize) -> Self {
        let bs = pool.block_size();
        let full_mask = if bs == 64 { u64::MAX } else { (1u64 << bs) - 1 };
        let reserve = max_slots.div_ceil(bs).saturating_add(1).min(pool.total_blocks());
        Self {
            pool,
            shared: Vec::new(),
            blocks: Vec::with_capacity(reserve),
            cursor: 0,
            full_mask,
        }
    }

    /// Adopt the shared leases of a prefix match (already refcounted by
    /// [`KvPool::acquire_prefix`]).
    pub fn from_acquire(pool: Arc<KvPool>, leases: Vec<SharedLease>) -> Self {
        let mut s = Self::empty(pool);
        s.shared = leases;
        s
    }

    /// [`PagedSlots::from_acquire`] with the [`PagedSlots::sized`]
    /// worst-case reservation.
    pub fn from_acquire_sized(
        pool: Arc<KvPool>,
        leases: Vec<SharedLease>,
        max_slots: usize,
    ) -> Self {
        let mut s = Self::sized(pool, max_slots);
        s.shared = leases;
        s
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Slot ids of the shared prefix, in prefix order.
    pub fn shared_slots(&self) -> impl Iterator<Item = u32> + '_ {
        let bs = self.pool.block_size() as u32;
        self.shared
            .iter()
            .flat_map(move |l| (0..l.used as u32).map(move |o| l.block * bs + o))
    }

    /// Tokens covered by the shared prefix.
    pub fn shared_len(&self) -> usize {
        self.shared.iter().map(|l| l.used).sum()
    }

    /// Allocate one private slot, leasing a new block from the pool when
    /// every held block is full. Amortized O(1): the cursor skips the
    /// (stable) full prefix of the block list.
    pub fn alloc_slot(&mut self) -> Result<u32, PoolExhausted> {
        let bs = self.pool.block_size() as u32;
        while self.cursor < self.blocks.len() {
            let b = &mut self.blocks[self.cursor];
            let open = !b.mask & self.full_mask;
            if open != 0 {
                let off = open.trailing_zeros();
                b.mask |= 1 << off;
                return Ok(b.id * bs + off);
            }
            self.cursor += 1;
        }
        let id = self.pool.alloc_block()?;
        self.blocks.push(PrivateBlock { id, mask: 1 });
        self.cursor = self.blocks.len() - 1;
        Ok(id * bs)
    }

    /// Free one private slot; a fully emptied block goes straight back
    /// to the pool.
    pub fn free_slot(&mut self, slot: u32) {
        let bs = self.pool.block_size() as u32;
        let (block, off) = (slot / bs, slot % bs);
        // scan from the rear: frees overwhelmingly target recently
        // allocated pending blocks, which live at the tail of the list
        // (the head is stable, full committed-prefix blocks)
        let idx = self
            .blocks
            .iter()
            .rposition(|b| b.id == block)
            .expect("freeing a slot of an unleased block");
        debug_assert!(self.blocks[idx].mask & (1 << off) != 0, "double free");
        self.blocks[idx].mask &= !(1 << off);
        if self.blocks[idx].mask == 0 {
            let b = self.blocks.swap_remove(idx);
            self.pool.release_block(b.id);
        }
        // the block at idx (this one, or the one swapped into its place)
        // now has - or may have - free bits
        self.cursor = self.cursor.min(idx);
    }

    /// Slots this session could still obtain: free slots in its own
    /// blocks plus everything allocatable pool-wide (free + evictable).
    pub fn capacity_left(&self) -> usize {
        let local: u32 = self
            .blocks
            .iter()
            .map(|b| (!b.mask & self.full_mask).count_ones())
            .sum();
        local as usize + self.pool.available_blocks() * self.pool.block_size()
    }

    /// Private slots currently live (excludes the shared prefix).
    pub fn live_slots(&self) -> usize {
        self.blocks.iter().map(|b| b.mask.count_ones() as usize).sum()
    }
}

impl Drop for PagedSlots {
    fn drop(&mut self) {
        for l in &self.shared {
            self.pool.release_lease(l);
        }
        for b in &self.blocks {
            self.pool.release_block(b.id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvConfig;

    fn pool(blocks: usize, bs: usize) -> Arc<KvPool> {
        Arc::new(KvPool::new(KvConfig { num_blocks: blocks, block_size: bs, share: true }))
    }

    #[test]
    fn slots_are_unique_and_block_packed() {
        let p = pool(4, 4);
        let mut s = PagedSlots::empty(p.clone());
        let slots: Vec<u32> = (0..6).map(|_| s.alloc_slot().unwrap()).collect();
        let mut sorted = slots.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6);
        // 6 slots over block_size 4 => exactly two blocks leased
        assert_eq!(p.status().blocks_in_use(), 2);
        assert_eq!(s.live_slots(), 6);
    }

    #[test]
    fn freeing_a_block_returns_it_to_the_pool() {
        let p = pool(2, 4);
        let mut s = PagedSlots::empty(p.clone());
        let slots: Vec<u32> = (0..8).map(|_| s.alloc_slot().unwrap()).collect();
        assert!(s.alloc_slot().is_err(), "pool exhausted");
        // free one whole block's slots
        for &slot in &slots[..4] {
            s.free_slot(slot);
        }
        assert_eq!(p.status().free_blocks, 1);
        // and it is allocatable again
        let again = s.alloc_slot().unwrap();
        assert!(!slots[4..].contains(&again));
    }

    #[test]
    fn capacity_counts_local_and_pool_slots() {
        let p = pool(2, 4);
        let mut s = PagedSlots::empty(p.clone());
        assert_eq!(s.capacity_left(), 8);
        let _a = s.alloc_slot().unwrap();
        // 3 free in the leased block + 4 in the free pool block
        assert_eq!(s.capacity_left(), 7);
    }

    #[test]
    fn drop_releases_everything() {
        let p = pool(4, 4);
        p.publish(&[1, 2, 3, 4]);
        {
            let m = p.acquire_prefix(&[1, 2, 3, 4], 4);
            let mut s = PagedSlots::from_acquire(p.clone(), m.leases);
            assert_eq!(s.shared_len(), 4);
            assert_eq!(s.shared_slots().count(), 4);
            let _ = s.alloc_slot().unwrap();
            // shared block pinned + one private block leased
            assert_eq!(p.status().evictable_blocks, 0);
        }
        let st = p.status();
        assert_eq!(st.blocks_in_use(), 0);
        assert_eq!(st.evictable_blocks, 1, "cached prefix survives the session");
        assert_eq!(st.free_blocks, 3);
    }
}
