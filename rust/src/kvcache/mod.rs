//! Paged KV-cache management: block-granular allocation, radix-tree
//! prefix sharing, copy-on-write branch publication, LRU eviction, and
//! the session suspend/resume substrate the engine's preemption builds
//! on.
//!
//! The serving problem this solves: RSD's draft trees make the KV cache
//! the scaling bottleneck — every tree branch shares a committed
//! prefix, and in a serving fleet most requests share a system-prompt
//! prefix — yet a monolithic per-session cache region recomputes shared
//! context and over-reserves memory per request. This module replaces
//! the per-session dense slot range with a fleet-wide pool:
//!
//! * [`KvPool`] — the fixed-size physical block pool (free list +
//!   refcounts) with an embedded radix prefix index and LRU eviction of
//!   unreferenced cached prefixes;
//! * [`PagedSlots`] — a session's lease: read-only shared prefix blocks
//!   plus exclusively owned private blocks, exposing the `slot =
//!   block * block_size + offset` address space that
//!   [`crate::tree::SessionCore`] (and the PJRT mask construction)
//!   consume;
//! * [`PoolStatus`] / [`KvStats`] — the occupancy and hit/CoW/eviction
//!   telemetry surfaced through [`crate::llm::Llm::pool_status`], the
//!   engine metrics and the server `done` payload;
//! * [`cold::ColdStore`] — the persistent cold tier: blocks evicted
//!   from the radix index spill to checksummed host-side tensorfiles
//!   (via the [`crate::llm::Llm::export_block`] seam), prefix lookups
//!   revive them (validated; corruption degrades to re-prefill), and a
//!   radix snapshot persists hot prefixes across restarts.
//!
//! Ownership rules (enforced by refcounts, exercised by the tests in
//! this module and `rust/tests/kvcache.rs`):
//!
//! 1. A session never writes a shared block. Draft-tree branches and
//!    post-verification commits diverge into private slots, so no copy
//!    happens at divergence time; the CoW cost is only paid when a
//!    divergent prefix is *published* into the radix index
//!    ([`KvPool::publish`]), and only for the overlapping head rows.
//! 2. A cached prefix with zero leases stays resident and servable
//!    until [`KvPool::alloc_block`] reclaims it (LRU, leaf-first).
//! 3. Suspending a session (engine preemption) drops its lease — every
//!    private block returns to the pool, shared blocks demote to cached
//!    prefixes — while the host-side token state stays in the stepper;
//!    resuming re-acquires whatever is still cached and re-prefills the
//!    rest through the ordinary phase machine, consuming no RNG, so
//!    token streams are bit-identical with and without preemption.

pub mod cold;
pub mod pool;
pub mod table;

pub use cold::ColdStore;
pub use pool::{
    ColdExporter, ColdImporter, KvConfig, KvPool, KvStats, PoolExhausted, PoolStatus,
    PrefixMatch, SharedLease, MAX_BLOCK_SIZE,
};
pub use table::PagedSlots;
