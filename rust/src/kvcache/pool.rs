//! The shared block pool: fixed-size physical KV blocks with free-list
//! allocation, a radix prefix index deduplicating committed prefixes
//! across sessions, and LRU eviction of unreferenced cached prefixes.
//!
//! One pool exists per model (target and draft KV contents differ, so
//! they never share blocks). All state sits behind one mutex: the
//! engine core is single-threaded and block operations happen at block
//! granularity (once per `block_size` tokens), so contention is nil and
//! the per-call cost is a handful of vector ops — no allocation in
//! steady state (every internal vector is capacity-reserved at
//! construction; radix nodes are only created by [`KvPool::publish`],
//! which runs once per admitted prompt, off the decode hot path).
//!
//! # Refcount scheme
//!
//! `refs[b]` counts *session leases* of block `b` (a private lease from
//! [`super::table::PagedSlots`] or a shared lease on a radix node's
//! block). A block is always in exactly one of three states:
//!
//! * **free** — on the free list, `refs == 0`;
//! * **private** — leased by one session (`refs == 1`), holds that
//!   session's pending/committed slots;
//! * **radix-resident** — owned by a radix node; `refs` equals the
//!   node's shared-lease count. With `refs == 0` the node is a *cached
//!   prefix*: still servable to future sessions, and the LRU eviction
//!   pool [`KvPool::alloc_block`] reclaims from under memory pressure.
//!
//! # Content-validity contract
//!
//! The radix index stores *token identity*, not KV data. Whether a
//! matched block's KV contents are actually valid is the backing
//! substrate's contract: [`crate::sim::SimLm`] recomputes logits from
//! tokens, so a published block is valid immediately (it may be
//! published at admission, before any forward pass); a real paged
//! backend must only publish after the prefill that fills the block has
//! executed (and holds per-session caches today — see
//! `crate::model`), which is why publication goes through the
//! substrate-owned [`crate::llm::Llm::cache_prefix`] hook rather than
//! being hard-wired into the engine.

use std::fmt;
use std::sync::Mutex;

use super::cold::{ColdStore, Fetch};
use crate::trace::{EventKind, Tracer};

/// Occupancy masks in [`super::table::PagedSlots`] are `u64`.
pub const MAX_BLOCK_SIZE: usize = 64;

/// Pool geometry + feature switch.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    /// Physical blocks in the pool.
    pub num_blocks: usize,
    /// Tokens (KV slots) per block; at most [`MAX_BLOCK_SIZE`].
    pub block_size: usize,
    /// Enable the radix prefix index (`false` = pure paged allocation,
    /// every lookup misses — the A/B baseline of `benches/kvcache.rs`).
    pub share: bool,
}

impl Default for KvConfig {
    fn default() -> Self {
        Self { num_blocks: 512, block_size: 16, share: true }
    }
}

/// Typed allocation failure: every physical block is leased or pinned by
/// a shared prefix. Callers that can shed load (the engine) preempt a
/// session instead of failing the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted;

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "KV block pool exhausted (all blocks leased or pinned)")
    }
}

impl std::error::Error for PoolExhausted {}

/// Cumulative pool counters (monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvStats {
    /// Prefix lookups ([`KvPool::acquire_prefix`] calls).
    pub lookups: u64,
    /// Tokens satisfied from the radix index across all lookups.
    pub hit_tokens: u64,
    /// Tokens requested across all lookups.
    pub lookup_tokens: u64,
    /// Copy-on-write branch copies: a published prefix diverged from a
    /// cached block mid-block, so the shared head rows were (logically)
    /// copied into the new branch block.
    pub cow_copies: u64,
    /// Tokens covered by those copies.
    pub cow_tokens: u64,
    /// Radix nodes evicted (each frees one block).
    pub evictions: u64,
    /// Blocks registered in the radix index by [`KvPool::publish`].
    pub published_blocks: u64,
    /// Evicted blocks persisted to the cold tier (incl. shutdown
    /// persists; re-spills of already-resident blocks don't count).
    pub cold_spills: u64,
    /// Blocks revived from the cold tier back into the radix index.
    pub cold_hits: u64,
    /// Tokens those revivals covered (prefill compute saved).
    pub cold_hit_tokens: u64,
    /// Cold consults that found no spilled block.
    pub cold_misses: u64,
    /// Spilled blocks rejected on revival — bad checksum, truncation,
    /// chain mismatch or payload validation failure. Each one is
    /// deleted and the lookup degrades to re-prefill (never fatal).
    pub cold_corrupt: u64,
}

impl KvStats {
    /// Fraction of looked-up tokens served from the cache.
    pub fn hit_rate(&self) -> f64 {
        if self.lookup_tokens == 0 {
            0.0
        } else {
            self.hit_tokens as f64 / self.lookup_tokens as f64
        }
    }

    /// Fraction of cold-tier consults that revived a block.
    pub fn cold_hit_rate(&self) -> f64 {
        let consults = self.cold_hits + self.cold_misses + self.cold_corrupt;
        if consults == 0 {
            0.0
        } else {
            self.cold_hits as f64 / consults as f64
        }
    }
}

/// Substrate hook extracting the serializable KV payload for the block
/// that closes `chain` (the full committed token path through the
/// block's last token). `None` = this substrate cannot spill.
pub type ColdExporter = Box<dyn Fn(&[u32]) -> Option<Vec<f32>> + Send>;

/// Substrate hook validating a revived payload for `chain`: `true` iff
/// the block is servable exactly as stored.
pub type ColdImporter = Box<dyn Fn(&[u32], &[f32]) -> bool + Send>;

/// The pool's attached cold tier: the on-disk store plus the substrate
/// seams that (de)serialize block payloads.
struct ColdTier {
    store: ColdStore,
    exporter: ColdExporter,
    importer: ColdImporter,
}

impl fmt::Debug for ColdTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ColdTier").field("store", &self.store).finish_non_exhaustive()
    }
}

/// Point-in-time pool occupancy, plus the cumulative [`KvStats`].
#[derive(Debug, Clone, Copy)]
pub struct PoolStatus {
    pub block_size: usize,
    pub total_blocks: usize,
    /// Immediately allocatable blocks.
    pub free_blocks: usize,
    /// Radix-resident blocks reclaimable by LRU eviction (no session
    /// leases anywhere in their subtree).
    pub evictable_blocks: usize,
    /// Blocks held by sessions (private leases + lease-pinned radix
    /// blocks and their ancestors).
    pub leased_blocks: usize,
    pub stats: KvStats,
}

impl PoolStatus {
    /// Slots a session could obtain right now (free + evictable).
    pub fn available_slots(&self) -> usize {
        (self.free_blocks + self.evictable_blocks) * self.block_size
    }

    pub fn blocks_in_use(&self) -> usize {
        self.leased_blocks
    }
}

/// One shared (read-only) reference to a radix node's block, as handed
/// to a session by [`KvPool::acquire_prefix`]. `used` is how many of the
/// block's leading tokens the session maps (the full block for interior
/// matches; possibly fewer for the last, partially matched block).
#[derive(Debug, Clone, Copy)]
pub struct SharedLease {
    pub node: usize,
    pub block: u32,
    pub used: usize,
}

/// Result of a prefix lookup: leases (already refcounted) covering the
/// first `matched` tokens of the queried prefix.
#[derive(Debug, Default)]
pub struct PrefixMatch {
    pub leases: Vec<SharedLease>,
    pub matched: usize,
}

const NO_NODE: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    /// Exactly `block_size` committed tokens.
    tokens: Vec<u32>,
    block: u32,
    parent: usize,
    children: Vec<usize>,
    /// Live shared leases on this node.
    leases: u32,
    /// Leased nodes in this node's subtree (itself included). A node is
    /// evictable-reachable iff this is 0 — maintained incrementally on
    /// lease/release so occupancy queries on the decode hot path are
    /// O(1), not a full-tree scan.
    pinned_desc: u32,
    /// Last-touched tick (LRU victim selection).
    lru: u64,
    live: bool,
}

#[derive(Debug)]
struct PoolInner {
    /// Session-lease count per block (see module docs).
    refs: Vec<u32>,
    free: Vec<u32>,
    nodes: Vec<Node>,
    node_free: Vec<usize>,
    /// Children of the virtual radix root.
    roots: Vec<usize>,
    tick: u64,
    stats: KvStats,
    /// Live radix nodes with `pinned_desc == 0` (reclaimable closure),
    /// maintained incrementally.
    evictable: usize,
    /// Flight-recorder handle (default off); lives inside the pool
    /// mutex so eviction deep in [`KvPool::alloc_block`] can record.
    tracer: Tracer,
    /// Attached cold tier (default none — with it absent, every cold
    /// path below is a single branch and the hot paths stay
    /// allocation-free).
    cold: Option<ColdTier>,
}

/// The shared paged KV-cache pool (see module docs). Cheap to share via
/// `Arc`; all methods take `&self`.
#[derive(Debug)]
pub struct KvPool {
    block_size: usize,
    num_blocks: usize,
    share: bool,
    inner: Mutex<PoolInner>,
}

impl KvPool {
    pub fn new(cfg: KvConfig) -> Self {
        assert!(cfg.num_blocks >= 1, "pool needs at least one block");
        assert!(
            (1..=MAX_BLOCK_SIZE).contains(&cfg.block_size),
            "block_size must be in 1..={MAX_BLOCK_SIZE}"
        );
        // allocate low blocks first (pop from the back)
        let free: Vec<u32> = (0..cfg.num_blocks as u32).rev().collect();
        Self {
            block_size: cfg.block_size,
            num_blocks: cfg.num_blocks,
            share: cfg.share,
            inner: Mutex::new(PoolInner {
                refs: vec![0; cfg.num_blocks],
                free,
                nodes: Vec::new(),
                node_free: Vec::new(),
                roots: Vec::new(),
                tick: 0,
                stats: KvStats::default(),
                evictable: 0,
                tracer: Tracer::off(),
                cold: None,
            }),
        }
    }

    /// Attach a flight-recorder handle: prefix lookups, publishes and
    /// evictions are journaled from here on. Recording is
    /// allocation-free (the journal is preallocated).
    pub fn set_trace(&self, tracer: &Tracer) {
        self.inner.lock().unwrap().tracer = tracer.clone();
    }

    /// Attach a cold tier: evicted radix blocks spill to `store`
    /// through `exporter`, prefix lookups revive spilled blocks through
    /// `importer` validation, and [`KvPool::persist_radix`] /
    /// [`KvPool::load_radix`] snapshot the index across restarts. The
    /// hooks must be pure functions of the token chain — they run under
    /// the pool mutex and must not call back into this pool.
    pub fn set_cold(&self, store: ColdStore, exporter: ColdExporter, importer: ColdImporter) {
        self.inner.lock().unwrap().cold = Some(ColdTier { store, exporter, importer });
    }

    pub fn has_cold(&self) -> bool {
        self.inner.lock().unwrap().cold.is_some()
    }

    /// Spilled blocks currently resident in the cold tier.
    pub fn cold_blocks(&self) -> usize {
        self.inner.lock().unwrap().cold.as_ref().map_or(0, |c| c.store.len())
    }

    /// The full token chain from the context start through node `id`'s
    /// block (the cold tier's block identity).
    fn chain_of(g: &PoolInner, id: usize) -> Vec<u32> {
        let mut ids = Vec::new();
        let mut at = id;
        while at != NO_NODE {
            ids.push(at);
            at = g.nodes[at].parent;
        }
        let mut chain = Vec::new();
        for &i in ids.iter().rev() {
            chain.extend_from_slice(&g.nodes[i].tokens);
        }
        chain
    }

    /// Best-effort spill of node `id` to the cold tier (no-op without
    /// one). Must run while the node and its root path are still live.
    /// Returns whether the block is resident in the store afterwards.
    fn spill_node(g: &mut PoolInner, id: usize) -> bool {
        if g.cold.is_none() {
            return false;
        }
        let chain = Self::chain_of(g, id);
        let (resident, newly) = {
            let cold = g.cold.as_mut().unwrap();
            if cold.store.contains(&chain) {
                (true, false)
            } else if let Some(payload) = (cold.exporter)(&chain) {
                let ok = cold.store.spill(&chain, &payload);
                (ok, ok)
            } else {
                (false, false)
            }
        };
        if newly {
            g.stats.cold_spills += 1;
        }
        resident
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Total KV slots the pool backs.
    pub fn total_slots(&self) -> usize {
        self.num_blocks * self.block_size
    }

    /// Lease one private block (evicting cached prefixes LRU-first when
    /// the free list is empty).
    pub fn alloc_block(&self) -> Result<u32, PoolExhausted> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(b) = g.free.pop() {
                debug_assert_eq!(g.refs[b as usize], 0);
                g.refs[b as usize] = 1;
                return Ok(b);
            }
            if !Self::evict_one(&mut g) {
                return Err(PoolExhausted);
            }
        }
    }

    /// Return a private block (lease count must be exactly 1).
    pub fn release_block(&self, block: u32) {
        let mut g = self.inner.lock().unwrap();
        debug_assert_eq!(g.refs[block as usize], 1, "releasing a non-private block");
        g.refs[block as usize] = 0;
        g.free.push(block);
    }

    /// Look up the longest cached prefix of `tokens`, capped at
    /// `max_tokens`, taking a shared lease on every matched node.
    /// Interior matches are whole blocks; the final block may match
    /// partially (the caller attends only its leading `used` slots —
    /// sharing without copying, the branch diverges into the session's
    /// private blocks).
    pub fn acquire_prefix(&self, tokens: &[u32], max_tokens: usize) -> PrefixMatch {
        let mut out = PrefixMatch::default();
        let cap = tokens.len().min(max_tokens);
        let mut g = self.inner.lock().unwrap();
        g.stats.lookups += 1;
        g.stats.lookup_tokens += cap as u64;
        if !self.share || cap == 0 {
            g.tracer.record(EventKind::KvAcquire, 0, 0, cap as u32);
            return out;
        }
        g.tick += 1;
        let tick = g.tick;
        let mut children: &[usize] = &g.roots;
        let mut pos = 0usize;
        let mut path: Vec<(usize, usize)> = Vec::new(); // (node, used)
        loop {
            let want = &tokens[pos..cap];
            if want.is_empty() {
                break;
            }
            // longest common head among this level's children
            let mut best: Option<(usize, usize)> = None;
            for &id in children {
                let n = &g.nodes[id];
                debug_assert!(n.live);
                let k = n
                    .tokens
                    .iter()
                    .zip(want.iter())
                    .take_while(|(a, b)| a == b)
                    .count();
                if k > best.map_or(0, |(_, k)| k) {
                    best = Some((id, k));
                }
            }
            let Some((id, k)) = best else { break };
            path.push((id, k));
            pos += k;
            if k < self.block_size {
                break; // partial match: the walk cannot continue below it
            }
            children = &g.nodes[id].children;
        }
        // Cold-tier extension: the hot walk stalled on a block boundary
        // with whole blocks still wanted — revive spilled blocks into
        // fresh radix nodes and keep matching. Free-list only (like
        // `publish`: never evict a warmer prefix to revive a colder
        // one); a corrupt or unvalidated payload deletes the spill file
        // and degrades to re-prefill.
        if g.cold.is_some() {
            let b = self.block_size;
            let mut parent = match path.last() {
                Some(&(id, k)) if k == b => id,
                Some(_) => NO_NODE, // partial tail: misaligned, loop won't run
                None => NO_NODE,
            };
            while pos % b == 0 && cap - pos >= b && !g.free.is_empty() {
                let chain = &tokens[pos..pos + b];
                let full_chain = &tokens[..pos + b];
                let fetched = g.cold.as_mut().unwrap().store.fetch(full_chain);
                let payload = match fetched {
                    Fetch::Miss => {
                        g.stats.cold_misses += 1;
                        break;
                    }
                    Fetch::Corrupt => {
                        g.stats.cold_corrupt += 1;
                        break;
                    }
                    Fetch::Hit(p) => p,
                };
                let usable = (g.cold.as_ref().unwrap().importer)(full_chain, &payload);
                if !usable {
                    g.stats.cold_corrupt += 1;
                    g.cold.as_mut().unwrap().store.remove(full_chain);
                    break;
                }
                let Some(block) = g.free.pop() else { break };
                debug_assert_eq!(g.refs[block as usize], 0);
                g.evictable += 1; // fresh nodes carry no leases (yet)
                let node = Node {
                    tokens: chain.to_vec(),
                    block,
                    parent,
                    children: Vec::new(),
                    leases: 0,
                    pinned_desc: 0,
                    lru: tick,
                    live: true,
                };
                let id = match g.node_free.pop() {
                    Some(id) => {
                        g.nodes[id] = node;
                        id
                    }
                    None => {
                        g.nodes.push(node);
                        g.nodes.len() - 1
                    }
                };
                if parent == NO_NODE {
                    g.roots.push(id);
                } else {
                    g.nodes[parent].children.push(id);
                }
                g.stats.cold_hits += 1;
                g.stats.cold_hit_tokens += b as u64;
                // the lease loop below picks this node up like any
                // hot-matched one
                path.push((id, b));
                parent = id;
                pos += b;
            }
        }
        for &(id, used) in &path {
            let n = &mut g.nodes[id];
            n.leases += 1;
            n.lru = tick;
            let block = n.block;
            let newly_leased = n.leases == 1;
            g.refs[block as usize] += 1;
            if newly_leased {
                Self::pin_path(&mut g, id);
            }
            out.leases.push(SharedLease { node: id, block, used });
        }
        out.matched = pos;
        g.stats.hit_tokens += pos as u64;
        g.tracer.record(EventKind::KvAcquire, 0, pos as u32, cap as u32);
        out
    }

    /// Drop one shared lease previously handed out by
    /// [`KvPool::acquire_prefix`]. The node stays in the index as an
    /// evictable cached prefix once its lease count reaches zero.
    pub fn release_lease(&self, lease: &SharedLease) {
        let mut g = self.inner.lock().unwrap();
        let n = &mut g.nodes[lease.node];
        debug_assert!(n.live && n.leases > 0);
        n.leases -= 1;
        let block = n.block;
        let last_lease = n.leases == 0;
        debug_assert!(g.refs[block as usize] > 0);
        g.refs[block as usize] -= 1;
        if last_lease {
            Self::unpin_path(&mut g, lease.node);
        }
    }

    /// A node became leased: bump the leased-descendant count of its
    /// whole root path (nodes leaving `pinned_desc == 0` stop being
    /// evictable).
    fn pin_path(g: &mut PoolInner, mut id: usize) {
        while id != NO_NODE {
            if g.nodes[id].pinned_desc == 0 {
                g.evictable -= 1;
            }
            g.nodes[id].pinned_desc += 1;
            id = g.nodes[id].parent;
        }
    }

    /// A node dropped its last lease: the reverse of
    /// [`KvPool::pin_path`].
    fn unpin_path(g: &mut PoolInner, mut id: usize) {
        while id != NO_NODE {
            debug_assert!(g.nodes[id].pinned_desc > 0);
            g.nodes[id].pinned_desc -= 1;
            if g.nodes[id].pinned_desc == 0 {
                g.evictable += 1;
            }
            id = g.nodes[id].parent;
        }
    }

    /// Register the full-block chunks of `tokens` in the radix index so
    /// future sessions can share them. Best-effort: uses free blocks
    /// only (never evicts warmer prefixes to cache a colder one) and
    /// stops at the first chunk it cannot place. Chunks already cached
    /// are deduplicated (LRU-touched, no new block). A chunk diverging
    /// mid-block from a cached sibling is a copy-on-write branch: the
    /// shared head rows are (logically) copied into the fresh branch
    /// block and counted in [`KvStats::cow_copies`] / `cow_tokens`.
    pub fn publish(&self, tokens: &[u32]) {
        if !self.share {
            return;
        }
        let b = self.block_size;
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        let mut parent = NO_NODE;
        let mut placed = 0u32;
        for chunk in tokens.chunks_exact(b) {
            // dedupe: exact chunk already cached -> descend
            let mut exact: Option<usize> = None;
            let mut overlap = 0usize;
            {
                let children: &[usize] = if parent == NO_NODE {
                    &g.roots
                } else {
                    &g.nodes[parent].children
                };
                for &id in children {
                    let n = &g.nodes[id];
                    let k = n
                        .tokens
                        .iter()
                        .zip(chunk.iter())
                        .take_while(|(a, b)| a == b)
                        .count();
                    if k == b {
                        exact = Some(id);
                        break;
                    }
                    overlap = overlap.max(k);
                }
            }
            if let Some(id) = exact {
                g.nodes[id].lru = tick;
                parent = id;
                continue;
            }
            // new branch: needs a block; free-list only (no eviction)
            let Some(block) = g.free.pop() else { break };
            debug_assert_eq!(g.refs[block as usize], 0);
            if overlap > 0 {
                g.stats.cow_copies += 1;
                g.stats.cow_tokens += overlap as u64;
            }
            g.stats.published_blocks += 1;
            placed += 1;
            g.evictable += 1; // fresh nodes carry no leases
            let node = Node {
                tokens: chunk.to_vec(),
                block,
                parent,
                children: Vec::new(),
                leases: 0,
                pinned_desc: 0,
                lru: tick,
                live: true,
            };
            let id = match g.node_free.pop() {
                Some(id) => {
                    g.nodes[id] = node;
                    id
                }
                None => {
                    g.nodes.push(node);
                    g.nodes.len() - 1
                }
            };
            if parent == NO_NODE {
                g.roots.push(id);
            } else {
                g.nodes[parent].children.push(id);
            }
            parent = id;
        }
        if placed > 0 {
            g.tracer.record(EventKind::KvPublish, 0, placed, placed * b as u32);
        }
    }

    /// Evict the least-recently-used unreferenced radix leaf, freeing
    /// its block. Returns false when nothing is evictable.
    fn evict_one(g: &mut PoolInner) -> bool {
        let mut victim: Option<(usize, u64)> = None;
        for (id, n) in g.nodes.iter().enumerate() {
            if n.live && n.leases == 0 && n.children.is_empty() {
                let colder = match victim {
                    None => true,
                    Some((_, lru)) => n.lru < lru,
                };
                if colder {
                    victim = Some((id, n.lru));
                }
            }
        }
        let Some((id, _)) = victim else { return false };
        // Spill before the node dies: eviction is leaf-first, so the
        // chain through `id` is intact exactly now.
        Self::spill_node(g, id);
        let (block, parent) = {
            let n = &mut g.nodes[id];
            debug_assert_eq!(n.pinned_desc, 0, "leafless unleased node must be unpinned");
            n.live = false;
            (n.block, n.parent)
        };
        if parent == NO_NODE {
            g.roots.retain(|&c| c != id);
        } else {
            g.nodes[parent].children.retain(|&c| c != id);
        }
        debug_assert_eq!(g.refs[block as usize], 0, "evicting a leased block");
        g.evictable -= 1;
        g.free.push(block);
        g.node_free.push(id);
        g.stats.evictions += 1;
        g.tracer.record(EventKind::KvEvict, 0, 1, 0);
        true
    }

    /// Evict every unreferenced cached prefix (test/maintenance hook —
    /// e.g. forcing the suspend→evict→resume path).
    pub fn evict_all(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let mut n = 0;
        while Self::evict_one(&mut g) {
            n += 1;
        }
        n
    }

    /// Non-mutating admission probe: how many leading tokens of
    /// `tokens` could be served without re-prefill right now — the hot
    /// radix match plus the cold tier's block-aligned continuation (by
    /// index membership only; payloads are validated on the real
    /// acquire). Takes no leases, touches no LRU state, performs no
    /// file I/O — a hint for admission headroom, never a reservation.
    pub fn peek_prefix(&self, tokens: &[u32]) -> usize {
        if !self.share {
            return 0;
        }
        let b = self.block_size;
        let g = self.inner.lock().unwrap();
        let mut children: &[usize] = &g.roots;
        let mut pos = 0usize;
        loop {
            let want = &tokens[pos..];
            if want.is_empty() {
                break;
            }
            let mut best: Option<(usize, usize)> = None;
            for &id in children {
                let n = &g.nodes[id];
                let k = n
                    .tokens
                    .iter()
                    .zip(want.iter())
                    .take_while(|(a, b)| a == b)
                    .count();
                if k > best.map_or(0, |(_, k)| k) {
                    best = Some((id, k));
                }
            }
            let Some((id, k)) = best else { break };
            pos += k;
            if k < b {
                return pos;
            }
            children = &g.nodes[id].children;
        }
        if let Some(cold) = g.cold.as_ref() {
            while pos % b == 0
                && tokens.len() - pos >= b
                && cold.store.contains(&tokens[..pos + b])
            {
                pos += b;
            }
        }
        pos
    }

    /// Persist the radix index across a restart: spill every live
    /// node's block to the cold tier and write the chain snapshot
    /// ([`KvPool::load_radix`] replays it on boot). Best-effort on
    /// every file operation. Returns the number of live nodes resident
    /// in the cold store afterwards.
    pub fn persist_radix(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        if g.cold.is_none() {
            return 0;
        }
        let live: Vec<usize> = (0..g.nodes.len()).filter(|&i| g.nodes[i].live).collect();
        let mut persisted = 0;
        let mut leaves: Vec<Vec<u32>> = Vec::new();
        for id in live {
            if Self::spill_node(&mut g, id) {
                persisted += 1;
            }
            if g.nodes[id].children.is_empty() {
                leaves.push(Self::chain_of(&g, id));
            }
        }
        let _ = g.cold.as_ref().unwrap().store.write_snapshot(&leaves);
        persisted
    }

    /// Replay the radix snapshot left by a previous process: revive
    /// each persisted chain's blocks from the cold tier into the index
    /// (validated block by block — a corrupt block truncates that chain
    /// and degrades to re-prefill, it never fails the boot). Returns
    /// blocks revived.
    pub fn load_radix(&self) -> usize {
        if !self.share {
            return 0;
        }
        let b = self.block_size;
        let mut g = self.inner.lock().unwrap();
        if g.cold.is_none() {
            return 0;
        }
        let chains = g.cold.as_ref().unwrap().store.read_snapshot();
        g.tick += 1;
        let tick = g.tick;
        let mut revived = 0usize;
        for chain in &chains {
            let mut parent = NO_NODE;
            let mut pos = 0usize;
            for chunk in chain.chunks_exact(b) {
                // dedupe: a sibling chain's shared prefix may already
                // have revived this block
                let exact = {
                    let children: &[usize] = if parent == NO_NODE {
                        &g.roots
                    } else {
                        &g.nodes[parent].children
                    };
                    children
                        .iter()
                        .copied()
                        .find(|&id| g.nodes[id].tokens.as_slice() == chunk)
                };
                if let Some(id) = exact {
                    g.nodes[id].lru = tick;
                    parent = id;
                    pos += b;
                    continue;
                }
                let full_chain = &chain[..pos + b];
                let payload = match g.cold.as_mut().unwrap().store.fetch(full_chain) {
                    Fetch::Miss => {
                        g.stats.cold_misses += 1;
                        break;
                    }
                    Fetch::Corrupt => {
                        g.stats.cold_corrupt += 1;
                        break;
                    }
                    Fetch::Hit(p) => p,
                };
                if !(g.cold.as_ref().unwrap().importer)(full_chain, &payload) {
                    g.stats.cold_corrupt += 1;
                    g.cold.as_mut().unwrap().store.remove(full_chain);
                    break;
                }
                let Some(block) = g.free.pop() else { break };
                debug_assert_eq!(g.refs[block as usize], 0);
                g.evictable += 1;
                let node = Node {
                    tokens: chunk.to_vec(),
                    block,
                    parent,
                    children: Vec::new(),
                    leases: 0,
                    pinned_desc: 0,
                    lru: tick,
                    live: true,
                };
                let id = match g.node_free.pop() {
                    Some(id) => {
                        g.nodes[id] = node;
                        id
                    }
                    None => {
                        g.nodes.push(node);
                        g.nodes.len() - 1
                    }
                };
                if parent == NO_NODE {
                    g.roots.push(id);
                } else {
                    g.nodes[parent].children.push(id);
                }
                g.stats.cold_hits += 1;
                g.stats.cold_hit_tokens += b as u64;
                revived += 1;
                parent = id;
                pos += b;
            }
        }
        revived
    }

    /// Blocks a session could obtain right now (free + evictable). O(1)
    /// — the evictable count is maintained incrementally by the
    /// lease/release/publish/evict transitions, so this is safe to call
    /// on every decode-path capacity query.
    pub fn available_blocks(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.free.len() + g.evictable
    }

    pub fn status(&self) -> PoolStatus {
        let g = self.inner.lock().unwrap();
        // leased = total - free - evictable (every non-free block is
        // either an evictable radix block or held/pinned by sessions; a
        // radix block is evictable when no node in its subtree holds a
        // lease — eviction is leaf-first, so a pinned descendant pins
        // the whole path)
        let free_blocks = g.free.len();
        let evictable_blocks = g.evictable;
        PoolStatus {
            block_size: self.block_size,
            total_blocks: self.num_blocks,
            free_blocks,
            evictable_blocks,
            leased_blocks: g.refs.len() - free_blocks - evictable_blocks,
            stats: g.stats,
        }
    }

    pub fn stats(&self) -> KvStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize, bs: usize) -> KvPool {
        KvPool::new(KvConfig { num_blocks: blocks, block_size: bs, share: true })
    }

    fn seq(n: usize, base: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i + base).collect()
    }

    #[test]
    fn alloc_release_roundtrip() {
        let p = pool(4, 4);
        let a = p.alloc_block().unwrap();
        let b = p.alloc_block().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.status().free_blocks, 2);
        p.release_block(a);
        p.release_block(b);
        assert_eq!(p.status().free_blocks, 4);
        for _ in 0..4 {
            p.alloc_block().unwrap();
        }
        assert_eq!(p.alloc_block(), Err(PoolExhausted));
    }

    #[test]
    fn publish_then_acquire_hits_full_blocks() {
        let p = pool(8, 4);
        let prompt = seq(10, 0); // 2 full blocks + 2 leftover
        p.publish(&prompt);
        assert_eq!(p.stats().published_blocks, 2);
        let m = p.acquire_prefix(&prompt, prompt.len() - 1);
        assert_eq!(m.matched, 8);
        assert_eq!(m.leases.len(), 2);
        assert!(m.leases.iter().all(|l| l.used == 4));
        // blocks are pinned while leased
        assert_eq!(p.status().evictable_blocks, 0);
        assert_eq!(p.evict_all(), 0);
        for l in &m.leases {
            p.release_lease(l);
        }
        assert_eq!(p.status().evictable_blocks, 2);
        let s = p.stats();
        assert_eq!(s.hit_tokens, 8);
        assert_eq!(s.lookup_tokens, 9);
    }

    #[test]
    fn partial_block_match_shares_without_copy() {
        let p = pool(8, 4);
        p.publish(&seq(8, 0)); // blocks [0..4), [4..8)
        // prompt shares 6 tokens then diverges
        let mut prompt = seq(6, 0);
        prompt.extend([90, 91, 92]);
        let m = p.acquire_prefix(&prompt, prompt.len());
        assert_eq!(m.matched, 6);
        assert_eq!(m.leases.len(), 2);
        assert_eq!(m.leases[1].used, 2);
        assert_eq!(p.stats().cow_copies, 0, "acquire never copies");
        for l in &m.leases {
            p.release_lease(l);
        }
    }

    #[test]
    fn publish_divergent_branch_counts_cow() {
        let p = pool(8, 4);
        p.publish(&seq(8, 0));
        // same first block, second block diverges after 2 tokens
        let mut other = seq(6, 0);
        other.extend([70, 71]);
        p.publish(&other);
        let s = p.stats();
        assert_eq!(s.published_blocks, 3); // 2 + 1 branch
        assert_eq!(s.cow_copies, 1);
        assert_eq!(s.cow_tokens, 2);
        // both chains fully matchable
        assert_eq!(p.acquire_prefix(&seq(8, 0), 8).matched, 8);
        assert_eq!(p.acquire_prefix(&other, 8).matched, 8);
    }

    #[test]
    fn lru_eviction_frees_cold_prefixes_first() {
        let p = pool(4, 4);
        p.publish(&seq(4, 0)); // cold
        p.publish(&seq(4, 100)); // warm (published later)
        // touch the cold one to make it warm
        let m = p.acquire_prefix(&seq(4, 0), 4);
        for l in &m.leases {
            p.release_lease(l);
        }
        // exhaust the pool: 2 free blocks, ask for 3
        let a = p.alloc_block().unwrap();
        let b = p.alloc_block().unwrap();
        let c = p.alloc_block().unwrap(); // must evict the LRU node (seq 100)
        assert_eq!(p.stats().evictions, 1);
        assert_eq!(p.acquire_prefix(&seq(4, 100), 4).matched, 0, "cold chain evicted");
        let m = p.acquire_prefix(&seq(4, 0), 4);
        assert_eq!(m.matched, 4, "recently touched chain survives");
        for l in &m.leases {
            p.release_lease(l);
        }
        p.release_block(a);
        p.release_block(b);
        p.release_block(c);
    }

    #[test]
    fn eviction_is_leaf_first_and_skips_pinned_subtrees() {
        let p = pool(4, 2);
        p.publish(&seq(6, 0)); // chain of 3 nodes
        let m = p.acquire_prefix(&seq(4, 0), 4); // pin the first two
        assert_eq!(m.matched, 4);
        // only the unpinned leaf is evictable
        assert_eq!(p.status().evictable_blocks, 1);
        assert_eq!(p.evict_all(), 1);
        for l in &m.leases {
            p.release_lease(l);
        }
        // now the rest of the chain can go, leaf first
        assert_eq!(p.evict_all(), 2);
        assert_eq!(p.status().free_blocks, 4);
    }

    #[test]
    fn share_disabled_never_matches() {
        let p = KvPool::new(KvConfig { num_blocks: 4, block_size: 4, share: false });
        p.publish(&seq(8, 0));
        assert_eq!(p.stats().published_blocks, 0);
        assert_eq!(p.acquire_prefix(&seq(8, 0), 8).matched, 0);
        assert_eq!(p.status().free_blocks, 4);
    }

    #[test]
    fn publish_is_best_effort_under_pressure() {
        let p = pool(2, 4);
        let a = p.alloc_block().unwrap();
        let b = p.alloc_block().unwrap();
        p.publish(&seq(8, 0)); // no free blocks: publishes nothing
        assert_eq!(p.stats().published_blocks, 0);
        p.release_block(a);
        p.publish(&seq(8, 0)); // one free block: publishes one chunk
        assert_eq!(p.stats().published_blocks, 1);
        assert_eq!(p.acquire_prefix(&seq(8, 0), 8).matched, 4);
        p.release_block(b);
    }
}
