//! Cold KV tier: a host-side persistent store for radix blocks evicted
//! from the hot pool, in the checksummed tensorfile format.
//!
//! Each spilled block is one `.tensors` file keyed by the block's full
//! token chain (context start through the block's last token):
//!
//! * `tokens` — i32 `[chain_len]`, the chain itself (identity check on
//!   revival: the filename hash is not trusted);
//! * `payload` — f32 `[n]`, the substrate-exported KV payload
//!   ([`crate::llm::Llm::export_block`]).
//!
//! Every tensor carries a CRC-32 the reader verifies, and files are
//! written atomically (tmp + rename), so the read path has exactly three
//! outcomes: a validated hit, a miss, or *corrupt* — in which case the
//! offending file is deleted and the caller degrades to re-prefill. A
//! damaged cold tier can cost recompute, never correctness and never a
//! crash (chaos-tested in `rust/tests/chaos.rs`).
//!
//! A `radix.tensors` snapshot in the same directory records the leaf
//! chains of the hot radix index at shutdown
//! ([`crate::kvcache::KvPool::persist_radix`]); on boot the pool
//! replays it through the ordinary validated-revival path, so hot
//! system prompts survive restarts without re-prefill.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensorfile::{self, Dtype, Tensor, Tensors};

/// Stable 64-bit chain key (FNV-1a over little-endian token bytes).
/// Deterministic across processes — it names spill files on disk.
pub fn chain_key(chain: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in chain {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Outcome of a cold lookup. `Corrupt` means the file existed but
/// failed validation (checksum, truncation, chain mismatch) and has
/// been deleted — the caller re-prefills.
#[derive(Debug)]
pub enum Fetch {
    Hit(Vec<f32>),
    Miss,
    Corrupt,
}

/// The on-disk spill store: a directory of per-block `.tensors` files
/// plus an in-memory key index (rebuilt by scanning the directory on
/// open, so a store survives the process that wrote it).
#[derive(Debug)]
pub struct ColdStore {
    dir: PathBuf,
    /// Capacity bound in blocks; spills beyond it are dropped.
    max_blocks: usize,
    index: HashSet<u64>,
}

const SNAPSHOT_FILE: &str = "radix.tensors";

impl ColdStore {
    /// Open (creating if needed) a store rooted at `dir`, indexing any
    /// spill files a previous process left there. File contents are not
    /// read here — corruption is detected (and the file discarded) on
    /// fetch, keeping open O(#files).
    pub fn open(dir: impl AsRef<Path>, max_blocks: usize) -> Result<ColdStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cold-tier dir {}", dir.display()))?;
        let mut index = HashSet::new();
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("scanning cold-tier dir {}", dir.display()))?
        {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(hex) = name.strip_prefix("spill_").and_then(|n| n.strip_suffix(".tensors"))
            {
                if let Ok(key) = u64::from_str_radix(hex, 16) {
                    index.insert(key);
                }
            }
        }
        Ok(ColdStore { dir, max_blocks, index })
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Index membership for the block closing `chain` (no file I/O; the
    /// admission-headroom probe calls this per candidate).
    pub fn contains(&self, chain: &[u32]) -> bool {
        self.index.contains(&chain_key(chain))
    }

    fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("spill_{key:016x}.tensors"))
    }

    /// Persist one evicted block. Returns `true` when the block is in
    /// the store afterwards (including "already there"); `false` when
    /// dropped (capacity bound) or the write failed — spilling is
    /// strictly best-effort and must never take the eviction path down.
    pub fn spill(&mut self, chain: &[u32], payload: &[f32]) -> bool {
        let key = chain_key(chain);
        if self.index.contains(&key) {
            return true;
        }
        if self.index.len() >= self.max_blocks {
            return false;
        }
        let mut ts = Tensors::new();
        let mut tok_bytes = Vec::with_capacity(chain.len() * 4);
        for &t in chain {
            tok_bytes.extend_from_slice(&t.to_le_bytes());
        }
        ts.insert(
            "tokens".to_string(),
            Tensor { dtype: Dtype::I32, shape: vec![chain.len()], data: tok_bytes },
        );
        let payload = match Tensor::from_f32(vec![payload.len()], payload) {
            Ok(t) => t,
            Err(_) => return false,
        };
        ts.insert("payload".to_string(), payload);
        if tensorfile::save(self.path_for(key), &ts).is_err() {
            return false;
        }
        self.index.insert(key);
        true
    }

    /// Look up the block closing `chain`, fully validated: checksummed
    /// load, then an exact chain-identity check (the key hash is not
    /// trusted against collisions or renamed files). Any violation
    /// deletes the file and reports [`Fetch::Corrupt`].
    pub fn fetch(&mut self, chain: &[u32]) -> Fetch {
        let key = chain_key(chain);
        if !self.index.contains(&key) {
            return Fetch::Miss;
        }
        let path = self.path_for(key);
        let valid = tensorfile::load(&path).ok().and_then(|ts| {
            let tok = ts.get("tokens")?;
            if tok.dtype != Dtype::I32 || tok.shape != [chain.len()] {
                return None;
            }
            let same = tok
                .data
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .eq(chain.iter().copied());
            if !same {
                return None;
            }
            ts.get("payload")?.as_f32().ok()
        });
        match valid {
            Some(payload) => Fetch::Hit(payload),
            None => {
                self.discard(key);
                Fetch::Corrupt
            }
        }
    }

    /// Drop the block closing `chain` from the store, if present.
    pub fn remove(&mut self, chain: &[u32]) {
        self.discard(chain_key(chain));
    }

    fn discard(&mut self, key: u64) {
        self.index.remove(&key);
        let _ = std::fs::remove_file(self.path_for(key));
    }

    /// Write the radix snapshot: one i32 tensor per leaf chain. The
    /// write is atomic (tensorfile staging), so a crash mid-persist
    /// leaves the previous snapshot intact.
    pub fn write_snapshot(&self, chains: &[Vec<u32>]) -> Result<()> {
        let mut ts = Tensors::new();
        for (i, chain) in chains.iter().enumerate() {
            let mut bytes = Vec::with_capacity(chain.len() * 4);
            for &t in chain {
                bytes.extend_from_slice(&t.to_le_bytes());
            }
            ts.insert(
                format!("chain_{i:05}"),
                Tensor { dtype: Dtype::I32, shape: vec![chain.len()], data: bytes },
            );
        }
        tensorfile::save(self.dir.join(SNAPSHOT_FILE), &ts)
    }

    /// Read the radix snapshot left by a previous process. A missing or
    /// corrupt snapshot yields no chains (never an error): restart
    /// recovery is best-effort, the blocks themselves are still
    /// individually revivable on demand.
    pub fn read_snapshot(&self) -> Vec<Vec<u32>> {
        let path = self.dir.join(SNAPSHOT_FILE);
        if !path.exists() {
            return Vec::new();
        }
        let Ok(ts) = tensorfile::load(&path) else { return Vec::new() };
        let mut chains: Vec<Vec<u32>> = Vec::new();
        for t in ts.values() {
            if t.dtype != Dtype::I32 {
                continue;
            }
            chains.push(
                t.data
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
        }
        chains
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cold_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn spill_fetch_roundtrip_and_reopen() {
        let dir = tdir("rt");
        let chain: Vec<u32> = (0..16).collect();
        let payload: Vec<f32> = (0..16).map(|i| i as f32 * 0.5).collect();
        {
            let mut s = ColdStore::open(&dir, 8).unwrap();
            assert!(!s.contains(&chain));
            assert!(s.spill(&chain, &payload));
            assert!(s.contains(&chain));
            match s.fetch(&chain) {
                Fetch::Hit(p) => assert_eq!(p, payload),
                other => panic!("expected hit, got {other:?}"),
            }
        }
        // a fresh store over the same dir re-indexes the spill
        let mut s = ColdStore::open(&dir, 8).unwrap();
        assert_eq!(s.len(), 1);
        assert!(matches!(s.fetch(&chain), Fetch::Hit(_)));
        // a different chain with the same length misses
        let other: Vec<u32> = (100..116).collect();
        assert!(matches!(s.fetch(&other), Fetch::Miss));
    }

    #[test]
    fn corrupt_file_degrades_to_miss_and_is_deleted() {
        let dir = tdir("corrupt");
        let chain: Vec<u32> = (0..8).collect();
        let payload = vec![1.5f32; 8];
        let mut s = ColdStore::open(&dir, 8).unwrap();
        assert!(s.spill(&chain, &payload));
        // flip a payload byte on disk
        let file = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.file_name().unwrap().to_str().unwrap().starts_with("spill_"))
            .unwrap();
        let mut bytes = std::fs::read(&file).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&file, &bytes).unwrap();
        assert!(matches!(s.fetch(&chain), Fetch::Corrupt));
        assert!(!file.exists(), "corrupt spill must be deleted");
        assert!(matches!(s.fetch(&chain), Fetch::Miss), "second fetch is a plain miss");
    }

    #[test]
    fn truncated_file_is_corrupt_not_fatal() {
        let dir = tdir("trunc");
        let chain: Vec<u32> = (0..8).collect();
        let mut s = ColdStore::open(&dir, 8).unwrap();
        assert!(s.spill(&chain, &[2.0f32; 8]));
        let key = chain_key(&chain);
        let path = s.path_for(key);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(s.fetch(&chain), Fetch::Corrupt));
    }

    #[test]
    fn chain_identity_is_checked_not_just_the_key() {
        let dir = tdir("ident");
        let a: Vec<u32> = (0..8).collect();
        let b: Vec<u32> = (8..16).collect();
        let mut s = ColdStore::open(&dir, 8).unwrap();
        assert!(s.spill(&a, &[1.0f32; 8]));
        // graft a's file onto b's key: must be rejected as corrupt
        std::fs::rename(s.path_for(chain_key(&a)), s.path_for(chain_key(&b))).unwrap();
        s.index.insert(chain_key(&b));
        assert!(matches!(s.fetch(&b), Fetch::Corrupt));
    }

    #[test]
    fn capacity_bound_drops_spills() {
        let dir = tdir("cap");
        let mut s = ColdStore::open(&dir, 2).unwrap();
        assert!(s.spill(&[1, 2], &[0.0; 2]));
        assert!(s.spill(&[3, 4], &[0.0; 2]));
        assert!(!s.spill(&[5, 6], &[0.0; 2]), "store at capacity must drop");
        assert!(s.spill(&[3, 4], &[0.0; 2]), "re-spill of a resident block is a no-op success");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn snapshot_roundtrip_and_corrupt_snapshot_degrades() {
        let dir = tdir("snap");
        let s = ColdStore::open(&dir, 8).unwrap();
        assert!(s.read_snapshot().is_empty());
        let chains = vec![(0..16u32).collect::<Vec<_>>(), (40..48u32).collect()];
        s.write_snapshot(&chains).unwrap();
        let back = ColdStore::open(&dir, 8).unwrap().read_snapshot();
        assert_eq!(back.len(), 2);
        assert!(back.contains(&chains[0]) && back.contains(&chains[1]));
        // truncate the snapshot: boot sees no chains, not an error
        let snap = dir.join(SNAPSHOT_FILE);
        let bytes = std::fs::read(&snap).unwrap();
        std::fs::write(&snap, &bytes[..6]).unwrap();
        assert!(ColdStore::open(&dir, 8).unwrap().read_snapshot().is_empty());
    }

    #[test]
    fn chain_key_is_order_sensitive_and_stable() {
        assert_ne!(chain_key(&[1, 2, 3]), chain_key(&[3, 2, 1]));
        assert_ne!(chain_key(&[1]), chain_key(&[1, 0]));
        // pinned value: the key names files on disk, so it must never
        // drift between builds
        assert_eq!(chain_key(&[]), 0xcbf2_9ce4_8422_2325);
    }
}
