//! Session bookkeeping substrate: slot allocation, tree topology,
//! visibility (attention-mask rows) and the zero-copy KV filter.
//!
//! The KV cache itself lives wherever the [`crate::llm::Llm`]
//! implementation keeps it (device literals for PJRT, nothing for the
//! sim). What is shared is the *index structure*: every live token owns a
//! cache slot; the committed prefix is an ordered slot list; pending
//! (draft-tree) nodes form a forest hanging off the prefix tail. Paper
//! Alg. 5 `BuildAttentionMask` and Alg. 2/7 step 4 `FilterKVCache` are
//! both pure index operations here — accepting a path never copies cache
//! contents.
//!
//! Slots come from one of two backings: a session-private **dense**
//! range (the original design: `0..cache_len`, free-list managed), or a
//! **paged** lease on the fleet-wide block pool
//! ([`crate::kvcache::PagedSlots`]) where `slot = block * block_size +
//! offset`, the committed prefix may begin with radix-shared read-only
//! blocks, and capacity is a pool-wide (not per-session) resource. All
//! topology/visibility/commit logic is backing-agnostic.

use anyhow::{bail, Result};

use crate::kvcache::PagedSlots;
use crate::llm::{EvalNode, PARENT_PREFIX};

#[derive(Debug, Clone)]
pub struct Pending {
    pub token: u32,
    pub parent: i64,
    pub slot: u32,
    /// Depth below the prefix tail (root nodes = 0).
    pub depth: u32,
}

/// Where a session's slots come from (see module docs).
#[derive(Debug)]
enum Backing {
    /// Session-private dense slot range, free-list managed.
    Dense { free: Vec<u32> },
    /// Lease on the shared block pool.
    Paged(PagedSlots),
}

impl Backing {
    fn capacity_left(&self) -> usize {
        match self {
            Backing::Dense { free } => free.len(),
            Backing::Paged(p) => p.capacity_left(),
        }
    }

    fn alloc(&mut self) -> Result<u32> {
        match self {
            Backing::Dense { free } => {
                free.pop().ok_or_else(|| anyhow::anyhow!("KV cache exhausted"))
            }
            Backing::Paged(p) => Ok(p.alloc_slot()?),
        }
    }

    fn free(&mut self, slot: u32) {
        match self {
            Backing::Dense { free } => free.push(slot),
            Backing::Paged(p) => p.free_slot(slot),
        }
    }
}

/// Core session state shared by all `Llm` implementations.
#[derive(Debug)]
pub struct SessionCore {
    pub prefix_tokens: Vec<u32>,
    pub prefix_slots: Vec<u32>,
    pub pending: Vec<Pending>,
    backing: Backing,
    /// One reserved slot that padding rows scatter their KV into; never
    /// attended, never allocated.
    pub scratch_slot: u32,
}

impl SessionCore {
    /// `cache_len` total slots; the last is reserved as scratch.
    pub fn new(cache_len: usize) -> Self {
        assert!(cache_len >= 2, "cache too small");
        let scratch = (cache_len - 1) as u32;
        // allocate low slots first (pop from the back)
        let free: Vec<u32> = (0..scratch).rev().collect();
        // pre-size the prefix so steady-state commits stay allocation-free
        // (growth past the reservation is amortized-rare, not wrong)
        let reserve = (cache_len - 1).min(4096);
        Self {
            prefix_tokens: Vec::with_capacity(reserve),
            prefix_slots: Vec::with_capacity(reserve),
            pending: Vec::new(),
            backing: Backing::Dense { free },
            scratch_slot: scratch,
        }
    }

    /// A pool-backed session whose committed prefix starts with
    /// `shared_prefix` tokens mapped onto `slots`' shared (radix)
    /// blocks — those tokens need no evaluation, their KV is already
    /// resident. `scratch_slot` must lie outside the pool's slot range
    /// (callers pass `pool.total_slots()` or the substrate's reserved
    /// padding slot).
    pub fn paged(slots: PagedSlots, shared_prefix: &[u32], scratch_slot: u32) -> Self {
        debug_assert_eq!(
            slots.shared_len(),
            shared_prefix.len(),
            "shared slots must cover exactly the matched prefix"
        );
        // size the prefix reservation from what the pool could actually
        // back, so commits stay allocation-free for the session's whole
        // lifetime (clamped like the dense path: growth past a huge
        // reservation is amortized-rare, not wrong)
        let reserve = shared_prefix.len() + slots.pool().total_slots().min(1 << 16);
        let mut prefix_tokens = Vec::with_capacity(reserve);
        prefix_tokens.extend_from_slice(shared_prefix);
        let mut prefix_slots = Vec::with_capacity(reserve);
        prefix_slots.extend(slots.shared_slots());
        Self {
            prefix_tokens,
            prefix_slots,
            pending: Vec::new(),
            backing: Backing::Paged(slots),
            scratch_slot,
        }
    }

    /// The pool lease, when this session is pool-backed.
    pub fn paged_slots(&self) -> Option<&PagedSlots> {
        match &self.backing {
            Backing::Paged(p) => Some(p),
            Backing::Dense { .. } => None,
        }
    }

    pub fn capacity_left(&self) -> usize {
        self.backing.capacity_left()
    }

    pub fn prefix_len(&self) -> usize {
        self.prefix_tokens.len()
    }

    /// Logical position of a pending node = prefix_len + depth.
    pub fn position(&self, pending_idx: usize) -> u32 {
        self.prefix_len() as u32 + self.pending[pending_idx].depth
    }

    /// Append nodes, assigning slots and validating topology. Returns the
    /// pending-index range of the new nodes.
    pub fn add_pending(&mut self, nodes: &[EvalNode]) -> Result<std::ops::Range<usize>> {
        if nodes.len() > self.backing.capacity_left() {
            bail!(
                "KV cache exhausted: need {} slots, {} free",
                nodes.len(),
                self.backing.capacity_left()
            );
        }
        let start = self.pending.len();
        for (i, n) in nodes.iter().enumerate() {
            let depth = if n.parent == PARENT_PREFIX {
                0
            } else {
                let p = n.parent as usize;
                if p >= start + i {
                    bail!("node {} references parent {} not yet evaluated", start + i, p);
                }
                self.pending[p].depth + 1
            };
            let slot = self.backing.alloc()?;
            self.pending.push(Pending { token: n.token, parent: n.parent, slot, depth });
        }
        Ok(start..self.pending.len())
    }

    /// Cache slots visible to a pending node: the whole committed prefix,
    /// its pending ancestors, and itself. Ordered (prefix order, then
    /// root-to-self) — order is irrelevant to attention but keeps tests
    /// simple.
    pub fn visible_slots(&self, pending_idx: usize) -> Vec<u32> {
        let mut anc = Vec::new();
        let mut cur = pending_idx as i64;
        while cur != PARENT_PREFIX {
            let p = &self.pending[cur as usize];
            anc.push(p.slot);
            cur = p.parent;
        }
        anc.reverse();
        let mut out = self.prefix_slots.clone();
        out.extend(anc);
        out
    }

    /// Token path from the start of the sequence through a pending node
    /// (prefix tokens + ancestor chain + self). Used by the sim LM.
    pub fn context_tokens(&self, pending_idx: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.context_tokens_into(pending_idx, &mut out);
        out
    }

    /// [`SessionCore::context_tokens`] into a caller-owned buffer, so
    /// batched evaluation ([`crate::llm::Llm::eval_batch_into`]) reuses
    /// one allocation across every row of a fused call.
    pub fn context_tokens_into(&self, pending_idx: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend_from_slice(&self.prefix_tokens);
        let anc_start = out.len();
        let mut cur = pending_idx as i64;
        while cur != PARENT_PREFIX {
            let p = &self.pending[cur as usize];
            out.push(p.token);
            cur = p.parent;
        }
        out[anc_start..].reverse();
    }

    /// The LAST `max_len` tokens of [`SessionCore::context_tokens`], into
    /// a caller-owned buffer. For bounded-Markov substrates (the sim LM
    /// hashes only a fixed context tail) this turns the per-node context
    /// build from O(prefix) into O(max_len) with a fixed-size buffer.
    pub fn context_tail_into(&self, pending_idx: usize, max_len: usize, out: &mut Vec<u32>) {
        out.clear();
        let mut cur = pending_idx as i64;
        while cur != PARENT_PREFIX && out.len() < max_len {
            let p = &self.pending[cur as usize];
            out.push(p.token);
            cur = p.parent;
        }
        if cur == PARENT_PREFIX {
            let take = (max_len - out.len()).min(self.prefix_tokens.len());
            for i in 0..take {
                out.push(self.prefix_tokens[self.prefix_tokens.len() - 1 - i]);
            }
        }
        out.reverse();
    }

    /// Commit an accepted rootward chain into the prefix and free all
    /// other pending slots (zero-copy `FilterKVCache`).
    pub fn commit(&mut self, accepted: &[usize]) -> Result<()> {
        // validate chain structure
        let mut expect_parent = PARENT_PREFIX;
        for &idx in accepted {
            if idx >= self.pending.len() {
                bail!("commit index {idx} out of range");
            }
            if self.pending[idx].parent != expect_parent {
                bail!(
                    "commit chain broken at {idx}: parent {} != expected {}",
                    self.pending[idx].parent,
                    expect_parent
                );
            }
            expect_parent = idx as i64;
        }
        for &idx in accepted {
            let p = &self.pending[idx];
            self.prefix_tokens.push(p.token);
            self.prefix_slots.push(p.slot);
        }
        // `accepted` is a validated rootward chain, so its indices are
        // strictly ascending (every parent precedes its child in the
        // pending list): a two-pointer merge frees the complement in
        // O(pending), allocation-free — prefill commits the whole prompt
        // chain at once, so membership scans must not be O(n^2)
        let mut next = 0;
        for i in 0..self.pending.len() {
            if next < accepted.len() && accepted[next] == i {
                next += 1;
            } else {
                self.backing.free(self.pending[i].slot);
            }
        }
        self.pending.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::EvalNode;

    #[test]
    fn slots_unique_and_bounded() {
        let mut s = SessionCore::new(16);
        let r = s
            .add_pending(&[
                EvalNode::root(1),
                EvalNode::child(2, 0),
                EvalNode::child(3, 0),
                EvalNode::child(4, 1),
            ])
            .unwrap();
        assert_eq!(r, 0..4);
        let mut slots: Vec<u32> = s.pending.iter().map(|p| p.slot).collect();
        slots.sort();
        slots.dedup();
        assert_eq!(slots.len(), 4);
        assert!(slots.iter().all(|&x| x < 15)); // scratch = 15 never allocated
    }

    #[test]
    fn depth_and_position() {
        let mut s = SessionCore::new(16);
        s.add_pending(&[EvalNode::root(1), EvalNode::child(2, 0)]).unwrap();
        s.commit(&[0, 1]).unwrap();
        assert_eq!(s.prefix_len(), 2);
        s.add_pending(&[EvalNode::root(5), EvalNode::child(6, 0), EvalNode::child(7, 1)])
            .unwrap();
        assert_eq!(s.position(0), 2);
        assert_eq!(s.position(2), 4);
    }

    #[test]
    fn visibility_is_prefix_plus_ancestors() {
        let mut s = SessionCore::new(32);
        s.add_pending(&[EvalNode::root(1)]).unwrap();
        s.commit(&[0]).unwrap();
        // tree: a(root) -> {b, c}; b -> d
        s.add_pending(&[
            EvalNode::root(10),      // a = 0
            EvalNode::child(11, 0),  // b = 1
            EvalNode::child(12, 0),  // c = 2
            EvalNode::child(13, 1),  // d = 3
        ])
        .unwrap();
        let slot = |i: usize| s.pending[i].slot;
        let pfx = s.prefix_slots[0];
        assert_eq!(s.visible_slots(0), vec![pfx, slot(0)]);
        assert_eq!(s.visible_slots(3), vec![pfx, slot(0), slot(1), slot(3)]);
        // sibling c must NOT see b
        assert!(!s.visible_slots(2).contains(&slot(1)));
    }

    #[test]
    fn commit_frees_rejected_and_reuses_slots() {
        let mut s = SessionCore::new(8); // 7 usable slots
        s.add_pending(&[
            EvalNode::root(1),
            EvalNode::child(2, 0),
            EvalNode::child(3, 0),
            EvalNode::child(4, 1),
            EvalNode::child(5, 2),
        ])
        .unwrap();
        assert_eq!(s.capacity_left(), 2);
        s.commit(&[0, 1, 3]).unwrap(); // accept a->b->d; free c, e
        assert_eq!(s.prefix_tokens, vec![1, 2, 4]);
        assert_eq!(s.capacity_left(), 4);
        // freed slots get reused
        s.add_pending(&[EvalNode::root(9), EvalNode::child(8, 0)]).unwrap();
        assert_eq!(s.capacity_left(), 2);
    }

    #[test]
    fn commit_rejects_broken_chain() {
        let mut s = SessionCore::new(16);
        s.add_pending(&[EvalNode::root(1), EvalNode::child(2, 0), EvalNode::child(3, 0)])
            .unwrap();
        assert!(s.commit(&[1]).is_err()); // does not start at prefix
        assert!(s.commit(&[0, 2, 1]).is_err()); // 1 is not child of 2
    }

    #[test]
    fn context_tokens_follow_path() {
        let mut s = SessionCore::new(16);
        s.add_pending(&[EvalNode::root(7)]).unwrap();
        s.commit(&[0]).unwrap();
        s.add_pending(&[EvalNode::root(1), EvalNode::child(2, 0), EvalNode::child(3, 0)])
            .unwrap();
        assert_eq!(s.context_tokens(1), vec![7, 1, 2]);
        assert_eq!(s.context_tokens(2), vec![7, 1, 3]);
    }

    #[test]
    fn context_tail_matches_full_context() {
        let mut s = SessionCore::new(64);
        let chain: Vec<EvalNode> = (0..12u32)
            .map(|i| {
                if i == 0 {
                    EvalNode::root(i)
                } else {
                    EvalNode::child(i + 100, (i - 1) as usize)
                }
            })
            .collect();
        s.add_pending(&chain).unwrap();
        s.commit(&(0..12).collect::<Vec<_>>()).unwrap();
        s.add_pending(&[EvalNode::root(7), EvalNode::child(8, 0), EvalNode::child(9, 1)])
            .unwrap();
        let mut tail = Vec::new();
        for idx in 0..3 {
            let full = s.context_tokens(idx);
            for max_len in [1usize, 4, 8, 100] {
                s.context_tail_into(idx, max_len, &mut tail);
                let want = &full[full.len().saturating_sub(max_len)..];
                assert_eq!(tail, want, "idx {idx} max_len {max_len}");
            }
        }
    }

    #[test]
    fn cache_exhaustion_errors() {
        let mut s = SessionCore::new(4); // 3 usable
        assert!(s
            .add_pending(&[
                EvalNode::root(1),
                EvalNode::child(2, 0),
                EvalNode::child(3, 1),
                EvalNode::child(4, 2)
            ])
            .is_err());
    }
}
