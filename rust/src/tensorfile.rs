//! safetensors-lite reader/writer: the weights interchange format
//! produced by `python/compile/tensorfile.py`, now also the on-disk
//! format for the cold KV tier ([`crate::kvcache::cold`]).
//!
//! Layout: `[u64 LE header_len][header JSON][raw tensor data]`, tensors
//! raw little-endian C-contiguous, offsets relative to the start of the
//! data section. Header schema per tensor:
//! `{"dtype": "f32"|"i32", "shape": [..], "offset": N, "nbytes": M}`
//! plus an optional `"crc32"` field (IEEE CRC-32 of the tensor bytes).
//! The python writer does not emit checksums; [`save`] always does, and
//! [`load`] verifies them whenever present — so cold-tier spill files
//! are integrity-checked while legacy weight files stay loadable.
//!
//! Hardening invariants (the cold tier trusts this layer with cache
//! state, so adversarial/corrupt headers must fail *cleanly*):
//! * all size arithmetic is checked — `offset + nbytes` and
//!   `product(shape) * 4` reject on overflow instead of wrapping past a
//!   bounds check;
//! * tensor ranges may not overlap each other;
//! * reads are ranged (seek + exact read per tensor), so peak memory is
//!   one tensor, not 2x the file;
//! * any violation — truncation, bad utf-8, bad JSON, bad checksum —
//!   is an `Err`, never a panic.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }
}

/// One named tensor loaded from a `.tensors` file.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Raw little-endian bytes (length = product(shape) * 4).
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is not f32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Pack an f32 slice into an `f32` tensor of the given shape.
    pub fn from_f32(shape: Vec<usize>, vals: &[f32]) -> Result<Tensor> {
        let want = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .context("shape element count overflows")?;
        if want != vals.len() {
            bail!("shape/value mismatch ({want} != {})", vals.len());
        }
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Tensor { dtype: Dtype::F32, shape, data })
    }
}

/// All tensors in a file, keyed by name (ordered for determinism).
pub type Tensors = BTreeMap<String, Tensor>;

/// IEEE CRC-32 (the polynomial zlib/zip use), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    const fn table() -> [u32; 256] {
        let mut t = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    }
    static TABLE: [u32; 256] = table();
    let mut c = u32::MAX;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// One validated header entry: where the tensor lives in the data
/// section and what it must look like.
struct Entry {
    name: String,
    dtype: Dtype,
    shape: Vec<usize>,
    offset: u64,
    nbytes: u64,
    crc: Option<u32>,
}

/// Parse and validate the header against the actual data-section size.
/// All arithmetic is checked; ranges are bounds- and overlap-checked.
fn plan_entries(header: &Json, data_len: u64) -> Result<Vec<Entry>> {
    let mut plan = Vec::new();
    for (name, e) in header.as_obj().context("header must be an object")? {
        let dtype = match e.str_field("dtype")? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("{name}: unsupported dtype {other}"),
        };
        let shape: Vec<usize> = e
            .get("shape")
            .and_then(Json::as_arr)
            .with_context(|| format!("{name}: shape missing"))?
            .iter()
            .map(|x| x.as_usize().with_context(|| format!("{name}: bad shape entry")))
            .collect::<Result<_>>()?;
        let offset = e.usize_field("offset")? as u64;
        let nbytes = e.usize_field("nbytes")? as u64;
        let want = shape
            .iter()
            .try_fold(1u64, |a, &d| a.checked_mul(d as u64))
            .and_then(|n| n.checked_mul(4))
            .with_context(|| format!("{name}: shape size overflows"))?;
        if want != nbytes {
            bail!("{name}: shape/nbytes mismatch ({want} != {nbytes})");
        }
        let end = offset
            .checked_add(nbytes)
            .with_context(|| format!("{name}: offset + nbytes overflows"))?;
        if end > data_len {
            bail!("{name}: data range {offset}..{end} out of bounds ({data_len})");
        }
        let crc = match e.get("crc32") {
            None => None,
            Some(c) => {
                let n = c
                    .as_f64()
                    .filter(|f| f.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(f))
                    .with_context(|| format!("{name}: invalid crc32 field"))?;
                Some(n as u32)
            }
        };
        plan.push(Entry { name: name.clone(), dtype, shape, offset, nbytes, crc });
    }
    // Reject overlapping ranges: a header that aliases two tensors onto
    // the same bytes is corrupt (or adversarial), not a weights file.
    let mut ranges: Vec<(u64, u64, &str)> = plan
        .iter()
        .filter(|e| e.nbytes > 0)
        .map(|e| (e.offset, e.offset + e.nbytes, e.name.as_str()))
        .collect();
    ranges.sort_unstable();
    for w in ranges.windows(2) {
        if w[1].0 < w[0].1 {
            bail!("tensors '{}' and '{}' overlap in the data section", w[0].2, w[1].2);
        }
    }
    Ok(plan)
}

pub fn load(path: impl AsRef<Path>) -> Result<Tensors> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening tensors file {}", path.display()))?;
    let file_len = f.metadata().context("stat tensors file")?.len();
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8).context("reading header length")?;
    let hlen = u64::from_le_bytes(len8);
    if hlen > 16 << 20 {
        bail!("implausible header length {hlen}");
    }
    let data_start = 8u64
        .checked_add(hlen)
        .filter(|&s| s <= file_len)
        .with_context(|| format!("truncated file: header {hlen} exceeds length {file_len}"))?;
    let data_len = file_len - data_start;
    let mut hjson = vec![0u8; hlen as usize];
    f.read_exact(&mut hjson).context("reading header json")?;
    let htext = std::str::from_utf8(&hjson).context("header not utf-8")?;
    let header = Json::parse(htext).context("parsing header json")?;

    let mut out = Tensors::new();
    for e in plan_entries(&header, data_len)? {
        // Ranged read: seek to this tensor and read exactly its bytes,
        // so peak memory is one tensor rather than the whole section.
        f.seek(SeekFrom::Start(data_start + e.offset))
            .with_context(|| format!("{}: seeking to data", e.name))?;
        let mut data = vec![0u8; e.nbytes as usize];
        f.read_exact(&mut data)
            .with_context(|| format!("{}: reading {} data bytes", e.name, e.nbytes))?;
        if let Some(want) = e.crc {
            let got = crc32(&data);
            if got != want {
                bail!("{}: checksum mismatch (header {want:#010x}, data {got:#010x})", e.name);
            }
        }
        out.insert(e.name, Tensor { dtype: e.dtype, shape: e.shape, data });
    }
    Ok(out)
}

/// Write tensors in the interchange format, checksummed and atomic: the
/// file is staged as `<path>.tmp` and renamed into place, so readers
/// never observe a half-written file (a torn write at worst leaves a
/// stale tmp behind). Offsets are assigned in key order; every entry
/// carries a `crc32` the reader will verify.
pub fn save(path: impl AsRef<Path>, tensors: &Tensors) -> Result<()> {
    let path = path.as_ref();
    let mut offset = 0usize;
    let mut entries: Vec<(String, Json)> = Vec::new();
    for (name, t) in tensors {
        let want = t
            .shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .and_then(|n| n.checked_mul(4))
            .with_context(|| format!("{name}: shape size overflows"))?;
        if want != t.data.len() {
            bail!("{name}: shape/data mismatch ({want} != {})", t.data.len());
        }
        entries.push((
            name.clone(),
            Json::obj(vec![
                ("dtype", Json::from(t.dtype.name())),
                ("shape", Json::Arr(t.shape.iter().map(|&d| Json::from(d)).collect())),
                ("offset", Json::from(offset)),
                ("nbytes", Json::from(t.data.len())),
                ("crc32", Json::Num(crc32(&t.data) as f64)),
            ]),
        ));
        offset = offset
            .checked_add(t.data.len())
            .context("total data size overflows")?;
    }
    let header = Json::Obj(entries.into_iter().collect()).to_string();

    let tmp = path.with_extension("tensors.tmp");
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(&(header.len() as u64).to_le_bytes()).context("writing header length")?;
    f.write_all(header.as_bytes()).context("writing header")?;
    for t in tensors.values() {
        f.write_all(&t.data).context("writing tensor data")?;
    }
    f.sync_all().context("syncing tensors file")?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_file(dir: &std::path::Path, header: &str, data: &[u8]) -> std::path::PathBuf {
        let p = dir.join("t.tensors");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(data).unwrap();
        p
    }

    fn tdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tf_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_small() {
        let dir = tdir("rt");
        let vals: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0, 7.0, 8.0];
        let mut data = Vec::new();
        for v in &vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        let header = r#"{"a": {"dtype": "f32", "shape": [2, 3], "offset": 0, "nbytes": 24}}"#;
        let p = write_file(&dir, header, &data);
        let t = load(&p).unwrap();
        let a = &t["a"];
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.as_f32().unwrap(), vals);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let dir = tdir("oob");
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16}}"#;
        let p = write_file(&dir, header, &[0u8; 8]);
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = tdir("sm");
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 12}}"#;
        let p = write_file(&dir, header, &[0u8; 16]);
        assert!(load(&p).is_err());
    }

    /// Regression: `offset + nbytes` used to wrap on adversarial
    /// offsets, letting the bounds check pass and the slice panic.
    #[test]
    fn rejects_offset_nbytes_overflow() {
        let dir = tdir("ov1");
        let header = format!(
            r#"{{"a": {{"dtype": "f32", "shape": [4], "offset": {}, "nbytes": 16}}}}"#,
            u64::MAX - 8
        );
        let p = write_file(&dir, &header, &[0u8; 16]);
        assert!(load(&p).is_err());
        // saturated-to-MAX offset (JSON f64 -> usize saturates)
        let header = format!(
            r#"{{"a": {{"dtype": "f32", "shape": [4], "offset": {}, "nbytes": 16}}}}"#,
            u64::MAX
        );
        let p = write_file(&dir, &header, &[0u8; 16]);
        assert!(load(&p).is_err());
    }

    /// Regression: `product(shape) * 4` used to wrap, matching a small
    /// nbytes and passing validation with a bogus element count.
    #[test]
    fn rejects_shape_product_overflow() {
        let dir = tdir("ov2");
        // 2^62 * 4 wraps to 0 in u64; must be an Err, not a 0-byte "match"
        let header = format!(
            r#"{{"a": {{"dtype": "f32", "shape": [{}, 4], "offset": 0, "nbytes": 0}}}}"#,
            1u64 << 62
        );
        let p = write_file(&dir, &header, &[0u8; 16]);
        assert!(load(&p).is_err());
        // huge multi-dim product
        let header = format!(
            r#"{{"a": {{"dtype": "f32", "shape": [{0}, {0}, {0}], "offset": 0, "nbytes": 16}}}}"#,
            u32::MAX
        );
        let p = write_file(&dir, &header, &[0u8; 16]);
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_overlapping_ranges() {
        let dir = tdir("olap");
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16},
                         "b": {"dtype": "f32", "shape": [4], "offset": 8, "nbytes": 16}}"#;
        let p = write_file(&dir, header, &[0u8; 24]);
        let err = load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("overlap"), "{err:#}");
        // adjacent (end == start) is fine
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16},
                         "b": {"dtype": "f32", "shape": [2], "offset": 16, "nbytes": 8}}"#;
        let p = write_file(&dir, header, &[0u8; 24]);
        assert!(load(&p).is_ok());
    }

    #[test]
    fn rejects_header_longer_than_file() {
        let dir = tdir("trunc");
        let p = dir.join("t.tensors");
        std::fs::write(&p, (1u64 << 20).to_le_bytes()).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn save_load_roundtrip_with_checksums() {
        let dir = tdir("save");
        let p = dir.join("w.tensors");
        let mut ts = Tensors::new();
        ts.insert("w".into(), Tensor::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap());
        ts.insert(
            "b".into(),
            Tensor { dtype: Dtype::I32, shape: vec![3], data: vec![1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0] },
        );
        save(&p, &ts).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["w"].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["b"].dtype, Dtype::I32);
        assert_eq!(back["b"].data, ts["b"].data);
        assert!(!dir.join("w.tensors.tmp").exists(), "tmp staging file left behind");
    }

    #[test]
    fn detects_checksum_mismatch() {
        let dir = tdir("crc");
        let p = dir.join("w.tensors");
        let mut ts = Tensors::new();
        ts.insert("w".into(), Tensor::from_f32(vec![4], &[1.0, 2.0, 3.0, 4.0]).unwrap());
        save(&p, &ts).unwrap();
        // flip one payload byte (last byte of the file is tensor data)
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn crc32_known_vector() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
