//! safetensors-lite reader: the weights interchange format produced by
//! `python/compile/tensorfile.py`.
//!
//! Layout: `[u64 LE header_len][header JSON][raw tensor data]`, tensors
//! raw little-endian C-contiguous. See the python writer for the header
//! schema.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One named tensor loaded from a `.tensors` file.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Raw little-endian bytes (length = product(shape) * 4).
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is not f32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// All tensors in a file, keyed by name (ordered for determinism).
pub type Tensors = BTreeMap<String, Tensor>;

pub fn load(path: impl AsRef<Path>) -> Result<Tensors> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening tensors file {}", path.display()))?;
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8).context("reading header length")?;
    let hlen = u64::from_le_bytes(len8) as usize;
    if hlen > 16 << 20 {
        bail!("implausible header length {hlen}");
    }
    let mut hjson = vec![0u8; hlen];
    f.read_exact(&mut hjson).context("reading header json")?;
    let htext = std::str::from_utf8(&hjson).context("header not utf-8")?;
    let header = Json::parse(htext).context("parsing header json")?;
    let mut data = Vec::new();
    f.read_to_end(&mut data).context("reading data section")?;

    let mut out = Tensors::new();
    for (name, e) in header.as_obj().context("header must be an object")? {
        let dtype = match e.str_field("dtype")? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("{name}: unsupported dtype {other}"),
        };
        let shape: Vec<usize> = e
            .get("shape")
            .and_then(Json::as_arr)
            .with_context(|| format!("{name}: shape missing"))?
            .iter()
            .map(|x| x.as_usize().with_context(|| format!("{name}: bad shape entry")))
            .collect::<Result<_>>()?;
        let offset = e.usize_field("offset")?;
        let nbytes = e.usize_field("nbytes")?;
        let want: usize = shape.iter().product::<usize>() * 4;
        if want != nbytes {
            bail!("{name}: shape/nbytes mismatch ({want} != {nbytes})");
        }
        let end = offset + nbytes;
        if end > data.len() {
            bail!("{name}: data range {offset}..{end} out of bounds ({})", data.len());
        }
        out.insert(
            name.clone(),
            Tensor { dtype, shape, data: data[offset..end].to_vec() },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_file(dir: &std::path::Path, header: &str, data: &[u8]) -> std::path::PathBuf {
        let p = dir.join("t.tensors");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(data).unwrap();
        p
    }

    #[test]
    fn roundtrip_small() {
        let dir = std::env::temp_dir().join(format!("tf_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0, 7.0, 8.0];
        let mut data = Vec::new();
        for v in &vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        let header = r#"{"a": {"dtype": "f32", "shape": [2, 3], "offset": 0, "nbytes": 24}}"#;
        let p = write_file(&dir, header, &data);
        let t = load(&p).unwrap();
        let a = &t["a"];
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.as_f32().unwrap(), vals);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join(format!("tf_test_oob_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16}}"#;
        let p = write_file(&dir, header, &[0u8; 8]);
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join(format!("tf_test_sm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let header = r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 12}}"#;
        let p = write_file(&dir, header, &[0u8; 16]);
        assert!(load(&p).is_err());
    }
}
