//! `rsd` — the Recursive Speculative Decoding serving CLI.
//!
//! Subcommands:
//! * `generate` — decode one prompt with any algorithm (PJRT or sim).
//! * `serve`    — run the JSON-lines TCP serving engine.
//! * `exp1`     — regenerate the paper's Exp1 tables/figure (fixed DL).
//! * `exp2`     — regenerate Exp2 (fixed target budget).
//! * `fig1`     — regenerate Figure 1 (Bernoulli toy acceptance rates).
//! * `selftest` — load artifacts and check the AOT round-trip end-to-end.
//!
//! Run `rsd help` for flags.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use rsd::bench::{self, workload, BenchOpts};
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::metrics::Metrics;
use rsd::coordinator::{engine, server};
use rsd::decode::generate;
use rsd::llm::Llm;
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::sim::SimLm;
use rsd::tokenizer::Tokenizer;
use rsd::trace::Tracer;
use rsd::trace::watchdog::Watchdog;
use rsd::util::args::Args;
use rsd::util::Rng;

const USAGE: &str = "\
rsd — Recursive Speculative Decoding serving framework

USAGE: rsd <COMMAND> [--flags]

COMMANDS:
  generate   --prompt STR --max-tokens N --decoder SPEC --temperature T
             --top-p P --seed N [--sim] [--artifacts DIR]
  serve      --addr HOST:PORT [--config FILE.json] [--artifacts DIR] [--sim]
             [--trace N] [--watchdog-ms MS] [--watchdog-path FILE]
             [--cold-dir DIR]
             (config "kv_blocks"/"kv_block_size" enable the paged KV
              pool with radix prefix sharing on the sim substrate;
              "cold_dir"/--cold-dir adds the persistent cold tier:
              evicted blocks spill to checksummed tensorfiles there,
              "cold_blocks" bounds the disk footprint, and hot
              prefixes survive restarts via the radix snapshot;
              "drain_batching": true switches continuous phase-boundary
              admission off, as the A/B baseline. Per-request wire
              fields: "priority" 0-255, "deadline_ms", "stream": true
              for per-token {"token", "index"} events.
              --trace N keeps a flight-recorder ring of the newest N
              events ({"cmd": "trace"} dumps Chrome trace JSON +
              Prometheus text; {"cmd": "metrics"} dumps counters;
              {"cmd": "stats", "window": K} dumps speculation
              analytics — per-level acceptance, accepted tokens
              per target forward, SLO attainment — over the last
              K windows of "stats_window_rounds" rounds each);
              --watchdog-ms MS snapshots journal + engine state to
              --watchdog-path when no phase boundary advances for MS)
  exp1       --dl 2,3,4,5 --max-tokens N --reps N [--sim] [--alpha A]
             [--tv-trials N] --temperature T
  exp2       --budget 6,10,14,21,30 (same flags as exp1)
  fig1       --grid N
  selftest   [--artifacts DIR]

Decoder SPEC strings: ar | sd:L | spectr:KxL | rsd-c:B-B-.. | rsd-s:WxL
                      adaptive:B[:rsd-c|:rsd-s]  (online tree shaping
                      under a hard per-round node budget B)
";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(raw, &["sim", "help"])?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let artifacts = args.get_or("artifacts", "artifacts");

    match cmd {
        "generate" => {
            let prompt = args.get_or("prompt", "the quick brown fox");
            let max_tokens: usize = args.parse_or("max-tokens", 64)?;
            let decoder: DecoderConfig = args.get_or("decoder", "rsd-s:3x3").parse()?;
            let sampling = SamplingConfig::new(
                args.parse_or("temperature", 0.3f32)?,
                args.parse_or("top-p", 1.0f32)?,
            );
            let seed: u64 = args.parse_or("seed", 0)?;
            let tok = Tokenizer::new();
            let mut rng = Rng::seed_from_u64(seed);
            let p = tok.encode(&prompt);
            if args.has("sim") {
                let (target, draft) = SimLm::pair(seed, 0.8, 256);
                let run =
                    generate(&decoder, &sampling, &target, &draft, &p, max_tokens, &mut rng)?;
                report_run(&tok, &prompt, &run, decoder.depth(), &target, &draft);
            } else {
                let rt = Runtime::cpu()?;
                let (target, draft) = PjrtLm::load_pair(&rt, &artifacts)?;
                let run =
                    generate(&decoder, &sampling, &target, &draft, &p, max_tokens, &mut rng)?;
                report_run(&tok, &prompt, &run, decoder.depth(), &target, &draft);
            }
        }
        "serve" => {
            let addr = args.get_or("addr", "127.0.0.1:7433");
            let mut cfg = match args.get("config") {
                Some(path) => EngineConfig::from_json_file(path)?,
                None => EngineConfig::default(),
            };
            // flags override the config file's observability knobs
            cfg.trace_events = args.parse_or("trace", cfg.trace_events)?;
            cfg.watchdog_ms = args.parse_or("watchdog-ms", cfg.watchdog_ms)?;
            if let Some(p) = args.get("watchdog-path") {
                cfg.watchdog_path = p.to_string();
            }
            let metrics = Arc::new(Metrics::default());
            let trace = Tracer::new(cfg.trace_events);
            // one analytics handle shared by the engine (records) and the
            // server ({"cmd": "stats"} reads) — from_config returns the
            // inert handle when "stats_window_rounds" is 0
            let analytics = rsd::obs::Analytics::from_config(&cfg);
            let watchdog_ms = cfg.watchdog_ms;
            let watchdog_path = cfg.watchdog_path.clone();
            let cancels = rsd::coordinator::CancelRegistry::default();
            let ctx = server::ServeCtx {
                metrics: Some(metrics.clone()),
                trace: trace.clone(),
                cancels: Some(cancels.clone()),
                analytics: analytics.clone(),
            };
            let spawn_watchdog = |status| {
                Watchdog::spawn(
                    trace.clone(),
                    status,
                    Duration::from_millis(watchdog_ms),
                    watchdog_path.clone().into(),
                )
            };
            if args.has("sim") {
                // sim substrate: paged KV pools when the config asks for
                // them ("kv_blocks" > 0), dense per-session caches else.
                // "cold_dir" (or --cold-dir) additionally attaches the
                // persistent cold tier: evicted blocks spill to disk and
                // hot prefixes survive restarts via the radix snapshot
                let seed = cfg.seed;
                if let Some(d) = args.get("cold-dir") {
                    cfg.cold_dir = Some(d.to_string());
                }
                let (target, draft) = if cfg.kv_blocks > 0 {
                    let kv = rsd::kvcache::KvConfig {
                        num_blocks: cfg.kv_blocks,
                        block_size: cfg.kv_block_size,
                        share: true,
                    };
                    match &cfg.cold_dir {
                        Some(dir) => rsd::sim::SimLm::pair_paged_cold(
                            seed,
                            0.8,
                            256,
                            kv,
                            dir,
                            cfg.cold_blocks,
                        )?,
                        None => rsd::sim::SimLm::pair_paged(seed, 0.8, 256, kv),
                    }
                } else {
                    SimLm::pair(seed, 0.8, 256)
                };
                let eng =
                    engine::Engine::with_telemetry(target, draft, cfg, metrics, trace.clone())
                        .with_cancels(cancels)
                        .with_analytics(analytics);
                let _watchdog = spawn_watchdog(eng.status_handle());
                let (tx, _handle) = engine::spawn(eng);
                server::serve(&addr, tx, ctx)?;
            } else {
                let artifacts_dir = artifacts.clone();
                let eng_metrics = metrics;
                let eng_trace = trace.clone();
                // the engine is built on its own thread (PJRT wants that),
                // so the watchdog's status handle comes back over a channel
                let (status_tx, status_rx) = std::sync::mpsc::channel();
                let (tx, _handle) = engine::spawn_with(move || {
                    let rt = Runtime::cpu()?;
                    let (target, draft) = PjrtLm::load_pair(&rt, &artifacts_dir)?;
                    let eng = engine::Engine::with_telemetry(
                        target,
                        draft,
                        cfg,
                        eng_metrics,
                        eng_trace,
                    )
                    .with_cancels(cancels)
                    .with_analytics(analytics);
                    let _ = status_tx.send(eng.status_handle());
                    Ok(eng)
                });
                // arm the watchdog from a helper thread: the listener
                // must bind now, not after the model finishes loading,
                // and a build that never reports a status handle is
                // logged instead of silently dropping the watchdog
                if trace.enabled() && watchdog_ms > 0 {
                    let wd_trace = trace.clone();
                    let wd_path = watchdog_path.clone();
                    std::thread::Builder::new()
                        .name("rsd-watchdog-arm".into())
                        .spawn(move || match status_rx.recv() {
                            Ok(status) => {
                                if let Some(w) = Watchdog::spawn(
                                    wd_trace,
                                    status,
                                    Duration::from_millis(watchdog_ms),
                                    wd_path.into(),
                                ) {
                                    // serve() blocks for the process
                                    // lifetime; dropping the handle here
                                    // would stop the watchdog at once
                                    std::mem::forget(w);
                                }
                            }
                            Err(_) => eprintln!(
                                "rsd: engine exited before reporting status; \
                                 stall watchdog not armed"
                            ),
                        })?;
                }
                server::serve(&addr, tx, ctx)?;
            }
        }
        "exp1" | "exp2" => {
            let sampling = SamplingConfig::new(
                args.parse_or("temperature", 0.3f32)?,
                args.parse_or("top-p", 1.0f32)?,
            );
            let opts = BenchOpts {
                max_new: args.parse_or("max-tokens", 64)?,
                reps: args.parse_or("reps", 4)?,
                tv_trials: args.parse_or("tv-trials", 0)?,
                seed: args.parse_or("seed", 0)?,
            };
            let alpha: f64 = args.parse_or("alpha", 0.8)?;
            let (points, configs): (Vec<usize>, fn(usize) -> Vec<DecoderConfig>) = if cmd == "exp1"
            {
                (args.list_or("dl", &[2, 3, 4, 5])?, bench::exp1_configs)
            } else {
                (args.list_or("budget", &[6, 10, 14, 21, 30])?, bench::exp2_configs)
            };
            let axis = if cmd == "exp1" { "DL" } else { "Budget" };
            run_sweep(&artifacts, args.has("sim"), alpha, &sampling, &opts, &points, configs, axis)?;
        }
        "fig1" => {
            let grid: usize = args.parse_or("grid", 10)?;
            println!("Figure 1 — acceptance rates, draft Ber(p), target Ber(q), K=2");
            println!(
                "{:>5} {:>5} {:>11} {:>9} {:>7} {:>7}",
                "p", "q", "multi-round", "K-SEQ*", "OTM", "RRS"
            );
            for row in bench::figure1(grid) {
                println!(
                    "{:>5.2} {:>5.2} {:>11.3} {:>9.3} {:>7.3} {:>7.3}",
                    row.p, row.q, row.multiround, row.kseq, row.otm, row.rrs
                );
            }
            println!("(K-SEQ* = gamma tuned; OTM exact for binary vocab)");
        }
        "selftest" => {
            let rt = Runtime::cpu()?;
            println!("PJRT platform: {}", rt.platform());
            let (target, draft) = PjrtLm::load_pair(&rt, &artifacts)?;
            println!(
                "loaded target ({} params) and draft ({} params)",
                target.param_count(),
                draft.param_count()
            );
            let tok = Tokenizer::new();
            let sampling = SamplingConfig::new(0.3, 1.0);
            let mut rng = Rng::seed_from_u64(0);
            let prompt = tok.encode("the sound of ");
            let cfg = DecoderConfig::RsdS { w: 3, l: 3 };
            let run = generate(&cfg, &sampling, &target, &draft, &prompt, 48, &mut rng)?;
            println!("RSD-S sample: {:?}", tok.decode(&run.tokens));
            println!(
                "block efficiency {:.3}, {} rounds, {} tree nodes",
                run.stats.block_efficiency(),
                run.stats.decode_calls,
                run.stats.tree_nodes
            );
            println!("selftest OK");
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("{USAGE}");
            bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

fn report_run<T: Llm, D: Llm>(
    tok: &Tokenizer,
    prompt: &str,
    run: &rsd::decode::DecodeRun,
    depth: usize,
    target: &T,
    draft: &D,
) {
    println!("prompt: {prompt}");
    println!("output: {}", tok.decode(&run.tokens));
    let s = &run.stats;
    println!(
        "generated {} tokens in {:.3}s | eff {:.3} | MBSU {:.3} | {:.1} tok/s | {} rounds | {} draft calls",
        s.generated,
        s.wall.as_secs_f64(),
        s.block_efficiency(),
        s.mbsu(depth, draft.param_count(), target.param_count()),
        s.token_rate(),
        s.decode_calls,
        s.draft_calls,
    );
}

#[allow(clippy::too_many_arguments)]
fn run_sweep(
    artifacts: &str,
    sim: bool,
    alpha: f64,
    sampling: &SamplingConfig,
    opts: &BenchOpts,
    points: &[usize],
    configs: fn(usize) -> Vec<DecoderConfig>,
    axis: &str,
) -> Result<()> {
    if sim {
        let (target, draft) = SimLm::pair(0, alpha, 256);
        let prompts = workload::random_prompts(opts.reps.max(4), 16, 256, 1);
        sweep_on(&target, &draft, sampling, opts, points, configs, axis, &prompts)
    } else {
        let rt = Runtime::cpu()?;
        let (target, draft) = PjrtLm::load_pair(&rt, artifacts)?;
        let prompts = workload::corpus_prompts(artifacts, opts.reps.max(4), 48, 1)?;
        sweep_on(&target, &draft, sampling, opts, points, configs, axis, &prompts)
    }
}

#[allow(clippy::too_many_arguments)]
fn sweep_on<T: Llm, D: Llm>(
    target: &T,
    draft: &D,
    sampling: &SamplingConfig,
    opts: &BenchOpts,
    points: &[usize],
    configs: fn(usize) -> Vec<DecoderConfig>,
    axis: &str,
    prompts: &[Vec<u32>],
) -> Result<()> {
    let ar = bench::bench_decoder(&DecoderConfig::Ar, sampling, target, draft, prompts, opts)?;
    for &pt in points {
        let mut rows = Vec::new();
        for cfg in configs(pt) {
            rows.push(bench::bench_decoder(&cfg, sampling, target, draft, prompts, opts)?);
        }
        bench::print_table(&format!("{axis} = {pt}"), &ar, &rows, true);
    }
    Ok(())
}
