//! Sampling substrate: logits processing (temperature / nucleus), Gumbel
//! machinery for sampling *without replacement* (Gumbel-Top-k and the
//! truncated-Gumbel recursion of Stochastic Beam Search), categorical
//! sampling and residual distributions.
//!
//! All verification math runs in f64 probability space: the distributions
//! involved are small relative to the model, and the acceptance
//! thresholds of recursive rejection sampling are exact identities — f32
//! drift would show up directly as distribution-recovery error.
//!
//! # Allocation discipline and bit-exactness
//!
//! Every hot-path operation has an `_into` / in-place form writing into
//! caller-owned buffers, so a steady-state decode round performs zero
//! heap allocations (see `rust/README.md` §Hot path). Selection is
//! *partial*: [`gumbel_top_k_into`] keeps a bounded min-heap (O(V + V
//! log k)) and [`nucleus_filter`] partitions via `select_nth_unstable`
//! instead of a full O(V log V) sort. Both are **bit-identical** to the
//! sort-based references in [`reference`] — same kept sets, same output
//! order (ties broken by index), same RNG draw order (one Gumbel per
//! unfiltered entry, ascending index) — which the property tests in
//! `tests/selection.rs` enforce. The RNG draw order is part of the API:
//! changing it silently re-randomizes every decoder.
//!
//! # Kernel layer
//!
//! The float inner loops live in [`kernels`]: vectorizable `exp` / `ln`
//! replacements for the libm calls that dominated per-round cost, plus
//! chunked reductions for the softmax folds. Two numeric regimes,
//! documented per kernel and in `rust/README.md` §Kernel numerics:
//!
//! * **bit-exact vs [`reference`]** — everything feeding kept-set
//!   selection or the RNG stream ([`gumbel_top_k_into`],
//!   [`nucleus_filter`], [`gumbel_max`], the beam's offer path). The
//!   optimized and reference forms share one Gumbel transform
//!   ([`kernels::gumbel_from_uniform`]) and one serial nucleus mass
//!   loop, so equality holds to the bit and the RNG advances
//!   identically.
//! * **ULP-contracted** — pure normalization kernels where the win comes
//!   from reassociation ([`log_normalize`]'s partition sum,
//!   [`residual_in_place`]'s mass fold) or from the polynomial `exp`
//!   ([`LogProbs::probs_into`]). Values move by ULPs relative to the
//!   serial libm forms; the 50k-draw statistical gates in
//!   `tests/conformance.rs` pin the resulting distributions.

use std::cmp::Ordering;

use crate::util::Rng;

pub mod kernels;
pub mod reference;

pub const NEG_INF: f64 = f64::NEG_INFINITY;

/// A processed, normalized categorical distribution in log space.
/// Filtered-out tokens carry `-inf` (paper Alg. 4 line 6: filtered tokens
/// are excluded from Gumbel-Top-k and from residuals).
#[derive(Debug, Clone, Default)]
pub struct LogProbs(pub Vec<f64>);

impl LogProbs {
    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Probabilities (-inf -> 0). ULP contract: uses the polynomial
    /// [`kernels::exp`] (~1 ULP vs libm).
    pub fn probs(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.probs_into(&mut out);
        out
    }

    /// [`LogProbs::probs`] into a caller-owned buffer (cleared first).
    pub fn probs_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.0.iter().map(|&l| kernels::exp(l)));
    }
}

/// Index scratch for the partial-selection nucleus filter, reusable
/// across calls (capacity sticks at the vocab size).
#[derive(Debug, Default)]
pub struct SelectScratch {
    idx: Vec<u32>,
}

/// Probability-space scratch for one verification-rule invocation:
/// target (`q`), draft (`p`) and an auxiliary buffer (K-SEQ residual).
#[derive(Debug, Default)]
pub struct VerifyScratch {
    pub q: Vec<f64>,
    pub p: Vec<f64>,
    pub aux: Vec<f64>,
}

/// Convert raw model logits to a processed log-distribution:
/// logits/temperature -> log_softmax -> nucleus(top_p) -> renormalize.
pub fn process_logits(logits: &[f32], temperature: f32, top_p: f32) -> LogProbs {
    let mut sel = SelectScratch::default();
    let mut out = Vec::new();
    process_logits_into(logits, temperature, top_p, &mut sel, &mut out);
    LogProbs(out)
}

/// [`process_logits`] into a caller-owned buffer (cleared first), using
/// `sel` as the nucleus-selection scratch. Allocation-free once both
/// buffers are warm.
pub fn process_logits_into(
    logits: &[f32],
    temperature: f32,
    top_p: f32,
    sel: &mut SelectScratch,
    out: &mut Vec<f64>,
) {
    assert!(temperature > 0.0, "temperature must be > 0 (greedy not supported)");
    let inv_t = 1.0 / temperature as f64;
    out.clear();
    out.extend(logits.iter().map(|&x| x as f64 * inv_t));
    log_normalize(out);
    if top_p < 1.0 {
        nucleus_filter(out, top_p as f64, sel);
        log_normalize(out);
    }
}

/// In-place log-softmax (stable). `-inf` entries stay `-inf`.
///
/// ULP contract: the max fold is exactly the serial fold (see
/// [`kernels::max`]); the partition sum is chunked + polynomial-`exp`
/// ([`kernels::sum_exp_shifted`]), so the normalizer moves by ULPs
/// relative to the serial libm form. `z.ln()` stays on libm — one scalar
/// call per vocab row is not worth a contract deviation.
pub fn log_normalize(lp: &mut [f64]) {
    let m = kernels::max(lp);
    if m == NEG_INF {
        return; // fully masked; caller's bug, keep as-is
    }
    let z = kernels::sum_exp_shifted(lp, m);
    let lz = m + z.ln();
    kernels::sub_from_unfiltered(lp, lz);
}

/// Descending-value order with ascending-index tie-break: the total
/// order every selection routine here ranks by. `total_cmp` keeps NaN
/// logits (degenerate upstream distributions) deterministic instead of
/// panicking mid-round.
#[inline]
pub(crate) fn rank_desc(value_a: f64, idx_a: usize, value_b: f64, idx_b: usize) -> Ordering {
    value_b.total_cmp(&value_a).then(idx_a.cmp(&idx_b))
}

/// Nucleus filter: keep the smallest prob-sorted prefix with mass >=
/// top_p, set the rest to -inf. Ties broken by index for determinism.
///
/// Partial selection: exponentially grows a candidate prefix (32, 128,
/// ...) and partitions with `select_nth_unstable` — O(V + keep·log keep)
/// instead of a full sort — while accumulating mass in exactly the
/// reference's order, so the kept set is byte-identical to
/// [`reference::nucleus_filter`]. The mass loop stays on libm `exp`
/// (shared with the reference): it is serial with a data-dependent early
/// exit, and the kept-set decision must not move by a ULP.
pub fn nucleus_filter(lp: &mut [f64], top_p: f64, sel: &mut SelectScratch) {
    let n = lp.len();
    if n == 0 {
        return;
    }
    let mut k = 32.min(n);
    loop {
        let idx = &mut sel.idx;
        idx.clear();
        idx.extend(0..n as u32);
        let cmp = |a: &u32, b: &u32| {
            rank_desc(lp[*a as usize], *a as usize, lp[*b as usize], *b as usize)
        };
        if k < n {
            idx.select_nth_unstable_by(k - 1, cmp);
            idx[..k].sort_unstable_by(cmp);
        } else {
            idx.sort_unstable_by(cmp);
        }
        let mut mass = 0.0;
        let mut keep = None;
        for (rank, &i) in idx[..k].iter().enumerate() {
            mass += lp[i as usize].exp();
            if mass >= top_p {
                keep = Some(rank + 1);
                break;
            }
        }
        match keep {
            Some(keep) => {
                // everything outside the kept prefix (the rest of the
                // sorted prefix + the unsorted partition tail) is masked
                for &i in &idx[keep..] {
                    lp[i as usize] = NEG_INF;
                }
                return;
            }
            // mass never reached top_p (degenerate / NaN): keep all,
            // matching the reference's `keep = len` fallthrough
            None if k >= n => return,
            None => k = (k * 4).min(n),
        }
    }
}

/// Standard Gumbel(0,1) sample, via the shared vectorizable transform
/// ([`kernels::gumbel_from_uniform`]) so scalar and batched draws are
/// bit-identical.
pub fn gumbel(rng: &mut Rng) -> f64 {
    kernels::gumbel_from_uniform(rng.gen_f64_open())
}

/// Gumbel-max trick: sample an index from the categorical `lp` directly
/// in log space — argmax of `lp[i] + Gumbel(0,1)` — without
/// materializing probabilities. One Gumbel per unfiltered entry, drawn
/// in ascending index order; `-inf` entries draw nothing and never win.
/// `None` when every entry is filtered. Ties (measure-zero) keep the
/// lowest index, matching [`gumbel_top_k_into`] with k = 1.
pub fn gumbel_max(lp: &[f64], rng: &mut Rng) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &l) in lp.iter().enumerate() {
        if l == NEG_INF {
            continue;
        }
        let g = l + gumbel(rng);
        let better = match best {
            None => true,
            Some((bi, bg)) => rank_desc(g, i, bg, bi) == Ordering::Less,
        };
        if better {
            best = Some((i, g));
        }
    }
    best.map(|(i, _)| i)
}

/// Gumbel-Top-k trick (Vieira 2014): returns up to `k` indices sampled
/// *without replacement* from the categorical `lp`, in decreasing order
/// of perturbed log-prob, together with the perturbed values. `-inf`
/// entries are never returned (paper Alg. 4 line 6).
pub fn gumbel_top_k(lp: &LogProbs, k: usize, rng: &mut Rng) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    gumbel_top_k_into(lp, k, rng, &mut out);
    out
}

/// Offer `cand` to a bounded worst-at-root heap of capacity `cap`:
/// push while under capacity, otherwise replace the root when it ranks
/// strictly below `cand`. `worse(a, b)` must be a strict total order
/// ("a ranks below b") — the heap invariant is that every parent is
/// worse than its children, so one root comparison decides replacement.
/// The single selection kernel behind [`gumbel_top_k_into`] and
/// `StochasticBeam`'s global top-W: its tie-breaking is load-bearing
/// for the bit-exactness contract, so there is exactly one copy.
pub fn bounded_heap_offer<T>(
    heap: &mut Vec<T>,
    cap: usize,
    cand: T,
    worse: impl Fn(&T, &T) -> bool,
) {
    if cap == 0 {
        return;
    }
    if heap.len() < cap {
        heap.push(cand);
        let mut c = heap.len() - 1;
        while c > 0 {
            let parent = (c - 1) / 2;
            if worse(&heap[c], &heap[parent]) {
                heap.swap(c, parent);
                c = parent;
            } else {
                break;
            }
        }
    } else if worse(&heap[0], &cand) {
        heap[0] = cand;
        let mut c = 0;
        loop {
            let (l, r) = (2 * c + 1, 2 * c + 2);
            let mut worst = c;
            if l < heap.len() && worse(&heap[l], &heap[worst]) {
                worst = l;
            }
            if r < heap.len() && worse(&heap[r], &heap[worst]) {
                worst = r;
            }
            if worst == c {
                break;
            }
            heap.swap(c, worst);
            c = worst;
        }
    }
}

/// [`gumbel_top_k`] into a caller-owned buffer via a bounded min-heap:
/// O(V + V log k) instead of the reference's O(V log V) full sort, with
/// byte-identical output (same values, order, ties and RNG stream —
/// property-tested against [`reference::gumbel_top_k`]).
///
/// The Gumbel perturbation is batched: uniforms are drawn serially (one
/// per unfiltered entry, ascending index — the RNG-order contract),
/// staged in a thread-local buffer, and pushed through the double-log
/// transform as one vectorizable slice map before the sequential heap
/// pass. Per-element values are bit-identical to the reference's scalar
/// draw-transform-offer loop because both run the same pure transform.
pub fn gumbel_top_k_into(lp: &LogProbs, k: usize, rng: &mut Rng, out: &mut Vec<(usize, f64)>) {
    out.clear();
    let worse =
        |a: &(usize, f64), b: &(usize, f64)| rank_desc(a.1, a.0, b.1, b.0) == Ordering::Greater;
    kernels::with_uniform_scratch(|us| {
        us.clear();
        for &l in &lp.0 {
            if l != NEG_INF {
                // the draw happens even when k == 0: RNG order is part
                // of the API
                us.push(rng.gen_f64_open());
            }
        }
        kernels::gumbel_map_in_place(us);
        let mut j = 0;
        for (i, &l) in lp.0.iter().enumerate() {
            if l == NEG_INF {
                continue;
            }
            let cand = (i, l + us[j]);
            j += 1;
            bounded_heap_offer(out, k, cand, worse);
        }
    });
    out.sort_unstable_by(|a, b| rank_desc(a.1, a.0, b.1, b.0));
}

/// Numerically-stable truncated Gumbel (Kool et al. 2019, App. B.3):
/// given the parent's truncated value `u`, the child perturbed values
/// `phi_tilde` and their max `z`, returns values distributed as the
/// perturbed ones conditioned on max == u:
///   g_hat = -log(exp(-u) - exp(-z) + exp(-phi))
pub fn truncated_gumbel(u: f64, z: f64, phi_tilde: &[f64]) -> Vec<f64> {
    let mut out = Vec::new();
    truncated_gumbel_into(u, z, phi_tilde, &mut out);
    out
}

/// [`truncated_gumbel`] into a caller-owned buffer (cleared first).
pub fn truncated_gumbel_into(u: f64, z: f64, phi_tilde: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.extend(phi_tilde.iter().map(|&g| truncated_gumbel_one(u, z, g)));
}

/// One element of the truncated-Gumbel map — the single shared formula
/// (the vector form and the beam's streaming form must not drift).
/// Deliberately NOT ported to the polynomial kernels: the formula is
/// branch-heavy, numerically delicate (`ln_1m_exp` near 0), and runs
/// once per *candidate*, not per vocab entry — libm accuracy is worth
/// more here than lane throughput.
#[inline]
pub fn truncated_gumbel_one(u: f64, z: f64, g: f64) -> f64 {
    if g == NEG_INF {
        return NEG_INF;
    }
    let v = u - g + ln_1m_exp(g - z);
    u - v.max(0.0) - ln_1p_exp(-v.abs())
}

/// log(1 - exp(x)) for x <= 0, stable near 0 and -inf.
fn ln_1m_exp(x: f64) -> f64 {
    if x >= 0.0 {
        // x == 0 => log(0) = -inf (happens exactly at the argmax child)
        return NEG_INF;
    }
    if x > -f64::ln(2.0) {
        (-f64::exp_m1(x)).ln()
    } else {
        f64::ln_1p(-x.exp())
    }
}

/// log(1 + exp(x)), stable.
fn ln_1p_exp(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        f64::ln_1p(x.exp())
    }
}

/// Sample an index from probabilities `p` (need not be normalized).
/// ULP contract: the total uses the chunked [`kernels::sum`]; the
/// subtractive scan itself is serial (data-dependent early exit).
pub fn sample_categorical(p: &[f64], rng: &mut Rng) -> usize {
    let total = kernels::sum(p);
    assert!(total > 0.0, "cannot sample from zero distribution");
    let mut u: f64 = rng.gen_f64() * total;
    for (i, &pi) in p.iter().enumerate() {
        u -= pi;
        if u <= 0.0 {
            return i;
        }
    }
    // floating point slack: return the last strictly-positive entry
    p.iter().rposition(|&x| x > 0.0).expect("nonzero entry exists")
}

/// Residual distribution Norm[[q - p]^+] in probability space. Returns
/// None when q <= p pointwise (residual mass ~ 0), which happens when the
/// draft already covers the target.
pub fn residual(q: &[f64], p: &[f64]) -> Option<Vec<f64>> {
    let mut r = q.to_vec();
    if residual_in_place(&mut r, p) {
        Some(r)
    } else {
        None
    }
}

/// [`residual`] computed in place: on success `q` becomes the normalized
/// residual and `true` is returned; when the residual mass vanishes `q`
/// is left untouched and `false` is returned (same arithmetic, same
/// accumulation order — bit-identical to the allocating form). ULP
/// contract: the mass fold is the chunked [`kernels::sum_relu_diff`];
/// the normalizing division is elementwise (vectorizes as-is).
pub fn residual_in_place(q: &mut [f64], p: &[f64]) -> bool {
    let z = kernels::sum_relu_diff(q, p);
    if z <= 1e-300 {
        return false;
    }
    for (qi, &pi) in q.iter_mut().zip(p) {
        *qi = (*qi - pi).max(0.0) / z;
    }
    true
}

/// Total-variation distance between two probability vectors.
pub fn tv_distance(a: &[f64], b: &[f64]) -> f64 {
    0.5 * a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn process_logits_normalizes() {
        let lp = process_logits(&[1.0, 2.0, 3.0], 0.7, 1.0);
        let s: f64 = lp.probs().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn temperature_sharpens() {
        let hot = process_logits(&[1.0, 2.0], 1.0, 1.0);
        let cold = process_logits(&[1.0, 2.0], 0.25, 1.0);
        assert!(cold.probs()[1] > hot.probs()[1]);
    }

    #[test]
    fn nucleus_drops_tail_and_renormalizes() {
        // probs ~ [0.6, 0.3, 0.1]; top_p = 0.8 keeps two tokens
        let logits = [0.6f32.ln(), 0.3f32.ln(), 0.1f32.ln()];
        let lp = process_logits(&logits, 1.0, 0.8);
        assert_eq!(lp.0[2], NEG_INF);
        let p = lp.probs();
        assert!((p[0] + p[1] - 1.0).abs() < 1e-9);
        assert!((p[0] / p[1] - 2.0).abs() < 1e-6);
    }

    /// SATELLITE regression: a NaN logit must not panic either selection
    /// routine (the old `partial_cmp().unwrap()` did) and must behave
    /// identically in optimized and reference forms.
    #[test]
    fn nan_logits_do_not_panic() {
        // NaN survives temperature scaling and log_normalize untouched
        let lp = LogProbs(vec![-0.5, f64::NAN, -1.5, NEG_INF, -0.7]);
        let mut r1 = rng(11);
        let mut r2 = rng(11);
        let heap = gumbel_top_k(&lp, 3, &mut r1);
        let full = reference::gumbel_top_k(&lp, 3, &mut r2);
        assert_eq!(heap.len(), 3);
        for (a, b) in heap.iter().zip(&full) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        // NaN ranks first under total_cmp: deterministically selected
        assert_eq!(heap[0].0, 1);

        let mut a = vec![-0.5, f64::NAN, -1.5, -0.7];
        let mut b = a.clone();
        let mut sel = SelectScratch::default();
        nucleus_filter(&mut a, 0.9, &mut sel);
        reference::nucleus_filter(&mut b, 0.9);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // NaN mass never reaches top_p: nothing gets filtered
        assert!(a.iter().all(|x| *x != NEG_INF));
    }

    #[test]
    fn gumbel_top_k_skips_filtered_and_orders() {
        let lp = LogProbs(vec![-0.5, NEG_INF, -1.5, -0.7]);
        let mut r = rng(0);
        for _ in 0..50 {
            let out = gumbel_top_k(&lp, 3, &mut r);
            assert_eq!(out.len(), 3);
            assert!(out.iter().all(|&(i, _)| i != 1));
            assert!(out.windows(2).all(|w| w[0].1 >= w[1].1));
        }
    }

    /// Gumbel-Top-1 must sample from the categorical itself.
    #[test]
    fn gumbel_top_one_matches_categorical() {
        let probs = [0.5, 0.2, 0.25, 0.05];
        let lp = LogProbs(probs.iter().map(|p| (*p as f64).ln()).collect());
        let mut r = rng(7);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[gumbel_top_k(&lp, 1, &mut r)[0].0] += 1;
        }
        for i in 0..4 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - probs[i]).abs() < 0.005, "{i}: {emp} vs {}", probs[i]);
        }
    }

    /// SATELLITE: log-space Gumbel-max sampling is distributionally
    /// equivalent to materializing probs + categorical sampling (the
    /// Chain / IidPaths expand rewrite relies on this).
    #[test]
    fn gumbel_max_matches_categorical() {
        let probs = [0.45, 0.05, 0.3, 0.2];
        let lp: Vec<f64> = probs.iter().map(|p| (*p as f64).ln()).collect();
        let mut r = rng(13);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[gumbel_max(&lp, &mut r).unwrap()] += 1;
        }
        for i in 0..4 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - probs[i]).abs() < 0.005, "{i}: {emp} vs {}", probs[i]);
        }
        // filtered entries never win; fully-masked input yields None
        let masked = vec![NEG_INF, -0.1, NEG_INF];
        for _ in 0..100 {
            assert_eq!(gumbel_max(&masked, &mut r), Some(1));
        }
        assert_eq!(gumbel_max(&[NEG_INF, NEG_INF], &mut r), None);
    }

    /// The first TWO Gumbel-Top-k outputs must follow sampling without
    /// replacement: P(first=i, second=j) = p_i * p_j / (1 - p_i).
    #[test]
    fn gumbel_top_two_is_without_replacement() {
        let probs = [0.5, 0.3, 0.2];
        let lp = LogProbs(probs.iter().map(|p| (*p as f64).ln()).collect());
        let mut r = rng(42);
        let n = 300_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..n {
            let out = gumbel_top_k(&lp, 2, &mut r);
            *counts.entry((out[0].0, out[1].0)).or_insert(0usize) += 1;
        }
        for i in 0..3 {
            for j in 0..3 {
                if i == j {
                    continue;
                }
                let expect = probs[i] * probs[j] / (1.0 - probs[i]);
                let emp = *counts.get(&(i, j)).unwrap_or(&0) as f64 / n as f64;
                assert!((emp - expect).abs() < 0.01, "({i},{j}): {emp} vs {expect}");
            }
        }
    }

    #[test]
    fn truncated_gumbel_bounded_and_monotone() {
        let phi = vec![-1.0, 0.5, -3.0, 0.2];
        let z = phi.iter().cloned().fold(NEG_INF, f64::max);
        let u = -0.3;
        let out = truncated_gumbel(u, z, &phi);
        for &o in &out {
            assert!(o <= u + 1e-12, "{o} > {u}");
        }
        // monotone in phi
        let mut pairs: Vec<(f64, f64)> = phi.iter().cloned().zip(out.iter().cloned()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(pairs.windows(2).all(|w| w[0].1 <= w[1].1));
        // the argmax child attains exactly u
        let imax = phi.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((out[imax] - u).abs() < 1e-9);
    }

    #[test]
    fn residual_basic() {
        let q = [0.5, 0.5];
        let p = [0.8, 0.2];
        let r = residual(&q, &p).unwrap();
        assert!((r[0] - 0.0).abs() < 1e-12);
        assert!((r[1] - 1.0).abs() < 1e-12);
        assert!(residual(&q, &q).is_none());
    }

    #[test]
    fn residual_in_place_matches_allocating() {
        let q = [0.4, 0.1, 0.3, 0.2];
        let p = [0.1, 0.4, 0.2, 0.3];
        let r = residual(&q, &p).unwrap();
        let mut q2 = q.to_vec();
        assert!(residual_in_place(&mut q2, &p));
        for (a, b) in r.iter().zip(&q2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // vanished residual leaves q untouched
        let mut q3 = q.to_vec();
        assert!(!residual_in_place(&mut q3, &q));
        assert_eq!(q3, q);
    }

    #[test]
    fn categorical_empirical() {
        let p = [0.1, 0.6, 0.3];
        let mut r = rng(3);
        let n = 100_000;
        let mut c = [0usize; 3];
        for _ in 0..n {
            c[sample_categorical(&p, &mut r)] += 1;
        }
        for i in 0..3 {
            assert!((c[i] as f64 / n as f64 - p[i]).abs() < 0.01);
        }
    }
}
