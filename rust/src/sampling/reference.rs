//! Sort-based reference implementations of the partial-selection
//! routines. These ARE the specification: the optimized forms in the
//! parent module must return byte-identical results (indices, values,
//! order, RNG stream position), enforced by `tests/selection.rs`. Kept
//! `pub` for those tests and for the hot-path bench's before/after
//! comparison.
//!
//! Both references draw their Gumbels through the same
//! [`gumbel`](super::gumbel) (and thus
//! [`kernels::gumbel_from_uniform`](super::kernels::gumbel_from_uniform))
//! as the optimized kernels, and accumulate nucleus mass with the same
//! libm `exp` in the same serial early-exit order — sharing the exact
//! arithmetic is what makes byte-identity achievable at all.

use super::*;

/// Full-sort Gumbel-Top-k (the pre-optimization implementation, with
/// the NaN-safe `total_cmp` + index tie-break comparator).
pub fn gumbel_top_k(lp: &LogProbs, k: usize, rng: &mut Rng) -> Vec<(usize, f64)> {
    let mut perturbed: Vec<(usize, f64)> = lp
        .0
        .iter()
        .enumerate()
        .filter(|(_, &l)| l != NEG_INF)
        .map(|(i, &l)| (i, l + gumbel(rng)))
        .collect();
    perturbed.sort_by(|a, b| rank_desc(a.1, a.0, b.1, b.0));
    perturbed.truncate(k);
    perturbed
}

/// Full-sort nucleus filter (the pre-optimization implementation,
/// with the NaN-safe comparator).
pub fn nucleus_filter(lp: &mut [f64], top_p: f64) {
    let mut idx: Vec<usize> = (0..lp.len()).collect();
    idx.sort_by(|&a, &b| rank_desc(lp[a], a, lp[b], b));
    let mut mass = 0.0;
    let mut keep = lp.len();
    for (rank, &i) in idx.iter().enumerate() {
        mass += lp[i].exp();
        if mass >= top_p {
            keep = rank + 1;
            break;
        }
    }
    for &i in &idx[keep..] {
        lp[i] = NEG_INF;
    }
}
