//! Vectorizable math kernels for the sampling hot path.
//!
//! Stable-Rust SIMD strategy (no nightly `std::simd`): every kernel is
//! either
//!
//! * an **elementwise map** ([`exp`], [`ln`], [`gumbel_from_uniform`],
//!   [`cos_2pi`]) written as straight-line, branch-light code that LLVM
//!   can inline and auto-vectorize — and that stays *bit-identical per
//!   element* whether or not the surrounding loop is vectorized, because
//!   IEEE-754 ops round the same in scalar and packed form and rustc
//!   never contracts `a * b + c` into an FMA; or
//! * a **chunked reduction** ([`max`], [`sum`], [`sum_exp_shifted`],
//!   [`sum_relu_diff`]) over [`LANES`] independent accumulators with a
//!   scalar tail. The accumulation order is fixed by the code (not by the
//!   target ISA), so results are deterministic across machines — but for
//!   float addition they differ from a sequential left fold by
//!   reassociation. That is the documented ULP contract (see
//!   `rust/README.md` §Kernel numerics): sums agree with the serial
//!   reference to ~`n/LANES` ULPs, maxima agree exactly (`f64::max` is
//!   associative over non-NaN values and NaN-ignoring in both forms).
//!
//! # Accuracy of the polynomial kernels
//!
//! [`exp`] and [`ln`] replace the libm calls that dominated the Gumbel
//! perturbation and softmax loops (two `ln` per vocab entry per draft
//! level for Gumbel-Top-k alone). Both were validated against a
//! bit-exact model over the full log-probability domain:
//!
//! * `exp`: Cody–Waite range reduction with the split-constant pair
//!   (`LN2_HI`, `LN2_LO`) and a degree-14 Taylor polynomial; observed
//!   worst-case relative error ~1 ULP on `[-700, 709.3]`. Contract
//!   deviations from libm, both irrelevant in log-prob space and covered
//!   by `tests/kernels.rs`: inputs below `-708` flush to `+0.0` (libm
//!   returns subnormals down to `-745`), and overflow to `+inf` begins
//!   at `~709.44` (libm at `~709.78`). Specials match libm: `exp(-inf)
//!   = 0` (masked tokens), `exp(NaN) = NaN`, `exp(+inf) = +inf`,
//!   `exp(0) = 1` exactly.
//! * `ln`: exponent/mantissa bit decomposition (subnormals pre-scaled by
//!   2^54), mantissa folded into `[1/sqrt(2), sqrt(2))`, atanh series
//!   `ln(m) = 2s(1 + s^2/3 + s^4/5 + ...)` with exact rational
//!   coefficients through `s^18/21`; observed worst-case relative error
//!   ~1.7 ULP including subnormals and the cancellation region near 1.
//!   Specials match libm: `ln(0) = -inf` (the `u = 1` Gumbel draw),
//!   `ln(x<0) = NaN`, `ln(+inf) = +inf`, `ln(NaN) = NaN`, `ln(1) = 0`
//!   exactly.
//!
//! The Gumbel transform `-ln(-ln(u))` is therefore a *different*
//! function from the libm-based one it replaced — perturbed values (and
//! hence sampled token streams) re-randomize, exactly like a seed bump.
//! What is preserved, and property-tested in `tests/selection.rs`, is
//! the contract that matters: the optimized selection kernels and
//! [`crate::sampling::reference`] share this one transform, so kept
//! sets, output order, perturbed values and RNG stream positions remain
//! byte-identical between them, and the 50k-draw statistical gates in
//! `tests/conformance.rs` pin the distributions themselves.

use std::cell::RefCell;

/// Accumulator count for the chunked reductions. Eight f64 lanes cover
/// one AVX-512 register or two AVX2 registers; on plain SSE2 the same
/// code compiles to four 2-wide partial sums. The value is part of the
/// numeric contract (it fixes the reduction tree), so it must not vary
/// by target.
pub const LANES: usize = 8;

/// High bits of ln(2) (fdlibm split: top 32 mantissa bits, exact when
/// multiplied by any |n| <= 2^20).
const LN2_HI: f64 = f64::from_bits(0x3FE6_2E42_FEE0_0000);
/// Low correction: ln(2) - LN2_HI to full precision (beyond f64's own
/// rounding of ln(2)), the fdlibm companion constant.
const LN2_LO: f64 = f64::from_bits(0x3DEA_39EF_3579_3C76);
/// 2^54, exact; rescales subnormals into the normal range for [`ln`].
const TWO54: f64 = 18014398509481984.0;

/// Vectorizable `e^x`: Cody–Waite reduction + degree-14 Taylor + exponent
/// bit-assembly. See the module docs for the accuracy/overflow contract.
#[inline(always)]
pub fn exp(x: f64) -> f64 {
    /// 1/k! for k = 0..=14 (exact integer factorials, one correctly
    /// rounded division each — no tuned magic constants).
    const C: [f64; 15] = [
        1.0,
        1.0,
        1.0 / 2.0,
        1.0 / 6.0,
        1.0 / 24.0,
        1.0 / 120.0,
        1.0 / 720.0,
        1.0 / 5040.0,
        1.0 / 40320.0,
        1.0 / 362_880.0,
        1.0 / 3_628_800.0,
        1.0 / 39_916_800.0,
        1.0 / 479_001_600.0,
        1.0 / 6_227_020_800.0,
        1.0 / 87_178_291_200.0,
    ];
    // upper clamp keeps n <= 1024 (the +inf exponent) so the bit-assembly
    // below never overflows; NaN fails the compare and flows through
    let xc = if x > 710.0 { 710.0 } else { x };
    // n = round(x / ln 2), in floor form (vectorizes as a single
    // round-toward-negative; `round()` would not)
    let mut n = (xc * std::f64::consts::LOG2_E + 0.5).floor();
    if n < -1022.0 {
        n = -1022.0; // lower clamp: result is overridden to 0 below
    }
    let n_i = n as i64; // in [-1022, 1024]; NaN saturates to 0
    // r = x - n*ln2 via the split constant: n*LN2_HI is exact (31-bit
    // mantissa x 11-bit n), the first subtraction is exact by Sterbenz,
    // so r carries only the LN2_LO rounding; |r| <= ln(2)/2 + eps
    let r = (xc - n * LN2_HI) - n * LN2_LO;
    let mut p = C[14];
    for &c in C[..14].iter().rev() {
        p = p * r + c;
    }
    // 2^n by exponent assembly; n_i + 1023 is in [1, 2047], where 2047
    // encodes +inf (the x > ~709.44 overflow path)
    let scale = f64::from_bits(((n_i + 1023) as u64) << 52);
    let y = p * scale;
    // flush-to-zero contract below -708; also maps -inf -> 0. NaN fails
    // the compare and keeps the poisoned core result (NaN).
    if x < -708.0 {
        0.0
    } else {
        y
    }
}

/// Vectorizable natural log: bit decomposition + atanh series. See the
/// module docs for the accuracy contract.
#[inline(always)]
pub fn ln(x: f64) -> f64 {
    /// 1/(2k+3) for k = 0..=9: the atanh-series coefficients, exact
    /// rationals (one correctly rounded division each).
    const C: [f64; 10] = [
        1.0 / 3.0,
        1.0 / 5.0,
        1.0 / 7.0,
        1.0 / 9.0,
        1.0 / 11.0,
        1.0 / 13.0,
        1.0 / 15.0,
        1.0 / 17.0,
        1.0 / 19.0,
        1.0 / 21.0,
    ];
    // subnormals: rescale by 2^54 (exact) so the exponent field is live
    let small = x < f64::MIN_POSITIVE; // NaN fails the compare
    let xs = if small { x * TWO54 } else { x };
    let ebias = if small { 54.0 } else { 0.0 };
    let bits = xs.to_bits();
    let e_raw = ((bits >> 52) & 0x7ff) as i64;
    // mantissa re-based into [0.5, 1), then folded into
    // [1/sqrt(2), sqrt(2)) so |s| <= 3 - 2*sqrt(2) below
    let m0 = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | 0x3FE0_0000_0000_0000);
    let fold = m0 < std::f64::consts::FRAC_1_SQRT_2;
    let m = if fold { m0 * 2.0 } else { m0 };
    let e = (e_raw - 1022) as f64 - ebias - if fold { 1.0 } else { 0.0 };
    // atanh form: ln(m) = 2s * (1 + s^2/3 + s^4/5 + ...) with
    // s = (m-1)/(m+1); m-1 is exact by Sterbenz, so s carries only the
    // division rounding and relative accuracy survives m -> 1
    let s = (m - 1.0) / (m + 1.0);
    let z = s * s;
    let mut p = C[9];
    for &c in C[..9].iter().rev() {
        p = p * z + c;
    }
    let lnm = 2.0 * s + 2.0 * s * z * p;
    let res = e * LN2_HI + (lnm + e * LN2_LO);
    // specials, resolved after the straight-line core (the core computes
    // garbage for them; these selects override it)
    if x == 0.0 {
        return f64::NEG_INFINITY;
    }
    if x < 0.0 {
        return f64::NAN;
    }
    if !x.is_finite() {
        return x; // +inf -> +inf, NaN -> NaN
    }
    res
}

/// The standard-Gumbel transform `-ln(-ln(u))` for `u` in (0, 1]. The
/// single shared implementation behind both the optimized selection
/// kernels and [`crate::sampling::reference`] — sharing it is what keeps
/// them bit-identical. `u = 1` (drawn with probability 2^-53) maps to
/// `-ln(0) = +inf`, matching the libm chain.
#[inline(always)]
pub fn gumbel_from_uniform(u: f64) -> f64 {
    -ln(-ln(u))
}

/// Apply [`gumbel_from_uniform`] elementwise, in place. A pure map with
/// no cross-element dependency: per-element results are bit-identical to
/// the scalar call whether or not LLVM vectorizes the loop.
pub fn gumbel_map_in_place(us: &mut [f64]) {
    for u in us.iter_mut() {
        *u = gumbel_from_uniform(*u);
    }
}

/// `cos(2*pi*u)` via range reduction in turns and a degree-9 minimax-free
/// Taylor polynomial in `(2*pi*t)^2`; absolute error <= ~4e-15 (validated
/// against libm over [0, 1]). Used by the sim substrate's Box–Muller
/// transform, where the `u` argument is a hash-derived uniform — the
/// turns form avoids the `2*pi*u` product-then-reduce of libm `cos` and
/// vectorizes cleanly.
#[inline(always)]
pub fn cos_2pi(u: f64) -> f64 {
    /// (-1)^k (2*pi)^(2k) / (2k)! for k = 0..=9, rounded from 60-digit
    /// decimal evaluation.
    const C: [f64; 10] = [
        1.0,
        -19.739208802178716,
        64.9393940226683,
        -85.45681720669373,
        60.24464137187666,
        -26.4262567833744,
        7.903536371318469,
        -1.714390711088672,
        0.28200596845579123,
        -0.03638284114254567,
    ];
    // v = u mod 1, centered in [-0.5, 0.5]; cosine is even, fold to w
    let v = u - (u + 0.5).floor();
    let w = v.abs();
    // fold the second quarter-turn: cos(2*pi*w) = -cos(2*pi*(1/2 - w)),
    // leaving t in [0, 1/4] (poly argument <= pi/2)
    let (t, sign) = if w > 0.25 { (0.5 - w, -1.0) } else { (w, 1.0) };
    let z = t * t;
    let mut p = C[9];
    for &c in C[..9].iter().rev() {
        p = p * z + c;
    }
    sign * p
}

/// Chunked NaN-ignoring maximum — same semantics as
/// `iter().fold(NEG_INFINITY, f64::max)`: NaN entries are skipped, empty
/// or all-NaN input yields `-inf`. `f64::max` is associative and
/// commutative over the values that can win, so unlike the float sums
/// this reduction is *exactly* equal to the sequential fold.
pub fn max(xs: &[f64]) -> f64 {
    let mut acc = [f64::NEG_INFINITY; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (a, &x) in acc.iter_mut().zip(c) {
            *a = a.max(x);
        }
    }
    let mut m = f64::NEG_INFINITY;
    for &x in chunks.remainder() {
        m = m.max(x);
    }
    for a in acc {
        m = m.max(a);
    }
    m
}

/// Chunked sum with [`LANES`] accumulators. Deterministic accumulation
/// order (lane-strided body, then the scalar tail, then lanes 0..LANES
/// left to right) but reassociated relative to a serial fold — ULP
/// contract, see module docs. NaN/inf propagate as in the serial sum.
pub fn sum(xs: &[f64]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (a, &x) in acc.iter_mut().zip(c) {
            *a += x;
        }
    }
    let mut s = 0.0;
    for &x in chunks.remainder() {
        s += x;
    }
    for a in acc {
        s += a;
    }
    s
}

/// `sum(exp(x - shift))` fused and chunked: the log-softmax partition
/// function. Same accumulation-order contract as [`sum`]; a NaN entry
/// poisons the result exactly as in the serial form.
pub fn sum_exp_shifted(xs: &[f64], shift: f64) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (a, &x) in acc.iter_mut().zip(c) {
            *a += exp(x - shift);
        }
    }
    let mut s = 0.0;
    for &x in chunks.remainder() {
        s += exp(x - shift);
    }
    for a in acc {
        s += a;
    }
    s
}

/// `sum(max(q - p, 0))` fused and chunked: the residual mass of
/// recursive rejection sampling. `f64::max(x, 0.0)` maps NaN diffs to
/// `0.0`, matching the serial form it replaced.
pub fn sum_relu_diff(q: &[f64], p: &[f64]) -> f64 {
    let n = q.len().min(p.len());
    let (q, p) = (&q[..n], &p[..n]);
    let mut acc = [0.0f64; LANES];
    let mut qc = q.chunks_exact(LANES);
    let mut pc = p.chunks_exact(LANES);
    for (cq, cp) in qc.by_ref().zip(pc.by_ref()) {
        for ((a, &qi), &pi) in acc.iter_mut().zip(cq).zip(cp) {
            *a += (qi - pi).max(0.0);
        }
    }
    let mut s = 0.0;
    for (&qi, &pi) in qc.remainder().iter().zip(pc.remainder()) {
        s += (qi - pi).max(0.0);
    }
    for a in acc {
        s += a;
    }
    s
}

/// `lp[i] -= lz` for unfiltered entries; `-inf` stays `-inf` and NaN
/// stays NaN, exactly as the branchy serial loop it replaced (the select
/// form if-converts and vectorizes).
pub fn sub_from_unfiltered(lp: &mut [f64], lz: f64) {
    for l in lp.iter_mut() {
        let v = *l;
        *l = if v == f64::NEG_INFINITY { v } else { v - lz };
    }
}

thread_local! {
    /// Staging buffer for the batched Gumbel transform: uniform draws are
    /// inherently serial (the RNG is a stream with a draw-order contract)
    /// but the double-log transform is elementwise, so callers stage the
    /// uniforms here and map them as a slice. Thread-local because
    /// `gumbel_top_k_into`'s public signature carries no scratch;
    /// capacity sticks at the vocab size after warm-up, so the
    /// steady-state decode round stays allocation-free (enforced by the
    /// hotpath bench's 0-alloc gate).
    static UNIFORM_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's uniform staging buffer. Not reentrant —
/// callers must not nest (the selection kernels never do).
pub fn with_uniform_scratch<R>(f: impl FnOnce(&mut Vec<f64>) -> R) -> R {
    UNIFORM_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}
