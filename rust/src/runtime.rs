//! PJRT runtime wrapper: load HLO-text artifacts, compile once, execute
//! from the serving hot path.
//!
//! HLO *text* is the interchange format (not serialized HloModuleProto):
//! jax >= 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file`
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README.md
//! and DESIGN.md §1).
//!
//! Execution goes through `execute_b` with caller-owned device buffers:
//! the crate's literal-based `execute` path leaks every input buffer per
//! call (the C++ shim `release()`s them and never frees), and re-uploads
//! the full weight set each call. Owning the buffers fixes the leak and
//! lets weights live on device across the whole serving session
//! (EXPERIMENTS.md §Perf).
//!
//! The `xla` binding is distributed from source and absent from default
//! builds; the real implementation is gated behind `--cfg pjrt_runtime`
//! (see Cargo.toml). Without it, the stub below keeps the same API and
//! returns a descriptive error from `Runtime::cpu()`, so the sim
//! substrate, the coordinator and the whole test suite work unchanged.

#[cfg(pjrt_runtime)]
use std::path::Path;

#[cfg(pjrt_runtime)]
use anyhow::{anyhow, Context, Result};

#[cfg(not(pjrt_runtime))]
use anyhow::{bail, Result};

/// Stub PJRT client used when the crate is built without
/// `--cfg pjrt_runtime` (no `xla` binding available).
#[cfg(not(pjrt_runtime))]
pub struct Runtime {
    #[allow(dead_code)]
    _private: (),
}

#[cfg(not(pjrt_runtime))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: this build has no `xla` binding. \
             Rebuild with RUSTFLAGS=\"--cfg pjrt_runtime\" and the xla \
             dependency (see rust/Cargo.toml), or use the sim substrate \
             (--sim / SimLm)."
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn clone_handle(&self) -> Runtime {
        Runtime { _private: () }
    }
}

/// A shared CPU PJRT client.
#[cfg(pjrt_runtime)]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(pjrt_runtime)]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Upload an f32 tensor to the device.
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e}"))
    }

    /// Upload an i32 tensor to the device.
    pub fn buffer_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e}"))
    }

    /// Upload a literal (e.g. a cache tensor fetched from a previous
    /// execution) to the device.
    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("upload literal: {e}"))
    }

    /// A second handle to the same client (refcounted internally).
    pub fn clone_handle(&self) -> Runtime {
        Runtime { client: self.client.clone() }
    }
}

/// A compiled step executable. Thin wrapper adding tuple unpacking and
/// error context.
#[cfg(pjrt_runtime)]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(pjrt_runtime)]
impl Executable {
    /// Execute with device-buffer inputs; returns the flattened tuple
    /// elements as host literals (the AOT step lowers with
    /// return_tuple=True, and this PJRT binding exposes tuple outputs
    /// only as a single buffer — splitting requires the host copy).
    pub fn run_b<L: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute_b::<L>(inputs).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("fetch result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple result: {e}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
#[cfg(pjrt_runtime)]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        anyhow::bail!("literal_f32 shape {:?} != data len {}", dims, data.len());
    }
    xla::Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an i32 literal of the given shape from a flat slice.
#[cfg(pjrt_runtime)]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        anyhow::bail!("literal_i32 shape {:?} != data len {}", dims, data.len());
    }
    xla::Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("reshape: {e}"))
}
