//! Configuration system: artifact manifest (produced by the AOT step),
//! decode configuration (which algorithm, which tree), and engine knobs.
//!
//! Decoder configs use compact spec strings mirroring the paper's tables:
//! `ar`, `sd:4`, `spectr:3x7`, `rsd-c:2-2-1`, `rsd-s:6x5` — parsed by
//! [`DecoderConfig::parse`], printed by [`DecoderConfig::label`].
//!
//! `adaptive:B` (optionally `adaptive:B:rsd-c` / `adaptive:B:rsd-s`)
//! selects the online controller of [`crate::adaptive`]: the tree shape
//! is re-chosen every speculative round from observed acceptance rates,
//! subject to the hard per-round node budget `B` (Exp2's target
//! computational budget).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Per-model entry of `artifacts/manifest.json` (written by aot.py).
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub d_head: usize,
    pub s_tile: usize,
    pub cache_len: usize,
    pub batch: usize,
    pub params: usize,
    pub hlo: String,
    /// Tile-width variants: (s_tile, hlo file), ascending.
    pub tiles: Vec<(usize, String)>,
    pub tensors: String,
    pub input_order: Vec<String>,
}

impl ModelManifest {
    fn from_json(j: &Json) -> Result<Self> {
        let input_order = j
            .get("input_order")
            .and_then(Json::as_arr)
            .context("input_order missing")?
            .iter()
            .map(|x| x.as_str().map(str::to_string).context("input_order entry"))
            .collect::<Result<Vec<_>>>()?;
        let mut tiles: Vec<(usize, String)> = match j.get("tiles").and_then(Json::as_obj) {
            Some(m) => m
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.parse::<usize>().context("tile key")?,
                        v.str_field("hlo")?.to_string(),
                    ))
                })
                .collect::<Result<_>>()?,
            None => vec![],
        };
        tiles.sort();
        Ok(Self {
            name: j.str_field("name")?.to_string(),
            vocab: j.usize_field("vocab")?,
            n_layers: j.usize_field("n_layers")?,
            d_model: j.usize_field("d_model")?,
            n_heads: j.usize_field("n_heads")?,
            d_ff: j.usize_field("d_ff")?,
            d_head: j.usize_field("d_head")?,
            s_tile: j.usize_field("s_tile")?,
            cache_len: j.usize_field("cache_len")?,
            batch: j.usize_field("batch")?,
            params: j.usize_field("params")?,
            hlo: j.str_field("hlo")?.to_string(),
            tiles,
            tensors: j.str_field("tensors")?.to_string(),
            input_order,
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<(Self, PathBuf)> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models").and_then(Json::as_obj).context("models missing")? {
            models.insert(name.clone(), ModelManifest::from_json(mj)?);
        }
        Ok((Manifest { models }, dir))
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }
}

/// Logits post-processing, matching the paper's experimental setup
/// (temperature 0.3 for WMT/XSum analogues; 1.0 + top-p 0.95 for Dolly),
/// plus the request's stop-token set.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingConfig {
    pub temperature: f32,
    /// Nucleus filtering: keep the smallest prefix of tokens (by prob)
    /// whose mass reaches `top_p`; 1.0 disables.
    pub top_p: f32,
    /// Stop tokens: generation finishes at the first generated occurrence
    /// of any of these token ids. The stop token itself is not emitted,
    /// and accepted draft tokens after it are dropped. Empty = disabled.
    pub stop: Vec<u32>,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        Self::new(1.0, 1.0)
    }
}

impl SamplingConfig {
    pub fn new(temperature: f32, top_p: f32) -> Self {
        Self { temperature, top_p, stop: Vec::new() }
    }

    /// Builder-style stop-token set.
    pub fn with_stop(mut self, stop: Vec<u32>) -> Self {
        self.stop = stop;
        self
    }

    pub fn is_stop(&self, token: u32) -> bool {
        self.stop.contains(&token)
    }
}

/// Per-request sampling overrides: fields left `None` inherit the
/// engine's configured [`SamplingConfig`], so a request setting only
/// `"stop"` keeps the fleet-wide temperature (and vice versa).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SamplingPatch {
    pub temperature: Option<f32>,
    pub top_p: Option<f32>,
    pub stop: Option<Vec<u32>>,
}

impl SamplingPatch {
    pub fn is_empty(&self) -> bool {
        self.temperature.is_none() && self.top_p.is_none() && self.stop.is_none()
    }

    /// Resolve against the engine's configured defaults.
    pub fn apply(&self, base: &SamplingConfig) -> SamplingConfig {
        SamplingConfig {
            temperature: self.temperature.unwrap_or(base.temperature),
            top_p: self.top_p.unwrap_or(base.top_p),
            stop: self.stop.clone().unwrap_or_else(|| base.stop.clone()),
        }
    }
}

/// Parse a JSON `"stop"` array of token ids — shared by the wire
/// protocol ([`crate::coordinator::server`]) and the engine-config file,
/// so validation can never diverge between the two. Rejects entries that
/// are not non-negative integers in u32 range (a lossy cast would
/// silently turn `-1` into token 0 or `10.7` into `10`).
pub fn parse_stop_tokens(arr: &[Json]) -> Result<Vec<u32>> {
    arr.iter()
        .map(|x| {
            let f = x.as_f64().context("stop entries must be token ids")?;
            if f.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&f) {
                bail!("stop entry {f} is not a valid token id");
            }
            Ok(f as u32)
        })
        .collect()
}

/// Deepest draft tree the adaptive allocator will consider. Acceptance
/// probabilities compound multiplicatively with depth, so expected gains
/// beyond this depth are negligible for any realistic draft.
pub const ADAPTIVE_MAX_DEPTH: usize = 8;

/// Largest accepted adaptive node budget. Matches the allocator's shape
/// search cap ([`crate::adaptive::allocator::MAX_SEARCH_BUDGET`]), so a
/// request's admission weight (its declared budget) never exceeds what
/// any round can actually use.
pub const ADAPTIVE_MAX_BUDGET: usize = 256;

/// Worst-case node count of an RSD-C branch vector: `sum_l prod_{j<=l}
/// b_j`. The single definition of the paper's "target computational
/// budget" for constant-branching trees, shared by [`DecoderConfig`],
/// the drafting strategy and the adaptive allocator.
pub fn rsd_c_budget(branches: &[usize]) -> usize {
    let mut level = 1usize;
    let mut total = 0usize;
    for &b in branches {
        level *= b;
        total += level;
    }
    total
}

/// Which tree family the adaptive controller may allocate from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveFamily {
    /// Both RSD-C branch vectors and RSD-S beams compete on expected
    /// accepted tokens; the argmax wins each round.
    Auto,
    /// Constant-branching trees only (Gumbel-Top-k drafting).
    RsdC,
    /// Stochastic-beam trees only (`(w, l)` plus early truncation).
    RsdS,
}

impl AdaptiveFamily {
    pub fn as_str(&self) -> &'static str {
        match self {
            AdaptiveFamily::Auto => "auto",
            AdaptiveFamily::RsdC => "rsd-c",
            AdaptiveFamily::RsdS => "rsd-s",
        }
    }
}

impl FromStr for AdaptiveFamily {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(AdaptiveFamily::Auto),
            "rsd-c" | "rsdc" => Ok(AdaptiveFamily::RsdC),
            "rsd-s" | "rsds" => Ok(AdaptiveFamily::RsdS),
            other => bail!("unknown adaptive family '{other}' (want auto|rsd-c|rsd-s)"),
        }
    }
}

/// Which decoding algorithm to run, with its tree specification. The
/// `Spec.` column of the paper's tables (App. C.3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecoderConfig {
    /// Auto-regressive baseline.
    Ar,
    /// Single-sequence speculative decoding with draft length `l`.
    Sd { l: usize },
    /// SpecTr K-SEQ: `k` i.i.d. draft paths of length `l`. γ fixed at
    /// the per-level candidate count (see decode::rrs::KSeq).
    SpecTr { k: usize, l: usize },
    /// RSD with constant branching factors (root-to-leaf), e.g. [2,2,1].
    RsdC { branches: Vec<usize> },
    /// ABLATION: RSD-C's without-replacement tree verified with the
    /// SpecInfer multi-round (with-replacement) rule — isolates the gain
    /// of recursive rejection sampling at the system level.
    RsdCMultiRound { branches: Vec<usize> },
    /// RSD with Stochastic Beam Search: beamwidth `w`, max depth `l`.
    RsdS { w: usize, l: usize },
    /// Online tree shaping ([`crate::adaptive`]): every round the shape
    /// with the highest expected accepted tokens under the hard node
    /// budget `budget` is chosen from `family`, using per-level
    /// acceptance rates estimated from this request (blended with
    /// engine-global decayed statistics when serving).
    Adaptive { budget: usize, family: AdaptiveFamily },
}

impl DecoderConfig {
    /// Number of draft-token tree nodes processed at the target model per
    /// iteration — the paper's "target computational budget" (Exp2).
    pub fn budget(&self) -> usize {
        match self {
            DecoderConfig::Ar => 0,
            DecoderConfig::Sd { l } => *l,
            DecoderConfig::SpecTr { k, l } => k * l,
            DecoderConfig::RsdC { branches }
            | DecoderConfig::RsdCMultiRound { branches } => rsd_c_budget(branches),
            DecoderConfig::RsdS { w, l } => w * l,
            // the allocator's hard cap; every emitted shape satisfies
            // shape.budget() <= budget (property-tested)
            DecoderConfig::Adaptive { budget, .. } => *budget,
        }
    }

    /// Maximum draft sequence length (tree depth) — the paper's DL (Exp1).
    pub fn depth(&self) -> usize {
        match self {
            DecoderConfig::Ar => 0,
            DecoderConfig::Sd { l } => *l,
            DecoderConfig::SpecTr { l, .. } => *l,
            DecoderConfig::RsdC { branches }
            | DecoderConfig::RsdCMultiRound { branches } => branches.len(),
            DecoderConfig::RsdS { l, .. } => *l,
            DecoderConfig::Adaptive { budget, .. } => (*budget).min(ADAPTIVE_MAX_DEPTH),
        }
    }

    /// Short human label matching the paper's `Dec. Spec.` columns.
    pub fn label(&self) -> String {
        match self {
            DecoderConfig::Ar => "AR".into(),
            DecoderConfig::Sd { l } => format!("SD {l}"),
            DecoderConfig::SpecTr { k, l } => format!("SpecTr {k}x{l}"),
            DecoderConfig::RsdC { branches } => {
                let b: Vec<String> = branches.iter().map(|x| x.to_string()).collect();
                format!("RSD-C {}", b.join("-"))
            }
            DecoderConfig::RsdCMultiRound { branches } => {
                let b: Vec<String> = branches.iter().map(|x| x.to_string()).collect();
                format!("RSD-C/mr {}", b.join("-"))
            }
            DecoderConfig::RsdS { w, l } => format!("RSD-S {w}x{l}"),
            DecoderConfig::Adaptive { budget, family } => match family {
                AdaptiveFamily::Auto => format!("Adaptive B{budget}"),
                f => format!("Adaptive B{budget}/{}", f.as_str()),
            },
        }
    }

    /// Compact spec string: `ar | sd:L | spectr:KxL | rsd-c:B-B-.. | rsd-s:WxL`.
    pub fn spec(&self) -> String {
        match self {
            DecoderConfig::Ar => "ar".into(),
            DecoderConfig::Sd { l } => format!("sd:{l}"),
            DecoderConfig::SpecTr { k, l } => format!("spectr:{k}x{l}"),
            DecoderConfig::RsdC { branches } => {
                let b: Vec<String> = branches.iter().map(|x| x.to_string()).collect();
                format!("rsd-c:{}", b.join("-"))
            }
            DecoderConfig::RsdCMultiRound { branches } => {
                let b: Vec<String> = branches.iter().map(|x| x.to_string()).collect();
                format!("rsd-c-mr:{}", b.join("-"))
            }
            DecoderConfig::RsdS { w, l } => format!("rsd-s:{w}x{l}"),
            DecoderConfig::Adaptive { budget, family } => match family {
                AdaptiveFamily::Auto => format!("adaptive:{budget}"),
                f => format!("adaptive:{budget}:{}", f.as_str()),
            },
        }
    }
}

impl FromStr for DecoderConfig {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        if s == "ar" {
            return Ok(DecoderConfig::Ar);
        }
        let (kind, rest) = s
            .split_once(':')
            .with_context(|| format!("bad decoder spec '{s}' (want kind:params)"))?;
        let kxl = |r: &str| -> Result<(usize, usize)> {
            let (a, b) = r.split_once('x').with_context(|| format!("want KxL, got '{r}'"))?;
            Ok((a.parse()?, b.parse()?))
        };
        match kind {
            "sd" => Ok(DecoderConfig::Sd { l: rest.parse()? }),
            "spectr" => {
                let (k, l) = kxl(rest)?;
                Ok(DecoderConfig::SpecTr { k, l })
            }
            "rsd-s" | "rsds" => {
                let (w, l) = kxl(rest)?;
                Ok(DecoderConfig::RsdS { w, l })
            }
            "adaptive" => {
                let (budget_s, family) = match rest.split_once(':') {
                    Some((b, f)) => (b, f.parse::<AdaptiveFamily>()?),
                    None => (rest, AdaptiveFamily::Auto),
                };
                let budget: usize = budget_s
                    .parse()
                    .with_context(|| format!("bad adaptive budget '{budget_s}'"))?;
                if budget == 0 {
                    bail!("adaptive budget must be positive");
                }
                if budget > ADAPTIVE_MAX_BUDGET {
                    bail!("adaptive budget {budget} too large (max {ADAPTIVE_MAX_BUDGET})");
                }
                Ok(DecoderConfig::Adaptive { budget, family })
            }
            "rsd-c" | "rsdc" | "rsd-c-mr" => {
                let branches = rest
                    .split('-')
                    .map(|x| x.parse::<usize>().map_err(Into::into))
                    .collect::<Result<Vec<usize>>>()?;
                if branches.is_empty() || branches.contains(&0) {
                    bail!("branches must be positive");
                }
                if kind == "rsd-c-mr" {
                    Ok(DecoderConfig::RsdCMultiRound { branches })
                } else {
                    Ok(DecoderConfig::RsdC { branches })
                }
            }
            other => bail!("unknown decoder kind '{other}'"),
        }
    }
}

/// Serving-engine knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Maximum concurrently active sessions (bounded by KV capacity).
    pub max_concurrency: usize,
    /// Maximum queued requests before admission control rejects.
    pub max_queue: usize,
    /// Default per-request generation cap.
    pub default_max_tokens: usize,
    /// Cap on the summed per-round node budget of concurrently active
    /// requests (0 = unlimited). With heterogeneous per-request budgets
    /// this keeps one burst of wide-tree requests from monopolizing the
    /// target model's per-iteration compute; an over-budget request is
    /// still admitted when the engine is otherwise idle.
    pub max_active_budget: usize,
    pub sampling: SamplingConfig,
    pub decoder: DecoderConfig,
    pub seed: u64,
    /// Whether the engine round loop issues one fused
    /// [`crate::llm::Llm::eval_batch`] call per phase across all active
    /// requests (the default) or falls back to one `eval` per request.
    /// Output is token-for-token identical either way (per-request RNG
    /// streams); the fallback exists for A/B benchmarking and debugging.
    pub fused: bool,
    /// `false` (the default) = continuous batching: waiting requests
    /// join the fused batch at the next phase boundary — mid-round when
    /// one is in flight — as soon as a slot and KV headroom open up.
    /// `true` = drain-then-refill: admission only happens when the
    /// engine is completely idle, so batches run to full completion
    /// before the queue moves. Token streams are identical either way
    /// (per-request RNG streams); the drain mode exists as the A/B
    /// baseline for `benches/continuous.rs`.
    pub drain_batching: bool,
    /// Paged KV-cache pool size in blocks, for substrates constructed
    /// from this config (`rsd serve --sim`): 0 = dense per-session
    /// caches, > 0 = a [`crate::kvcache::KvPool`] per model with radix
    /// prefix sharing and engine preemption.
    pub kv_blocks: usize,
    /// Tokens per KV block (only meaningful with `kv_blocks > 0`).
    pub kv_block_size: usize,
    /// Cold-tier directory (only meaningful with `kv_blocks > 0`):
    /// blocks evicted from the radix index spill here as checksummed
    /// tensorfiles and prefix lookups revive them instead of
    /// re-prefilling; a radix snapshot in the same directory persists
    /// hot prefixes across restarts. `None` (the default) disables the
    /// cold tier.
    pub cold_dir: Option<String>,
    /// Maximum spilled blocks the cold tier retains per model store
    /// (a disk-footprint bound; spills past it are dropped, not
    /// errors). Only meaningful with `cold_dir` set.
    pub cold_blocks: usize,
    /// Flight-recorder journal capacity in events (0 = tracing off).
    /// The ring is preallocated once at engine construction and the
    /// newest events overwrite the oldest; steady-state recording
    /// allocates nothing (see [`crate::trace`]).
    pub trace_events: usize,
    /// Stall-watchdog interval in milliseconds (0 = off; needs
    /// `trace_events > 0`): if no engine phase boundary advances for
    /// this long while work is in flight, the journal and engine status
    /// are dumped to `watchdog_path`.
    pub watchdog_ms: u64,
    /// Where the watchdog writes its post-mortem JSON dump.
    pub watchdog_path: String,
    /// Per-request budget of engine-side retries for *retryable* faults
    /// (transient eval failures, pool exhaustion on resume). Each retry
    /// suspends the stepper, requeues the request at the queue front,
    /// and resumes after a deterministic backoff; past the budget the
    /// request terminates with a `retries_exhausted` error.
    pub retry_budget: usize,
    /// Base backoff in engine rounds before a retried request is
    /// re-eligible for admission; doubles per consecutive retry.
    pub retry_backoff_rounds: usize,
    /// `true` = queued requests whose `deadline_ms` has elapsed are shed
    /// at admission boundaries with a typed retryable error. `false`
    /// (the default) keeps deadlines as a pure scheduling hint.
    pub enforce_deadlines: bool,
    /// Speculation-analytics window length in engine rounds (0 =
    /// analytics off). The acceptance ledger and SLO tracker roll a
    /// cumulative boundary snapshot into the stats ring every this
    /// many rounds; the `stats` wire command aggregates across them.
    pub stats_window_rounds: usize,
    /// Stats ring capacity in window boundaries (how far back the
    /// `stats` command can aggregate; preallocated once).
    pub stats_windows: usize,
    /// TTFT service-level objective in milliseconds (0 = objective
    /// disabled). Attainment against it is reported per stats window.
    pub slo_ttft_ms: u64,
    /// End-to-end latency SLO in milliseconds (0 = disabled).
    pub slo_latency_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_concurrency: 4,
            max_queue: 256,
            default_max_tokens: 64,
            max_active_budget: 0,
            sampling: SamplingConfig::new(0.3, 1.0),
            decoder: DecoderConfig::RsdS { w: 3, l: 3 },
            seed: 0,
            fused: true,
            drain_batching: false,
            kv_blocks: 0,
            kv_block_size: 16,
            cold_dir: None,
            cold_blocks: 4096,
            trace_events: 0,
            watchdog_ms: 0,
            watchdog_path: "rsd-watchdog.json".into(),
            retry_budget: 3,
            retry_backoff_rounds: 2,
            enforce_deadlines: false,
            stats_window_rounds: 32,
            stats_windows: 64,
            slo_ttft_ms: 0,
            slo_latency_ms: 0,
        }
    }
}

impl EngineConfig {
    /// Load from a JSON file; absent fields keep defaults.
    /// Example: {"max_concurrency": 8, "decoder": "rsd-s:6x5",
    ///           "temperature": 0.3, "top_p": 1.0}
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading engine config {}", path.as_ref().display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = j.get("max_concurrency").and_then(Json::as_usize) {
            cfg.max_concurrency = v;
        }
        if let Some(v) = j.get("max_queue").and_then(Json::as_usize) {
            cfg.max_queue = v;
        }
        if let Some(v) = j.get("default_max_tokens").and_then(Json::as_usize) {
            cfg.default_max_tokens = v;
        }
        if let Some(v) = j.get("max_active_budget").and_then(Json::as_usize) {
            cfg.max_active_budget = v;
        }
        if let Some(v) = j.get("temperature").and_then(Json::as_f64) {
            cfg.sampling.temperature = v as f32;
        }
        if let Some(v) = j.get("top_p").and_then(Json::as_f64) {
            cfg.sampling.top_p = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        if let Some(v) = j.get("fused").and_then(Json::as_bool) {
            cfg.fused = v;
        }
        if let Some(v) = j.get("drain_batching").and_then(Json::as_bool) {
            cfg.drain_batching = v;
        }
        if let Some(v) = j.get("kv_blocks").and_then(Json::as_usize) {
            cfg.kv_blocks = v;
        }
        if let Some(v) = j.get("kv_block_size").and_then(Json::as_usize) {
            if !(1..=crate::kvcache::MAX_BLOCK_SIZE).contains(&v) {
                bail!(
                    "kv_block_size {v} out of range 1..={}",
                    crate::kvcache::MAX_BLOCK_SIZE
                );
            }
            cfg.kv_block_size = v;
        }
        if let Some(s) = j.get("cold_dir").and_then(Json::as_str) {
            cfg.cold_dir = Some(s.to_string());
        }
        if let Some(v) = j.get("cold_blocks").and_then(Json::as_usize) {
            cfg.cold_blocks = v;
        }
        if let Some(v) = j.get("trace_events").and_then(Json::as_usize) {
            cfg.trace_events = v;
        }
        if let Some(v) = j.get("watchdog_ms").and_then(Json::as_usize) {
            cfg.watchdog_ms = v as u64;
        }
        if let Some(s) = j.get("watchdog_path").and_then(Json::as_str) {
            cfg.watchdog_path = s.to_string();
        }
        if let Some(v) = j.get("retry_budget").and_then(Json::as_usize) {
            cfg.retry_budget = v;
        }
        if let Some(v) = j.get("retry_backoff_rounds").and_then(Json::as_usize) {
            cfg.retry_backoff_rounds = v;
        }
        if let Some(v) = j.get("enforce_deadlines").and_then(Json::as_bool) {
            cfg.enforce_deadlines = v;
        }
        if let Some(v) = j.get("stats_window_rounds").and_then(Json::as_usize) {
            cfg.stats_window_rounds = v;
        }
        if let Some(v) = j.get("stats_windows").and_then(Json::as_usize) {
            cfg.stats_windows = v;
        }
        if let Some(v) = j.get("slo_ttft_ms").and_then(Json::as_usize) {
            cfg.slo_ttft_ms = v as u64;
        }
        if let Some(v) = j.get("slo_latency_ms").and_then(Json::as_usize) {
            cfg.slo_latency_ms = v as u64;
        }
        if let Some(arr) = j.get("stop").and_then(Json::as_arr) {
            cfg.sampling.stop = parse_stop_tokens(arr)?;
        }
        if let Some(s) = j.get("decoder").and_then(Json::as_str) {
            cfg.decoder = s.parse()?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_match_paper_appendix_c3() {
        // App. C.3.2, B = 6
        assert_eq!(DecoderConfig::RsdC { branches: vec![2, 1, 1] }.budget(), 6);
        assert_eq!(DecoderConfig::RsdC { branches: vec![2, 2] }.budget(), 6);
        assert_eq!(DecoderConfig::RsdC { branches: vec![3, 1] }.budget(), 6);
        assert_eq!(DecoderConfig::SpecTr { k: 2, l: 3 }.budget(), 6);
        assert_eq!(DecoderConfig::RsdS { w: 3, l: 2 }.budget(), 6);
        // B = 30
        assert_eq!(DecoderConfig::RsdC { branches: vec![2, 2, 2, 2] }.budget(), 30);
        assert_eq!(DecoderConfig::RsdS { w: 5, l: 6 }.budget(), 30);
        assert_eq!(DecoderConfig::RsdC { branches: vec![6, 1, 1, 1, 1] }.budget(), 30);
        // the adaptive controller inherits the budget as a hard cap
        let ad = DecoderConfig::Adaptive { budget: 30, family: AdaptiveFamily::Auto };
        assert_eq!(ad.budget(), 30);
        assert_eq!(ad.depth(), ADAPTIVE_MAX_DEPTH);
    }

    #[test]
    fn depth_is_draft_length() {
        assert_eq!(DecoderConfig::Sd { l: 4 }.depth(), 4);
        assert_eq!(DecoderConfig::RsdC { branches: vec![2, 2, 2] }.depth(), 3);
        assert_eq!(DecoderConfig::RsdS { w: 6, l: 5 }.depth(), 5);
    }

    #[test]
    fn spec_string_roundtrip() {
        let cfgs = vec![
            DecoderConfig::Ar,
            DecoderConfig::Sd { l: 3 },
            DecoderConfig::SpecTr { k: 2, l: 5 },
            DecoderConfig::RsdC { branches: vec![3, 2, 1] },
            DecoderConfig::RsdS { w: 6, l: 5 },
            DecoderConfig::Adaptive { budget: 30, family: AdaptiveFamily::Auto },
            DecoderConfig::Adaptive { budget: 6, family: AdaptiveFamily::RsdC },
            DecoderConfig::Adaptive { budget: 14, family: AdaptiveFamily::RsdS },
        ];
        for c in cfgs {
            let s = c.spec();
            let back: DecoderConfig = s.parse().unwrap();
            assert_eq!(c, back, "{s}");
        }
    }

    #[test]
    fn bad_specs_rejected() {
        let bad = [
            "", "sd", "sd:x", "spectr:3", "rsd-c:2-0", "warp:9", "adaptive:0", "adaptive:x",
            "adaptive:6:warp", "adaptive:300",
        ];
        for s in bad {
            assert!(s.parse::<DecoderConfig>().is_err(), "{s}");
        }
    }

    #[test]
    fn sampling_patch_inherits_unset_fields() {
        let base = SamplingConfig::new(0.7, 0.9).with_stop(vec![10]);
        let patch = SamplingPatch { stop: Some(vec![0]), ..Default::default() };
        let resolved = patch.apply(&base);
        assert!((resolved.temperature - 0.7).abs() < 1e-6);
        assert!((resolved.top_p - 0.9).abs() < 1e-6);
        assert_eq!(resolved.stop, vec![0]);
        assert!(SamplingPatch::default().is_empty());
        assert_eq!(SamplingPatch::default().apply(&base), base);
    }

    #[test]
    fn engine_config_parses_fused_and_stop() {
        let j = Json::parse(r#"{"fused": false, "stop": [10, 0]}"#).unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert!(!cfg.fused);
        assert_eq!(cfg.sampling.stop, vec![10, 0]);
        // non-integer / negative stop entries must be rejected, not cast
        for bad in [r#"{"stop": [10.7]}"#, r#"{"stop": [-1]}"#, r#"{"stop": ["x"]}"#] {
            assert!(EngineConfig::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
        let d = EngineConfig::default();
        assert!(d.fused);
        assert!(!d.drain_batching, "continuous admission is the default");
        assert!(d.sampling.stop.is_empty());
        assert!(!d.sampling.is_stop(7));
        let j = Json::parse(r#"{"drain_batching": true}"#).unwrap();
        assert!(EngineConfig::from_json(&j).unwrap().drain_batching);
    }

    #[test]
    fn engine_config_from_json() {
        let j = Json::parse(
            r#"{"max_concurrency": 8, "decoder": "rsd-c:2-2-1", "temperature": 0.7}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.max_concurrency, 8);
        assert_eq!(cfg.decoder, DecoderConfig::RsdC { branches: vec![2, 2, 1] });
        assert!((cfg.sampling.temperature - 0.7).abs() < 1e-6);
        assert_eq!(cfg.max_queue, 256); // default kept
    }

    #[test]
    fn engine_config_cold_tier_knobs() {
        let d = EngineConfig::default();
        assert!(d.cold_dir.is_none(), "cold tier is opt-in");
        assert_eq!(d.cold_blocks, 4096);
        let j = Json::parse(r#"{"cold_dir": "/tmp/rsd-cold", "cold_blocks": 128}"#).unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cold_dir.as_deref(), Some("/tmp/rsd-cold"));
        assert_eq!(cfg.cold_blocks, 128);
    }
}
