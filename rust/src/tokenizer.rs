//! Byte-level tokenizer over the synthetic corpus alphabet.
//!
//! Tokens are alphabet indices (0..32); the model vocab is 256 so any
//! byte value round-trips, but corpus text only uses the 32-symbol
//! alphabet defined in python/compile/corpus.py (kept in sync by test).

pub const ALPHABET: &str = "abcdefghijklmnopqrstuvwxyz .,;\n'";

pub struct Tokenizer {
    to_id: [Option<u8>; 128],
    to_ch: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = [None; 128];
        let to_ch: Vec<char> = ALPHABET.chars().collect();
        for (i, c) in to_ch.iter().enumerate() {
            to_id[*c as usize] = Some(i as u8);
        }
        Self { to_id, to_ch }
    }

    /// Encode text; characters outside the alphabet map to space.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let space = ALPHABET.find(' ').unwrap() as u32;
        text.chars()
            .map(|c| {
                let lc = c.to_ascii_lowercase();
                if (lc as usize) < 128 {
                    self.to_id[lc as usize].map(|x| x as u32).unwrap_or(space)
                } else {
                    space
                }
            })
            .collect()
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| *self.to_ch.get(t as usize).unwrap_or(&'?'))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tk = Tokenizer::new();
        let s = "hello world, this is rsd;\n'quoted'";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn unknown_maps_to_space() {
        let tk = Tokenizer::new();
        assert_eq!(tk.decode(&tk.encode("a#b")), "a b");
    }

    #[test]
    fn alphabet_is_32_symbols() {
        assert_eq!(ALPHABET.chars().count(), 32);
    }
}
