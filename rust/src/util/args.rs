//! Flag-style argument parser for the CLI (replaces `clap`, unavailable
//! in this offline build). Supports `--flag value`, `--flag=value`,
//! boolean `--flag`, and positional subcommands.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse an iterator of raw args (without argv[0]). `bools` lists
    /// flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bools: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if bools.contains(&name) {
                    out.flags.entry(name.to_string()).or_default().push("true".into());
                } else {
                    match it.next() {
                        Some(v) => out.flags.entry(name.to_string()).or_default().push(v),
                        None => bail!("flag --{name} expects a value"),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|x| x.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--{name}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(sv(&["exp1", "--dl", "2,3", "--sim", "--reps=5"]), &["sim"]).unwrap();
        assert_eq!(a.positional, vec!["exp1"]);
        assert_eq!(a.get("dl"), Some("2,3"));
        assert!(a.has("sim"));
        assert_eq!(a.parse_or("reps", 0usize).unwrap(), 5);
        assert_eq!(a.list_or("dl", &[]).unwrap(), vec![2, 3]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["--x"]), &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(sv(&[]), &[]).unwrap();
        assert_eq!(a.get_or("addr", "1.2.3.4:5"), "1.2.3.4:5");
        assert_eq!(a.parse_or("n", 7u32).unwrap(), 7);
    }
}
