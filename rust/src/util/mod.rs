//! In-repo substrates for an offline build: PRNG, JSON, CLI args.
//!
//! The build environment ships only the `xla` crate's dependency closure,
//! so the utilities a serving framework would normally take from
//! crates.io are implemented here from scratch (DESIGN.md §2): a
//! xoshiro256++ PRNG with the distribution helpers the decoders need, a
//! small recursive-descent JSON parser/serializer (manifest, wire
//! protocol, configs), and a flag-style argument parser for the CLI.

pub mod args;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
