//! xoshiro256++ PRNG (Blackman & Vigna), seeded via splitmix64.
//!
//! Deterministic, fast, and good enough for the sampling workloads here
//! (verified against the statistical tests in sampling.rs / props.rs).
//! Replaces the `rand`/`rand_chacha` crates, which are unavailable in
//! this offline build.

/// Seedable PRNG used throughout the crate.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut x = seed;
        Self { s: [splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in (0, 1] — safe for `ln()`.
    #[inline]
    pub fn gen_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n). Rejection-free (n << 2^64 bias is
    /// negligible for our vocab/queue sizes; documented tradeoff).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fork an independent stream (for per-request rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed_from_u64(0);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.gen_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "{mean}");
    }

    #[test]
    fn gen_range_covers_support_uniformly() {
        let mut r = Rng::seed_from_u64(7);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.gen_range(5)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "{frac}");
        }
    }

    #[test]
    fn open_interval_never_zero() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..100_000 {
            assert!(r.gen_f64_open() > 0.0);
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::seed_from_u64(5);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
