//! Minimal JSON: recursive-descent parser + serializer.
//!
//! Handles everything this crate exchanges as JSON — the AOT manifest,
//! the `.tensors` header, engine config files and the TCP wire protocol.
//! Replaces `serde_json`, unavailable in this offline build. Supports the
//! full JSON grammar (objects, arrays, strings with escapes incl. \uXXXX,
//! numbers, bools, null); rejects trailing garbage.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: required string field.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    /// Convenience: required numeric field as usize.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

/// Nesting cap for the recursive-descent parser: hostile input
/// (`[[[[...`) must return an error, not overflow the thread stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.i);
        }
        Ok(())
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                other => bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        let mixed = "{\"a\":".repeat(10_000) + "1" + &"}".repeat(10_000);
        assert!(Json::parse(&mixed).is_err());
        // Sane nesting stays parseable.
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
        // Huge exponents saturate to inf rather than failing or panicking.
        assert!(Json::parse("1e999").is_ok());
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"models": {"target": {"vocab": 256, "hlo": "t.hlo.txt",
            "input_order": ["tok_emb", "w_q"]}}, "ok": true, "x": null}"#;
        let j = Json::parse(doc).unwrap();
        let t = j.get("models").unwrap().get("target").unwrap();
        assert_eq!(t.usize_field("vocab").unwrap(), 256);
        assert_eq!(t.str_field("hlo").unwrap(), "t.hlo.txt");
        assert_eq!(t.get("input_order").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrips_strings_with_escapes() {
        let j = Json::Str("a\"b\\c\nd\ttab \u{1f600}".to_string());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parses_numbers() {
        for (s, v) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e3", 1000.0), ("-2.5e-2", -0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), v, "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_roundtrip() {
        let doc = r#"{"a":[1,2,{"b":[true,false,null,"s"]}],"c":-3.25}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }
}
