//! The real model: AOT-compiled transformer step executed via PJRT.
//!
//! `PjrtLm` implements [`Llm`] over the step-executable contract of
//! DESIGN.md §1: one fixed-shape executable per checkpoint serves prefill
//! chunks, single-token decode, draft-tree levels and the target pass
//! over the whole tree. The Rust side owns all coordination state —
//! positions, KV scatter destinations, the {0,-inf} topology mask — while
//! the executable is a pure tensor program.
//!
//! Buffer strategy (§Perf): weights are uploaded to the device once at
//! load; per call only the small operand tensors and the KV caches move.
//! Tuple outputs come back as host literals (the PJRT binding cannot
//! split a device tuple), so caches round-trip host<->device once per
//! call — measured and accounted in EXPERIMENTS.md §Perf.

//! Like [`crate::runtime`], the real implementation needs the `xla`
//! binding and is gated behind `--cfg pjrt_runtime`; default builds get
//! a stub whose `load` fails with a pointer at the sim substrate, so
//! every caller (CLI, benches, examples) compiles unchanged.

use std::path::Path;

use anyhow::{bail, Result};
#[cfg(pjrt_runtime)]
use anyhow::Context;

#[cfg(pjrt_runtime)]
use crate::config::{Manifest, ModelManifest};
#[cfg(pjrt_runtime)]
use crate::kvcache::{KvPool, PagedSlots};
use crate::llm::{EvalNode, Llm, LogitsBatch};
#[cfg(pjrt_runtime)]
use crate::runtime::Executable;
use crate::runtime::Runtime;
use crate::tree::SessionCore;

/// f32 additive-mask value for "cannot attend" (matches kernels/ref.py).
pub const MASK_OFF: f32 = -1e30;

#[cfg(pjrt_runtime)]
pub struct PjrtLm {
    pub man: ModelManifest,
    rt: Runtime,
    /// (s_tile, executable), ascending tile width. `run_tile` picks the
    /// smallest tile that fits an eval call, so single-token decode does
    /// not pay for the full 32-wide tile (§Perf iteration 2).
    exes: Vec<(usize, Executable)>,
    /// Weight buffers resident on device, in executable input order.
    weights: Vec<xla::PjRtBuffer>,
    /// Optional paged block table for slot allocation
    /// ([`PjrtLm::with_kv_pool`]): sessions then draw their cache slots
    /// block-granularly from the shared pool instead of a private dense
    /// range, so admission/preemption can reason about fleet-wide KV
    /// headroom. Cross-session *data* sharing (radix prefix hits) stays
    /// disabled here: each session still owns its cache literals, so a
    /// shared slot id would not see another session's KV rows. Lifting
    /// that needs step executables compiled against one global paged
    /// cache buffer (lane-shared K/V operands) — the packing contract
    /// sketched at [`PjrtLm::run_packed`]; until then
    /// `begin_with_prefix` allocates but never matches.
    kv: Option<std::sync::Arc<KvPool>>,
}

#[cfg(pjrt_runtime)]
pub struct PjrtSession {
    pub core: SessionCore,
    kcache: xla::Literal,
    vcache: xla::Literal,
    /// Reused host staging for the attention mask (avoids a fresh
    /// S*M-sized allocation per call).
    mask_host: Vec<f32>,
}

#[cfg(pjrt_runtime)]
impl PjrtLm {
    /// Load `name` ("target" | "draft") from an artifacts directory.
    pub fn load(rt: &Runtime, artifacts_dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let (man, dir) = Manifest::load(&artifacts_dir)?;
        let mm = man.model(name)?.clone();
        let mut exes = Vec::new();
        if mm.tiles.is_empty() {
            exes.push((mm.s_tile, rt.load_hlo_text(dir.join(&mm.hlo))?));
        } else {
            for (s_tile, hlo) in &mm.tiles {
                exes.push((*s_tile, rt.load_hlo_text(dir.join(hlo))?));
            }
        }
        let tensors = crate::tensorfile::load(dir.join(&mm.tensors))?;
        let n_params = mm.input_order.len() - 6; // trailing 6 are operands
        let mut weights = Vec::with_capacity(n_params);
        for field in &mm.input_order[..n_params] {
            let t = tensors
                .get(field)
                .with_context(|| format!("weights missing field '{field}'"))?;
            weights.push(rt.buffer_f32(&t.as_f32()?, &t.shape)?);
        }
        Ok(Self { man: mm, rt: rt.clone_handle(), exes, weights, kv: None })
    }

    /// Route this model's slot allocation through a shared paged block
    /// pool (see the `kv` field docs for what is and is not shared).
    /// The pool's slot range must fit inside the compiled cache minus
    /// the scratch slot.
    pub fn with_kv_pool(mut self, pool: std::sync::Arc<KvPool>) -> Result<Self> {
        if pool.total_slots() > self.man.cache_len - 1 {
            bail!(
                "pool of {} slots exceeds compiled cache_len {} - 1",
                pool.total_slots(),
                self.man.cache_len
            );
        }
        self.kv = Some(pool);
        Ok(self)
    }

    /// Load both models sharing one runtime.
    pub fn load_pair(rt: &Runtime, artifacts_dir: impl AsRef<Path>) -> Result<(Self, Self)> {
        let dir = artifacts_dir.as_ref();
        Ok((Self::load(rt, dir, "target")?, Self::load(rt, dir, "draft")?))
    }

    fn cache_dims(&self) -> [i64; 5] {
        [
            self.man.n_layers as i64,
            self.man.batch as i64,
            self.man.n_heads as i64,
            self.man.cache_len as i64,
            self.man.d_head as i64,
        ]
    }

    /// Smallest compiled tile that fits `n` nodes (falls back to max).
    fn pick_exe(&self, n: usize) -> (usize, &Executable) {
        for (st, exe) in &self.exes {
            if *st >= n {
                return (*st, exe);
            }
        }
        let (st, exe) = self.exes.last().expect("at least one executable");
        (*st, exe)
    }

    /// Execute one tile of up to `s_tile` pending nodes (already added to
    /// the session core), appending one logits row per node to `out` in
    /// place (no per-row vectors).
    fn run_tile(
        &self,
        s: &mut PjrtSession,
        idxs: std::ops::Range<usize>,
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let (st, exe) = self.pick_exe(idxs.len());
        let m = self.man.cache_len;
        let v = self.man.vocab;
        let n = idxs.len();
        debug_assert!(n <= st && n > 0);

        let mut tokens = vec![0i32; st];
        let mut positions = vec![0i32; st];
        let mut dest = vec![(m - 1) as i32; st];
        let mask = &mut s.mask_host;
        mask.clear();
        mask.resize(st * m, MASK_OFF);
        for (row, i) in idxs.clone().enumerate() {
            let p = &s.core.pending[i];
            tokens[row] = p.token as i32;
            positions[row] = s.core.position(i) as i32;
            dest[row] = p.slot as i32;
            for slot in s.core.visible_slots(i) {
                mask[row * m + slot as usize] = 0.0;
            }
        }
        let b_tokens = self.rt.buffer_i32(&tokens, &[1, st])?;
        let b_pos = self.rt.buffer_i32(&positions, &[1, st])?;
        let b_dest = self.rt.buffer_i32(&dest, &[1, st])?;
        let b_mask = self.rt.buffer_f32(mask, &[1, st, m])?;
        let b_kc = self.rt.buffer_from_literal(&s.kcache)?;
        let b_vc = self.rt.buffer_from_literal(&s.vcache)?;

        let mut inputs: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        inputs.push(&b_tokens);
        inputs.push(&b_pos);
        inputs.push(&b_dest);
        inputs.push(&b_mask);
        inputs.push(&b_kc);
        inputs.push(&b_vc);

        let mut outs = exe.run_b(&inputs)?;
        if outs.len() != 3 {
            bail!("step executable returned {} outputs, want 3", outs.len());
        }
        s.vcache = outs.pop().unwrap();
        s.kcache = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(logits.len(), st * v);
        for row in 0..n {
            out.push_row_from(&logits[row * v..(row + 1) * v]);
        }
        Ok(())
    }

    /// Fused cross-session execution: pack every group into ONE padded
    /// device call, one batch lane per session (the serving engine's
    /// cross-request batch dimension), appending each lane's rows to
    /// `out` in group order.
    ///
    /// Contract (sketch — requires step executables AOT-compiled with
    /// `batch = B > 1`, which today's artifacts do not ship): operands
    /// become `tokens/positions/dest: [B, s_tile]`, `mask: [B, s_tile,
    /// M]`; lane `i` carries group `i`'s rows, shorter groups are padded
    /// with scratch-slot rows that attend nothing; the K/V caches are
    /// stacked lane-wise (lane 0 of each session's cache literal) and
    /// scattered back per session afterwards. Per-lane semantics are
    /// exactly [`PjrtLm::run_tile`] on that session.
    fn run_packed(
        &self,
        groups: &mut [(&mut PjrtSession, &[EvalNode])],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let m = self.man.cache_len;
        let v = self.man.vocab;
        let b = self.man.batch;
        let lanes = groups.len();
        debug_assert!(lanes >= 2 && lanes <= b);

        // append pending nodes per session; the widest group picks the tile
        let mut ranges = Vec::with_capacity(lanes);
        let mut widest = 0usize;
        for (s, nodes) in groups.iter_mut() {
            let range = s.core.add_pending(nodes)?;
            widest = widest.max(range.len());
            ranges.push(range);
        }
        let (st, exe) = self.pick_exe(widest);
        if widest > st {
            bail!("fused group of {widest} nodes exceeds the largest tile {st}");
        }

        // lane-packed operands; padding rows scatter into scratch
        let mut tokens = vec![0i32; b * st];
        let mut positions = vec![0i32; b * st];
        let mut dest = vec![(m - 1) as i32; b * st];
        let mut mask = vec![MASK_OFF; b * st * m];
        for (lane, ((s, _), range)) in groups.iter().zip(&ranges).enumerate() {
            for (row, i) in range.clone().enumerate() {
                let p = &s.core.pending[i];
                tokens[lane * st + row] = p.token as i32;
                positions[lane * st + row] = s.core.position(i) as i32;
                dest[lane * st + row] = p.slot as i32;
                for slot in s.core.visible_slots(i) {
                    mask[(lane * st + row) * m + slot as usize] = 0.0;
                }
            }
        }

        // stack lane 0 of every session's K/V cache along the batch dim
        let dims = self.cache_dims();
        let (nl, nh, dh) = (dims[0] as usize, dims[2] as usize, dims[4] as usize);
        let lane_elems = nh * m * dh;
        let mut kpack = vec![0f32; nl * b * lane_elems];
        let mut vpack = vec![0f32; nl * b * lane_elems];
        for (lane, (s, _)) in groups.iter().enumerate() {
            let kv = s.kcache.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
            let vv = s.vcache.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
            for l in 0..nl {
                let src = (l * b) * lane_elems; // session lane 0 of layer l
                let dst = (l * b + lane) * lane_elems;
                kpack[dst..dst + lane_elems].copy_from_slice(&kv[src..src + lane_elems]);
                vpack[dst..dst + lane_elems].copy_from_slice(&vv[src..src + lane_elems]);
            }
        }
        let klit = crate::runtime::literal_f32(&kpack, &dims)?;
        let vlit = crate::runtime::literal_f32(&vpack, &dims)?;

        let b_tokens = self.rt.buffer_i32(&tokens, &[b, st])?;
        let b_pos = self.rt.buffer_i32(&positions, &[b, st])?;
        let b_dest = self.rt.buffer_i32(&dest, &[b, st])?;
        let b_mask = self.rt.buffer_f32(&mask, &[b, st, m])?;
        let b_kc = self.rt.buffer_from_literal(&klit)?;
        let b_vc = self.rt.buffer_from_literal(&vlit)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        inputs.push(&b_tokens);
        inputs.push(&b_pos);
        inputs.push(&b_dest);
        inputs.push(&b_mask);
        inputs.push(&b_kc);
        inputs.push(&b_vc);

        let mut outs = exe.run_b(&inputs)?;
        if outs.len() != 3 {
            bail!("step executable returned {} outputs, want 3", outs.len());
        }
        let vout = outs.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let kout = outs.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let logits = outs.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(logits.len(), b * st * v);

        // scatter caches and logits rows back to their sessions
        for (lane, ((s, _), range)) in groups.iter_mut().zip(&ranges).enumerate() {
            let mut kback = vec![0f32; nl * b * lane_elems];
            let mut vback = vec![0f32; nl * b * lane_elems];
            for l in 0..nl {
                let src = (l * b + lane) * lane_elems;
                let dst = (l * b) * lane_elems;
                kback[dst..dst + lane_elems].copy_from_slice(&kout[src..src + lane_elems]);
                vback[dst..dst + lane_elems].copy_from_slice(&vout[src..src + lane_elems]);
            }
            s.kcache = crate::runtime::literal_f32(&kback, &dims)?;
            s.vcache = crate::runtime::literal_f32(&vback, &dims)?;
            for row in 0..range.len() {
                let at = (lane * st + row) * v;
                out.push_row_from(&logits[at..at + v]);
            }
        }
        Ok(())
    }
}

#[cfg(pjrt_runtime)]
impl Llm for PjrtLm {
    type Session = PjrtSession;

    fn vocab(&self) -> usize {
        self.man.vocab
    }

    fn param_count(&self) -> usize {
        self.man.params
    }

    fn begin(&self) -> Result<Self::Session> {
        let dims = self.cache_dims();
        let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        // zero-initialized; dtype must match CACHE_DTYPE in model.py
        // (f32 on this testbed — see EXPERIMENTS.md §Perf iteration 3)
        let make = || xla::Literal::create_from_shape(xla::PrimitiveType::F32, &udims);
        // paged: slots come from the fleet-wide block table (the scratch
        // slot stays the compiled cache's last slot, outside the pool's
        // range by the `with_kv_pool` check); dense: private slot range
        let core = match &self.kv {
            Some(pool) => SessionCore::paged(
                PagedSlots::empty(pool.clone()),
                &[],
                (self.man.cache_len - 1) as u32,
            ),
            None => SessionCore::new(self.man.cache_len),
        };
        Ok(PjrtSession {
            core,
            kcache: make(),
            vcache: make(),
            mask_host: Vec::new(),
        })
    }

    fn eval_into(
        &self,
        s: &mut Self::Session,
        nodes: &[EvalNode],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let range = s.core.add_pending(nodes)?;
        let mut start = range.start;
        while start < range.end {
            let end = (start + self.man.s_tile).min(range.end);
            self.run_tile(s, start..end, out)?;
            start = end;
        }
        Ok(())
    }

    /// One padded device call per fused batch when a multi-lane step
    /// executable is available; today's batch=1 artifacts take the
    /// per-session fallback (still one eval per session, each already
    /// tile-padded). See [`PjrtLm::run_packed`] for the packing contract.
    fn eval_batch_into(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let packable = groups.len() >= 2
            && groups.len() <= self.man.batch
            && groups.iter().all(|(_, nodes)| nodes.len() <= self.man.s_tile);
        if packable {
            return self.run_packed(groups, out);
        }
        for (session, nodes) in groups.iter_mut() {
            self.eval_into(session, nodes, out)?;
        }
        Ok(())
    }

    fn commit(&self, s: &mut Self::Session, accepted: &[usize]) -> Result<()> {
        s.core.commit(accepted)
    }

    fn prefix_len(&self, s: &Self::Session) -> usize {
        s.core.prefix_len()
    }

    fn capacity_left(&self, s: &Self::Session) -> usize {
        s.core.capacity_left()
    }

    fn pool_status(&self) -> Option<crate::kvcache::PoolStatus> {
        self.kv.as_ref().map(|p| p.status())
    }

    fn session_capacity(&self) -> usize {
        match &self.kv {
            Some(pool) => pool.total_slots(),
            None => self.man.cache_len - 1,
        }
    }
}

/// Stub model for builds without `--cfg pjrt_runtime`: loading always
/// fails with a descriptive error, and no instance can ever exist, so
/// the `Llm` methods below are unreachable — they only satisfy the
/// trait so generic callers compile.
#[cfg(not(pjrt_runtime))]
pub struct PjrtLm {
    #[allow(dead_code)]
    _private: (),
}

#[cfg(not(pjrt_runtime))]
pub struct PjrtSession {
    pub core: SessionCore,
}

#[cfg(not(pjrt_runtime))]
impl PjrtLm {
    pub fn load(_rt: &Runtime, _artifacts_dir: impl AsRef<Path>, _name: &str) -> Result<Self> {
        bail!(
            "PJRT model unavailable: built without --cfg pjrt_runtime \
             (see rust/Cargo.toml); use the sim substrate (--sim / SimLm)"
        )
    }

    pub fn load_pair(rt: &Runtime, artifacts_dir: impl AsRef<Path>) -> Result<(Self, Self)> {
        Ok((
            Self::load(rt, artifacts_dir.as_ref(), "target")?,
            Self::load(rt, artifacts_dir.as_ref(), "draft")?,
        ))
    }
}

#[cfg(not(pjrt_runtime))]
impl Llm for PjrtLm {
    type Session = PjrtSession;

    fn vocab(&self) -> usize {
        0
    }

    fn param_count(&self) -> usize {
        0
    }

    fn begin(&self) -> Result<Self::Session> {
        bail!("PJRT model unavailable (stub build)")
    }

    fn eval_into(
        &self,
        _s: &mut Self::Session,
        _nodes: &[EvalNode],
        _out: &mut LogitsBatch,
    ) -> Result<()> {
        bail!("PJRT model unavailable (stub build)")
    }

    fn commit(&self, s: &mut Self::Session, accepted: &[usize]) -> Result<()> {
        s.core.commit(accepted)
    }

    fn prefix_len(&self, s: &Self::Session) -> usize {
        s.core.prefix_len()
    }

    fn capacity_left(&self, s: &Self::Session) -> usize {
        s.core.capacity_left()
    }
}
