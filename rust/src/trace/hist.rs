//! Fixed-size log-bucketed duration histogram.
//!
//! Replaces the unbounded `Mutex<Vec<f64>>` sample lists the metrics
//! used to keep (they grew forever and were cloned + sorted on every
//! snapshot). Buckets are geometric: [`BUCKETS_PER_DECADE`] per decade
//! over [1 µs, 10 000 s), so any reported percentile sits within one
//! bucket ratio (10^(1/16) ≈ 1.155, i.e. ≤ ~7.5%) of the exact sample
//! percentile. The mean stays exact via a tracked running sum.
//!
//! Memory is constant: 160 × u64 counts + two scalars, whatever the
//! request volume.

use std::sync::Mutex;

/// Geometric resolution: 16 buckets per decade ⇒ bucket ratio 10^(1/16).
pub const BUCKETS_PER_DECADE: usize = 16;
/// Covered range: [1e-6, 1e4) seconds, ten decades.
pub const DECADES: usize = 10;
pub const NUM_BUCKETS: usize = BUCKETS_PER_DECADE * DECADES;
const MIN_VALUE: f64 = 1e-6;

struct Inner {
    counts: [u64; NUM_BUCKETS],
    n: u64,
    sum: f64,
}

/// Bounded histogram of durations in seconds. All methods take `&self`;
/// recording is allocation-free (one lock, one counter bump).
pub struct LogHistogram {
    inner: Mutex<Inner>,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { inner: Mutex::new(Inner { counts: [0; NUM_BUCKETS], n: 0, sum: 0.0 }) }
    }
}

/// Index of the bucket holding `v` (seconds). Values below the range
/// clamp to bucket 0, above to the last bucket.
fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= MIN_VALUE {
        return 0;
    }
    let idx = ((v / MIN_VALUE).log10() * BUCKETS_PER_DECADE as f64).floor() as isize;
    idx.clamp(0, NUM_BUCKETS as isize - 1) as usize
}

/// Geometric center of bucket `i` — the value reported for any
/// percentile that lands in it.
fn bucket_center(i: usize) -> f64 {
    MIN_VALUE * 10f64.powf((i as f64 + 0.5) / BUCKETS_PER_DECADE as f64)
}

/// Point-in-time summary of one histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    /// Exact mean (running sum / count), not bucket-approximated.
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LogHistogram {
    pub fn record(&self, v: f64) {
        let i = bucket_of(v);
        let mut g = self.inner.lock().unwrap();
        g.counts[i] += 1;
        g.n += 1;
        g.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().n
    }

    /// Mean/percentile summary under one lock. Percentiles come from
    /// geometric bucket centers (±1 bucket of exact); empty ⇒ zeros.
    pub fn summary(&self) -> HistSummary {
        let g = self.inner.lock().unwrap();
        if g.n == 0 {
            return HistSummary::default();
        }
        let pct = |p: f64| -> f64 {
            let rank = ((g.n as f64 * p).ceil() as u64).clamp(1, g.n);
            let mut seen = 0u64;
            for (i, &c) in g.counts.iter().enumerate() {
                seen += c;
                if seen >= rank {
                    return bucket_center(i);
                }
            }
            bucket_center(NUM_BUCKETS - 1)
        };
        HistSummary {
            count: g.n,
            mean: g.sum / g.n as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Relative tolerance: one bucket ratio, with a little slack.
    const TOL: f64 = 0.08;

    fn close(got: f64, want: f64) -> bool {
        (got - want).abs() <= want * TOL
    }

    #[test]
    fn percentiles_within_one_bucket_of_exact() {
        let h = LogHistogram::default();
        for v in 1..=1000 {
            h.record(v as f64 / 1000.0); // 1ms .. 1s uniform
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        assert!(close(s.p50, 0.5), "p50 {}", s.p50);
        assert!(close(s.p95, 0.95), "p95 {}", s.p95);
        assert!(close(s.p99, 0.99), "p99 {}", s.p99);
        assert!((s.mean - 0.5005).abs() < 1e-9, "mean is exact, got {}", s.mean);
    }

    #[test]
    fn out_of_range_values_clamp() {
        let h = LogHistogram::default();
        h.record(0.0);
        h.record(-3.0);
        h.record(1e-9);
        h.record(1e9);
        let s = h.summary();
        assert_eq!(s.count, 4);
        // clamped values still report finite in-range centers
        assert!(s.p50 >= 1e-6 && s.p99 <= 1e4);
    }

    #[test]
    fn memory_is_bounded_by_construction() {
        // the whole point: a million records, still 160 buckets
        let h = LogHistogram::default();
        for i in 0..1_000_000u64 {
            h.record((i % 977) as f64 * 1e-4);
        }
        assert_eq!(h.count(), 1_000_000);
        assert_eq!(std::mem::size_of::<Inner>(), NUM_BUCKETS * 8 + 16);
    }

    #[test]
    fn empty_summary_is_zeroed() {
        assert_eq!(LogHistogram::default().summary(), HistSummary::default());
    }

    #[test]
    fn bucket_geometry() {
        // centers grow by exactly one ratio per bucket
        let r = 10f64.powf(1.0 / BUCKETS_PER_DECADE as f64);
        assert!((bucket_center(10) / bucket_center(9) - r).abs() < 1e-12);
        // a value maps into a bucket whose center is within one ratio
        for &v in &[2e-6, 1e-3, 0.42, 7.0, 300.0] {
            let c = bucket_center(bucket_of(v));
            assert!(c / v < r && v / c < r, "v {v} center {c}");
        }
    }
}
