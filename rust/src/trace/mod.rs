//! Flight-recorder tracing for the serving engine.
//!
//! A preallocated, fixed-capacity ring buffer of compact binary events
//! ([`TraceEvent`]: 40 bytes, `Copy`) recorded through a cheap cloneable
//! handle ([`Tracer`]) threaded through the engine round loop, the
//! batcher, the steppers and the KV pool. Steady-state recording
//! allocates nothing: the ring is sized once at construction and every
//! `record` is a mutex lock + one slot write (the hot-path
//! zero-allocation gate in `benches/hotpath.rs` runs with tracing ON).
//!
//! With tracing off the handle is a `None` — one branch per record
//! call, no journal allocated at all.
//!
//! The journal is a *flight recorder*: it keeps the newest `capacity`
//! events and silently overwrites the oldest, so it is always safe to
//! leave enabled in production and dump post-mortem (the `trace` wire
//! command, or the [`watchdog`] when the engine stalls). Export to
//! Chrome trace-event JSON / Prometheus text lives in [`export`];
//! bounded log-bucketed histograms for phase timing live in [`hist`].

pub mod export;
pub mod hist;
pub mod watchdog;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What happened. The payload fields `id`/`a`/`b` of [`TraceEvent`] are
/// interpreted per kind (documented per variant; `export` renders them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Request reached the engine. `id` = request, `a` = prompt tokens,
    /// `b` = queue depth at arrival.
    ReqArrive = 0,
    /// Request admitted to the active batch. `id` = request,
    /// `a` = 1 if admitted mid-round, `b` = charged weight.
    ReqAdmit = 1,
    /// Request suspended under KV pressure. `id` = request,
    /// `a` = tokens committed so far.
    ReqPreempt = 2,
    /// Preempted request re-admitted. `id` = request, `a` = KV tokens
    /// re-served from cache on resume.
    ReqResume = 3,
    /// Request completed. `id` = request, `a` = tokens generated,
    /// `b` = preemption count.
    ReqDone = 4,
    /// Request failed. `id` = request.
    ReqError = 5,
    /// Engine round started. `id` = round number, `a` = active
    /// requests, `b` = queued requests.
    RoundBegin = 6,
    /// Engine phase opened. `id` = round number, `a` = phase code
    /// (low byte, see [`PHASE_SCHED`] etc.) | draft level << 8,
    /// `b` = fused groups dispatched in the phase.
    PhaseBegin = 7,
    /// Engine phase closed; same payload as the matching begin.
    PhaseEnd = 8,
    /// Commit boundary for one request. `id` = request, `a` = draft
    /// tokens accepted this round, `b` = bonus tokens.
    Commit = 9,
    /// KV prefix lookup. `id` = request-agnostic (0), `a` = tokens
    /// served from cache, `b` = tokens requested.
    KvAcquire = 10,
    /// Prefix published to the radix index. `a` = blocks newly
    /// published, `b` = tokens covered.
    KvPublish = 11,
    /// Block evicted from the pool LRU. `a` = blocks freed.
    KvEvict = 12,
    /// Batcher queue-depth sample. `a` = waiting requests,
    /// `b` = active requests.
    QueueDepth = 13,
    /// Watchdog fired (stall detected). `a` = stalled heartbeat value
    /// (truncated).
    Watchdog = 14,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::ReqArrive => "arrive",
            EventKind::ReqAdmit => "admit",
            EventKind::ReqPreempt => "preempt",
            EventKind::ReqResume => "resume",
            EventKind::ReqDone => "done",
            EventKind::ReqError => "error",
            EventKind::RoundBegin => "round",
            EventKind::PhaseBegin => "phase_begin",
            EventKind::PhaseEnd => "phase_end",
            EventKind::Commit => "commit",
            EventKind::KvAcquire => "kv_acquire",
            EventKind::KvPublish => "kv_publish",
            EventKind::KvEvict => "kv_evict",
            EventKind::QueueDepth => "queue_depth",
            EventKind::Watchdog => "watchdog",
        }
    }
}

/// Engine phase codes carried in the low byte of `PhaseBegin.a`.
pub const PHASE_SCHED: u32 = 0;
pub const PHASE_DRAFT: u32 = 1;
pub const PHASE_VERIFY: u32 = 2;
pub const PHASE_HOST: u32 = 3;

pub fn phase_name(code: u32) -> &'static str {
    match code & 0xff {
        PHASE_SCHED => "sched",
        PHASE_DRAFT => "draft",
        PHASE_VERIFY => "verify",
        PHASE_HOST => "sampling",
        _ => "phase?",
    }
}

/// One journal slot: fixed-size, `Copy`, no heap behind it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since the journal epoch.
    pub t_us: u64,
    /// Global record sequence number (monotonic; `seq` differences
    /// across a snapshot reveal how many events were overwritten).
    pub seq: u64,
    pub kind: EventKind,
    /// Primary subject (request id, round number, ...; per kind).
    pub id: u64,
    pub a: u32,
    pub b: u32,
    /// Extra payload (fits the struct's existing padding): draft-tree
    /// node count on phase slices, 0 elsewhere.
    pub c: u32,
}

impl Default for TraceEvent {
    fn default() -> Self {
        TraceEvent { t_us: 0, seq: 0, kind: EventKind::ReqArrive, id: 0, a: 0, b: 0, c: 0 }
    }
}

struct Ring {
    /// Preallocated to capacity at construction; never grows.
    buf: Vec<TraceEvent>,
    /// Next sequence number == total events ever recorded.
    next: u64,
}

/// The flight-recorder journal: a mutex-guarded ring of the newest
/// `capacity` events plus a lock-free heartbeat for the watchdog.
pub struct Journal {
    epoch: Instant,
    ring: Mutex<Ring>,
    /// Phase-boundary heartbeat: bumped by [`Tracer::phase_advanced`],
    /// watched by the [`watchdog`]. Not a count of anything — only
    /// "did it change".
    progress: AtomicU64,
}

impl Journal {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Journal {
            epoch: Instant::now(),
            ring: Mutex::new(Ring {
                buf: vec![TraceEvent::default(); capacity],
                next: 0,
            }),
            progress: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    /// Events recorded over the journal's lifetime (≥ what a snapshot
    /// can return once the ring has wrapped).
    pub fn recorded(&self) -> u64 {
        self.ring.lock().unwrap().next
    }

    /// Append one event. Allocation-free: one lock, one slot write.
    pub fn record(&self, kind: EventKind, id: u64, a: u32, b: u32) {
        self.record_c(kind, id, a, b, 0);
    }

    /// [`Journal::record`] with the extra `c` payload word.
    pub fn record_c(&self, kind: EventKind, id: u64, a: u32, b: u32, c: u32) {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let mut g = self.ring.lock().unwrap();
        let cap = g.buf.len() as u64;
        let seq = g.next;
        g.next += 1;
        g.buf[(seq % cap) as usize] = TraceEvent { t_us, seq, kind, id, a, b, c };
    }

    /// Copy out the surviving events, oldest first. Allocates (cold
    /// path: wire command, watchdog, tests).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let g = self.ring.lock().unwrap();
        let cap = g.buf.len() as u64;
        if g.next <= cap {
            return g.buf[..g.next as usize].to_vec();
        }
        let split = (g.next % cap) as usize;
        let mut out = Vec::with_capacity(cap as usize);
        out.extend_from_slice(&g.buf[split..]);
        out.extend_from_slice(&g.buf[..split]);
        out
    }

    pub fn progress(&self) -> u64 {
        self.progress.load(Ordering::Relaxed)
    }
}

/// The recording handle: a clone-cheap `Option<Arc<Journal>>`. The
/// default (`Tracer::off()`) records nothing and holds nothing.
#[derive(Clone, Default)]
pub struct Tracer {
    journal: Option<Arc<Journal>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer").field("enabled", &self.enabled()).finish()
    }
}

impl Tracer {
    /// Disabled tracer: no journal is allocated, `record` is one branch.
    pub fn off() -> Self {
        Tracer { journal: None }
    }

    /// Enabled tracer with a ring of `capacity` events (0 = disabled).
    pub fn new(capacity: usize) -> Self {
        if capacity == 0 {
            return Self::off();
        }
        Tracer { journal: Some(Arc::new(Journal::new(capacity))) }
    }

    pub fn enabled(&self) -> bool {
        self.journal.is_some()
    }

    pub fn journal(&self) -> Option<&Arc<Journal>> {
        self.journal.as_ref()
    }

    #[inline]
    pub fn record(&self, kind: EventKind, id: u64, a: u32, b: u32) {
        if let Some(j) = &self.journal {
            j.record(kind, id, a, b);
        }
    }

    /// [`Tracer::record`] with the extra `c` payload word (tree-shape
    /// args on phase slices).
    #[inline]
    pub fn record_c(&self, kind: EventKind, id: u64, a: u32, b: u32, c: u32) {
        if let Some(j) = &self.journal {
            j.record_c(kind, id, a, b, c);
        }
    }

    /// Bump the watchdog heartbeat: call at every engine phase
    /// boundary. The watchdog treats a frozen heartbeat (with work in
    /// flight) as a stall.
    #[inline]
    pub fn phase_advanced(&self) {
        if let Some(j) = &self.journal {
            j.progress.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Heartbeat value (0 when disabled).
    pub fn progress(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.progress())
    }

    /// Snapshot of surviving events (empty when disabled).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.journal.as_ref().map_or_else(Vec::new, |j| j.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_events_across_wraparound() {
        let j = Journal::new(8);
        for i in 0..20u64 {
            j.record(EventKind::Commit, i, i as u32, 0);
        }
        let snap = j.snapshot();
        assert_eq!(snap.len(), 8);
        let seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<_>>());
        assert!(snap.iter().all(|e| e.id == e.seq), "slot/seq mismatch");
        assert_eq!(j.recorded(), 20);
    }

    #[test]
    fn snapshot_before_wrap_is_exact() {
        let j = Journal::new(16);
        j.record(EventKind::ReqArrive, 7, 3, 1);
        j.record(EventKind::ReqAdmit, 7, 0, 6);
        let snap = j.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].kind, EventKind::ReqArrive);
        assert_eq!(snap[1].kind, EventKind::ReqAdmit);
        assert!(snap[0].t_us <= snap[1].t_us);
    }

    #[test]
    fn off_tracer_has_no_journal() {
        let t = Tracer::off();
        assert!(!t.enabled());
        assert!(t.journal().is_none());
        t.record(EventKind::Commit, 1, 2, 3); // must be a no-op
        t.phase_advanced();
        assert_eq!(t.progress(), 0);
        assert!(t.snapshot().is_empty());
        // capacity 0 is the documented "disabled" spelling
        assert!(!Tracer::new(0).enabled());
    }

    #[test]
    fn heartbeat_advances_only_when_told() {
        let t = Tracer::new(4);
        assert_eq!(t.progress(), 0);
        t.record(EventKind::RoundBegin, 0, 0, 0);
        assert_eq!(t.progress(), 0, "plain records are not heartbeats");
        t.phase_advanced();
        t.phase_advanced();
        assert_eq!(t.progress(), 2);
    }
}
