//! Stall watchdog: post-mortem dumps for a hung engine.
//!
//! The engine bumps the journal heartbeat ([`super::Tracer::phase_advanced`])
//! at every phase boundary. This watchdog watches that heartbeat from
//! its own thread; if it freezes for longer than the configured stall
//! interval *while work is in flight* (an idle engine parked on its
//! request channel is not a stall), it writes one JSON dump — the
//! engine status (in-flight requests, queue depth, pool occupancy) plus
//! the full journal as a Chrome trace — so a soak-test hang turns from
//! "recv timeout" into a readable timeline ending at the stalled
//! request's last recorded event. One dump per frozen heartbeat value:
//! it re-arms when progress resumes.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::kvcache::PoolStatus;
use crate::util::json::Json;

use super::export::chrome_trace;
use super::{EventKind, Tracer};

/// Coarse engine state for the dump, refreshed by the engine loop at
/// round boundaries (and when it goes idle).
#[derive(Debug, Default, Clone)]
pub struct EngineStatus {
    /// Rounds completed so far.
    pub rounds: u64,
    /// Active requests as (request id, tokens committed so far).
    pub active: Vec<(u64, u64)>,
    /// Requests waiting in the batcher queue.
    pub queued: usize,
    /// Requests suspended (preempted, awaiting resume).
    pub parked: usize,
    /// Target-pool occupancy, when the substrate is pool-backed.
    pub pool: Option<PoolStatus>,
}

impl EngineStatus {
    pub fn in_flight(&self) -> bool {
        !self.active.is_empty() || self.queued > 0 || self.parked > 0
    }

    pub fn to_json(&self) -> Json {
        let active = self
            .active
            .iter()
            .map(|&(id, committed)| {
                Json::obj(vec![
                    ("request", Json::from(id as usize)),
                    ("committed", Json::from(committed as usize)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("rounds", Json::from(self.rounds as usize)),
            ("active", Json::Arr(active)),
            ("queued", Json::from(self.queued)),
            ("parked", Json::from(self.parked)),
        ];
        if let Some(p) = &self.pool {
            fields.push((
                "pool",
                Json::obj(vec![
                    ("total_blocks", Json::from(p.total_blocks)),
                    ("free_blocks", Json::from(p.free_blocks)),
                    ("leased_blocks", Json::from(p.leased_blocks)),
                    ("evictable_blocks", Json::from(p.evictable_blocks)),
                    ("blocks_in_use", Json::from(p.blocks_in_use())),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

/// Handle to the watchdog thread; stops (and joins) on drop.
pub struct Watchdog {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Watchdog {
    /// Start watching. Returns `None` when there is nothing to watch
    /// (tracing disabled or a zero interval).
    pub fn spawn(
        tracer: Tracer,
        status: Arc<Mutex<EngineStatus>>,
        stall: Duration,
        path: PathBuf,
    ) -> Option<Watchdog> {
        if !tracer.enabled() || stall.is_zero() {
            return None;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("rsd-watchdog".into())
            .spawn(move || watch(tracer, status, stall, path, stop2))
            .ok()?;
        Some(Watchdog { stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn watch(
    tracer: Tracer,
    status: Arc<Mutex<EngineStatus>>,
    stall: Duration,
    path: PathBuf,
    stop: Arc<AtomicBool>,
) {
    let tick = (stall / 8).clamp(Duration::from_millis(2), Duration::from_millis(250));
    let mut last_beat = tracer.progress();
    let mut frozen_since = Instant::now();
    // heartbeat value already dumped (never re-dump the same stall)
    let mut dumped_at: Option<u64> = None;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(tick);
        let beat = tracer.progress();
        if beat != last_beat {
            last_beat = beat;
            frozen_since = Instant::now();
            dumped_at = None;
            continue;
        }
        if frozen_since.elapsed() < stall || dumped_at == Some(beat) {
            continue;
        }
        let st = status.lock().unwrap().clone();
        if !st.in_flight() {
            // idle engine: a parked heartbeat is expected, keep waiting
            frozen_since = Instant::now();
            continue;
        }
        dumped_at = Some(beat);
        tracer.record(EventKind::Watchdog, 0, beat as u32, 0);
        let doc = Json::obj(vec![
            (
                "watchdog",
                Json::obj(vec![
                    ("stalled_ms", Json::from(frozen_since.elapsed().as_millis() as usize)),
                    ("heartbeat", Json::from(beat as usize)),
                    ("status", st.to_json()),
                ]),
            ),
            ("trace", chrome_trace(&tracer.snapshot())),
        ]);
        if let Err(e) = std::fs::write(&path, format!("{doc}\n")) {
            eprintln!("watchdog: failed to write {}: {e}", path.display());
        } else {
            eprintln!(
                "watchdog: no phase boundary for {:?} with work in flight; dumped {}",
                stall,
                path.display()
            );
        }
    }
}
