//! Journal/metrics export: Chrome trace-event JSON (loadable in
//! `chrome://tracing` or Perfetto) and a Prometheus-style text
//! exposition of a metrics [`Snapshot`].
//!
//! Layout of the Chrome trace: the engine's phase machine lives on
//! `tid 0` ("engine") as B/E duration slices (sched / draft / verify /
//! sampling, with the draft level in the slice args); each request gets
//! its own lane at `tid = request id + 1` carrying instant events for
//! its lifecycle (arrive → admit → commit* → done, plus
//! preempt/resume); batcher queue-depth samples are a counter track.
//! All of this is cold-path: export allocates freely, recording never
//! does.

use crate::coordinator::metrics::Snapshot;
use crate::util::json::Json;

use super::{phase_name, EventKind, TraceEvent, PHASE_DRAFT};

/// Lane for engine-wide events (phases, rounds, KV pool traffic).
const ENGINE_TID: u64 = 0;

fn ev(ph: &str, name: &str, tid: u64, ts_us: u64, args: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("ph", Json::from(ph)),
        ("name", Json::from(name)),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(tid as usize)),
        ("ts", Json::from(ts_us as usize)),
    ];
    if ph == "i" {
        // instant scope: thread
        fields.push(("s", Json::from("t")));
    }
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

/// Render journal events as a Chrome trace-event document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut out = Vec::with_capacity(events.len() + 2);
    // name the engine lane so the viewer reads "engine", not "thread 0"
    out.push(Json::obj(vec![
        ("ph", Json::from("M")),
        ("name", Json::from("thread_name")),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(ENGINE_TID as usize)),
        ("args", Json::obj(vec![("name", Json::from("engine"))])),
    ]));
    // Open-slice depth per phase code (unknown codes share the last
    // slot, matching `phase_name`). Once the ring has wrapped, a
    // PhaseEnd can survive its matching PhaseBegin; emitting that "E"
    // unpaired makes chrome://tracing/Perfetto misnest every later
    // slice on the lane, so orphaned ends are dropped.
    let mut open_phases = [0u64; 5];
    for e in events {
        let req_lane = e.id + 1;
        match e.kind {
            EventKind::PhaseBegin | EventKind::PhaseEnd => {
                let slot = ((e.a & 0xff) as usize).min(open_phases.len() - 1);
                let ph = if e.kind == EventKind::PhaseBegin {
                    open_phases[slot] += 1;
                    "B"
                } else if open_phases[slot] > 0 {
                    open_phases[slot] -= 1;
                    "E"
                } else {
                    continue; // begin was overwritten by wraparound
                };
                let mut args = vec![
                    ("round", Json::from(e.id as usize)),
                    ("groups", Json::from(e.b as usize)),
                ];
                if e.a & 0xff == PHASE_DRAFT {
                    args.push(("level", Json::from((e.a >> 8) as usize)));
                }
                out.push(ev(ph, phase_name(e.a), ENGINE_TID, e.t_us, args));
            }
            EventKind::RoundBegin => out.push(ev(
                "i",
                "round",
                ENGINE_TID,
                e.t_us,
                vec![
                    ("round", Json::from(e.id as usize)),
                    ("active", Json::from(e.a as usize)),
                    ("queued", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqArrive => out.push(ev(
                "i",
                "arrive",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("prompt_tokens", Json::from(e.a as usize)),
                    ("queue_depth", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqAdmit => out.push(ev(
                "i",
                "admit",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("mid_round", Json::Bool(e.a != 0)),
                    ("weight", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqPreempt => out.push(ev(
                "i",
                "preempt",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("committed", Json::from(e.a as usize)),
                ],
            )),
            EventKind::ReqResume => out.push(ev(
                "i",
                "resume",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("kv_hit_tokens", Json::from(e.a as usize)),
                ],
            )),
            EventKind::ReqDone => out.push(ev(
                "i",
                "done",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("generated", Json::from(e.a as usize)),
                    ("preemptions", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqError => out.push(ev(
                "i",
                "error",
                req_lane,
                e.t_us,
                vec![("request", Json::from(e.id as usize))],
            )),
            EventKind::Commit => out.push(ev(
                "i",
                "commit",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("accepted", Json::from(e.a as usize)),
                    ("bonus", Json::from(e.b as usize)),
                ],
            )),
            EventKind::KvAcquire | EventKind::KvPublish | EventKind::KvEvict => {
                let (ka, kb) = match e.kind {
                    EventKind::KvAcquire => ("hit_tokens", "lookup_tokens"),
                    EventKind::KvPublish => ("blocks", "tokens"),
                    _ => ("blocks", "_"),
                };
                let mut args = vec![(ka, Json::from(e.a as usize))];
                if kb != "_" {
                    args.push((kb, Json::from(e.b as usize)));
                }
                out.push(ev("i", e.kind.name(), ENGINE_TID, e.t_us, args));
            }
            EventKind::QueueDepth => out.push(ev(
                "C",
                "queue",
                ENGINE_TID,
                e.t_us,
                vec![
                    ("queued", Json::from(e.a as usize)),
                    ("active", Json::from(e.b as usize)),
                ],
            )),
            EventKind::Watchdog => out.push(ev(
                "i",
                "watchdog",
                ENGINE_TID,
                e.t_us,
                vec![("heartbeat", Json::from(e.a as usize))],
            )),
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

fn prom_line(out: &mut String, name: &str, kind: &str, value: f64) {
    out.push_str(&format!("# TYPE {name} {kind}\n{name} {value}\n"));
}

fn prom_summary(out: &mut String, name: &str, s: &crate::trace::hist::HistSummary) {
    out.push_str(&format!("# TYPE {name} summary\n"));
    for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
        out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
    }
    out.push_str(&format!("{name}_sum {}\n", s.mean * s.count as f64));
    out.push_str(&format!("{name}_count {}\n", s.count));
}

/// Prometheus text exposition of a metrics snapshot. Metric names are
/// stable (documented in the README's Observability section).
pub fn prometheus(s: &Snapshot) -> String {
    let mut o = String::new();
    prom_line(&mut o, "rsd_requests_admitted_total", "counter", s.admitted as f64);
    prom_line(&mut o, "rsd_requests_rejected_total", "counter", s.rejected as f64);
    prom_line(&mut o, "rsd_requests_completed_total", "counter", s.completed as f64);
    prom_line(&mut o, "rsd_requests_failed_total", "counter", s.failed as f64);
    prom_line(&mut o, "rsd_requests_shed_total", "counter", s.shed as f64);
    prom_line(&mut o, "rsd_retries_total", "counter", s.retries as f64);
    prom_line(&mut o, "rsd_requests_cancelled_total", "counter", s.cancelled as f64);
    prom_line(&mut o, "rsd_tokens_out_total", "counter", s.tokens_out as f64);
    prom_line(&mut o, "rsd_decode_rounds_total", "counter", s.decode_rounds as f64);
    prom_line(&mut o, "rsd_draft_calls_total", "counter", s.draft_calls as f64);
    prom_line(&mut o, "rsd_fused_calls_total", "counter", s.fused_calls as f64);
    prom_line(&mut o, "rsd_mid_round_admitted_total", "counter", s.mid_round_admitted as f64);
    prom_line(&mut o, "rsd_preemptions_total", "counter", s.preemptions as f64);
    prom_line(&mut o, "rsd_resumes_total", "counter", s.resumes as f64);
    prom_line(&mut o, "rsd_kv_hit_tokens_total", "counter", s.kv_hit_tokens as f64);
    prom_line(&mut o, "rsd_kv_lookup_tokens_total", "counter", s.kv_lookup_tokens as f64);
    prom_line(&mut o, "rsd_kv_cow_copies_total", "counter", s.kv_cow_copies as f64);
    prom_line(&mut o, "rsd_kv_evictions_total", "counter", s.kv_evictions as f64);
    prom_line(&mut o, "rsd_kv_blocks_in_use", "gauge", s.kv_blocks_in_use as f64);
    prom_line(&mut o, "rsd_kv_blocks_total", "gauge", s.kv_blocks_total as f64);
    prom_line(&mut o, "rsd_kv_hit_rate", "gauge", s.kv_hit_rate);
    prom_line(&mut o, "rsd_fused_mean_batch", "gauge", s.fused_mean_batch);
    // latency/ttft/queue-wait summaries carry their own exact sample
    // counts: TTFT is only recorded for requests that streamed a token,
    // and queue-wait counts resume-after-preemption re-admissions, so
    // neither `completed` nor `admitted` would make `_sum`/`_count` add up
    prom_summary(&mut o, "rsd_request_latency_seconds", &s.latency);
    prom_summary(&mut o, "rsd_ttft_seconds", &s.ttft);
    prom_summary(&mut o, "rsd_queue_wait_seconds", &s.queue_wait);
    prom_summary(&mut o, "rsd_round_seconds", &s.round_time);
    prom_summary(&mut o, "rsd_phase_sched_seconds", &s.phase_sched);
    prom_summary(&mut o, "rsd_phase_draft_seconds", &s.phase_draft);
    prom_summary(&mut o, "rsd_phase_verify_seconds", &s.phase_verify);
    prom_summary(&mut o, "rsd_phase_sampling_seconds", &s.phase_host);
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Tracer, PHASE_VERIFY};

    #[test]
    fn chrome_trace_is_valid_and_balanced() {
        let t = Tracer::new(64);
        t.record(EventKind::ReqArrive, 3, 5, 1);
        t.record(EventKind::ReqAdmit, 3, 0, 9);
        t.record(EventKind::PhaseBegin, 0, PHASE_DRAFT | (2 << 8), 1);
        t.record(EventKind::PhaseEnd, 0, PHASE_DRAFT | (2 << 8), 1);
        t.record(EventKind::PhaseBegin, 0, PHASE_VERIFY, 1);
        t.record(EventKind::PhaseEnd, 0, PHASE_VERIFY, 1);
        t.record(EventKind::Commit, 3, 2, 1);
        t.record(EventKind::ReqDone, 3, 6, 0);
        let doc = chrome_trace(&t.snapshot());
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata + 8 events
        assert_eq!(evs.len(), 9);
        let phs: Vec<&str> =
            evs.iter().map(|e| e.str_field("ph").unwrap()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "B").count(), 2);
        assert_eq!(phs.iter().filter(|p| **p == "E").count(), 2);
        // the draft slice carries its level
        let draft = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("draft"))
            .unwrap();
        assert_eq!(draft.get("args").unwrap().usize_field("level").unwrap(), 2);
        // request events live on the request lane (tid = id + 1)
        let done = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("done"))
            .unwrap();
        assert_eq!(done.usize_field("tid").unwrap(), 4);
    }

    #[test]
    fn wrapped_ring_drops_orphaned_phase_ends() {
        let t = Tracer::new(4);
        t.record(EventKind::PhaseBegin, 0, PHASE_VERIFY, 1);
        for i in 0..4 {
            t.record(EventKind::Commit, i, 1, 0); // overwrites the begin
        }
        t.record(EventKind::PhaseEnd, 0, PHASE_VERIFY, 1);
        t.record(EventKind::PhaseBegin, 1, PHASE_DRAFT, 1);
        t.record(EventKind::PhaseEnd, 1, PHASE_DRAFT, 1);
        let snap = t.snapshot();
        // the verify end survived its begin; the draft pair is intact
        assert!(snap.iter().any(|e| e.kind == EventKind::PhaseEnd && e.a == PHASE_VERIFY));
        let doc = chrome_trace(&snap);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let count = |ph: &str, name: &str| {
            evs.iter()
                .filter(|e| {
                    e.str_field("ph").unwrap() == ph && e.str_field("name").unwrap() == name
                })
                .count()
        };
        // the orphaned verify "E" is dropped, never emitted unmatched
        assert_eq!(count("E", "verify"), 0);
        assert_eq!(count("B", "draft"), 1);
        assert_eq!(count("E", "draft"), 1);
    }

    #[test]
    fn prometheus_exposition_has_stable_names() {
        let m = crate::coordinator::metrics::Metrics::default();
        m.add(&m.completed, 3);
        m.record_latency(0.25);
        m.record_phase(crate::trace::PHASE_DRAFT, 0.004);
        let text = prometheus(&m.snapshot());
        for needle in [
            "# TYPE rsd_requests_completed_total counter",
            "rsd_requests_completed_total 3",
            "rsd_request_latency_seconds{quantile=\"0.5\"}",
            "rsd_phase_draft_seconds_count 1",
            "rsd_kv_blocks_total",
            "# TYPE rsd_requests_shed_total counter",
            "rsd_retries_total 0",
            "rsd_requests_cancelled_total 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
