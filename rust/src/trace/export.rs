//! Journal/metrics export: Chrome trace-event JSON (loadable in
//! `chrome://tracing` or Perfetto) and a Prometheus-style text
//! exposition of a metrics [`Snapshot`].
//!
//! Layout of the Chrome trace: the engine's phase machine lives on
//! `tid 0` ("engine") as B/E duration slices (sched / draft / verify /
//! sampling, with the draft level in the slice args); each request gets
//! its own lane at `tid = request id + 1` carrying instant events for
//! its lifecycle (arrive → admit → commit* → done, plus
//! preempt/resume); batcher queue-depth samples are a counter track.
//! All of this is cold-path: export allocates freely, recording never
//! does.

use crate::coordinator::metrics::Snapshot;
use crate::util::json::Json;

use super::{phase_name, EventKind, TraceEvent, PHASE_DRAFT};

/// Lane for engine-wide events (phases, rounds, KV pool traffic).
const ENGINE_TID: u64 = 0;

fn ev(ph: &str, name: &str, tid: u64, ts_us: u64, args: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("ph", Json::from(ph)),
        ("name", Json::from(name)),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(tid as usize)),
        ("ts", Json::from(ts_us as usize)),
    ];
    if ph == "i" {
        // instant scope: thread
        fields.push(("s", Json::from("t")));
    }
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

/// Render journal events as a Chrome trace-event document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut out = Vec::with_capacity(events.len() + 2);
    // name the engine lane so the viewer reads "engine", not "thread 0"
    out.push(Json::obj(vec![
        ("ph", Json::from("M")),
        ("name", Json::from("thread_name")),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(ENGINE_TID as usize)),
        ("args", Json::obj(vec![("name", Json::from("engine"))])),
    ]));
    // Open-slice depth per phase code (unknown codes share the last
    // slot, matching `phase_name`). Once the ring has wrapped, a
    // PhaseEnd can survive its matching PhaseBegin; emitting that "E"
    // unpaired makes chrome://tracing/Perfetto misnest every later
    // slice on the lane, so orphaned ends are dropped.
    let mut open_phases = [0u64; 5];
    for e in events {
        let req_lane = e.id + 1;
        match e.kind {
            EventKind::PhaseBegin | EventKind::PhaseEnd => {
                let slot = ((e.a & 0xff) as usize).min(open_phases.len() - 1);
                let ph = if e.kind == EventKind::PhaseBegin {
                    open_phases[slot] += 1;
                    "B"
                } else if open_phases[slot] > 0 {
                    open_phases[slot] -= 1;
                    "E"
                } else {
                    continue; // begin was overwritten by wraparound
                };
                let mut args = vec![
                    ("round", Json::from(e.id as usize)),
                    ("groups", Json::from(e.b as usize)),
                ];
                if e.a & 0xff == PHASE_DRAFT {
                    args.push(("level", Json::from((e.a >> 8) as usize)));
                }
                if e.c > 0 {
                    // round tree shape: draft-tree nodes in flight
                    args.push(("nodes", Json::from(e.c as usize)));
                }
                out.push(ev(ph, phase_name(e.a), ENGINE_TID, e.t_us, args));
            }
            EventKind::RoundBegin => out.push(ev(
                "i",
                "round",
                ENGINE_TID,
                e.t_us,
                vec![
                    ("round", Json::from(e.id as usize)),
                    ("active", Json::from(e.a as usize)),
                    ("queued", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqArrive => out.push(ev(
                "i",
                "arrive",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("prompt_tokens", Json::from(e.a as usize)),
                    ("queue_depth", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqAdmit => out.push(ev(
                "i",
                "admit",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("mid_round", Json::Bool(e.a != 0)),
                    ("weight", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqPreempt => out.push(ev(
                "i",
                "preempt",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("committed", Json::from(e.a as usize)),
                ],
            )),
            EventKind::ReqResume => out.push(ev(
                "i",
                "resume",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("kv_hit_tokens", Json::from(e.a as usize)),
                ],
            )),
            EventKind::ReqDone => out.push(ev(
                "i",
                "done",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("generated", Json::from(e.a as usize)),
                    ("preemptions", Json::from(e.b as usize)),
                ],
            )),
            EventKind::ReqError => out.push(ev(
                "i",
                "error",
                req_lane,
                e.t_us,
                vec![("request", Json::from(e.id as usize))],
            )),
            EventKind::Commit => out.push(ev(
                "i",
                "commit",
                req_lane,
                e.t_us,
                vec![
                    ("request", Json::from(e.id as usize)),
                    ("accepted", Json::from(e.a as usize)),
                    ("bonus", Json::from(e.b as usize)),
                ],
            )),
            EventKind::KvAcquire | EventKind::KvPublish | EventKind::KvEvict => {
                let (ka, kb) = match e.kind {
                    EventKind::KvAcquire => ("hit_tokens", "lookup_tokens"),
                    EventKind::KvPublish => ("blocks", "tokens"),
                    _ => ("blocks", "_"),
                };
                let mut args = vec![(ka, Json::from(e.a as usize))];
                if kb != "_" {
                    args.push((kb, Json::from(e.b as usize)));
                }
                out.push(ev("i", e.kind.name(), ENGINE_TID, e.t_us, args));
            }
            EventKind::QueueDepth => out.push(ev(
                "C",
                "queue",
                ENGINE_TID,
                e.t_us,
                vec![
                    ("queued", Json::from(e.a as usize)),
                    ("active", Json::from(e.b as usize)),
                ],
            )),
            EventKind::Watchdog => out.push(ev(
                "i",
                "watchdog",
                ENGINE_TID,
                e.t_us,
                vec![("heartbeat", Json::from(e.a as usize))],
            )),
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

fn prom_line(out: &mut String, name: &str, kind: &str, value: f64) {
    out.push_str(&format!("# TYPE {name} {kind}\n{name} {value}\n"));
}

fn prom_summary(out: &mut String, name: &str, s: &crate::trace::hist::HistSummary) {
    out.push_str(&format!("# TYPE {name} summary\n"));
    for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
        out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
    }
    out.push_str(&format!("{name}_sum {}\n", s.mean * s.count as f64));
    out.push_str(&format!("{name}_count {}\n", s.count));
}

/// Prometheus text exposition of a metrics snapshot. Metric names are
/// stable (documented in the README's Observability section).
pub fn prometheus(s: &Snapshot) -> String {
    let mut o = String::new();
    // every shared scalar comes off the export table (keeps JSON and
    // Prometheus key sets in lockstep; see `Snapshot::scalar_exports`)
    for e in s.scalar_exports() {
        prom_line(&mut o, e.prom_name, e.prom_kind, e.value);
    }
    // latency/ttft/queue-wait summaries carry their own exact sample
    // counts: TTFT is only recorded for requests that streamed a token,
    // and queue-wait counts resume-after-preemption re-admissions, so
    // neither `completed` nor `admitted` would make `_sum`/`_count` add up
    prom_summary(&mut o, "rsd_request_latency_seconds", &s.latency);
    prom_summary(&mut o, "rsd_ttft_seconds", &s.ttft);
    prom_summary(&mut o, "rsd_queue_wait_seconds", &s.queue_wait);
    prom_summary(&mut o, "rsd_round_seconds", &s.round_time);
    prom_summary(&mut o, "rsd_phase_sched_seconds", &s.phase_sched);
    prom_summary(&mut o, "rsd_phase_draft_seconds", &s.phase_draft);
    prom_summary(&mut o, "rsd_phase_verify_seconds", &s.phase_verify);
    prom_summary(&mut o, "rsd_phase_sampling_seconds", &s.phase_host);
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Tracer, PHASE_VERIFY};

    #[test]
    fn chrome_trace_is_valid_and_balanced() {
        let t = Tracer::new(64);
        t.record(EventKind::ReqArrive, 3, 5, 1);
        t.record(EventKind::ReqAdmit, 3, 0, 9);
        t.record(EventKind::PhaseBegin, 0, PHASE_DRAFT | (2 << 8), 1);
        t.record(EventKind::PhaseEnd, 0, PHASE_DRAFT | (2 << 8), 1);
        t.record(EventKind::PhaseBegin, 0, PHASE_VERIFY, 1);
        t.record(EventKind::PhaseEnd, 0, PHASE_VERIFY, 1);
        t.record(EventKind::Commit, 3, 2, 1);
        t.record(EventKind::ReqDone, 3, 6, 0);
        let doc = chrome_trace(&t.snapshot());
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata + 8 events
        assert_eq!(evs.len(), 9);
        let phs: Vec<&str> =
            evs.iter().map(|e| e.str_field("ph").unwrap()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "B").count(), 2);
        assert_eq!(phs.iter().filter(|p| **p == "E").count(), 2);
        // the draft slice carries its level
        let draft = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("draft"))
            .unwrap();
        assert_eq!(draft.get("args").unwrap().usize_field("level").unwrap(), 2);
        // request events live on the request lane (tid = id + 1)
        let done = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("done"))
            .unwrap();
        assert_eq!(done.usize_field("tid").unwrap(), 4);
    }

    #[test]
    fn wrapped_ring_drops_orphaned_phase_ends() {
        let t = Tracer::new(4);
        t.record(EventKind::PhaseBegin, 0, PHASE_VERIFY, 1);
        for i in 0..4 {
            t.record(EventKind::Commit, i, 1, 0); // overwrites the begin
        }
        t.record(EventKind::PhaseEnd, 0, PHASE_VERIFY, 1);
        t.record(EventKind::PhaseBegin, 1, PHASE_DRAFT, 1);
        t.record(EventKind::PhaseEnd, 1, PHASE_DRAFT, 1);
        let snap = t.snapshot();
        // the verify end survived its begin; the draft pair is intact
        assert!(snap.iter().any(|e| e.kind == EventKind::PhaseEnd && e.a == PHASE_VERIFY));
        let doc = chrome_trace(&snap);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let count = |ph: &str, name: &str| {
            evs.iter()
                .filter(|e| {
                    e.str_field("ph").unwrap() == ph && e.str_field("name").unwrap() == name
                })
                .count()
        };
        // the orphaned verify "E" is dropped, never emitted unmatched
        assert_eq!(count("E", "verify"), 0);
        assert_eq!(count("B", "draft"), 1);
        assert_eq!(count("E", "draft"), 1);
    }

    #[test]
    fn phase_slices_carry_tree_shape_args() {
        let t = Tracer::new(16);
        t.record_c(EventKind::PhaseBegin, 7, PHASE_DRAFT | (1 << 8), 3, 12);
        t.record_c(EventKind::PhaseEnd, 7, PHASE_DRAFT | (1 << 8), 3, 12);
        t.record(EventKind::PhaseBegin, 7, PHASE_VERIFY, 3);
        t.record(EventKind::PhaseEnd, 7, PHASE_VERIFY, 3);
        let doc = chrome_trace(&t.snapshot());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let draft = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("draft"))
            .unwrap();
        assert_eq!(draft.get("args").unwrap().usize_field("nodes").unwrap(), 12);
        // no c payload recorded -> no nodes arg
        let verify = evs
            .iter()
            .find(|e| e.str_field("name").ok() == Some("verify"))
            .unwrap();
        assert!(verify.get("args").unwrap().get("nodes").is_none());
    }

    /// Reflection gate for the cumulative-vs-window drift class of bug:
    /// every scalar on the shared export table must appear in BOTH the
    /// snapshot JSON and the Prometheus exposition, every exposed `#
    /// TYPE` must be a table scalar or a known summary, and every
    /// scalar JSON key must be a table entry or a known derived field.
    #[test]
    fn snapshot_json_and_prometheus_scalars_stay_in_lockstep() {
        let m = crate::coordinator::metrics::Metrics::default();
        m.add(&m.completed, 3);
        m.record_latency(0.25);
        let s = m.snapshot();
        let table = s.scalar_exports();
        let json = s.to_json();
        let text = prometheus(&s);
        for e in &table {
            assert!(json.get(e.json_key).is_some(), "table key {:?} missing from JSON", e.json_key);
            assert!(
                text.contains(&format!("# TYPE {} {}\n", e.prom_name, e.prom_kind)),
                "table metric {:?} missing from exposition",
                e.prom_name
            );
        }
        // every exposed metric family is accounted for
        let summaries = [
            "rsd_request_latency_seconds",
            "rsd_ttft_seconds",
            "rsd_queue_wait_seconds",
            "rsd_round_seconds",
            "rsd_phase_sched_seconds",
            "rsd_phase_draft_seconds",
            "rsd_phase_verify_seconds",
            "rsd_phase_sampling_seconds",
        ];
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            let known = table.iter().any(|e| e.prom_name == name) || summaries.contains(&name);
            assert!(known, "exposed metric {name:?} is not on the export table");
        }
        // every scalar JSON key is a table entry or a derived field
        // whose source histogram IS exported as a Prometheus summary
        let derived = [
            "latency_p50", "latency_p95", "latency_p99", "latency_mean",
            "ttft_p50", "ttft_p95", "ttft_p99", "ttft_mean",
            "queue_wait_p50", "queue_wait_p95", "queue_wait_p99", "queue_wait_mean",
        ];
        for (key, val) in json.as_obj().unwrap() {
            if matches!(val, Json::Num(_)) {
                let known = table.iter().any(|e| e.json_key == key)
                    || derived.contains(&key.as_str());
                assert!(known, "scalar JSON key {key:?} is not on the export table");
            }
        }
    }

    #[test]
    fn prometheus_exposition_has_stable_names() {
        let m = crate::coordinator::metrics::Metrics::default();
        m.add(&m.completed, 3);
        m.record_latency(0.25);
        m.record_phase(crate::trace::PHASE_DRAFT, 0.004);
        let text = prometheus(&m.snapshot());
        for needle in [
            "# TYPE rsd_requests_completed_total counter",
            "rsd_requests_completed_total 3",
            "rsd_request_latency_seconds{quantile=\"0.5\"}",
            "rsd_phase_draft_seconds_count 1",
            "rsd_kv_blocks_total",
            "# TYPE rsd_requests_shed_total counter",
            "rsd_retries_total 0",
            "rsd_requests_cancelled_total 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
