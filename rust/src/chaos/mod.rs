//! Deterministic fault injection for the serving engine.
//!
//! [`ChaosLm`] wraps any [`Llm`] (in practice [`crate::sim::SimLm`]) and
//! injects failures according to a seeded [`FaultPlan`]: transient and
//! persistent eval faults targeting specific *sessions*, latency spikes
//! on specific *eval calls*, and resume-path failures (the
//! `begin_with_prefix` calls the engine issues when un-parking a
//! preempted request). Everything is deterministic — the same plan
//! against the same workload trips the same faults in the same order —
//! which is what lets the chaos soak assert bit-identical streams for
//! unaffected (and retried) requests.
//!
//! Fault identity is the *session id*, assigned in `begin` /
//! `begin_with_prefix` call order. The engine opens target sessions in
//! admission order (one per stepper), so "session 3" is a stable,
//! reproducible handle on "the 4th admitted request's target session".
//! A transient fault poisons a session until it is dropped; the
//! engine's retry machinery suspends the stepper (dropping its
//! sessions) and resumes into *fresh* sessions, so a bounded retry
//! deterministically clears a transient fault. Persistent faults follow
//! the request id-space the same way but are reported non-retryable.
//!
//! ## Atomicity contract
//!
//! `eval_batch_into` checks the plan against **every** group before
//! delegating a single row to the inner model. A fused call that is
//! going to fail therefore fails without mutating any session — the
//! precondition for the engine's blast-radius re-drive (retry the phase
//! per group; only the poisoned group fails).

use std::collections::{BTreeMap, BTreeSet};
use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::errors::{EngineError, ErrorKind};
use crate::llm::{EvalNode, Llm, LogitsBatch};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Knobs for [`FaultPlan::seeded`].
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Session-id universe faults are drawn from (engine target sessions
    /// are numbered in admission order, so set this near the request
    /// count).
    pub sessions: u64,
    /// Number of sessions hit by transient (retryable) eval faults.
    pub transient: usize,
    /// Number of sessions hit by persistent (terminal) eval faults.
    pub persistent: usize,
    /// Number of latency spikes, scattered over the first
    /// `spike_calls` eval calls.
    pub spikes: usize,
    pub spike_calls: u64,
    /// Deterministic spin rounds per spike.
    pub spike_spin: u64,
    /// Fail the first N resume-path `begin_with_prefix` calls.
    pub resume_faults: u64,
    /// Only hints longer than this trip a resume fault (resume hints
    /// are prompt+generated, so a threshold above the longest prompt
    /// targets resumes exclusively).
    pub resume_hint_min: usize,
    /// Report resume faults as retryable (pool exhaustion) or terminal.
    pub resume_retryable: bool,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            sessions: 64,
            transient: 4,
            persistent: 2,
            spikes: 8,
            spike_calls: 512,
            spike_spin: 2_000,
            resume_faults: 0,
            resume_hint_min: usize::MAX,
            resume_retryable: true,
        }
    }
}

/// The full, serializable fault schedule for one chaos run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Sessions whose evals fail retryably until the session is dropped.
    pub transient_sessions: BTreeSet<u64>,
    /// Sessions whose evals fail terminally.
    pub persistent_sessions: BTreeSet<u64>,
    /// eval-call index → spin rounds burned before that call proceeds.
    pub latency_spikes: BTreeMap<u64, u64>,
    /// Fail the first N qualifying `begin_with_prefix` calls.
    pub resume_faults: u64,
    /// Hint-length threshold for a resume fault to qualify.
    pub resume_hint_min: usize,
    /// Retryable (pool-exhausted) vs terminal resume failures.
    pub resume_retryable: bool,
}

impl FaultPlan {
    /// No faults: the wrapper becomes a transparent passthrough.
    pub fn none() -> Self {
        Self { resume_hint_min: usize::MAX, resume_retryable: true, ..Self::default() }
    }

    /// Draw a deterministic plan from a seed. Persistent targets are
    /// picked first; transient picks skip them, so the two sets are
    /// disjoint and a session's failure mode is unambiguous.
    pub fn seeded(seed: u64, cfg: &ChaosConfig) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut persistent = BTreeSet::new();
        while persistent.len() < cfg.persistent.min(cfg.sessions as usize) {
            persistent.insert(rng.next_u64() % cfg.sessions);
        }
        let mut transient = BTreeSet::new();
        let room = (cfg.sessions as usize).saturating_sub(persistent.len());
        while transient.len() < cfg.transient.min(room) {
            let id = rng.next_u64() % cfg.sessions;
            if !persistent.contains(&id) {
                transient.insert(id);
            }
        }
        let mut latency_spikes = BTreeMap::new();
        for _ in 0..cfg.spikes {
            latency_spikes.insert(rng.next_u64() % cfg.spike_calls.max(1), cfg.spike_spin);
        }
        Self {
            transient_sessions: transient,
            persistent_sessions: persistent,
            latency_spikes,
            resume_faults: cfg.resume_faults,
            resume_hint_min: cfg.resume_hint_min,
            resume_retryable: cfg.resume_retryable,
        }
    }

    /// Serializable schedule (CI uploads this next to the trace so a
    /// failing soak is reproducible from artifacts alone).
    pub fn to_json(&self) -> Json {
        let ids = |s: &BTreeSet<u64>| {
            Json::Arr(s.iter().map(|&id| Json::from(id as usize)).collect())
        };
        let spikes = self
            .latency_spikes
            .iter()
            .map(|(&call, &spin)| {
                Json::obj(vec![
                    ("call", Json::from(call as usize)),
                    ("spin", Json::from(spin as usize)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("transient_sessions", ids(&self.transient_sessions)),
            ("persistent_sessions", ids(&self.persistent_sessions)),
            ("latency_spikes", Json::Arr(spikes)),
            ("resume_faults", Json::from(self.resume_faults as usize)),
            (
                "resume_hint_min",
                if self.resume_hint_min == usize::MAX {
                    Json::Null
                } else {
                    Json::from(self.resume_hint_min)
                },
            ),
            ("resume_retryable", Json::Bool(self.resume_retryable)),
        ])
    }
}

/// How many faults of each class actually fired (assert coverage in
/// tests: a plan that never trips proves nothing).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosTrips {
    pub transient: u64,
    pub persistent: u64,
    pub resume: u64,
    pub spikes: u64,
}

#[derive(Debug)]
struct ChaosState {
    plan: FaultPlan,
    next_session: u64,
    evals: u64,
    trips: ChaosTrips,
}

/// A session with its chaos identity attached.
#[derive(Debug)]
pub struct ChaosSession<S> {
    inner: S,
    id: u64,
}

impl<S> ChaosSession<S> {
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// Fault-injecting wrapper: see the module docs for the model.
#[derive(Debug, Clone)]
pub struct ChaosLm<L: Llm> {
    inner: L,
    st: Arc<Mutex<ChaosState>>,
}

impl<L: Llm> ChaosLm<L> {
    pub fn new(inner: L, plan: FaultPlan) -> Self {
        let st = ChaosState {
            plan,
            next_session: 0,
            evals: 0,
            trips: ChaosTrips::default(),
        };
        Self { inner, st: Arc::new(Mutex::new(st)) }
    }

    pub fn inner(&self) -> &L {
        &self.inner
    }

    pub fn plan_json(&self) -> Json {
        self.st.lock().unwrap().plan.to_json()
    }

    pub fn trips(&self) -> ChaosTrips {
        self.st.lock().unwrap().trips
    }

    /// Deterministic CPU burn standing in for a slow device dispatch
    /// (a spin, not a sleep: wall-clock noise would break timing-free
    /// determinism arguments in tests).
    fn spin(rounds: u64) {
        let mut acc = 0x9e3779b97f4a7c15u64;
        for i in 0..rounds {
            acc = (acc ^ i).wrapping_mul(0xbf58476d1ce4e5b9);
            acc ^= acc >> 27;
        }
        std::hint::black_box(acc);
    }

    /// One eval call: bump the call counter, burn any scheduled spike,
    /// and return the fault (if any) for `session_id`.
    fn fault_for(st: &mut ChaosState, session_id: u64) -> Option<EngineError> {
        if st.plan.persistent_sessions.contains(&session_id) {
            st.trips.persistent += 1;
            return Some(EngineError::new(
                ErrorKind::EvalPersistent,
                format!("chaos: persistent eval fault on session {session_id}"),
            ));
        }
        if st.plan.transient_sessions.contains(&session_id) {
            st.trips.transient += 1;
            return Some(EngineError::new(
                ErrorKind::EvalTransient,
                format!("chaos: transient eval fault on session {session_id}"),
            ));
        }
        None
    }

    fn on_eval_call(st: &mut ChaosState) -> Option<u64> {
        let call = st.evals;
        st.evals += 1;
        let spin = st.plan.latency_spikes.get(&call).copied();
        if spin.is_some() {
            st.trips.spikes += 1;
        }
        spin
    }

    /// Shared admission gate for `begin_with_prefix` / `begin_sized`:
    /// trips a resume fault when the hint qualifies, otherwise assigns
    /// the next session id. Both entry points share one budget — the
    /// engine resumes through `begin_sized` when the substrate supports
    /// right-sized sessions, and the fault plan must not care which
    /// doorway the resume used.
    fn admit_with_hint(&self, prefix_hint: &[u32]) -> Result<u64> {
        let mut st = self.st.lock().unwrap();
        if st.trips.resume < st.plan.resume_faults
            && prefix_hint.len() > st.plan.resume_hint_min
        {
            st.trips.resume += 1;
            let e = if st.plan.resume_retryable {
                EngineError::new(
                    ErrorKind::PoolExhausted,
                    "chaos: resume denied (simulated pool exhaustion)",
                )
            } else {
                EngineError::new(ErrorKind::EvalPersistent, "chaos: resume denied (terminal)")
            };
            return Err(e.into());
        }
        let id = st.next_session;
        st.next_session += 1;
        Ok(id)
    }
}

/// How to damage a cold-tier spill file on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillDamage {
    /// Flip one byte in the middle of the file (checksum mismatch).
    CorruptByte,
    /// Cut the file to half its length (truncated header/payload).
    Truncate,
}

/// Deterministically damage up to `count` cold-tier spill files under
/// `dir`, returning the paths actually hit. Victims are drawn with a
/// seeded RNG over the *sorted* `spill_*.tensors` listing, so the same
/// seed against the same store damages the same files — the chaos soak
/// needs reproducible corruption to assert the read path degrades (and
/// deletes the bad file) rather than faulting. Non-spill files (the
/// radix snapshot) are left alone; pass them explicitly to
/// [`damage_file`] to soak snapshot corruption.
pub fn damage_spill_files(
    dir: &Path,
    seed: u64,
    count: usize,
    mode: SpillDamage,
) -> Vec<PathBuf> {
    let mut spills: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("spill_") && n.ends_with(".tensors"))
            })
            .collect(),
        Err(_) => return Vec::new(),
    };
    spills.sort();
    let mut rng = Rng::seed_from_u64(seed);
    let mut hit = Vec::new();
    for _ in 0..count.min(spills.len()) {
        let idx = (rng.next_u64() % spills.len() as u64) as usize;
        let p = spills.swap_remove(idx);
        if damage_file(&p, mode) {
            hit.push(p);
        }
    }
    hit
}

/// Apply one [`SpillDamage`] to a single file; returns whether the
/// damage stuck (a vanished or empty file counts as already-damaged
/// enough to skip).
pub fn damage_file(path: &Path, mode: SpillDamage) -> bool {
    let Ok(meta) = std::fs::metadata(path) else { return false };
    let len = meta.len();
    if len == 0 {
        return false;
    }
    match mode {
        SpillDamage::Truncate => {
            let Ok(f) = OpenOptions::new().write(true).open(path) else { return false };
            f.set_len(len / 2).is_ok()
        }
        SpillDamage::CorruptByte => {
            let Ok(mut f) = OpenOptions::new().read(true).write(true).open(path) else {
                return false;
            };
            let pos = len / 2;
            let mut b = [0u8; 1];
            if f.seek(SeekFrom::Start(pos)).is_err() || f.read_exact(&mut b).is_err() {
                return false;
            }
            b[0] ^= 0xA5;
            f.seek(SeekFrom::Start(pos)).is_ok() && f.write_all(&b).is_ok()
        }
    }
}

impl<L: Llm> Llm for ChaosLm<L> {
    type Session = ChaosSession<L::Session>;

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn param_count(&self) -> usize {
        self.inner.param_count()
    }

    fn begin(&self) -> Result<Self::Session> {
        let id = {
            let mut st = self.st.lock().unwrap();
            let id = st.next_session;
            st.next_session += 1;
            id
        };
        Ok(ChaosSession { inner: self.inner.begin()?, id })
    }

    fn begin_with_prefix(&self, prefix_hint: &[u32]) -> Result<Self::Session> {
        let id = self.admit_with_hint(prefix_hint)?;
        Ok(ChaosSession { inner: self.inner.begin_with_prefix(prefix_hint)?, id })
    }

    fn begin_sized(&self, prefix_hint: &[u32], max_slots: usize) -> Result<Self::Session> {
        let id = self.admit_with_hint(prefix_hint)?;
        Ok(ChaosSession { inner: self.inner.begin_sized(prefix_hint, max_slots)?, id })
    }

    fn cache_prefix(&self, tokens: &[u32]) {
        self.inner.cache_prefix(tokens)
    }

    fn set_trace(&self, tracer: &crate::trace::Tracer) {
        self.inner.set_trace(tracer)
    }

    fn pool_status(&self) -> Option<crate::kvcache::PoolStatus> {
        self.inner.pool_status()
    }

    fn session_capacity(&self) -> usize {
        self.inner.session_capacity()
    }

    fn export_block(&self, chain: &[u32]) -> Option<Vec<f32>> {
        self.inner.export_block(chain)
    }

    fn import_block(&self, chain: &[u32], payload: &[f32]) -> bool {
        self.inner.import_block(chain, payload)
    }

    fn cached_prefix_len(&self, tokens: &[u32]) -> usize {
        self.inner.cached_prefix_len(tokens)
    }

    fn persist_cold(&self) {
        self.inner.persist_cold()
    }

    fn eval_into(
        &self,
        session: &mut Self::Session,
        nodes: &[EvalNode],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        let fault = {
            let mut st = self.st.lock().unwrap();
            let spike = Self::on_eval_call(&mut st);
            let fault = Self::fault_for(&mut st, session.id);
            drop(st);
            if let Some(rounds) = spike {
                Self::spin(rounds);
            }
            fault
        };
        if let Some(e) = fault {
            return Err(e.into());
        }
        self.inner.eval_into(&mut session.inner, nodes, out)
    }

    fn eval_batch_into(
        &self,
        groups: &mut [(&mut Self::Session, &[EvalNode])],
        out: &mut LogitsBatch,
    ) -> Result<()> {
        // Fail BEFORE delegating: a faulted fused call must leave every
        // session untouched so the engine can re-drive per group.
        let fault = {
            let mut st = self.st.lock().unwrap();
            let spike = Self::on_eval_call(&mut st);
            let fault = groups
                .iter()
                .find_map(|(s, _)| Self::fault_for(&mut st, s.id));
            drop(st);
            if let Some(rounds) = spike {
                Self::spin(rounds);
            }
            fault
        };
        if let Some(e) = fault {
            return Err(e.into());
        }
        let mut inner_groups: Vec<(&mut L::Session, &[EvalNode])> =
            groups.iter_mut().map(|(s, n)| (&mut s.inner, *n)).collect();
        self.inner.eval_batch_into(&mut inner_groups, out)
    }

    fn commit(&self, session: &mut Self::Session, accepted: &[usize]) -> Result<()> {
        self.inner.commit(&mut session.inner, accepted)
    }

    fn prefix_len(&self, session: &Self::Session) -> usize {
        self.inner.prefix_len(&session.inner)
    }

    fn capacity_left(&self, session: &Self::Session) -> usize {
        self.inner.capacity_left(&session.inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimLm;

    fn lm() -> SimLm {
        SimLm::pair(7, 0.5, 16).0
    }

    #[test]
    fn seeded_plan_is_deterministic_and_disjoint() {
        let cfg = ChaosConfig { sessions: 32, transient: 5, persistent: 3, ..Default::default() };
        let a = FaultPlan::seeded(11, &cfg);
        let b = FaultPlan::seeded(11, &cfg);
        assert_eq!(a.transient_sessions, b.transient_sessions);
        assert_eq!(a.persistent_sessions, b.persistent_sessions);
        assert_eq!(a.latency_spikes, b.latency_spikes);
        assert!(a.transient_sessions.is_disjoint(&a.persistent_sessions));
        assert_eq!(a.transient_sessions.len(), 5);
        assert_eq!(a.persistent_sessions.len(), 3);
    }

    #[test]
    fn passthrough_matches_inner() {
        let plain = lm();
        let chaos = ChaosLm::new(lm(), FaultPlan::none());
        let mut sp = plain.begin().unwrap();
        let mut sc = chaos.begin().unwrap();
        let nodes = [EvalNode::root(3), EvalNode::child(5, 0)];
        let rp = plain.eval(&mut sp, &nodes).unwrap();
        let rc = chaos.eval(&mut sc, &nodes).unwrap();
        assert_eq!(rp, rc);
        chaos.commit(&mut sc, &[0]).unwrap();
        assert_eq!(chaos.prefix_len(&sc), 1);
    }

    #[test]
    fn transient_fault_targets_one_session_and_clears_on_fresh_session() {
        let plan = FaultPlan {
            transient_sessions: [1u64].into_iter().collect(),
            ..FaultPlan::none()
        };
        let chaos = ChaosLm::new(lm(), plan);
        let mut s0 = chaos.begin().unwrap(); // id 0
        let mut s1 = chaos.begin().unwrap(); // id 1 — faulted
        let nodes = [EvalNode::root(2)];
        assert!(chaos.eval(&mut s0, &nodes).is_ok());
        let err = chaos.eval(&mut s1, &nodes).unwrap_err();
        let e = EngineError::classify(&err);
        assert_eq!(e.kind, ErrorKind::EvalTransient);
        assert!(e.retryable);
        // Dropping the poisoned session and opening a fresh one clears
        // the fault (fresh sessions get new ids).
        drop(s1);
        let mut s2 = chaos.begin().unwrap(); // id 2
        assert!(chaos.eval(&mut s2, &nodes).is_ok());
        assert_eq!(chaos.trips().transient, 1);
    }

    #[test]
    fn fused_fault_fails_before_mutating_any_session() {
        let plan = FaultPlan {
            persistent_sessions: [1u64].into_iter().collect(),
            ..FaultPlan::none()
        };
        let chaos = ChaosLm::new(lm(), plan);
        let mut s0 = chaos.begin().unwrap();
        let mut s1 = chaos.begin().unwrap();
        let n0 = [EvalNode::root(2)];
        let n1 = [EvalNode::root(3)];
        let mut out = LogitsBatch::default();
        out.reset(chaos.vocab());
        {
            let mut groups = vec![(&mut s0, &n0[..]), (&mut s1, &n1[..])];
            let err = chaos.eval_batch_into(&mut groups, &mut out).unwrap_err();
            assert_eq!(EngineError::classify(&err).kind, ErrorKind::EvalPersistent);
        }
        assert_eq!(out.rows(), 0, "no rows may be appended by a failed fused call");
        // The healthy session was not mutated: a per-group re-drive
        // evaluates the same nodes cleanly.
        assert!(chaos.eval_into(&mut s0, &n0, &mut out).is_ok());
        assert_eq!(out.rows(), 1);
    }

    #[test]
    fn resume_faults_fire_on_long_hints_only() {
        let plan = FaultPlan {
            resume_faults: 1,
            resume_hint_min: 4,
            resume_retryable: true,
            ..FaultPlan::none()
        };
        let chaos = ChaosLm::new(lm(), plan);
        // Short hint (a fresh prompt) never trips.
        assert!(chaos.begin_with_prefix(&[1, 2, 3]).is_ok());
        // Long hint (a resume) trips once, then the budget is spent.
        let err = chaos.begin_with_prefix(&[1, 2, 3, 4, 5, 6]).unwrap_err();
        let e = EngineError::classify(&err);
        assert_eq!(e.kind, ErrorKind::PoolExhausted);
        assert!(e.retryable);
        assert!(chaos.begin_with_prefix(&[1, 2, 3, 4, 5, 6]).is_ok());
        assert_eq!(chaos.trips().resume, 1);
    }

    #[test]
    fn latency_spike_burns_but_preserves_results() {
        let mut spikes = BTreeMap::new();
        spikes.insert(0u64, 10_000u64);
        let plan = FaultPlan { latency_spikes: spikes, ..FaultPlan::none() };
        let chaos = ChaosLm::new(lm(), plan);
        let plain = lm();
        let mut sc = chaos.begin().unwrap();
        let mut sp = plain.begin().unwrap();
        let nodes = [EvalNode::root(9)];
        assert_eq!(
            chaos.eval(&mut sc, &nodes).unwrap(),
            plain.eval(&mut sp, &nodes).unwrap()
        );
        assert_eq!(chaos.trips().spikes, 1);
    }
}
