//! Workload generation: prompts drawn from the synthetic corpus (the
//! trained model's native distribution — the WMT/XSum analogue) or from
//! seeded random tokens (for the sim substrate), plus a Poisson arrival
//! generator for the serving benchmark.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Rng;

/// Prompts sliced from artifacts/corpus.bin: in-distribution inputs for
/// the trained models.
pub fn corpus_prompts(
    artifacts_dir: impl AsRef<Path>,
    n: usize,
    len: usize,
    seed: u64,
) -> Result<Vec<Vec<u32>>> {
    let path = artifacts_dir.as_ref().join("corpus.bin");
    let data = std::fs::read(&path)
        .with_context(|| format!("reading {} — run `make artifacts`", path.display()))?;
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let start = rng.gen_range(data.len().saturating_sub(len + 1).max(1));
        out.push(data[start..start + len].iter().map(|&b| b as u32).collect());
    }
    Ok(out)
}

/// Deterministic pseudo-logits with a realistic spread: the shared
/// synthetic vocab-row workload for the kernel benches (one copy here so
/// every bench binary measures the same distribution shape).
pub fn synth_logits(vocab: usize) -> Vec<f32> {
    (0..vocab).map(|i| ((i * 37) % 97) as f32 / 9.0 - ((i * 13) % 29) as f32 / 7.0).collect()
}

/// Seeded random prompts over a vocab (sim substrate workloads).
pub fn random_prompts(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.gen_range(vocab) as u32).collect())
        .collect()
}

/// Poisson-process arrival offsets (seconds) for `n` requests at `rate`
/// requests/second — the serving bench's open-loop workload.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            let u: f64 = rng.gen_f64_open();
            t += -u.ln() / rate;
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_prompts_deterministic_and_bounded() {
        let a = random_prompts(4, 8, 32, 1);
        let b = random_prompts(4, 8, 32, 1);
        assert_eq!(a, b);
        assert!(a.iter().flatten().all(|&t| t < 32));
    }

    #[test]
    fn poisson_arrivals_increasing_with_mean_rate() {
        let arr = poisson_arrivals(2000, 50.0, 3);
        assert!(arr.windows(2).all(|w| w[1] > w[0]));
        let mean_gap = arr.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.02).abs() < 0.004, "{mean_gap}");
    }
}
