//! Counting global allocator for the bench binaries: wraps the system
//! allocator and counts every allocation (and reallocation) plus the
//! bytes requested. The bench binaries install it with
//! `#[global_allocator]`; the counters live here in the library so the
//! harness can read them regardless of which binary registered it. When
//! no binary registers it the counters simply stay at zero.
//!
//! This is how the hot-path bench *proves* the zero-allocation
//! steady-state claim instead of asserting it by inspection: warm a
//! stepper up, snapshot [`counts`], run N rounds, and the delta is the
//! exact number of heap allocations those rounds performed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// (allocations, bytes requested) since process start.
pub fn counts() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
}

/// System-allocator wrapper counting allocs/bytes. `realloc` counts as
/// one allocation of the new size (a Vec growth is real heap traffic).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
