//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section.
//!
//! * [`exp1_configs`] / [`exp2_configs`] — the exact tree structures of
//!   App. C.3.1 / C.3.2.
//! * [`bench_decoder`] — measures block efficiency, MBSU, token rate and
//!   the distribution-recovery TV distance (the accuracy analogue) for
//!   one decoder config.
//! * [`run_exp1`] / [`run_exp2`] / [`figure1`] — full sweeps printing
//!   paper-style tables (Tables 1-54 rows; Figure 1/4/5 series).

pub mod alloc;
pub mod harness;
pub mod workload;

use anyhow::Result;

use crate::config::{DecoderConfig, SamplingConfig};
use crate::decode::{generate, toy};
use crate::llm::Llm;
use crate::sampling::{process_logits, tv_distance};
use crate::util::Rng;

/// One row of a paper-style results table.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub decoder: String,
    pub spec: String,
    pub eff: f64,
    pub mbsu: f64,
    pub token_rate: f64,
    /// TV distance between the decoder's first-token distribution and the
    /// exact target distribution (the "Acc." column analogue: all exact
    /// decoders must sit near 0). None when not measured.
    pub tv: Option<f64>,
    /// Mean draft-tree nodes per target call (actual budget).
    pub nodes_per_call: f64,
}

/// Benchmark options.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub max_new: usize,
    pub reps: usize,
    /// Trials for the first-token TV check (0 disables).
    pub tv_trials: usize,
    pub seed: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { max_new: 64, reps: 4, tv_trials: 0, seed: 0 }
    }
}

fn split_label(cfg: &DecoderConfig) -> (String, String) {
    let label = cfg.label();
    match label.split_once(' ') {
        Some((a, b)) => (a.to_string(), b.to_string()),
        None => (label, "-".to_string()),
    }
}

/// Measure one decoder config over `opts.reps` prompts.
pub fn bench_decoder<T: Llm, D: Llm>(
    cfg: &DecoderConfig,
    sampling: &SamplingConfig,
    target: &T,
    draft: &D,
    prompts: &[Vec<u32>],
    opts: &BenchOpts,
) -> Result<BenchRow> {
    let mut rng = Rng::seed_from_u64(opts.seed);
    let mut eff = 0.0;
    let mut mbsu = 0.0;
    let mut rate = 0.0;
    let mut nodes = 0.0;
    let mut n = 0usize;
    for rep in 0..opts.reps {
        let prompt = &prompts[rep % prompts.len()];
        let run = generate(cfg, sampling, target, draft, prompt, opts.max_new, &mut rng)?;
        eff += run.stats.block_efficiency();
        mbsu += run.stats.mbsu(cfg.depth(), draft.param_count(), target.param_count());
        rate += run.stats.token_rate();
        nodes += if run.stats.decode_calls > 0 {
            run.stats.tree_nodes as f64 / run.stats.decode_calls as f64
        } else {
            0.0
        };
        n += 1;
    }
    let tv = if opts.tv_trials > 0 {
        Some(first_token_tv(cfg, sampling, target, draft, &prompts[0], opts.tv_trials, opts.seed)?)
    } else {
        None
    };
    let (decoder, spec) = split_label(cfg);
    let nf = n as f64;
    Ok(BenchRow {
        decoder,
        spec,
        eff: eff / nf,
        mbsu: mbsu / nf,
        token_rate: rate / nf,
        tv,
        nodes_per_call: nodes / nf,
    })
}

/// Distribution-recovery check: empirical first-token distribution of the
/// decoder vs the exact (processed) target distribution at the prompt.
/// This is the sharp version of the paper's accuracy columns — every
/// exact decoder must drive it to 0 as trials grow.
pub fn first_token_tv<T: Llm, D: Llm>(
    cfg: &DecoderConfig,
    sampling: &SamplingConfig,
    target: &T,
    draft: &D,
    prompt: &[u32],
    trials: usize,
    seed: u64,
) -> Result<f64> {
    // exact target distribution at the prompt
    let mut sess = target.begin()?;
    let nodes: Vec<crate::llm::EvalNode> = prompt
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            if i == 0 {
                crate::llm::EvalNode::root(t)
            } else {
                crate::llm::EvalNode::child(t, i - 1)
            }
        })
        .collect();
    let rows = target.eval(&mut sess, &nodes)?;
    let exact = process_logits(rows.last().unwrap(), sampling.temperature, sampling.top_p).probs();

    let mut rng = Rng::seed_from_u64(seed.wrapping_add(0x5eed));
    let mut hist = vec![0f64; target.vocab()];
    for _ in 0..trials {
        let run = generate(cfg, sampling, target, draft, prompt, 1, &mut rng)?;
        hist[run.tokens[0] as usize] += 1.0;
    }
    for h in &mut hist {
        *h /= trials as f64;
    }
    Ok(tv_distance(&hist, &exact))
}

/// Tree structures for Exp1 (fixed draft length), App. C.3.1.
pub fn exp1_configs(dl: usize) -> Vec<DecoderConfig> {
    use DecoderConfig::*;
    let spectr = |k, l| SpecTr { k, l };
    let rsdc = |b: &[usize]| RsdC { branches: b.to_vec() };
    match dl {
        2 => vec![
            Sd { l: 2 },
            spectr(2, 2),
            spectr(3, 2),
            rsdc(&[2, 1]),
            rsdc(&[2, 2]),
            rsdc(&[3, 1]),
            RsdS { w: 2, l: 2 },
            RsdS { w: 3, l: 2 },
        ],
        3 => vec![
            Sd { l: 3 },
            spectr(3, 3),
            spectr(4, 3),
            rsdc(&[2, 2, 2]),
            rsdc(&[3, 1, 1]),
            rsdc(&[4, 1, 1]),
            RsdS { w: 3, l: 3 },
            RsdS { w: 4, l: 3 },
        ],
        4 => vec![
            Sd { l: 4 },
            spectr(5, 4),
            spectr(7, 4),
            rsdc(&[2, 2, 2, 2]),
            rsdc(&[5, 1, 1, 1]),
            rsdc(&[7, 1, 1, 1]),
            RsdS { w: 5, l: 4 },
            RsdS { w: 7, l: 4 },
        ],
        5 => vec![
            Sd { l: 5 },
            spectr(6, 5),
            spectr(12, 5),
            rsdc(&[2, 2, 2, 2, 2]),
            rsdc(&[6, 1, 1, 1, 1]),
            rsdc(&[12, 1, 1, 1, 1]),
            RsdS { w: 6, l: 5 },
            RsdS { w: 12, l: 5 },
        ],
        _ => panic!("paper sweeps DL in {{2,3,4,5}}, got {dl}"),
    }
}

/// Tree structures for Exp2 (fixed target budget), App. C.3.2.
pub fn exp2_configs(budget: usize) -> Vec<DecoderConfig> {
    use DecoderConfig::*;
    let spectr = |k, l| SpecTr { k, l };
    let rsdc = |b: &[usize]| RsdC { branches: b.to_vec() };
    match budget {
        6 => vec![
            Sd { l: 6 },
            spectr(2, 3),
            spectr(3, 2),
            rsdc(&[2, 1, 1]),
            rsdc(&[2, 2]),
            rsdc(&[3, 1]),
            RsdS { w: 2, l: 3 },
            RsdS { w: 3, l: 2 },
        ],
        10 => vec![
            Sd { l: 10 },
            spectr(2, 5),
            spectr(5, 2),
            rsdc(&[2, 1, 1, 1, 1]),
            rsdc(&[2, 2, 1]),
            rsdc(&[5, 1]),
            RsdS { w: 2, l: 5 },
            RsdS { w: 5, l: 2 },
        ],
        14 => vec![
            Sd { l: 14 },
            spectr(2, 7),
            spectr(7, 2),
            rsdc(&[2, 1, 1, 1, 1, 1, 1]),
            rsdc(&[2, 2, 2]),
            rsdc(&[7, 1]),
            RsdS { w: 2, l: 7 },
            RsdS { w: 7, l: 2 },
        ],
        21 => vec![
            Sd { l: 21 },
            spectr(3, 7),
            spectr(7, 3),
            rsdc(&[3, 1, 1, 1, 1, 1, 1]),
            rsdc(&[3, 2, 2]),
            rsdc(&[7, 1, 1]),
            RsdS { w: 3, l: 7 },
            RsdS { w: 7, l: 3 },
        ],
        30 => vec![
            Sd { l: 30 },
            spectr(5, 6),
            spectr(6, 5),
            rsdc(&[2, 2, 2, 2]),
            rsdc(&[5, 1, 1, 1, 1, 1]),
            rsdc(&[6, 1, 1, 1, 1]),
            RsdS { w: 5, l: 6 },
            RsdS { w: 6, l: 5 },
        ],
        _ => panic!("paper sweeps budgets in {{6,10,14,21,30}}, got {budget}"),
    }
}

/// Print a paper-style table (Tables 1-54 row structure). Values are
/// normalized by the AR row when `normalize` is set (Figures 4/5 style).
pub fn print_table(title: &str, ar: &BenchRow, rows: &[BenchRow], normalize: bool) {
    println!("\n== {title} ==");
    println!(
        "{:<8} {:<14} {:>7} {:>7} {:>9} {:>7} {:>7}",
        "Dec.", "Spec.", "Eff.", "MBSU", "TR", "TV", "Nodes"
    );
    let (e0, m0, t0) = if normalize {
        (ar.eff, ar.mbsu, ar.token_rate)
    } else {
        (1.0, 1.0, 1.0)
    };
    let print_row = |r: &BenchRow| {
        println!(
            "{:<8} {:<14} {:>7.3} {:>7.3} {:>9.3} {:>7} {:>7.1}",
            r.decoder,
            r.spec,
            r.eff / e0,
            r.mbsu / m0,
            r.token_rate / t0,
            r.tv.map(|t| format!("{t:.3}")).unwrap_or_else(|| "-".into()),
            r.nodes_per_call,
        );
    };
    print_row(ar);
    for r in rows {
        print_row(r);
    }
}

/// Figure 1: acceptance rates on the Bernoulli toy, K = 2. Returns rows
/// over a grid of (p, q) pairs; `rsd fig1` prints them.
pub fn figure1(grid: usize) -> Vec<toy::ToyRow> {
    let mut out = Vec::new();
    for i in 1..grid {
        for j in 1..grid {
            let p = i as f64 / grid as f64;
            let q = j as f64 / grid as f64;
            out.push(toy::figure1_row(p, q));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimLm;

    #[test]
    fn exp_configs_budgets_are_exact() {
        for b in [6, 10, 14, 21, 30] {
            for cfg in exp2_configs(b).iter().skip(1) {
                assert_eq!(cfg.budget(), b, "{cfg:?}");
            }
        }
        for dl in [2, 3, 4, 5] {
            for cfg in exp1_configs(dl) {
                assert_eq!(cfg.depth(), dl, "{cfg:?}");
            }
        }
    }

    #[test]
    fn bench_decoder_runs_on_sim() {
        let (target, draft) = SimLm::pair(0, 0.8, 48);
        let sampling = SamplingConfig::default();
        let opts = BenchOpts { max_new: 32, reps: 2, tv_trials: 0, seed: 1 };
        let prompts = vec![vec![1u32, 2, 3]];
        let row = bench_decoder(
            &DecoderConfig::RsdS { w: 3, l: 3 },
            &sampling,
            &target,
            &draft,
            &prompts,
            &opts,
        )
        .unwrap();
        assert!(row.eff > 1.0);
        assert!(row.nodes_per_call > 1.0);
    }

    #[test]
    fn tv_check_small_on_exact_decoder() {
        let (target, draft) = SimLm::pair(1, 0.6, 24);
        let sampling = SamplingConfig::default();
        let tv = first_token_tv(
            &DecoderConfig::RsdC { branches: vec![2, 2] },
            &sampling,
            &target,
            &draft,
            &[3, 1, 4],
            4000,
            7,
        )
        .unwrap();
        assert!(tv < 0.08, "tv {tv}");
    }
}
