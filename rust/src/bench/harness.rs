//! Micro-benchmark harness (offline substitute for criterion): warmup,
//! timed batches, mean / stddev / throughput reporting, per-op
//! allocation counting (via [`super::alloc`], when the bench binary
//! registered the counting global allocator) and machine-readable
//! snapshots (`--json` writes `BENCH_*.json` at the repo root).
//! Wallclock-based, best-of-batches resistant to noise.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Quick mode (`--quick`): shorter batches for CI — same measurements,
/// coarser precision.
static QUICK: AtomicBool = AtomicBool::new(false);

pub fn set_quick(quick: bool) {
    QUICK.store(quick, Ordering::Relaxed);
}

pub fn quick() -> bool {
    QUICK.load(Ordering::Relaxed)
}

pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub iters: u64,
    /// Heap allocations per op (0 when no counting allocator is
    /// registered — the library default).
    pub allocs_per_op: f64,
    /// Heap bytes requested per op.
    pub bytes_per_op: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            return f64::INFINITY;
        }
        1.0 / self.mean.as_secs_f64()
    }
}

/// Time `f`, auto-calibrating the iteration count to roughly
/// `target_time` per batch, over `batches` batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let (target_time, batches) = if quick() {
        (Duration::from_millis(25), 3usize)
    } else {
        (Duration::from_millis(120), 7usize)
    };

    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed();
        if el >= Duration::from_millis(if quick() { 5 } else { 15 }) || iters >= 1 << 24 {
            let scale = target_time.as_secs_f64() / el.as_secs_f64().max(1e-9);
            iters = ((iters as f64 * scale).ceil() as u64).max(1);
            break;
        }
        iters *= 4;
    }

    // measure (the samples vector is pre-sized so the timed region
    // performs no harness-side allocation)
    let mut samples = Vec::with_capacity(batches);
    let (a0, b0) = super::alloc::counts();
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let (a1, b1) = super::alloc::counts();
    let total_ops = (iters * batches as u64) as f64;
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / (samples.len().saturating_sub(1)).max(1) as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        iters,
        allocs_per_op: (a1 - a0) as f64 / total_ops,
        bytes_per_op: (b1 - b0) as f64 / total_ops,
    };
    println!(
        "{:<44} {:>12} ± {:>10}   ({:>12.1} /s, {:>8.1} allocs/op, {} iters/batch)",
        r.name,
        fmt_dur(r.mean),
        fmt_dur(r.stddev),
        r.per_sec(),
        r.allocs_per_op,
        r.iters
    );
    r
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", d.as_secs_f64())
    }
}

/// Section header for bench binaries.
pub fn section(title: &str) {
    println!("\n——— {title} ———");
}

/// One entry of a perf snapshot (a measured bench or a derived scalar).
pub fn snapshot_entry(section: &str, r: &BenchResult) -> Json {
    Json::obj(vec![
        ("section", Json::from(section)),
        ("name", Json::from(r.name.as_str())),
        ("ns_per_op", Json::Num(r.mean.as_secs_f64() * 1e9)),
        ("stddev_ns", Json::Num(r.stddev.as_secs_f64() * 1e9)),
        ("allocs_per_op", Json::Num(r.allocs_per_op)),
        ("bytes_per_op", Json::Num(r.bytes_per_op)),
        ("iters_per_batch", Json::from(r.iters as usize)),
    ])
}

/// Where `BENCH_*.json` snapshots go: the repository root (benches run
/// with the crate directory as CWD; fall back to CWD when run from the
/// root itself).
pub fn snapshot_path(file: &str) -> PathBuf {
    let parent = PathBuf::from("..");
    if parent.join("ROADMAP.md").exists() {
        parent.join(file)
    } else {
        PathBuf::from(file)
    }
}

/// The commit the snapshot was taken at, for trend provenance
/// ("unknown" outside a git checkout or when git is unavailable).
fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Write a perf snapshot: `entries` (from [`snapshot_entry`]) plus
/// free-form top-level fields. Every snapshot carries the shared
/// provenance header (`schema` / `git_sha` / `config`) so trend
/// tooling can refuse cross-machine or cross-schema comparisons.
/// Returns the written path.
pub fn write_snapshot(
    file: &str,
    entries: Vec<Json>,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<PathBuf> {
    let mut fields = vec![
        ("schema", Json::from("rsd-bench-v1")),
        ("git_sha", Json::from(git_sha().as_str())),
        (
            "config",
            Json::obj(vec![
                ("os", Json::from(std::env::consts::OS)),
                ("arch", Json::from(std::env::consts::ARCH)),
                ("quick", Json::Bool(quick())),
            ]),
        ),
        ("quick", Json::Bool(quick())),
        ("entries", Json::Arr(entries)),
    ];
    fields.extend(extra);
    let path = snapshot_path(file);
    std::fs::write(&path, format!("{}\n", Json::obj(fields)))?;
    Ok(path)
}
