//! Micro-benchmark harness (offline substitute for criterion): warmup,
//! timed batches, mean / stddev / throughput reporting, and a tiny
//! comparison table. Wallclock-based, best-of-batches resistant to noise.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            return f64::INFINITY;
        }
        1.0 / self.mean.as_secs_f64()
    }
}

/// Time `f`, auto-calibrating the iteration count to roughly
/// `target_time` per batch, over `batches` batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let target_time = Duration::from_millis(120);
    let batches = 7usize;

    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed();
        if el >= Duration::from_millis(15) || iters >= 1 << 24 {
            let scale = target_time.as_secs_f64() / el.as_secs_f64().max(1e-9);
            iters = ((iters as f64 * scale).ceil() as u64).max(1);
            break;
        }
        iters *= 4;
    }

    // measure
    let mut samples = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / (samples.len() - 1) as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        iters,
    };
    println!(
        "{:<44} {:>12} ± {:>10}   ({:>12.1} /s, {} iters/batch)",
        r.name,
        fmt_dur(r.mean),
        fmt_dur(r.stddev),
        r.per_sec(),
        r.iters
    );
    r
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", d.as_secs_f64())
    }
}

/// Section header for bench binaries.
pub fn section(title: &str) {
    println!("\n——— {title} ———");
}
