//! # rsd — Recursive Speculative Decoding
//!
//! A serving-oriented reproduction of *Recursive Speculative Decoding:
//! Accelerating LLM Inference via Sampling Without Replacement* (Jeon et
//! al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   batching, KV-cache management, draft-tree construction (Gumbel-Top-k
//!   and Stochastic Beam Search), and recursive rejection sampling
//!   verification. Python never runs on the request path.
//! * **Layer 2** — the JAX transformer step function (python/compile),
//!   AOT-lowered once to HLO text and executed here via the PJRT C API
//!   ([`runtime`]).
//! * **Layer 1** — the Pallas tree-attention kernel inlined into the same
//!   HLO (python/compile/kernels).
//!
//! Entry points: [`decode`] hosts the paper's algorithms over the
//! [`llm::Llm`] abstraction; [`model::PjrtLm`] is the real AOT-compiled
//! model; [`sim::SimLm`] is an analytic substitute for fast controlled
//! sweeps; [`coordinator`] is the serving engine; [`bench`] regenerates
//! every table and figure of the paper's evaluation.
//!
//! On top of the static tree shapes of the paper's tables, [`adaptive`]
//! adds per-request *online tree shaping under a fixed target-compute
//! budget*: per-level acceptance rates are estimated from every
//! verification walk (per request, blended with engine-global decayed
//! statistics) and each speculative round runs the RSD-C branch vector
//! or RSD-S beam maximizing expected accepted tokens subject to a hard
//! per-round node budget B — `DecoderConfig::Adaptive`, spec string
//! `adaptive:B[:rsd-c|:rsd-s]`, available per request over the serving
//! protocol and swept against the static Exp2 grid by
//! `benches/adaptive.rs`.

pub mod adaptive;
pub mod bench;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod decode;
pub mod kvcache;
pub mod llm;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sampling;
pub mod sim;
pub mod tensorfile;
pub mod tokenizer;
pub mod trace;
pub mod tree;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
