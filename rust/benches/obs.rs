//! Observability overhead bench: proves the flight recorder AND the
//! speculation-analytics ledger are cheap enough to leave on in
//! production, and gates both claims in CI.
//!
//! Four layers, matching the observability design:
//!
//! * **record path** — one `Tracer::record` into the preallocated ring
//!   and one `Analytics::record_commit` into the atomic ledger must
//!   each be allocation-free (asserted via the counting allocator) and
//!   sub-microsecond; disabled handles must cost one branch;
//! * **per-round overhead** — identical speculative decode rounds with
//!   tracing off vs on, then analytics off vs on, interleaved
//!   min-of-N to damp scheduler noise. Gate: each layer adds **≤5%**
//!   per round (or ≤250 ns absolute, which catches the "ratio blew up
//!   because the round got faster" case);
//! * **export path** — Chrome-trace rendering of a full ring, the
//!   Prometheus exposition and the windowed `stats_json` report,
//!   measured but not gated (cold path by design: wire command /
//!   watchdog / post-mortem only).
//!
//!     cargo bench --bench obs             # human-readable
//!     cargo bench --bench obs -- --json   # + BENCH_obs.json (repo root)
//!     cargo bench --bench obs -- --quick  # CI-speed batches
//!
//! The process exits non-zero when the overhead gate fails — that is
//! what CI gates on.

use rsd::bench::alloc::CountingAlloc;
use rsd::bench::harness::{bench, section, set_quick, snapshot_entry, write_snapshot, BenchResult};
use rsd::config::SamplingConfig;
use rsd::coordinator::metrics::Metrics;
use rsd::decode::build_parts;
use rsd::decode::spec::{SpecStepper, StepOutcome};
use rsd::obs::{Analytics, Family};
use rsd::sim::SimLm;
use rsd::trace::export::{chrome_trace, prometheus};
use rsd::trace::{EventKind, Tracer, PHASE_DRAFT};
use rsd::util::json::Json;
use rsd::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");
    if quick {
        set_quick(true);
    }
    let mut entries: Vec<Json> = Vec::new();

    // ---- record path ----------------------------------------------------
    section("record path (ring = 4096)");
    let tracer = Tracer::new(4096);
    let rec = bench("tracer.record/commit", || {
        tracer.record(EventKind::Commit, 9, 3, 1);
    });
    entries.push(snapshot_entry("record", &rec));
    let beat = bench("tracer.phase_advanced", || tracer.phase_advanced());
    entries.push(snapshot_entry("record", &beat));
    let off = Tracer::off();
    let rec_off = bench("tracer.record/disabled", || {
        off.record(EventKind::Commit, 9, 3, 1);
    });
    entries.push(snapshot_entry("record", &rec_off));

    // ---- analytics record path ------------------------------------------
    section("analytics record path (ledger + windowed ring)");
    let analytics = Analytics::new(8, 64, 0, 0);
    let trials = [(3usize, 1usize), (2, 1), (2, 0)];
    let led = bench("analytics.record_commit", || {
        analytics.record_forward(Family::RsdS, 9);
        analytics.record_commit(Family::RsdS, 2, 0, &trials);
    });
    entries.push(snapshot_entry("record", &led));
    let tick_metrics = Metrics::default();
    let tick = bench("analytics.tick", || {
        analytics.tick(&tick_metrics, 3, 2);
    });
    entries.push(snapshot_entry("record", &tick));
    let analytics_off = Analytics::off();
    let led_off = bench("analytics.record/disabled", || {
        analytics_off.record_commit(Family::RsdS, 2, 0, &trials);
    });
    entries.push(snapshot_entry("record", &led_off));

    // ---- per-round overhead: tracing off vs on --------------------------
    section("speculative rounds, tracing off vs on (SimLm, rsd-s:3x3)");
    let (target, draft) = SimLm::pair(0, 0.8, 256);
    let sampling = SamplingConfig::new(0.5, 1.0);
    let mk = || {
        let cfg: rsd::config::DecoderConfig = "rsd-s:3x3".parse().unwrap();
        let (strategy, rule) = build_parts(&cfg);
        SpecStepper::new(&target, &draft, strategy, rule, sampling.clone(), &[1, 2, 3], 1 << 16)
            .unwrap()
    };
    let measure = |trace: Option<&Tracer>, name: &str| -> BenchResult {
        let mut st = mk();
        if let Some(t) = trace {
            st.set_trace(t, 1);
        }
        let mut rng = Rng::seed_from_u64(11);
        bench(name, || {
            // rebuild on budget exhaustion (~2^16 tokens): rare enough
            // to vanish in the mean, identical in both variants
            if st.step(&target, &draft, &mut rng).unwrap() != StepOutcome::Progress {
                st = mk();
                if let Some(t) = trace {
                    st.set_trace(t, 1);
                }
            }
        })
    };
    // interleave off/on reps and keep the best of each: the minima see
    // the same machine, so the ratio isolates the tracing cost
    let reps = if quick { 2 } else { 3 };
    let mut best_off = f64::INFINITY;
    let mut best_on = f64::INFINITY;
    for rep in 0..reps {
        let r = measure(None, &format!("round/trace-off/rep{rep}"));
        best_off = best_off.min(r.mean.as_secs_f64());
        entries.push(snapshot_entry("round-overhead", &r));
        let r = measure(Some(&tracer), &format!("round/trace-on/rep{rep}"));
        best_on = best_on.min(r.mean.as_secs_f64());
        entries.push(snapshot_entry("round-overhead", &r));
    }
    let ratio = best_on / best_off.max(1e-12);
    let delta_ns = (best_on - best_off) * 1e9;
    println!(
        "tracing overhead: {:.2}% per round ({delta_ns:+.0} ns)",
        (ratio - 1.0) * 100.0
    );

    // ---- per-round overhead: analytics off vs on ------------------------
    section("speculative rounds, analytics off vs on (SimLm, rsd-s:3x3)");
    // same interleaved min-of-N protocol; the analytics variant also
    // ticks the windowed aggregator every round, as the engine does
    let measure_stats = |analytics: Option<&Analytics>, name: &str| -> BenchResult {
        let mut st = mk();
        if let Some(a) = analytics {
            st.set_analytics(a, Family::RsdS);
        }
        let mut rng = Rng::seed_from_u64(11);
        bench(name, || {
            if st.step(&target, &draft, &mut rng).unwrap() != StepOutcome::Progress {
                st = mk();
                if let Some(a) = analytics {
                    st.set_analytics(a, Family::RsdS);
                }
            }
            if let Some(a) = analytics {
                a.tick(&tick_metrics, 0, 1);
            }
        })
    };
    let mut stats_best_off = f64::INFINITY;
    let mut stats_best_on = f64::INFINITY;
    for rep in 0..reps {
        let r = measure_stats(None, &format!("round/stats-off/rep{rep}"));
        stats_best_off = stats_best_off.min(r.mean.as_secs_f64());
        entries.push(snapshot_entry("round-overhead", &r));
        let r = measure_stats(Some(&analytics), &format!("round/stats-on/rep{rep}"));
        stats_best_on = stats_best_on.min(r.mean.as_secs_f64());
        entries.push(snapshot_entry("round-overhead", &r));
    }
    let stats_ratio = stats_best_on / stats_best_off.max(1e-12);
    let stats_delta_ns = (stats_best_on - stats_best_off) * 1e9;
    println!(
        "analytics overhead: {:.2}% per round ({stats_delta_ns:+.0} ns)",
        (stats_ratio - 1.0) * 100.0
    );

    // ---- export path (cold, informational) ------------------------------
    section("export path (cold)");
    // the record bench above filled the ring; freeze one full snapshot
    let events = tracer.snapshot();
    let r = bench("journal.snapshot/4096", || {
        std::hint::black_box(tracer.snapshot());
    });
    entries.push(snapshot_entry("export", &r));
    let r = bench(&format!("export.chrome_trace/{} events", events.len()), || {
        std::hint::black_box(chrome_trace(&events));
    });
    entries.push(snapshot_entry("export", &r));
    let m = Metrics::default();
    m.add(&m.completed, 5);
    m.record_latency(0.5);
    m.record_phase(PHASE_DRAFT, 0.004);
    let snap = m.snapshot();
    let r = bench("export.prometheus", || {
        std::hint::black_box(prometheus(&snap));
    });
    entries.push(snapshot_entry("export", &r));
    let r = bench("export.stats_json", || {
        std::hint::black_box(analytics.stats_json(8));
    });
    entries.push(snapshot_entry("export", &r));
    let r = bench("export.analytics_prometheus", || {
        std::hint::black_box(analytics.prometheus());
    });
    entries.push(snapshot_entry("export", &r));

    // write the snapshot BEFORE the gates: a regressing run must still
    // ship its diagnostic JSON (CI uploads it with `if: always()`)
    if json_out {
        let extra = vec![(
            "asserts",
            Json::obj(vec![
                ("tracing_overhead_ratio", Json::Num(ratio)),
                ("tracing_overhead_ns_per_round", Json::Num(delta_ns)),
                ("record_ns", Json::Num(rec.mean.as_secs_f64() * 1e9)),
                ("record_allocs_per_op", Json::Num(rec.allocs_per_op)),
                ("analytics_overhead_ratio", Json::Num(stats_ratio)),
                ("analytics_overhead_ns_per_round", Json::Num(stats_delta_ns)),
                ("analytics_record_ns", Json::Num(led.mean.as_secs_f64() * 1e9)),
                ("analytics_record_allocs_per_op", Json::Num(led.allocs_per_op)),
                ("analytics_tick_allocs_per_op", Json::Num(tick.allocs_per_op)),
            ]),
        )];
        let path = write_snapshot("BENCH_obs.json", entries, extra)?;
        println!("\nwrote {}", path.display());
    }

    // ---- gates ----------------------------------------------------------
    assert!(
        rec.allocs_per_op == 0.0,
        "recording into the ring must be allocation-free \
         (got {} allocs/record)",
        rec.allocs_per_op
    );
    println!("0 allocations per record ✓");
    assert!(
        ratio <= 1.05 || delta_ns <= 250.0,
        "tracing must add ≤5% per decode round \
         (got {:.2}%, {delta_ns:+.0} ns/round)",
        (ratio - 1.0) * 100.0
    );
    println!("≤5% tracing overhead per round ✓");
    assert!(
        led.allocs_per_op == 0.0,
        "recording into the analytics ledger must be allocation-free \
         (got {} allocs/record)",
        led.allocs_per_op
    );
    assert!(
        tick.allocs_per_op == 0.0,
        "the analytics window tick must be allocation-free \
         (got {} allocs/tick)",
        tick.allocs_per_op
    );
    println!("0 allocations per analytics record + tick ✓");
    assert!(
        stats_ratio <= 1.05 || stats_delta_ns <= 250.0,
        "analytics must add ≤5% per decode round \
         (got {:.2}%, {stats_delta_ns:+.0} ns/round)",
        (stats_ratio - 1.0) * 100.0
    );
    println!("≤5% analytics overhead per round ✓");
    Ok(())
}
