//! Bench/regeneration target for the paper's Figure 1: the Bernoulli-toy
//! acceptance-rate comparison (multi-round vs K-SEQ vs OTM vs RRS), plus
//! timing of the verification rules themselves.
//!
//!     cargo bench --bench fig1

use rsd::bench::harness::{bench, section};
use rsd::decode::rrs::{KSeq, MultiRound, Rrs, VerifyRule};
use rsd::decode::toy;
use rsd::sampling::{gumbel_top_k, LogProbs};
use rsd::util::Rng;

fn main() {
    section("Figure 1 regeneration (closed forms, K = 2)");
    println!(
        "{:>5} {:>5} {:>12} {:>9} {:>7} {:>7}",
        "p", "q", "multi-round", "K-SEQ*", "OTM", "RRS"
    );
    for &(p, q) in &[
        (0.5, 0.5),
        (0.6, 0.4),
        (0.7, 0.3),
        (0.8, 0.2),
        (0.9, 0.1),
        (0.95, 0.05),
    ] {
        let r = toy::figure1_row(p, q);
        println!(
            "{:>5.2} {:>5.2} {:>12.3} {:>9.3} {:>7.3} {:>7.3}",
            p, q, r.multiround, r.kseq, r.otm, r.rrs
        );
        assert!(r.rrs >= r.otm - 1e-9 && r.otm >= r.kseq - 1e-9 && r.kseq >= r.multiround - 1e-9);
    }
    println!("(RRS = 1.0 everywhere: the paper's without-replacement effect)");

    section("verification-rule latency (vocab 256, K = 4 siblings)");
    let mut rng = Rng::seed_from_u64(0);
    let p = LogProbs((0..256).map(|i| -((i + 1) as f64).ln() * 1.1).collect());
    let q = LogProbs((0..256).rev().map(|i| -((i + 1) as f64).ln() * 1.3).collect());
    let mut pn = p.clone();
    rsd::sampling::log_normalize(&mut pn.0);
    let mut qn = q.clone();
    rsd::sampling::log_normalize(&mut qn.0);
    let sib: Vec<u32> = gumbel_top_k(&pn, 4, &mut rng).iter().map(|&(i, _)| i as u32).collect();

    bench("rrs_verify/vocab=256/k=4", || {
        let _ = Rrs.verify(&sib, &pn, &qn, &mut rng);
    });
    bench("multiround_verify/vocab=256/k=4", || {
        let _ = MultiRound.verify(&sib, &pn, &qn, &mut rng);
    });
    bench("kseq_verify/vocab=256/k=4", || {
        let _ = (KSeq { gamma: None }).verify(&sib, &pn, &qn, &mut rng);
    });
    bench("figure1_row (closed forms incl. gamma tuning)", || {
        let _ = toy::figure1_row(0.8, 0.2);
    });
}
