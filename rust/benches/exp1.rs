//! Exp1 (paper §5.1, Figure 4, Tables 1-27): block efficiency, MBSU and
//! token rate at fixed draft sequence length DL ∈ {2,3,4,5}, for SD /
//! SpecTr / RSD-C / RSD-S with the exact tree structures of App. C.3.1.
//!
//! This bench runs the sweep on both substrates:
//!  * sim (fast, controlled discrepancy) — the full grid;
//!  * the real AOT-compiled model pair — a spot-check subset (the full
//!    real-model sweep is `rsd exp1`).
//!
//!     cargo bench --bench exp1

use rsd::bench::{self, workload, BenchOpts};
use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::sim::SimLm;

fn main() -> anyhow::Result<()> {
    let sampling = SamplingConfig::new(0.3, 1.0);

    // ---- sim substrate: full App. C.3.1 grid ---------------------------
    let (target, draft) = SimLm::pair(0, 0.8, 256);
    let prompts = workload::random_prompts(6, 16, 256, 1);
    let opts = BenchOpts { max_new: 64, reps: 6, tv_trials: 0, seed: 0 };
    let ar = bench::bench_decoder(&DecoderConfig::Ar, &sampling, &target, &draft, &prompts, &opts)?;
    for dl in [2usize, 3, 4, 5] {
        let mut rows = Vec::new();
        for cfg in bench::exp1_configs(dl) {
            rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
        }
        bench::print_table(&format!("Exp1 sim (alpha=0.8) DL = {dl}"), &ar, &rows, true);
    }

    // ---- real model: spot-check DL = 3 ---------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::cpu()?;
        let (target, draft) = PjrtLm::load_pair(&rt, "artifacts")?;
        let prompts = workload::corpus_prompts("artifacts", 3, 48, 2)?;
        let opts = BenchOpts { max_new: 48, reps: 3, tv_trials: 0, seed: 0 };
        let ar =
            bench::bench_decoder(&DecoderConfig::Ar, &sampling, &target, &draft, &prompts, &opts)?;
        let mut rows = Vec::new();
        for cfg in bench::exp1_configs(3) {
            rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
        }
        bench::print_table("Exp1 REAL MODEL (AOT/PJRT) DL = 3", &ar, &rows, true);
    } else {
        eprintln!("artifacts missing — skipping real-model spot check (run `make artifacts`)");
    }
    Ok(())
}
