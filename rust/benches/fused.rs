//! Fused cross-request batch execution vs per-request sequential
//! stepping, on the sim substrate.
//!
//! The engine's round loop batches all draft and target forwards across
//! active requests into one `Llm::eval_batch` call per phase
//! (`EngineConfig::fused`). What that buys is amortization of the fixed
//! per-dispatch cost every real accelerator charges per forward pass
//! (kernel launch, host-device transfer, executable entry): with N
//! concurrent requests the sequential path pays it N times per phase,
//! the fused path once. `SimLm::with_call_overhead` models exactly that
//! cost (deterministic CPU work per `eval`/`eval_batch` call), so this
//! bench reproduces the serving-hardware tradeoff end to end — both
//! paths decode the SAME tokens (asserted), only dispatch count differs.
//!
//!     cargo bench --bench fused             # human-readable
//!     cargo bench --bench fused -- --json   # + BENCH_fused.json (repo root)
//!     cargo bench --bench fused -- --quick  # shorter streams for CI

use std::sync::mpsc;
use std::time::Instant;

use rsd::bench::alloc::{self, CountingAlloc};
use rsd::bench::harness::write_snapshot;
use rsd::config::{AdaptiveFamily, DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::Snapshot;
use rsd::sim::SimLm;
use rsd::util::json::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const N_REQUESTS: u64 = 8;
/// Tokens per request in the full (non `--quick`) run.
const MAX_NEW: usize = 48;
/// splitmix64 rounds charged per model dispatch (~a few hundred µs of
/// CPU work: the order of a real kernel-launch + transfer overhead).
const DISPATCH_OVERHEAD: u64 = 200_000;

/// Heterogeneous per-request decoders: the mixed workload the fused
/// round loop has to keep in lockstep.
fn decoder_for(i: u64) -> Option<DecoderConfig> {
    match i % 4 {
        0 => None, // engine default (rsd-s:3x3)
        1 => Some(DecoderConfig::Ar),
        2 => Some(DecoderConfig::RsdC { branches: vec![2, 2, 1] }),
        _ => Some(DecoderConfig::Adaptive { budget: 12, family: AdaptiveFamily::Auto }),
    }
}

/// Drive one full engine run; returns (per-request token streams,
/// tokens/sec, (allocs, bytes) per token, final metrics snapshot).
fn run(fused: bool, max_new: usize) -> (Vec<Vec<u32>>, f64, (f64, f64), Snapshot) {
    let (target, draft) = SimLm::pair(3, 0.8, 64);
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let cfg = EngineConfig {
        max_concurrency: N_REQUESTS as usize,
        max_queue: 64,
        default_max_tokens: max_new,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 42,
        fused,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let (a0, b0) = alloc::counts();
    let mut receivers = Vec::new();
    for i in 0..N_REQUESTS {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: vec![1 + i as u32, 2, 3],
            max_new,
            decoder: decoder_for(i),
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (a1, b1) = alloc::counts();
    let snap = handle.join().unwrap().snapshot();
    let per_tok = total.max(1) as f64;
    let heap = ((a1 - a0) as f64 / per_tok, (b1 - b0) as f64 / per_tok);
    (streams, total as f64 / wall, heap, snap)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let max_new = if args.iter().any(|a| a == "--quick") { 16 } else { MAX_NEW };
    println!(
        "=== fused vs per-request execution ({N_REQUESTS} concurrent requests, \
         SimLm, dispatch overhead {DISPATCH_OVERHEAD} rounds) ==="
    );
    // warmup (page in, stabilize frequency scaling)
    let _ = run(true, max_new);

    let (seq_streams, seq_tps, seq_heap, seq_snap) = run(false, max_new);
    let (fused_streams, fused_tps, fused_heap, snap) = run(true, max_new);

    assert_eq!(
        seq_streams, fused_streams,
        "fused stepping must be token-for-token identical to sequential"
    );
    println!("decoded tokens identical across both paths ✓");

    // in sequential mode every "fused" phase call issues one dispatch
    // per participating request; in fused mode exactly one
    let seq_dispatches =
        (seq_snap.fused_mean_batch * seq_snap.fused_calls as f64).round() as u64;
    println!("sequential: {seq_tps:>10.1} tok/s  ({seq_dispatches} model dispatches)");
    println!("fused:      {fused_tps:>10.1} tok/s  ({} model dispatches)", snap.fused_calls);
    let speedup = fused_tps / seq_tps;
    println!("speedup:    {speedup:>10.2}x");

    println!("\nfused-batch telemetry:");
    println!("  fused calls: {}  mean batch size: {:.2}", snap.fused_calls, snap.fused_mean_batch);
    let hist: Vec<String> =
        snap.fused_batch_hist.iter().map(|(g, c)| format!("{g}:{c}")).collect();
    println!("  requests-per-call histogram: {{{}}}", hist.join(", "));
    let fill: Vec<String> = snap
        .fused_fill_hist
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(b, c)| format!("≤{}%:{}", (b + 1) * 10, c))
        .collect();
    println!("  fill-ratio deciles: {{{}}}", fill.join(", "));

    assert!(
        speedup >= 2.0,
        "fused stepping must be ≥2x sequential at {N_REQUESTS} requests (got {speedup:.2}x)"
    );
    println!("\n≥2x acceptance criterion met ✓");

    if json_out {
        let entry = |name: &str, tps: f64, (allocs, bytes): (f64, f64)| {
            Json::obj(vec![
                ("section", Json::from("fused-engine")),
                ("name", Json::from(name)),
                ("ns_per_op", Json::Num(1e9 / tps.max(1e-9))), // per decoded token
                ("allocs_per_op", Json::Num(allocs)),
                ("bytes_per_op", Json::Num(bytes)),
            ])
        };
        let entries = vec![
            entry("sequential/token", seq_tps, seq_heap),
            entry("fused/token", fused_tps, fused_heap),
        ];
        let extra = vec![
            ("speedup", Json::Num(speedup)),
            ("sequential_dispatches", Json::from(seq_dispatches as usize)),
            ("fused_dispatches", Json::from(snap.fused_calls as usize)),
            ("max_new", Json::from(max_new)),
        ];
        match write_snapshot("BENCH_fused.json", entries, extra) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write BENCH_fused.json: {e}"),
        }
    }
}
