//! Fused cross-request batch execution vs per-request sequential
//! stepping, on the sim substrate.
//!
//! The engine's round loop batches all draft and target forwards across
//! active requests into one `Llm::eval_batch` call per phase
//! (`EngineConfig::fused`). What that buys is amortization of the fixed
//! per-dispatch cost every real accelerator charges per forward pass
//! (kernel launch, host-device transfer, executable entry): with N
//! concurrent requests the sequential path pays it N times per phase,
//! the fused path once. `SimLm::with_call_overhead` models exactly that
//! cost (deterministic CPU work per `eval`/`eval_batch` call), so this
//! bench reproduces the serving-hardware tradeoff end to end — both
//! paths decode the SAME tokens (asserted), only dispatch count differs.
//!
//!     cargo bench --bench fused

use std::sync::mpsc;
use std::time::Instant;

use rsd::config::{AdaptiveFamily, DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::Snapshot;
use rsd::sim::SimLm;

const N_REQUESTS: u64 = 8;
const MAX_NEW: usize = 48;
/// splitmix64 rounds charged per model dispatch (~a few hundred µs of
/// CPU work: the order of a real kernel-launch + transfer overhead).
const DISPATCH_OVERHEAD: u64 = 200_000;

/// Heterogeneous per-request decoders: the mixed workload the fused
/// round loop has to keep in lockstep.
fn decoder_for(i: u64) -> Option<DecoderConfig> {
    match i % 4 {
        0 => None, // engine default (rsd-s:3x3)
        1 => Some(DecoderConfig::Ar),
        2 => Some(DecoderConfig::RsdC { branches: vec![2, 2, 1] }),
        _ => Some(DecoderConfig::Adaptive { budget: 12, family: AdaptiveFamily::Auto }),
    }
}

/// Drive one full engine run; returns (per-request token streams,
/// tokens/sec, final metrics snapshot).
fn run(fused: bool) -> (Vec<Vec<u32>>, f64, Snapshot) {
    let (target, draft) = SimLm::pair(3, 0.8, 64);
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let cfg = EngineConfig {
        max_concurrency: N_REQUESTS as usize,
        max_queue: 64,
        default_max_tokens: MAX_NEW,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 42,
        fused,
    };
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..N_REQUESTS {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: vec![1 + i as u32, 2, 3],
            max_new: MAX_NEW,
            decoder: decoder_for(i),
            sampling: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = handle.join().unwrap().snapshot();
    (streams, total as f64 / wall, snap)
}

fn main() {
    println!(
        "=== fused vs per-request execution ({N_REQUESTS} concurrent requests, \
         SimLm, dispatch overhead {DISPATCH_OVERHEAD} rounds) ==="
    );
    // warmup (page in, stabilize frequency scaling)
    let _ = run(true);

    let (seq_streams, seq_tps, seq_snap) = run(false);
    let (fused_streams, fused_tps, snap) = run(true);

    assert_eq!(
        seq_streams, fused_streams,
        "fused stepping must be token-for-token identical to sequential"
    );
    println!("decoded tokens identical across both paths ✓");

    // in sequential mode every "fused" phase call issues one dispatch
    // per participating request; in fused mode exactly one
    let seq_dispatches =
        (seq_snap.fused_mean_batch * seq_snap.fused_calls as f64).round() as u64;
    println!("sequential: {seq_tps:>10.1} tok/s  ({seq_dispatches} model dispatches)");
    println!("fused:      {fused_tps:>10.1} tok/s  ({} model dispatches)", snap.fused_calls);
    let speedup = fused_tps / seq_tps;
    println!("speedup:    {speedup:>10.2}x");

    println!("\nfused-batch telemetry:");
    println!("  fused calls: {}  mean batch size: {:.2}", snap.fused_calls, snap.fused_mean_batch);
    let hist: Vec<String> =
        snap.fused_batch_hist.iter().map(|(g, c)| format!("{g}:{c}")).collect();
    println!("  requests-per-call histogram: {{{}}}", hist.join(", "));
    let fill: Vec<String> = snap
        .fused_fill_hist
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(b, c)| format!("≤{}%:{}", (b + 1) * 10, c))
        .collect();
    println!("  fill-ratio deciles: {{{}}}", fill.join(", "));

    assert!(
        speedup >= 2.0,
        "fused stepping must be ≥2x sequential at {N_REQUESTS} requests (got {speedup:.2}x)"
    );
    println!("\n≥2x acceptance criterion met ✓");
}
