//! Adaptive vs. static tree shaping at fixed target computational
//! budgets (the Exp2 axis, §5.2 / App. C.3.2): at B ∈ {6, 30} the
//! static rows are the paper's exact shapes and the adaptive rows are
//! `adaptive:B` (auto / rsd-c / rsd-s families). Same block-efficiency
//! metrics as `exp2.rs`, so the trajectories are directly comparable —
//! the adaptive controller should sit within noise of (or above) the
//! best static shape on the default workload, and clearly above
//! mismatched static shapes when alignment drops.
//!
//!     cargo bench --bench adaptive

use rsd::bench::{self, workload, BenchOpts};
use rsd::config::{AdaptiveFamily, DecoderConfig, SamplingConfig};
use rsd::sim::SimLm;

fn main() -> anyhow::Result<()> {
    let sampling = SamplingConfig::new(0.3, 1.0);

    // two alignment regimes: well-aligned (deep shapes win) and
    // misaligned (width-heavy shapes win) — adaptive must track both
    for alpha in [0.9, 0.5] {
        let (target, draft) = SimLm::pair(0, alpha, 256);
        let prompts = workload::random_prompts(6, 16, 256, 1);
        let opts = BenchOpts { max_new: 64, reps: 6, tv_trials: 0, seed: 0 };
        let ar =
            bench::bench_decoder(&DecoderConfig::Ar, &sampling, &target, &draft, &prompts, &opts)?;
        for b in [6usize, 30] {
            let mut rows = Vec::new();
            for cfg in bench::exp2_configs(b) {
                rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
            }
            for family in [AdaptiveFamily::Auto, AdaptiveFamily::RsdC, AdaptiveFamily::RsdS] {
                let cfg = DecoderConfig::Adaptive { budget: b, family };
                rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
            }
            bench::print_table(
                &format!("Adaptive vs static (alpha={alpha}) Budget = {b}"),
                &ar,
                &rows,
                true,
            );
        }
    }
    println!(
        "\nNodes column = mean draft-tree nodes per target call: for the \
         adaptive rows it is the realized budget (hard-capped at B), \
         typically below B when early truncation prunes doomed branches."
    );
    Ok(())
}
