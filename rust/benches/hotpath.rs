//! Hot-path microbenchmarks (L3 profile, EXPERIMENTS.md §Perf): every
//! per-round operation of the coordinator, plus the PJRT step call that
//! dominates end-to-end time. Drafting + verification must be negligible
//! next to one model call — this bench proves (or disproves) it.
//!
//!     cargo bench --bench hotpath

use rsd::bench::harness::{bench, section};
use rsd::config::SamplingConfig;
use rsd::decode::rrs::{Rrs, VerifyRule};
use rsd::decode::spec::{SpecStepper, StepOutcome};
use rsd::decode::{build_parts, generate};
use rsd::llm::{EvalNode, Llm};
use rsd::sampling::{gumbel_top_k, process_logits, truncated_gumbel};
use rsd::sim::SimLm;
use rsd::tree::SessionCore;
use rsd::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from_u64(0);
    let vocab = 256usize;
    let logits: Vec<f32> = (0..vocab).map(|i| ((i * 37) % 97) as f32 / 9.0).collect();

    section("sampling substrate (per call, vocab = 256)");
    bench("process_logits/temp", || {
        let _ = process_logits(&logits, 0.3, 1.0);
    });
    bench("process_logits/temp+top_p", || {
        let _ = process_logits(&logits, 1.0, 0.95);
    });
    let lp = process_logits(&logits, 0.3, 1.0);
    bench("gumbel_top_k/k=4", || {
        let _ = gumbel_top_k(&lp, 4, &mut rng);
    });
    bench("gumbel_top_k/k=12", || {
        let _ = gumbel_top_k(&lp, 12, &mut rng);
    });
    let phi: Vec<f64> = lp.0.clone();
    bench("truncated_gumbel/vocab=256", || {
        let _ = truncated_gumbel(-0.5, 0.1, &phi);
    });
    let q = process_logits(&logits.iter().rev().cloned().collect::<Vec<_>>(), 0.3, 1.0);
    let sib: Vec<u32> = gumbel_top_k(&lp, 4, &mut rng).iter().map(|&(i, _)| i as u32).collect();
    bench("rrs_verify/k=4", || {
        let _ = Rrs.verify(&sib, &lp, &q, &mut rng);
    });

    section("tree / session bookkeeping (cache_len = 256)");
    bench("mask_row_build/prefix=128", || {
        let mut s = SessionCore::new(256);
        let nodes: Vec<EvalNode> = (0..128u32)
            .map(|i| if i == 0 { EvalNode::root(i) } else { EvalNode::child(i, (i - 1) as usize) })
            .collect();
        s.add_pending(&nodes).unwrap();
        let _ = s.visible_slots(127);
    });
    {
        let mut s = SessionCore::new(256);
        let nodes: Vec<EvalNode> = (0..128u32)
            .map(|i| if i == 0 { EvalNode::root(i) } else { EvalNode::child(i, (i - 1) as usize) })
            .collect();
        s.add_pending(&nodes).unwrap();
        bench("visible_slots only/prefix=128", || {
            let _ = s.visible_slots(127);
        });
    }
    bench("commit/30-node tree", || {
        let mut s = SessionCore::new(256);
        let mut nodes = vec![EvalNode::root(0)];
        for i in 1..30u32 {
            nodes.push(EvalNode::child(i, (i as usize).saturating_sub(1)));
        }
        s.add_pending(&nodes).unwrap();
        s.commit(&[0, 1, 2, 3]).unwrap();
    });

    section("whole rounds on the sim substrate");
    let (target, draft) = SimLm::pair(0, 0.8, vocab);
    let sampling = SamplingConfig::new(0.3, 1.0);
    for spec in ["sd:3", "rsd-c:2-2-2", "rsd-s:6x5"] {
        let cfg: rsd::config::DecoderConfig = spec.parse().unwrap();
        bench(&format!("spec_round/{spec}"), || {
            let (strategy, rule) = build_parts(&cfg);
            let mut st = SpecStepper::new(
                &target,
                &draft,
                strategy,
                rule,
                sampling.clone(),
                &[1, 2, 3],
                64,
            )
            .unwrap();
            while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {
                if st.out.len() >= 8 {
                    break;
                }
            }
        });
    }

    // ---- the real bottleneck: one PJRT step call ------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        section("PJRT model calls (REAL artifacts)");
        let rt = rsd::runtime::Runtime::cpu()?;
        let (ptarget, pdraft) = rsd::model::PjrtLm::load_pair(&rt, "artifacts")?;
        {
            let mut sess = ptarget.begin()?;
            // fill some prefix first
            let nodes: Vec<EvalNode> = (0..32u32)
                .map(|i| {
                    if i == 0 { EvalNode::root(5) } else { EvalNode::child(7, (i - 1) as usize) }
                })
                .collect();
            ptarget.eval(&mut sess, &nodes)?;
            let chain: Vec<usize> = (0..32).collect();
            ptarget.commit(&mut sess, &chain)?;
            bench("target_step/one 32-token tile", || {
                let rows = ptarget.eval(&mut sess, &[EvalNode::root(9)]).unwrap();
                std::hint::black_box(&rows);
                // discard pending (commit nothing) so the prefix stays fixed
                ptarget.commit(&mut sess, &[]).unwrap();
            });
        }
        {
            let mut sess = pdraft.begin()?;
            let nodes: Vec<EvalNode> = (0..32u32)
                .map(|i| {
                    if i == 0 { EvalNode::root(5) } else { EvalNode::child(7, (i - 1) as usize) }
                })
                .collect();
            pdraft.eval(&mut sess, &nodes)?;
            let chain: Vec<usize> = (0..32).collect();
            pdraft.commit(&mut sess, &chain)?;
            bench("draft_step/one 32-token tile", || {
                let rows = pdraft.eval(&mut sess, &[EvalNode::root(9)]).unwrap();
                std::hint::black_box(&rows);
                pdraft.commit(&mut sess, &[]).unwrap();
            });
        }
        section("end-to-end decode (REAL artifacts, 16 tokens)");
        let sampling = SamplingConfig::new(0.3, 1.0);
        for spec in ["ar", "sd:3", "rsd-s:3x3"] {
            let cfg: rsd::config::DecoderConfig = spec.parse().unwrap();
            bench(&format!("generate16/{spec}"), || {
                let _ =
                    generate(&cfg, &sampling, &ptarget, &pdraft, &[1, 2, 3], 16, &mut rng)
                        .unwrap();
            });
        }
    } else {
        eprintln!("artifacts missing — skipping PJRT hot-path benches");
    }
    Ok(())
}
