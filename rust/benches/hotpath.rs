//! Hot-path microbenchmarks (L3 profile, EXPERIMENTS.md §Perf): every
//! per-round operation of the coordinator, plus the PJRT step call that
//! dominates end-to-end time. Drafting + verification must be negligible
//! next to one model call — this bench proves (or disproves) it.
//!
//! Beyond timings, this binary is the enforcement point for two
//! structural claims of the zero-allocation hot path:
//!
//! * **0 heap allocations per steady-state decode round** on the sim
//!   substrate for `sd:3`, `rsd-c:2-2-2` and `rsd-s:6x5` — measured with
//!   a counting global allocator, asserted (the process exits non-zero
//!   on regression, which is what CI gates on);
//! * **≥2x faster selection/processing kernels at vocab = 8192 and at
//!   vocab = 32000 (Llama-2 scale)** than the sort-based, per-call-
//!   allocating baseline the pre-optimization code ran (kept
//!   bit-identical in `rsd::sampling::reference`), also asserted.
//!
//! The `--json` snapshot additionally records a top-level `kernels`
//! object: per-kernel nanoseconds (whole-slice and per-element) for the
//! vectorizable math kernels in `rsd::sampling::kernels`, next to their
//! libm baselines — the kernel-level trend CI diffs run to run.
//!
//!     cargo bench --bench hotpath             # human-readable
//!     cargo bench --bench hotpath -- --json   # + BENCH_hotpath.json (repo root)
//!     cargo bench --bench hotpath -- --quick  # CI-speed batches
//!     bench/run_pgo.sh                        # plain + PGO snapshot pair

use rsd::bench::alloc::{self, CountingAlloc};
use rsd::bench::harness::{bench, section, set_quick, snapshot_entry, write_snapshot, BenchResult};
use rsd::bench::workload::synth_logits;
use rsd::config::SamplingConfig;
use rsd::decode::rrs::{Rrs, VerifyRule};
use rsd::decode::spec::{SpecStepper, StepOutcome};
use rsd::decode::{build_parts, generate};
use rsd::llm::{EvalNode, Llm};
use rsd::sampling::{
    gumbel_top_k, gumbel_top_k_into, kernels, process_logits, process_logits_into, reference,
    truncated_gumbel_into, SelectScratch, VerifyScratch,
};
use rsd::sim::SimLm;
use rsd::tree::SessionCore;
use rsd::util::json::Json;
use rsd::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Measure steady-state heap allocations per decode round: warm a
/// stepper until a full round runs allocation-free (pool high-water
/// marks are only reached once every buffer has seen its largest use),
/// then count allocator traffic across `rounds` rounds. `top_p = 1.0`
/// keeps every tree at its full static shape, so the high-water mark is
/// deterministic; the nucleus kernel's own zero-allocation behaviour is
/// covered by the warm-scratch `process_logits` entries above.
fn steady_state_allocs(
    spec: &str,
    vocab: usize,
    rounds: usize,
    paged: bool,
) -> anyhow::Result<(f64, f64)> {
    // `paged = true` runs the identical decode on pool-backed sessions:
    // block-table slot lookups, block lease/release and pool headroom
    // queries all sit on the decode path and must stay allocation-free
    // (block vectors are capacity-reserved at session/pool creation)
    let (target, draft) = if paged {
        SimLm::pair_paged(
            0,
            0.8,
            vocab,
            rsd::kvcache::KvConfig { num_blocks: 512, block_size: 16, share: true },
        )
    } else {
        SimLm::pair(0, 0.8, vocab)
    };
    let sampling = SamplingConfig::new(0.5, 1.0);
    let cfg: rsd::config::DecoderConfig = spec.parse().unwrap();
    let (strategy, rule) = build_parts(&cfg);
    let mut rng = Rng::seed_from_u64(7);
    let mut st =
        SpecStepper::new(&target, &draft, strategy, rule, sampling, &[1, 2, 3], 1 << 16)?;
    // the gate runs with the flight recorder AND the speculation
    // analytics ENABLED: recording into the preallocated ring (commit
    // boundaries + KV pool traffic), bumping the atomic ledger and
    // ticking the windowed aggregator must not add a single allocation
    // to the steady-state round
    let tracer = rsd::trace::Tracer::new(4096);
    st.set_trace(&tracer, 1);
    target.set_trace(&tracer);
    draft.set_trace(&tracer);
    let analytics = rsd::obs::Analytics::new(8, 64, 0, 0);
    st.set_analytics(&analytics, rsd::obs::Family::RsdS);
    let tick_metrics = rsd::coordinator::metrics::Metrics::default();
    let mut warm = 0;
    loop {
        let (a0, _) = alloc::counts();
        assert_eq!(st.step(&target, &draft, &mut rng)?, StepOutcome::Progress);
        analytics.tick(&tick_metrics, 0, 1);
        let (a1, _) = alloc::counts();
        warm += 1;
        // bounded so a genuine regression (no clean round ever) still
        // reaches the measured window and fails the gate there
        if a1 == a0 || warm >= 64 {
            break;
        }
    }
    let (a0, b0) = alloc::counts();
    for _ in 0..rounds {
        assert_eq!(st.step(&target, &draft, &mut rng)?, StepOutcome::Progress);
        analytics.tick(&tick_metrics, 0, 1);
    }
    let (a1, b1) = alloc::counts();
    Ok(((a1 - a0) as f64 / rounds as f64, (b1 - b0) as f64 / rounds as f64))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    if args.iter().any(|a| a == "--quick") {
        set_quick(true);
    }
    let mut entries: Vec<Json> = Vec::new();
    let rec = |section: &str, r: BenchResult, entries: &mut Vec<Json>| {
        entries.push(snapshot_entry(section, &r));
        r
    };

    let mut rng = Rng::seed_from_u64(0);
    let vocab = 256usize;
    let logits = synth_logits(vocab);

    section("sampling substrate (per call, vocab = 256)");
    let mut sel = SelectScratch::default();
    let mut lp_buf: Vec<f64> = Vec::new();
    let r = bench("process_logits/temp", || {
        process_logits_into(&logits, 0.3, 1.0, &mut sel, &mut lp_buf);
    });
    rec("sampling-256", r, &mut entries);
    let r = bench("process_logits/temp+top_p", || {
        process_logits_into(&logits, 1.0, 0.95, &mut sel, &mut lp_buf);
    });
    rec("sampling-256", r, &mut entries);
    let lp = process_logits(&logits, 0.3, 1.0);
    let mut topk: Vec<(usize, f64)> = Vec::new();
    let r = bench("gumbel_top_k/k=4", || {
        gumbel_top_k_into(&lp, 4, &mut rng, &mut topk);
    });
    rec("sampling-256", r, &mut entries);
    let r = bench("gumbel_top_k/k=12", || {
        gumbel_top_k_into(&lp, 12, &mut rng, &mut topk);
    });
    rec("sampling-256", r, &mut entries);
    let phi: Vec<f64> = lp.0.clone();
    let mut tg_buf: Vec<f64> = Vec::new();
    let r = bench("truncated_gumbel/vocab=256", || {
        truncated_gumbel_into(-0.5, 0.1, &phi, &mut tg_buf);
    });
    rec("sampling-256", r, &mut entries);
    let q = process_logits(&logits.iter().rev().cloned().collect::<Vec<_>>(), 0.3, 1.0);
    let sib: Vec<u32> = gumbel_top_k(&lp, 4, &mut rng).iter().map(|&(i, _)| i as u32).collect();
    let mut vscratch = VerifyScratch::default();
    let r = bench("rrs_verify/k=4", || {
        let _ = Rrs.verify_with(&sib, &lp, &q, &mut vscratch, &mut rng);
    });
    rec("sampling-256", r, &mut entries);

    section("tree / session bookkeeping (cache_len = 256)");
    let r = bench("mask_row_build/prefix=128", || {
        let mut s = SessionCore::new(256);
        let nodes: Vec<EvalNode> = (0..128u32)
            .map(|i| if i == 0 { EvalNode::root(i) } else { EvalNode::child(i, (i - 1) as usize) })
            .collect();
        s.add_pending(&nodes).unwrap();
        let _ = s.visible_slots(127);
    });
    rec("session", r, &mut entries);
    {
        let mut s = SessionCore::new(256);
        let nodes: Vec<EvalNode> = (0..128u32)
            .map(|i| if i == 0 { EvalNode::root(i) } else { EvalNode::child(i, (i - 1) as usize) })
            .collect();
        s.add_pending(&nodes).unwrap();
        let r = bench("visible_slots only/prefix=128", || {
            let _ = s.visible_slots(127);
        });
        rec("session", r, &mut entries);
    }
    let r = bench("commit/30-node tree", || {
        let mut s = SessionCore::new(256);
        let mut nodes = vec![EvalNode::root(0)];
        for i in 1..30u32 {
            nodes.push(EvalNode::child(i, (i as usize).saturating_sub(1)));
        }
        s.add_pending(&nodes).unwrap();
        s.commit(&[0, 1, 2, 3]).unwrap();
    });
    rec("session", r, &mut entries);

    // ---- partial selection vs the sort-based baseline, real-vocab scale --
    section("selection kernels: partial vs sort baseline (vocab = 8192)");
    let big = synth_logits(8192);
    let big_lp = process_logits(&big, 0.7, 1.0);
    let heap = rec(
        "selection-8192",
        bench("gumbel_top_k/heap k=8", || {
            gumbel_top_k_into(&big_lp, 8, &mut rng, &mut topk);
        }),
        &mut entries,
    );
    let sorted = rec(
        "selection-8192",
        bench("gumbel_top_k/full-sort k=8 (baseline)", || {
            let _ = reference::gumbel_top_k(&big_lp, 8, &mut rng);
        }),
        &mut entries,
    );
    let topk_speedup = sorted.mean.as_secs_f64() / heap.mean.as_secs_f64();
    println!("gumbel_top_k heap vs sort: {topk_speedup:.2}x");

    let nuc = rec(
        "selection-8192",
        bench("process_logits/partial top_p=0.95", || {
            process_logits_into(&big, 1.0, 0.95, &mut sel, &mut lp_buf);
        }),
        &mut entries,
    );
    let nuc_base = rec(
        "selection-8192",
        bench("process_logits/full-sort top_p=0.95 (baseline)", || {
            // the pre-optimization path: fresh buffer + sort-based filter
            let inv_t = 1.0f64;
            let mut v: Vec<f64> = big.iter().map(|&x| x as f64 * inv_t).collect();
            rsd::sampling::log_normalize(&mut v);
            reference::nucleus_filter(&mut v, 0.95);
            rsd::sampling::log_normalize(&mut v);
            std::hint::black_box(&v);
        }),
        &mut entries,
    );
    let nucleus_speedup = nuc_base.mean.as_secs_f64() / nuc.mean.as_secs_f64();
    println!("nucleus partial vs full sort: {nucleus_speedup:.2}x");

    // ---- vectorizable math kernels vs their libm equivalents ------------
    // the poly kernels win by being branch-light slice maps the compiler
    // can auto-vectorize; libm's scalar calls are the pre-PR cost model
    section("fastmath kernels: poly vs libm (n = 8192)");
    let xs: Vec<f64> = big.iter().map(|&x| x as f64 * 0.25 - 4.0).collect();
    let mut ybuf = vec![0.0f64; xs.len()];
    let exp_poly = rec(
        "kernels-8192",
        bench("exp/poly", || {
            for (y, &x) in ybuf.iter_mut().zip(&xs) {
                *y = kernels::exp(x);
            }
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    let exp_libm = rec(
        "kernels-8192",
        bench("exp/libm (baseline)", || {
            for (y, &x) in ybuf.iter_mut().zip(&xs) {
                *y = x.exp();
            }
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    println!(
        "exp poly vs libm: {:.2}x",
        exp_libm.mean.as_secs_f64() / exp_poly.mean.as_secs_f64()
    );
    let pos: Vec<f64> = big.iter().map(|&x| (x as f64).abs() + 1e-3).collect();
    let ln_poly = rec(
        "kernels-8192",
        bench("ln/poly", || {
            for (y, &x) in ybuf.iter_mut().zip(&pos) {
                *y = kernels::ln(x);
            }
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    let ln_libm = rec(
        "kernels-8192",
        bench("ln/libm (baseline)", || {
            for (y, &x) in ybuf.iter_mut().zip(&pos) {
                *y = x.ln();
            }
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    println!("ln poly vs libm: {:.2}x", ln_libm.mean.as_secs_f64() / ln_poly.mean.as_secs_f64());
    let us: Vec<f64> = {
        let mut r2 = Rng::seed_from_u64(11);
        (0..8192).map(|_| r2.gen_f64_open()).collect()
    };
    let gum_poly = rec(
        "kernels-8192",
        bench("gumbel_map/poly", || {
            ybuf.copy_from_slice(&us);
            kernels::gumbel_map_in_place(&mut ybuf);
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    let gum_libm = rec(
        "kernels-8192",
        bench("gumbel_map/libm (baseline)", || {
            for (y, &u) in ybuf.iter_mut().zip(&us) {
                *y = -(-(u.ln())).ln();
            }
            std::hint::black_box(&ybuf);
        }),
        &mut entries,
    );
    println!(
        "gumbel_map poly vs libm: {:.2}x",
        gum_libm.mean.as_secs_f64() / gum_poly.mean.as_secs_f64()
    );

    // ---- the same selection contest at Llama-2 vocab scale --------------
    section("selection kernels: partial vs sort baseline (vocab = 32000)");
    let llama = synth_logits(32000);
    let llama_lp = process_logits(&llama, 0.7, 1.0);
    let heap32 = rec(
        "selection-32000",
        bench("gumbel_top_k/heap k=8", || {
            gumbel_top_k_into(&llama_lp, 8, &mut rng, &mut topk);
        }),
        &mut entries,
    );
    let sorted32 = rec(
        "selection-32000",
        bench("gumbel_top_k/full-sort k=8 (baseline)", || {
            let _ = reference::gumbel_top_k(&llama_lp, 8, &mut rng);
        }),
        &mut entries,
    );
    let topk_speedup_32000 = sorted32.mean.as_secs_f64() / heap32.mean.as_secs_f64();
    println!("gumbel_top_k heap vs sort (vocab 32000): {topk_speedup_32000:.2}x");

    let nuc32 = rec(
        "selection-32000",
        bench("process_logits/partial top_p=0.95", || {
            process_logits_into(&llama, 1.0, 0.95, &mut sel, &mut lp_buf);
        }),
        &mut entries,
    );
    let nuc32_base = rec(
        "selection-32000",
        bench("process_logits/full-sort top_p=0.95 (baseline)", || {
            let inv_t = 1.0f64;
            let mut v: Vec<f64> = llama.iter().map(|&x| x as f64 * inv_t).collect();
            rsd::sampling::log_normalize(&mut v);
            reference::nucleus_filter(&mut v, 0.95);
            rsd::sampling::log_normalize(&mut v);
            std::hint::black_box(&v);
        }),
        &mut entries,
    );
    let nucleus_speedup_32000 = nuc32_base.mean.as_secs_f64() / nuc32.mean.as_secs_f64();
    println!("nucleus partial vs full sort (vocab 32000): {nucleus_speedup_32000:.2}x");

    // per-round kernel chain at vocab 8192, shaped like one rsd-c:2-2-2
    // round (7 parents x Gumbel-Top-2 + 14 node distributions + one
    // 3-level verification walk): the pre-PR chain allocated per node
    // and sorted the full vocab; the new chain is pooled + partial.
    let verify_lp = process_logits(&big.iter().rev().cloned().collect::<Vec<_>>(), 0.7, 1.0);
    let sib2: Vec<u32> = sib[..2].to_vec();
    let chain_new = rec(
        "spec-round-8192",
        bench("round_kernels/pooled+partial", || {
            for _ in 0..7 {
                gumbel_top_k_into(&big_lp, 2, &mut rng, &mut topk);
            }
            for _ in 0..14 {
                process_logits_into(&big, 0.7, 0.95, &mut sel, &mut lp_buf);
            }
            for _ in 0..3 {
                let _ = Rrs.verify_with(&sib2, &big_lp, &verify_lp, &mut vscratch, &mut rng);
            }
        }),
        &mut entries,
    );
    let chain_base = rec(
        "spec-round-8192",
        bench("round_kernels/alloc+sort (pre-PR baseline)", || {
            for _ in 0..7 {
                let _ = reference::gumbel_top_k(&big_lp, 2, &mut rng);
            }
            for _ in 0..14 {
                let mut v: Vec<f64> = big.iter().map(|&x| x as f64 / 0.7).collect();
                rsd::sampling::log_normalize(&mut v);
                reference::nucleus_filter(&mut v, 0.95);
                rsd::sampling::log_normalize(&mut v);
                std::hint::black_box(&v);
            }
            for _ in 0..3 {
                // the pre-PR verify allocated its probability vectors
                let sib_owned: Vec<u32> = sib[..2].to_vec();
                let _ = Rrs.verify(&sib_owned, &big_lp, &verify_lp, &mut rng);
            }
        }),
        &mut entries,
    );
    let round_speedup = chain_base.mean.as_secs_f64() / chain_new.mean.as_secs_f64();
    println!("per-round kernel chain vs pre-PR baseline: {round_speedup:.2}x");

    section("whole rounds on the sim substrate");
    let sampling = SamplingConfig::new(0.3, 1.0);
    for (vocab, tag) in [(256usize, "vocab=256"), (8192, "vocab=8192")] {
        let (target, draft) = SimLm::pair(0, 0.8, vocab);
        for spec in ["sd:3", "rsd-c:2-2-2", "rsd-s:6x5"] {
            let cfg: rsd::config::DecoderConfig = spec.parse().unwrap();
            let r = bench(&format!("spec_round/{tag}/{spec}"), || {
                let (strategy, rule) = build_parts(&cfg);
                let mut st = SpecStepper::new(
                    &target,
                    &draft,
                    strategy,
                    rule,
                    sampling.clone(),
                    &[1, 2, 3],
                    64,
                )
                .unwrap();
                while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {
                    if st.out.len() >= 8 {
                        break;
                    }
                }
            });
            rec(if vocab == 8192 { "spec-round-8192" } else { "spec-round-256" }, r, &mut entries);
        }
    }

    // ---- the zero-allocation acceptance gate ----------------------------
    section("steady-state heap allocations per decode round (SimLm)");
    let mut max_allocs_per_round = 0.0f64;
    for paged in [false, true] {
        for spec in ["sd:3", "rsd-c:2-2-2", "rsd-s:6x5"] {
            let (allocs, bytes) = steady_state_allocs(spec, 256, 64, paged)?;
            let backing = if paged { "paged" } else { "dense" };
            println!(
                "{spec:<14} [{backing}] {allocs:>8.2} allocs/round  {bytes:>10.1} bytes/round"
            );
            let name = format!("allocs_per_round/{backing}/{spec}");
            entries.push(Json::obj(vec![
                ("section", Json::from("steady-state")),
                ("name", Json::from(name.as_str())),
                ("ns_per_op", Json::Num(0.0)),
                ("allocs_per_op", Json::Num(allocs)),
                ("bytes_per_op", Json::Num(bytes)),
            ]));
            max_allocs_per_round = max_allocs_per_round.max(allocs);
        }
    }

    // write the snapshot BEFORE the gates below: a regressing run must
    // still ship its diagnostic JSON (CI uploads it with `if: always()`)
    if json_out {
        // per-kernel nanoseconds (whole slice + per element) — the
        // kernel-level trend the CI diff step compares run to run
        let kern = |r: &BenchResult, n: usize| {
            Json::obj(vec![
                ("ns_per_op", Json::Num(r.mean.as_secs_f64() * 1e9)),
                ("ns_per_element", Json::Num(r.mean.as_secs_f64() * 1e9 / n as f64)),
            ])
        };
        let extra = vec![
            (
                "kernels",
                Json::obj(vec![
                    ("exp_poly_8192", kern(&exp_poly, 8192)),
                    ("exp_libm_8192", kern(&exp_libm, 8192)),
                    ("ln_poly_8192", kern(&ln_poly, 8192)),
                    ("ln_libm_8192", kern(&ln_libm, 8192)),
                    ("gumbel_map_poly_8192", kern(&gum_poly, 8192)),
                    ("gumbel_map_libm_8192", kern(&gum_libm, 8192)),
                    ("gumbel_top_k_heap_8192", kern(&heap, 8192)),
                    ("gumbel_top_k_sort_8192", kern(&sorted, 8192)),
                    ("nucleus_partial_8192", kern(&nuc, 8192)),
                    ("nucleus_sort_8192", kern(&nuc_base, 8192)),
                    ("gumbel_top_k_heap_32000", kern(&heap32, 32000)),
                    ("gumbel_top_k_sort_32000", kern(&sorted32, 32000)),
                    ("nucleus_partial_32000", kern(&nuc32, 32000)),
                    ("nucleus_sort_32000", kern(&nuc32_base, 32000)),
                ]),
            ),
            (
                "asserts",
                Json::obj(vec![
                    ("steady_state_allocs_per_round", Json::Num(max_allocs_per_round)),
                    ("round_kernel_speedup_vs_baseline", Json::Num(round_speedup)),
                    ("gumbel_top_k_speedup", Json::Num(topk_speedup)),
                    ("nucleus_speedup", Json::Num(nucleus_speedup)),
                    ("gumbel_top_k_speedup_32000", Json::Num(topk_speedup_32000)),
                    ("nucleus_speedup_32000", Json::Num(nucleus_speedup_32000)),
                ]),
            ),
        ];
        let path = write_snapshot("BENCH_hotpath.json", entries, extra)?;
        println!("\nwrote {}", path.display());
    }

    assert!(
        max_allocs_per_round == 0.0,
        "steady-state decode rounds must be allocation-free \
         (got {max_allocs_per_round} allocs/round)"
    );
    println!("0 allocations per steady-state round ✓");
    assert!(
        round_speedup >= 2.0,
        "per-round kernel chain must be ≥2x the sort/alloc baseline at vocab 8192 \
         (got {round_speedup:.2}x)"
    );
    println!("≥2x over the pre-PR kernel baseline at vocab 8192 ✓");
    assert!(
        topk_speedup_32000 >= 2.0,
        "gumbel_top_k must be ≥2x the full-sort baseline at vocab 32000 \
         (got {topk_speedup_32000:.2}x)"
    );
    println!("≥2x over the full-sort top-k baseline at vocab 32000 ✓");

    // ---- the real bottleneck: one PJRT step call ------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        section("PJRT model calls (REAL artifacts)");
        let rt = rsd::runtime::Runtime::cpu()?;
        let (ptarget, pdraft) = rsd::model::PjrtLm::load_pair(&rt, "artifacts")?;
        {
            let mut sess = ptarget.begin()?;
            // fill some prefix first
            let nodes: Vec<EvalNode> = (0..32u32)
                .map(|i| {
                    if i == 0 { EvalNode::root(5) } else { EvalNode::child(7, (i - 1) as usize) }
                })
                .collect();
            ptarget.eval(&mut sess, &nodes)?;
            let chain: Vec<usize> = (0..32).collect();
            ptarget.commit(&mut sess, &chain)?;
            bench("target_step/one 32-token tile", || {
                let rows = ptarget.eval(&mut sess, &[EvalNode::root(9)]).unwrap();
                std::hint::black_box(&rows);
                // discard pending (commit nothing) so the prefix stays fixed
                ptarget.commit(&mut sess, &[]).unwrap();
            });
        }
        {
            let mut sess = pdraft.begin()?;
            let nodes: Vec<EvalNode> = (0..32u32)
                .map(|i| {
                    if i == 0 { EvalNode::root(5) } else { EvalNode::child(7, (i - 1) as usize) }
                })
                .collect();
            pdraft.eval(&mut sess, &nodes)?;
            let chain: Vec<usize> = (0..32).collect();
            pdraft.commit(&mut sess, &chain)?;
            bench("draft_step/one 32-token tile", || {
                let rows = pdraft.eval(&mut sess, &[EvalNode::root(9)]).unwrap();
                std::hint::black_box(&rows);
                pdraft.commit(&mut sess, &[]).unwrap();
            });
        }
        section("end-to-end decode (REAL artifacts, 16 tokens)");
        let sampling = SamplingConfig::new(0.3, 1.0);
        for spec in ["ar", "sd:3", "rsd-s:3x3"] {
            let cfg: rsd::config::DecoderConfig = spec.parse().unwrap();
            bench(&format!("generate16/{spec}"), || {
                let _ =
                    generate(&cfg, &sampling, &ptarget, &pdraft, &[1, 2, 3], 16, &mut rng)
                        .unwrap();
            });
        }
    } else {
        eprintln!("artifacts missing — skipping PJRT hot-path benches");
    }
    Ok(())
}
