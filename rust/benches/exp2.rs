//! Exp2 (paper §5.2, Figure 5, Tables 28-54): performance at fixed
//! *target computational budget* B ∈ {6,10,14,21,30} — the number of
//! draft-tree tokens the target processes per iteration — with the exact
//! tree structures of App. C.3.2. This is the experiment the paper
//! stresses no prior work ran (resource-bounded devices).
//!
//!     cargo bench --bench exp2

use rsd::bench::{self, workload, BenchOpts};
use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::sim::SimLm;

fn main() -> anyhow::Result<()> {
    let sampling = SamplingConfig::new(0.3, 1.0);

    // ---- sim substrate: full App. C.3.2 grid at two alignments ---------
    for alpha in [0.9, 0.6] {
        let (target, draft) = SimLm::pair(0, alpha, 256);
        let prompts = workload::random_prompts(6, 16, 256, 1);
        let opts = BenchOpts { max_new: 64, reps: 6, tv_trials: 0, seed: 0 };
        let ar =
            bench::bench_decoder(&DecoderConfig::Ar, &sampling, &target, &draft, &prompts, &opts)?;
        for b in [6usize, 10, 14, 21, 30] {
            let mut rows = Vec::new();
            for cfg in bench::exp2_configs(b) {
                rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
            }
            bench::print_table(&format!("Exp2 sim (alpha={alpha}) Budget = {b}"), &ar, &rows, true);
        }
    }

    // ---- real model: spot-check B = 14 ---------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::cpu()?;
        let (target, draft) = PjrtLm::load_pair(&rt, "artifacts")?;
        let prompts = workload::corpus_prompts("artifacts", 3, 48, 2)?;
        let opts = BenchOpts { max_new: 48, reps: 3, tv_trials: 0, seed: 0 };
        let ar =
            bench::bench_decoder(&DecoderConfig::Ar, &sampling, &target, &draft, &prompts, &opts)?;
        let mut rows = Vec::new();
        for cfg in bench::exp2_configs(14) {
            rows.push(bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?);
        }
        bench::print_table("Exp2 REAL MODEL (AOT/PJRT) Budget = 14", &ar, &rows, true);
    } else {
        eprintln!("artifacts missing — skipping real-model spot check (run `make artifacts`)");
    }
    Ok(())
}
