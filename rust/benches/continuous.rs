//! Continuous admission vs drain-then-refill, on the sim substrate.
//!
//! The engine admits waiting requests at every PHASE boundary
//! (continuous batching): as soon as a completion frees a slot — or a
//! new request arrives mid-round — the joiner's first phase work fuses
//! into the next model call. The alternative this bench A/Bs against is
//! drain-then-refill (`EngineConfig::drain_batching`): admit a batch,
//! run every member to completion, only then touch the queue.
//!
//! With 16 mixed requests over 4 slots and heterogeneous lengths, the
//! drain policy makes every queued request wait for the SLOWEST member
//! of the running batch; continuous admission backfills each slot the
//! round it opens. That shows up directly in queue-wait and
//! time-to-first-token (both measured from arrival), while the decoded
//! streams stay bit-identical — admission timing is scheduling, never
//! sampling.
//!
//!     cargo bench --bench continuous             # human-readable
//!     cargo bench --bench continuous -- --json   # + BENCH_continuous.json
//!     cargo bench --bench continuous -- --quick  # shorter streams for CI
//!
//! Asserted (the acceptance gate):
//! * continuous mean TTFT < drain mean TTFT;
//! * continuous p50 queue-wait < drain p50 queue-wait;
//! * per-request token streams bit-identical between the two admission
//!   modes AND the per-request (`fused = false`) execution path.

use std::sync::mpsc;
use std::time::Instant;

use rsd::bench::harness::write_snapshot;
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::Snapshot;
use rsd::sim::SimLm;
use rsd::util::json::Json;

const N_REQUESTS: u64 = 16;
const CONCURRENCY: usize = 4;
/// splitmix64 rounds charged per model dispatch (the fixed cost real
/// accelerators pay per forward pass; makes rounds take real time so
/// scheduling differences are measurable).
const DISPATCH_OVERHEAD: u64 = 150_000;

/// Heterogeneous decoders — mixed tree shapes, depths, and one AR lane.
/// No adaptive requests: their engine-global estimator intentionally
/// couples tree shapes to scheduling, which would break the
/// bit-identity assertion.
fn decoder_for(i: u64) -> Option<DecoderConfig> {
    match i % 5 {
        0 => None, // engine default (rsd-s:3x3)
        1 => Some(DecoderConfig::Ar),
        2 => Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
        3 => Some(DecoderConfig::Sd { l: 4 }),
        _ => Some(DecoderConfig::SpecTr { k: 2, l: 2 }),
    }
}

/// Heterogeneous lengths: completions stagger, so continuous admission
/// gets a backfill opportunity nearly every round.
fn max_new_for(i: u64, base: usize) -> usize {
    base + 2 * i as usize
}

/// Drive one full engine run; all 16 requests submitted at t=0.
fn run(drain: bool, fused: bool, base: usize) -> (Vec<Vec<u32>>, Snapshot, f64) {
    let (target, draft) = SimLm::pair(7, 0.8, 64);
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let cfg = EngineConfig {
        max_concurrency: CONCURRENCY,
        max_queue: 64,
        default_max_tokens: base,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 13,
        fused,
        drain_batching: drain,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..N_REQUESTS {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: vec![1 + i as u32, 5, 3],
            max_new: max_new_for(i, base),
            decoder: decoder_for(i),
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = handle.join().unwrap().snapshot();
    (streams, snap, total as f64 / wall)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let base = if args.iter().any(|a| a == "--quick") { 6 } else { 10 };
    println!(
        "=== continuous admission vs drain-then-refill ({N_REQUESTS} mixed requests, \
         {CONCURRENCY} slots, SimLm, dispatch overhead {DISPATCH_OVERHEAD} rounds) ==="
    );
    // warmup (page in, stabilize frequency scaling)
    let _ = run(false, true, base);

    let (drain_streams, drain_snap, drain_tps) = run(true, true, base);
    let (cont_streams, cont_snap, cont_tps) = run(false, true, base);
    let (seq_streams, _, _) = run(false, false, base);

    assert_eq!(
        cont_streams, drain_streams,
        "admission schedule must be token-invisible (continuous vs drain)"
    );
    assert_eq!(
        cont_streams, seq_streams,
        "admission schedule must be token-invisible (fused vs per-request)"
    );
    println!("decoded streams identical across drain / continuous / per-request ✓");

    let report = |name: &str, s: &Snapshot, tps: f64| {
        println!(
            "{name:>12}: TTFT mean {:>7.1} ms  p50 {:>7.1} ms  |  queue-wait p50 {:>7.1} ms \
             mean {:>7.1} ms  |  {tps:>8.1} tok/s",
            s.ttft_mean * 1e3,
            s.ttft_p50 * 1e3,
            s.queue_wait_p50 * 1e3,
            s.queue_wait_mean * 1e3,
        );
    };
    report("drain", &drain_snap, drain_tps);
    report("continuous", &cont_snap, cont_tps);
    let ttft_ratio = drain_snap.ttft_mean / cont_snap.ttft_mean.max(1e-12);
    let qwait_ratio = drain_snap.queue_wait_p50 / cont_snap.queue_wait_p50.max(1e-12);
    println!("TTFT mean improvement: {ttft_ratio:.2}x  |  queue-wait p50: {qwait_ratio:.2}x");

    assert!(
        cont_snap.ttft_mean < drain_snap.ttft_mean,
        "continuous admission must lower mean TTFT ({:.4}s vs {:.4}s)",
        cont_snap.ttft_mean,
        drain_snap.ttft_mean
    );
    assert!(
        cont_snap.queue_wait_p50 < drain_snap.queue_wait_p50,
        "continuous admission must lower p50 queue wait ({:.4}s vs {:.4}s)",
        cont_snap.queue_wait_p50,
        drain_snap.queue_wait_p50
    );
    println!("\nlower-TTFT + lower-queue-wait acceptance criteria met ✓");

    if json_out {
        let entry = |mode: &str, name: &str, secs: f64| {
            Json::obj(vec![
                ("section", Json::from("continuous-batching")),
                ("name", Json::from(format!("{mode}/{name}").as_str())),
                ("ns_per_op", Json::Num(secs * 1e9)),
            ])
        };
        let entries = vec![
            entry("drain", "ttft_mean", drain_snap.ttft_mean),
            entry("drain", "ttft_p50", drain_snap.ttft_p50),
            entry("drain", "queue_wait_p50", drain_snap.queue_wait_p50),
            entry("drain", "queue_wait_mean", drain_snap.queue_wait_mean),
            entry("continuous", "ttft_mean", cont_snap.ttft_mean),
            entry("continuous", "ttft_p50", cont_snap.ttft_p50),
            entry("continuous", "queue_wait_p50", cont_snap.queue_wait_p50),
            entry("continuous", "queue_wait_mean", cont_snap.queue_wait_mean),
        ];
        let extra = vec![
            ("ttft_mean_improvement", Json::Num(ttft_ratio)),
            ("queue_wait_p50_improvement", Json::Num(qwait_ratio)),
            ("requests", Json::from(N_REQUESTS as usize)),
            ("concurrency", Json::from(CONCURRENCY)),
            ("mid_round_admitted", Json::from(cont_snap.mid_round_admitted as usize)),
            ("drain_tok_per_s", Json::Num(drain_tps)),
            ("continuous_tok_per_s", Json::Num(cont_tps)),
        ];
        match write_snapshot("BENCH_continuous.json", entries, extra) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write BENCH_continuous.json: {e}"),
        }
    }
}
