//! Ablations for the design choices DESIGN.md §6 calls out:
//!
//! 1. **Verification rule on the same without-replacement tree** —
//!    RRS vs the SpecInfer multi-round rule (`rsd-c-mr`). Multi-round
//!    assumes i.i.d. siblings, so on RSD's without-replacement trees it
//!    is *inexact*: the TV column exposes the distortion while RRS stays
//!    at ~0. This is the system-level version of the paper's Fig. 1
//!    argument.
//! 2. **Deep vs wide at a fixed budget** — RSD-C [2,2,2] vs [7,1] vs
//!    chain at budget 14 across alignment levels (the Exp2 crossover).
//! 3. **Drafting overhead** — tree construction cost per round vs one
//!    draft model call (sim-free measurement of the L3 share).
//!
//!     cargo bench --bench ablation

use rsd::bench::harness::{bench, section};
use rsd::bench::{bench_decoder, first_token_tv, BenchOpts};
use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::decode::spec::{DraftTree, TreeStrategy};
use rsd::decode::strategies::{GumbelTopK, StochasticBeam};
use rsd::sampling::process_logits;
use rsd::sim::SimLm;
use rsd::util::Rng;

fn main() -> anyhow::Result<()> {
    let sampling = SamplingConfig::new(0.7, 1.0);

    section("ablation 1: RRS vs multi-round on the SAME w/o-replacement tree");
    let (target, draft) = SimLm::pair(3, 0.55, 48);
    let opts = BenchOpts { max_new: 64, reps: 12, tv_trials: 0, seed: 0 };
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| vec![i as u32 + 1, 2, 3]).collect();
    println!("{:<22} {:>7} {:>9}", "decoder", "eff", "TV(30k)");
    for cfg in [
        DecoderConfig::RsdC { branches: vec![2, 2, 2] },
        DecoderConfig::RsdCMultiRound { branches: vec![2, 2, 2] },
    ] {
        let row = bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?;
        let tv = first_token_tv(&cfg, &sampling, &target, &draft, &[5, 1, 9], 30_000, 7)?;
        println!("{:<22} {:>7.3} {:>9.4}", cfg.label(), row.eff, tv);
    }
    println!("=> RRS must match or beat eff AND keep TV ~ 0; multi-round on");
    println!("   without-replacement siblings is inexact (elevated TV).");

    section("ablation 2: deep vs wide at fixed budget 14");
    for alpha in [0.9, 0.5] {
        let (target, draft) = SimLm::pair(8, alpha, 48);
        println!("alpha = {alpha}:");
        for cfg in [
            DecoderConfig::Sd { l: 14 },
            DecoderConfig::RsdC { branches: vec![2, 1, 1, 1, 1, 1, 1] },
            DecoderConfig::RsdC { branches: vec![2, 2, 2] },
            DecoderConfig::RsdC { branches: vec![7, 1] },
            DecoderConfig::RsdS { w: 2, l: 7 },
            DecoderConfig::RsdS { w: 7, l: 2 },
        ] {
            let row = bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts)?;
            println!("  {:<22} eff {:>6.3}  mbsu {:>6.3}", cfg.label(), row.eff, row.mbsu);
        }
    }
    println!("=> aligned drafts favour depth; misaligned favour width (paper §5.2)");

    section("ablation 3: drafting overhead per level (strategy only, no model)");
    let (_, draft) = SimLm::pair(0, 0.8, 256);
    let logits = draft.logits(&[1, 2, 3]);
    let root = process_logits(&logits, 0.7, 1.0);
    let mut rng = Rng::seed_from_u64(0);
    let mut children = Vec::new();
    {
        let mut strat = GumbelTopK::new(vec![4, 2, 1]);
        bench("expand/gumbel-top-k b=4 (vocab 256)", || {
            let tree = DraftTree {
                nodes: Vec::new(),
                levels: Vec::new(),
                root_draft_lp: root.clone(),
            };
            strat.begin_round();
            children.clear();
            strat.expand(&tree, 0, &mut rng, &mut children);
        });
    }
    {
        let mut strat = StochasticBeam::new(6, 5);
        bench("expand/stochastic-beam W=6 (vocab 256)", || {
            let tree = DraftTree {
                nodes: Vec::new(),
                levels: Vec::new(),
                root_draft_lp: root.clone(),
            };
            strat.begin_round();
            children.clear();
            strat.expand(&tree, 0, &mut rng, &mut children);
        });
    }
    println!("=> compare against one draft step call (~ms on the real model,");
    println!("   see hotpath bench): drafting logic is noise.");
    Ok(())
}
