//! Paged KV cache with radix prefix sharing vs the same paged substrate
//! with sharing disabled, on a shared-system-prompt serving workload.
//!
//! The workload models a serving fleet: every request carries the same
//! long system prompt plus a short unique user suffix. With the radix
//! index on, the first admission publishes the prompt's full blocks and
//! every later session maps them as shared read-only blocks — its
//! prefill shrinks from the whole prompt to the unique suffix. With
//! sharing off, every session recomputes the full prompt. Each logits
//! row costs O(vocab) real work in the sim, so the win is genuine
//! compute, and both modes must decode bit-identical token streams
//! (asserted) — sharing only changes which prefill rows are computed,
//! never a distribution or an RNG draw.
//!
//!     cargo bench --bench kvcache             # human-readable
//!     cargo bench --bench kvcache -- --json   # + BENCH_kvcache.json (repo root)
//!     cargo bench --bench kvcache -- --quick  # shorter workload for CI

use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use rsd::bench::alloc::CountingAlloc;
use rsd::bench::harness::write_snapshot;
use rsd::chaos::{damage_spill_files, SpillDamage};
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::kvcache::{KvConfig, KvStats};
use rsd::sim::SimLm;
use rsd::util::json::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const N_REQUESTS: u64 = 8;
const VOCAB: usize = 512;
/// Shared system-prompt length (tokens) in the full run.
const SYS_PROMPT: usize = 256;
/// Unique per-request suffix length.
const SUFFIX: usize = 4;
/// Tokens generated per request in the full run.
const MAX_NEW: usize = 12;
/// Fixed per-dispatch cost (splitmix64 rounds), as in benches/fused.rs.
const DISPATCH_OVERHEAD: u64 = 20_000;

fn prompt_for(i: u64, sys_len: usize) -> Vec<u32> {
    let mut p: Vec<u32> = (0..sys_len as u32).map(|t| (t * 7 + 3) % VOCAB as u32).collect();
    p.extend((0..SUFFIX as u32).map(|t| (t * 31 + 11 * i as u32 + 1) % VOCAB as u32));
    p
}

/// Drive one full engine run over the shared-prompt workload; returns
/// (per-request streams, tokens/sec, target-pool stats).
fn run(share: bool, sys_len: usize, max_new: usize) -> (Vec<Vec<u32>>, f64, KvStats) {
    let cfg = KvConfig { num_blocks: 512, block_size: 16, share };
    let (target, draft) = SimLm::pair_paged(3, 0.8, VOCAB, cfg);
    let tpool = target.kv_pool().expect("paged").clone();
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let ecfg = EngineConfig {
        max_concurrency: N_REQUESTS as usize,
        max_queue: 64,
        default_max_tokens: max_new,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 42,
        fused: true,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, ecfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..N_REQUESTS {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: prompt_for(i, sys_len),
            max_new,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    handle.join().unwrap();
    (streams, total as f64 / wall, tpool.stats())
}

/// Distinct tenants for the cold-tier section: the radix working set
/// (every tenant's system prompt) is ~4x the pool, so serving them
/// round-robin evicts each tenant's blocks before its next request.
const TENANTS: u64 = 8;

fn tenant_prompt(i: u64, sys_len: usize) -> Vec<u32> {
    let t = (i % TENANTS) as u32;
    let mut p: Vec<u32> =
        (0..sys_len as u32).map(|x| (x * 7 + 13 * t + 3) % VOCAB as u32).collect();
    p.extend((0..SUFFIX as u32).map(|x| (x * 31 + 11 * i as u32 + 1) % VOCAB as u32));
    p
}

/// One engine run of the multi-tenant workload over a pool sized to
/// ~1/4 of the radix working set, serially (so eviction provably hits
/// every tenant between its two requests). `cold_dir` None = no cold
/// tier: evicted prefixes are simply recomputed.
fn run_cold(
    cold_dir: Option<&Path>,
    sys_len: usize,
    max_new: usize,
) -> (Vec<Vec<u32>>, f64, KvStats) {
    let n = 2 * TENANTS;
    let kv = KvConfig { num_blocks: 2 * (sys_len / 16), block_size: 16, share: true };
    let (target, draft) = match cold_dir {
        Some(dir) => SimLm::pair_paged_cold(3, 0.8, VOCAB, kv, dir, 4096).expect("cold attach"),
        None => SimLm::pair_paged(3, 0.8, VOCAB, kv),
    };
    let tpool = target.kv_pool().expect("paged").clone();
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let ecfg = EngineConfig {
        max_concurrency: 1,
        max_queue: 64,
        default_max_tokens: max_new,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 42,
        fused: true,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, ecfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..n {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: tenant_prompt(i, sys_len),
            max_new,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    handle.join().unwrap();
    (streams, total as f64 / wall, tpool.stats())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let (sys_len, max_new) = if args.iter().any(|a| a == "--quick") {
        (96, 8)
    } else {
        (SYS_PROMPT, MAX_NEW)
    };
    println!(
        "=== radix prefix sharing on vs off ({N_REQUESTS} requests, shared \
         {sys_len}-token system prompt + {SUFFIX}-token unique suffix, SimLm) ==="
    );
    // warmup (page in, stabilize frequency scaling)
    let _ = run(true, sys_len, max_new);

    let (off_streams, off_tps, off_stats) = run(false, sys_len, max_new);
    let (on_streams, on_tps, on_stats) = run(true, sys_len, max_new);

    assert_eq!(
        off_streams, on_streams,
        "prefix sharing must be token-for-token invisible"
    );
    println!("decoded tokens identical with sharing on vs off ✓");

    let speedup = on_tps / off_tps;
    println!("sharing off: {off_tps:>10.1} tok/s  (hit rate {:.2})", off_stats.hit_rate());
    println!("sharing on:  {on_tps:>10.1} tok/s  (hit rate {:.2})", on_stats.hit_rate());
    println!("speedup:     {speedup:>10.2}x");
    println!(
        "\ntarget-pool telemetry (sharing on): {} hit / {} looked-up tokens, \
         {} published blocks, {} CoW copies, {} evictions",
        on_stats.hit_tokens,
        on_stats.lookup_tokens,
        on_stats.published_blocks,
        on_stats.cow_copies,
        on_stats.evictions,
    );

    assert!(on_stats.hit_tokens > 0, "shared workload must produce prefix hits");
    assert_eq!(off_stats.hit_tokens, 0, "sharing-off baseline must not hit");
    assert!(
        speedup >= 1.5,
        "prefix sharing must be ≥1.5x on the shared-prompt workload (got {speedup:.2}x)"
    );
    println!("\n≥1.5x acceptance criterion met ✓");

    // ---- cold tier: spill/revive vs re-prefill ----------------------
    println!(
        "\n=== cold tier on vs off vs corrupted ({} requests round-robin over \
         {TENANTS} tenants, radix working set ~4x pool) ===",
        2 * TENANTS
    );
    let dir = std::env::temp_dir().join("rsd-bench-kvcold");
    let _ = std::fs::remove_dir_all(&dir);

    let (coff_streams, coff_tps, coff) = run_cold(None, sys_len, max_new);
    let (con_streams, con_tps, con) = run_cold(Some(&dir), sys_len, max_new);
    // break every spilled block on disk, then restart over the damaged
    // store: detection must degrade to re-prefill, never change tokens
    for (sub, mode) in [("target", SpillDamage::CorruptByte), ("draft", SpillDamage::Truncate)] {
        let hit = damage_spill_files(&dir.join(sub), 5, usize::MAX, mode);
        assert!(!hit.is_empty(), "no {sub} spill files to damage");
    }
    let (cc_streams, cc_tps, cc) = run_cold(Some(&dir), sys_len, max_new);

    assert_eq!(coff_streams, con_streams, "cold tier must be token-invisible");
    assert_eq!(coff_streams, cc_streams, "corrupted cold store must be token-invisible");
    println!("decoded tokens identical cold-off / cold-on / corrupted ✓");

    // the gate is deterministic token accounting, not wall-clock: every
    // prompt token not served by the radix (hot or revived) was
    // re-prefilled at O(vocab) compute
    let reprefill = |s: &KvStats| s.lookup_tokens - s.hit_tokens;
    println!(
        "cold off:  {:>10.1} tok/s  ({} tokens re-prefilled)",
        coff_tps,
        reprefill(&coff)
    );
    println!(
        "cold on:   {:>10.1} tok/s  ({} tokens re-prefilled, {} revived from cold, \
         {} spills)",
        con_tps,
        reprefill(&con),
        con.cold_hit_tokens,
        con.cold_spills
    );
    println!(
        "corrupted: {:>10.1} tok/s  ({} tokens re-prefilled, {} corrupt blocks dropped)",
        cc_tps,
        reprefill(&cc),
        cc.cold_corrupt
    );
    assert!(con.cold_hit_tokens > 0, "eviction churn must produce cold revivals");
    assert!(
        reprefill(&con) < reprefill(&coff),
        "cold tier must beat re-prefill: {} vs {} tokens recomputed",
        reprefill(&con),
        reprefill(&coff)
    );
    assert!(cc.cold_corrupt > 0, "damaged store must be detected, not trusted");
    println!("cold tier saves re-prefill and survives corruption ✓");
    let _ = std::fs::remove_dir_all(&dir);

    if json_out {
        let entry = |name: &str, tps: f64| {
            Json::obj(vec![
                ("section", Json::from("kvcache")),
                ("name", Json::from(name)),
                ("ns_per_op", Json::Num(1e9 / tps.max(1e-9))), // per decoded token
                ("allocs_per_op", Json::Num(0.0)),
                ("bytes_per_op", Json::Num(0.0)),
            ])
        };
        let entries = vec![
            entry("sharing-off/token", off_tps),
            entry("sharing-on/token", on_tps),
            entry("cold-off/token", coff_tps),
            entry("cold-on/token", con_tps),
            entry("cold-corrupted/token", cc_tps),
        ];
        let extra = vec![
            ("cold_reprefill_tokens", Json::from(reprefill(&con) as usize)),
            ("nocold_reprefill_tokens", Json::from(reprefill(&coff) as usize)),
            ("cold_hit_tokens", Json::from(con.cold_hit_tokens as usize)),
            ("cold_spills", Json::from(con.cold_spills as usize)),
            ("cold_corrupt_dropped", Json::from(cc.cold_corrupt as usize)),
            ("speedup", Json::Num(speedup)),
            ("hit_rate", Json::Num(on_stats.hit_rate())),
            ("hit_tokens", Json::from(on_stats.hit_tokens as usize)),
            ("lookup_tokens", Json::from(on_stats.lookup_tokens as usize)),
            ("published_blocks", Json::from(on_stats.published_blocks as usize)),
            ("cow_copies", Json::from(on_stats.cow_copies as usize)),
            ("sys_prompt_tokens", Json::from(sys_len)),
            ("max_new", Json::from(max_new)),
        ];
        match write_snapshot("BENCH_kvcache.json", entries, extra) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write BENCH_kvcache.json: {e}"),
        }
    }
}
