//! Paged KV cache with radix prefix sharing vs the same paged substrate
//! with sharing disabled, on a shared-system-prompt serving workload.
//!
//! The workload models a serving fleet: every request carries the same
//! long system prompt plus a short unique user suffix. With the radix
//! index on, the first admission publishes the prompt's full blocks and
//! every later session maps them as shared read-only blocks — its
//! prefill shrinks from the whole prompt to the unique suffix. With
//! sharing off, every session recomputes the full prompt. Each logits
//! row costs O(vocab) real work in the sim, so the win is genuine
//! compute, and both modes must decode bit-identical token streams
//! (asserted) — sharing only changes which prefill rows are computed,
//! never a distribution or an RNG draw.
//!
//!     cargo bench --bench kvcache             # human-readable
//!     cargo bench --bench kvcache -- --json   # + BENCH_kvcache.json (repo root)
//!     cargo bench --bench kvcache -- --quick  # shorter workload for CI

use std::sync::mpsc;
use std::time::Instant;

use rsd::bench::alloc::CountingAlloc;
use rsd::bench::harness::write_snapshot;
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::kvcache::{KvConfig, KvStats};
use rsd::sim::SimLm;
use rsd::util::json::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const N_REQUESTS: u64 = 8;
const VOCAB: usize = 512;
/// Shared system-prompt length (tokens) in the full run.
const SYS_PROMPT: usize = 256;
/// Unique per-request suffix length.
const SUFFIX: usize = 4;
/// Tokens generated per request in the full run.
const MAX_NEW: usize = 12;
/// Fixed per-dispatch cost (splitmix64 rounds), as in benches/fused.rs.
const DISPATCH_OVERHEAD: u64 = 20_000;

fn prompt_for(i: u64, sys_len: usize) -> Vec<u32> {
    let mut p: Vec<u32> = (0..sys_len as u32).map(|t| (t * 7 + 3) % VOCAB as u32).collect();
    p.extend((0..SUFFIX as u32).map(|t| (t * 31 + 11 * i as u32 + 1) % VOCAB as u32));
    p
}

/// Drive one full engine run over the shared-prompt workload; returns
/// (per-request streams, tokens/sec, target-pool stats).
fn run(share: bool, sys_len: usize, max_new: usize) -> (Vec<Vec<u32>>, f64, KvStats) {
    let cfg = KvConfig { num_blocks: 512, block_size: 16, share };
    let (target, draft) = SimLm::pair_paged(3, 0.8, VOCAB, cfg);
    let tpool = target.kv_pool().expect("paged").clone();
    let target = target.with_call_overhead(DISPATCH_OVERHEAD);
    let draft = draft.with_call_overhead(DISPATCH_OVERHEAD);
    let ecfg = EngineConfig {
        max_concurrency: N_REQUESTS as usize,
        max_queue: 64,
        default_max_tokens: max_new,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 42,
        fused: true,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, ecfg);
    let (tx, handle) = spawn(engine);

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..N_REQUESTS {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: prompt_for(i, sys_len),
            max_new,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut streams = Vec::new();
    let mut total = 0usize;
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        total += toks.len();
        streams.push(toks);
    }
    let wall = t0.elapsed().as_secs_f64();
    handle.join().unwrap();
    (streams, total as f64 / wall, tpool.stats())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let (sys_len, max_new) = if args.iter().any(|a| a == "--quick") {
        (96, 8)
    } else {
        (SYS_PROMPT, MAX_NEW)
    };
    println!(
        "=== radix prefix sharing on vs off ({N_REQUESTS} requests, shared \
         {sys_len}-token system prompt + {SUFFIX}-token unique suffix, SimLm) ==="
    );
    // warmup (page in, stabilize frequency scaling)
    let _ = run(true, sys_len, max_new);

    let (off_streams, off_tps, off_stats) = run(false, sys_len, max_new);
    let (on_streams, on_tps, on_stats) = run(true, sys_len, max_new);

    assert_eq!(
        off_streams, on_streams,
        "prefix sharing must be token-for-token invisible"
    );
    println!("decoded tokens identical with sharing on vs off ✓");

    let speedup = on_tps / off_tps;
    println!("sharing off: {off_tps:>10.1} tok/s  (hit rate {:.2})", off_stats.hit_rate());
    println!("sharing on:  {on_tps:>10.1} tok/s  (hit rate {:.2})", on_stats.hit_rate());
    println!("speedup:     {speedup:>10.2}x");
    println!(
        "\ntarget-pool telemetry (sharing on): {} hit / {} looked-up tokens, \
         {} published blocks, {} CoW copies, {} evictions",
        on_stats.hit_tokens,
        on_stats.lookup_tokens,
        on_stats.published_blocks,
        on_stats.cow_copies,
        on_stats.evictions,
    );

    assert!(on_stats.hit_tokens > 0, "shared workload must produce prefix hits");
    assert_eq!(off_stats.hit_tokens, 0, "sharing-off baseline must not hit");
    assert!(
        speedup >= 1.5,
        "prefix sharing must be ≥1.5x on the shared-prompt workload (got {speedup:.2}x)"
    );
    println!("\n≥1.5x acceptance criterion met ✓");

    if json_out {
        let entry = |name: &str, tps: f64| {
            Json::obj(vec![
                ("section", Json::from("kvcache")),
                ("name", Json::from(name)),
                ("ns_per_op", Json::Num(1e9 / tps.max(1e-9))), // per decoded token
                ("allocs_per_op", Json::Num(0.0)),
                ("bytes_per_op", Json::Num(0.0)),
            ])
        };
        let entries = vec![
            entry("sharing-off/token", off_tps),
            entry("sharing-on/token", on_tps),
        ];
        let extra = vec![
            ("speedup", Json::Num(speedup)),
            ("hit_rate", Json::Num(on_stats.hit_rate())),
            ("hit_tokens", Json::from(on_stats.hit_tokens as usize)),
            ("lookup_tokens", Json::from(on_stats.lookup_tokens as usize)),
            ("published_blocks", Json::from(on_stats.published_blocks as usize)),
            ("cow_copies", Json::from(on_stats.cow_copies as usize)),
            ("sys_prompt_tokens", Json::from(sys_len)),
            ("max_new", Json::from(max_new)),
        ];
        match write_snapshot("BENCH_kvcache.json", entries, extra) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write BENCH_kvcache.json: {e}"),
        }
    }
}
