//! Coordinator integration tests on the sim substrate: the engine's
//! continuous batching, admission control, overrides, and fairness.

use std::sync::mpsc;

use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::sim::SimLm;

fn engine_cfg(max_concurrency: usize, max_queue: usize) -> EngineConfig {
    EngineConfig {
        max_concurrency,
        max_queue,
        default_max_tokens: 16,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 7,
        fused: true,
        ..EngineConfig::default()
    }
}

fn request(id: u64, max_new: usize, resp: mpsc::Sender<Event>) -> Request {
    Request {
        id,
        prompt: vec![1, 2, 3],
        max_new,
        decoder: None,
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp,
    }
}

#[test]
fn all_requests_complete_with_exact_token_counts() {
    let (target, draft) = SimLm::pair(0, 0.8, 64);
    let engine = Engine::new(target, draft, engine_cfg(3, 64));
    let (tx, handle) = spawn(engine);

    let mut receivers = Vec::new();
    for i in 0..8u64 {
        let (rtx, rrx) = mpsc::channel();
        tx.send(request(i, 10 + i as usize, rtx)).unwrap();
        receivers.push((i, rrx));
    }
    drop(tx);

    for (i, rrx) in receivers {
        let mut tokens = Vec::new();
        let mut done = false;
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => tokens.extend(t),
                Event::Done(report) => {
                    assert_eq!(report.id, i);
                    assert_eq!(report.stats.generated, 10 + i as usize);
                    // arrival-relative timeline: wait <= ttft <= latency
                    assert!(report.queue_wait >= 0.0);
                    let ttft = report.ttft.expect("tokens were produced");
                    assert!(report.queue_wait <= ttft && ttft <= report.latency);
                    done = true;
                    break;
                }
                Event::Error(e) => panic!("request {i}: {e}"),
            }
        }
        assert!(done, "request {i} did not finish");
        assert_eq!(tokens.len(), 10 + i as usize);
    }
    let metrics = handle.join().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.tokens_out, (0..8).map(|i| 10 + i).sum::<u64>());
}

#[test]
fn queue_overflow_is_shed_with_error() {
    let (target, draft) = SimLm::pair(1, 0.8, 64);
    // concurrency 1, queue 2: the 4th+ concurrent offer can be shed
    let engine = Engine::new(target, draft, engine_cfg(1, 2));
    let (tx, handle) = spawn(engine);

    let mut receivers = Vec::new();
    for i in 0..12u64 {
        let (rtx, rrx) = mpsc::channel();
        tx.send(request(i, 200, rtx)).unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut completed = 0;
    let mut shed = 0;
    for rrx in receivers {
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Done(_) => {
                    completed += 1;
                    break;
                }
                Event::Error(e) => {
                    assert!(e.to_string().contains("queue full"), "{e}");
                    assert_eq!(e.kind, rsd::coordinator::ErrorKind::QueueFull);
                    assert!(e.retryable, "queue-full must be retryable: {e}");
                    shed += 1;
                    break;
                }
                Event::Tokens(_) => {}
            }
        }
    }
    let metrics = handle.join().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap.completed as usize, completed);
    assert_eq!(snap.rejected as usize, shed);
    assert_eq!(snap.shed as usize, shed, "queue-full rejections count as shed");
    assert!(shed > 0, "expected at least one shed request");
    assert_eq!(completed + shed, 12);
}

#[test]
fn per_request_decoder_override_applies() {
    let (target, draft) = SimLm::pair(2, 0.9, 64);
    let engine = Engine::new(target, draft, engine_cfg(2, 16));
    let (tx, handle) = spawn(engine);

    // AR override: stats must show zero draft calls and eff == 1
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request {
        id: 1,
        prompt: vec![4, 5],
        max_new: 12,
        decoder: Some(DecoderConfig::Ar),
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp: rtx,
    })
    .unwrap();
    // RSD-C override
    let (rtx2, rrx2) = mpsc::channel();
    tx.send(Request {
        id: 2,
        prompt: vec![4, 5],
        max_new: 12,
        decoder: Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp: rtx2,
    })
    .unwrap();
    drop(tx);

    let stats_of = |rrx: mpsc::Receiver<Event>| loop {
        match rrx.recv().unwrap() {
            Event::Done(r) => return r.stats,
            Event::Error(e) => panic!("{e}"),
            _ => {}
        }
    };
    let s1 = stats_of(rrx);
    let s2 = stats_of(rrx2);
    assert_eq!(s1.draft_calls, 0, "AR must not touch the draft model");
    assert!((s1.block_efficiency() - 1.0).abs() < 1e-9);
    assert!(s2.draft_calls > 0);
    assert!(s2.tree_nodes > 0);
    handle.join().unwrap();
}

/// Round-level fairness: with two long concurrent requests, both must
/// stream tokens before either finishes (continuous batching, not FCFS
/// run-to-completion).
#[test]
fn concurrent_requests_interleave() {
    let (target, draft) = SimLm::pair(3, 0.8, 64);
    let engine = Engine::new(target, draft, engine_cfg(2, 8));
    let (tx, handle) = spawn(engine);

    let (rtx_a, rrx_a) = mpsc::channel();
    let (rtx_b, rrx_b) = mpsc::channel();
    tx.send(request(1, 64, rtx_a)).unwrap();
    tx.send(request(2, 64, rtx_b)).unwrap();
    drop(tx);

    // collect the event interleaving as (who, is_done)
    let mut a_first_tokens = false;
    let mut b_first_tokens = false;
    let mut a_done = false;
    let mut b_done = false;
    // drain both receivers; order across channels is unknowable, but both
    // must produce Tokens before either produces Done IF fairness holds.
    let mut a_tokens_before_b_done = false;
    let mut b_tokens_before_a_done = false;
    loop {
        let mut progressed = false;
        if !a_done {
            if let Ok(ev) = rrx_a.try_recv() {
                progressed = true;
                match ev {
                    Event::Tokens(_) => {
                        a_first_tokens = true;
                        if !b_done {
                            a_tokens_before_b_done = true;
                        }
                    }
                    Event::Done(_) => a_done = true,
                    Event::Error(e) => panic!("{e}"),
                }
            }
        }
        if !b_done {
            if let Ok(ev) = rrx_b.try_recv() {
                progressed = true;
                match ev {
                    Event::Tokens(_) => {
                        b_first_tokens = true;
                        if !a_done {
                            b_tokens_before_a_done = true;
                        }
                    }
                    Event::Done(_) => b_done = true,
                    Event::Error(e) => panic!("{e}"),
                }
            }
        }
        if a_done && b_done {
            break;
        }
        if !progressed {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    assert!(a_first_tokens && b_first_tokens);
    assert!(a_tokens_before_b_done && b_tokens_before_a_done, "no interleaving observed");
    handle.join().unwrap();
}

/// Priority scheduling end to end: with one slot and the queue holding
/// a default-priority and a high-priority request, the high-priority
/// one must run first. Whichever of the first-arrived requests grabs
/// the slot, the default-priority queued request always finishes LAST.
#[test]
fn high_priority_requests_jump_the_queue() {
    // dispatch cost makes each request take several ms, so completion
    // timestamps (taken by dedicated receiver threads at event arrival)
    // order reliably despite thread-wakeup jitter
    let (target, draft) = SimLm::pair(5, 0.8, 64);
    let target = target.with_call_overhead(200_000);
    let draft = draft.with_call_overhead(200_000);
    let engine = Engine::new(target, draft, engine_cfg(1, 16));
    let (tx, handle) = spawn(engine);

    let done_order = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut joins = Vec::new();
    for (id, priority) in [(1u64, 0u8), (2, 0), (3, 200)] {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id,
            prompt: vec![1, 2, 3],
            max_new: 16,
            decoder: None,
            sampling: None,
            priority,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        let done_order = done_order.clone();
        joins.push(std::thread::spawn(move || {
            while let Ok(ev) = rrx.recv() {
                match ev {
                    Event::Done(_) => {
                        done_order.lock().unwrap().push(id);
                        break;
                    }
                    Event::Error(e) => panic!("request {id}: {e}"),
                    Event::Tokens(_) => {}
                }
            }
        }));
    }
    drop(tx);
    for j in joins {
        j.join().unwrap();
    }
    handle.join().unwrap();
    let order = done_order.lock().unwrap().clone();
    assert_eq!(order.len(), 3);
    // whichever first arrival grabbed the single slot, the queued
    // default-priority request is always outranked by priority 200
    assert_eq!(*order.last().unwrap(), 2, "default-priority request must finish last: {order:?}");
}

/// Metrics snapshot is consistent after a burst.
#[test]
fn metrics_are_consistent() {
    let (target, draft) = SimLm::pair(4, 0.85, 64);
    let engine = Engine::new(target, draft, engine_cfg(4, 64));
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for i in 0..6u64 {
        let (rtx, rrx) = mpsc::channel();
        tx.send(request(i, 20, rtx)).unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    for rrx in receivers {
        while let Ok(ev) = rrx.recv() {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
    let snap = handle.join().unwrap().snapshot();
    assert_eq!(snap.admitted, 6);
    assert_eq!(snap.completed, 6);
    assert_eq!(snap.tokens_out, 120);
    assert!(snap.decode_rounds >= snap.completed);
    assert!(snap.latency_p50 > 0.0);
    assert!(snap.ttft_p50 > 0.0);
    assert!(snap.latency_p95 >= snap.latency_p50);
}
