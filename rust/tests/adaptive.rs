//! Adaptive draft-budget controller: allocator budget-safety property
//! tests and engine-level integration on the sim substrate.

use std::sync::mpsc;

use rsd::adaptive::allocator::{best_shape, enumerate_shapes, initial_shape};
use rsd::adaptive::TreeShape;
use rsd::config::{AdaptiveFamily, DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::decode::generate;
use rsd::sim::SimLm;
use rsd::util::Rng;

/// PROPERTY: whatever the acceptance estimates, the allocator never
/// emits a shape whose worst-case node count exceeds the hard budget.
#[test]
fn allocator_never_exceeds_budget() {
    let mut rng = Rng::seed_from_u64(0xadab);
    let families = [AdaptiveFamily::Auto, AdaptiveFamily::RsdC, AdaptiveFamily::RsdS];
    for budget in 1..=40usize {
        for _ in 0..32 {
            let depth = 1 + rng.gen_range(8);
            let rates: Vec<f64> =
                (0..depth).map(|_| 0.02 + 0.96 * rng.gen_f64()).collect();
            for family in families {
                let shape = best_shape(budget, family, &rates);
                assert!(
                    shape.budget() <= budget,
                    "budget {budget} family {family:?} rates {rates:?} -> {shape:?}"
                );
            }
        }
        for family in families {
            assert!(initial_shape(budget, family).budget() <= budget);
            for shape in enumerate_shapes(budget, family) {
                assert!(shape.budget() <= budget, "{shape:?} vs {budget}");
            }
        }
    }
}

/// Degenerate rate vectors (empty, all-low, all-high) must still yield a
/// valid shape.
#[test]
fn allocator_handles_degenerate_rates() {
    for rates in [vec![], vec![0.0; 4], vec![1.0; 4]] {
        for budget in [1usize, 6, 30] {
            let s = best_shape(budget, AdaptiveFamily::Auto, &rates);
            assert!(s.budget() <= budget && s.budget() >= 1, "{rates:?} -> {s:?}");
        }
    }
}

/// A zero budget (only reachable programmatically — the parser rejects
/// it) is clamped to the single-node chain instead of panicking, for
/// every family including RsdS (whose raw shape space would be empty).
#[test]
fn allocator_clamps_zero_budget() {
    for family in [AdaptiveFamily::Auto, AdaptiveFamily::RsdC, AdaptiveFamily::RsdS] {
        let shapes = enumerate_shapes(0, family);
        assert!(!shapes.is_empty(), "{family:?}");
        let s = best_shape(0, family, &[0.5]);
        assert_eq!(s.budget(), 1, "{family:?} -> {s:?}");
    }
}

/// Runtime half of the acceptance criterion: under
/// `DecoderConfig::Adaptive { budget: B, .. }` no round's actual tree
/// ever uses more than B nodes, for every family.
#[test]
fn adaptive_rounds_respect_budget_at_runtime() {
    let (target, draft) = SimLm::pair(5, 0.6, 96);
    let sampling = SamplingConfig::new(0.6, 1.0);
    let mut rng = Rng::seed_from_u64(2);
    for b in [6usize, 30] {
        for family in [AdaptiveFamily::Auto, AdaptiveFamily::RsdC, AdaptiveFamily::RsdS] {
            let cfg = DecoderConfig::Adaptive { budget: b, family };
            let run =
                generate(&cfg, &sampling, &target, &draft, &[1, 2, 3], 40, &mut rng).unwrap();
            assert_eq!(run.tokens.len(), 40);
            let worst = run.stats.round_nodes.iter().copied().max().unwrap_or(0);
            assert!(
                worst as usize <= b,
                "{family:?} B={b}: a round used {worst} nodes"
            );
        }
    }
}

fn engine_mean_efficiency(decoder: DecoderConfig, alpha: f64, seed: u64) -> f64 {
    let (target, draft) = SimLm::pair(seed, alpha, 64);
    let cfg = EngineConfig {
        max_concurrency: 3,
        max_queue: 32,
        default_max_tokens: 48,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.7, 1.0),
        decoder: decoder.clone(),
        seed,
        fused: true,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for i in 0..6u64 {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: vec![3 + i as u32, 7, 11],
            max_new: 48,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    let mut effs = Vec::new();
    for rrx in receivers {
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Done(r) => {
                    effs.push(r.stats.block_efficiency());
                    break;
                }
                Event::Error(e) => panic!("{e}"),
                Event::Tokens(_) => {}
            }
        }
    }
    handle.join().unwrap();
    assert_eq!(effs.len(), 6);
    effs.iter().sum::<f64>() / effs.len() as f64
}

/// Integration half of the acceptance criterion: on a skewed-acceptance
/// workload (heavily misaligned draft, where a deep chain wastes its
/// budget on doomed levels) the adaptive controller at the same budget
/// must match or beat the mismatched static shape's block efficiency.
#[test]
fn adaptive_matches_or_beats_static_on_skewed_workload() {
    let alpha = 0.35; // high draft-target discrepancy
    let mut chain = 0.0;
    let mut adaptive = 0.0;
    for seed in [9u64, 10, 11] {
        // static budget-6 chain (SD-style): worst fit for this workload
        chain += engine_mean_efficiency(
            DecoderConfig::RsdC { branches: vec![1, 1, 1, 1, 1, 1] },
            alpha,
            seed,
        );
        adaptive += engine_mean_efficiency(
            DecoderConfig::Adaptive { budget: 6, family: AdaptiveFamily::Auto },
            alpha,
            seed,
        );
    }
    assert!(
        adaptive >= chain * 0.97,
        "adaptive {adaptive:.3} fell behind static chain {chain:.3}"
    );
}

/// The engine accepts heterogeneous per-request adaptive budgets and the
/// `done` stats carry the controller telemetry.
#[test]
fn engine_runs_heterogeneous_adaptive_budgets() {
    let (target, draft) = SimLm::pair(4, 0.8, 64);
    let cfg = EngineConfig {
        max_concurrency: 4,
        max_queue: 32,
        default_max_tokens: 24,
        max_active_budget: 40,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 1,
        fused: true,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);
    let budgets = [6usize, 30, 6, 30];
    let mut receivers = Vec::new();
    for (i, &b) in budgets.iter().enumerate() {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i as u64,
            prompt: vec![2, 4, 6],
            max_new: 24,
            decoder: Some(DecoderConfig::Adaptive { budget: b, family: AdaptiveFamily::Auto }),
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push((b, rrx));
    }
    drop(tx);
    for (b, rrx) in receivers {
        loop {
            match rrx.recv().unwrap() {
                Event::Done(r) => {
                    let stats = r.stats;
                    assert_eq!(stats.generated, 24);
                    assert!(!stats.level_attempts.is_empty());
                    assert!(stats
                        .round_nodes
                        .iter()
                        .all(|&n| n as usize <= b), "budget {b}: {:?}", stats.round_nodes);
                    break;
                }
                Event::Error(e) => panic!("{e}"),
                Event::Tokens(_) => {}
            }
        }
    }
    let snap = handle.join().unwrap().snapshot();
    assert_eq!(snap.completed, 4);
    // engine-level controller telemetry aggregated across requests
    assert!(!snap.accept_rate_by_level.is_empty());
    assert!(!snap.round_nodes_hist.is_empty());
    let max_nodes = snap.round_nodes_hist.iter().map(|&(n, _)| n).max().unwrap();
    assert!(max_nodes <= 30, "round used {max_nodes} nodes");
}

/// The shape space always contains a shape for tiny budgets and the
/// chosen shapes differ across acceptance regimes (the controller has
/// something to choose between).
#[test]
fn shape_space_is_meaningfully_diverse() {
    let low = best_shape(30, AdaptiveFamily::Auto, &[0.15]);
    let high = best_shape(30, AdaptiveFamily::Auto, &[0.95]);
    assert_ne!(low, high);
    assert!(matches!(
        best_shape(1, AdaptiveFamily::Auto, &[0.5]),
        TreeShape::RsdC { .. } | TreeShape::RsdS { .. }
    ));
}
