//! End-to-end tests over the REAL three-layer stack: AOT HLO artifacts
//! loaded through PJRT, exercised by the same decoders as the sim tests.
//!
//! Requires a `--cfg pjrt_runtime` build (the default build has only the
//! PJRT stubs, so this whole file compiles away) and `make artifacts` to
//! have run (the repo ships a Makefile rule; tests fail with a clear
//! message otherwise).
#![cfg(pjrt_runtime)]

use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::decode::generate;
use rsd::llm::{EvalNode, Llm};
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::sampling::process_logits;
use rsd::tokenizer::Tokenizer;
use rsd::util::Rng;

fn artifacts_dir() -> String {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        manifest.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    manifest.to_string_lossy().into_owned()
}

fn load() -> (Runtime, PjrtLm, PjrtLm) {
    let rt = Runtime::cpu().expect("PJRT cpu client");
    let (t, d) = PjrtLm::load_pair(&rt, artifacts_dir()).expect("load artifacts");
    (rt, t, d)
}

/// Incremental decode through the KV cache must match a fresh prefill of
/// the same sequence (the L3 equivalent of python/tests/test_model.py).
#[test]
fn incremental_matches_fresh_prefill() {
    let (_rt, target, _draft) = load();
    let toks: Vec<u32> = vec![5, 9, 13, 2, 7, 1, 30, 12];

    // path A: prefill all 8 tokens in one eval
    let mut sa = target.begin().unwrap();
    let nodes: Vec<EvalNode> = toks
        .iter()
        .enumerate()
        .map(|(i, &t)| if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) })
        .collect();
    let rows_a = target.eval(&mut sa, &nodes).unwrap();

    // path B: feed in chunks of 3 with commits between
    let mut sb = target.begin().unwrap();
    let mut rows_b: Vec<Vec<f32>> = Vec::new();
    for chunk in toks.chunks(3) {
        let nodes: Vec<EvalNode> = chunk
            .iter()
            .enumerate()
            .map(|(i, &t)| if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) })
            .collect();
        let rows = target.eval(&mut sb, &nodes).unwrap();
        rows_b.extend(rows);
        let chain: Vec<usize> = (0..chunk.len()).collect();
        target.commit(&mut sb, &chain).unwrap();
    }

    for (i, (a, b)) in rows_a.iter().zip(&rows_b).enumerate() {
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() < 2e-3,
                "row {i}: logits diverge ({x} vs {y})"
            );
        }
    }
}

/// Tree evaluation in one call must match evaluating each branch as its
/// own sequence — the core of the paper's parallel tree verification.
#[test]
fn tree_eval_matches_per_branch_decode() {
    let (_rt, target, _draft) = load();
    let prefix = [4u32, 8];

    let decode_branch = |branch: &[u32]| -> Vec<Vec<f32>> {
        let mut s = target.begin().unwrap();
        let mut rows = Vec::new();
        for (i, &t) in branch.iter().enumerate() {
            let node =
                if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) };
            rows.push(target.eval(&mut s, &[node]).unwrap().remove(0));
        }
        rows
    };
    let seq_a = decode_branch(&[4, 8, 3, 1]);
    let seq_b = decode_branch(&[4, 8, 7, 2]);

    // one session: prefill prefix, then the 4-node tree in one eval
    let mut s = target.begin().unwrap();
    target
        .eval(&mut s, &[EvalNode::root(prefix[0]), EvalNode::child(prefix[1], 0)])
        .unwrap();
    target.commit(&mut s, &[0, 1]).unwrap();
    let tree_rows = target
        .eval(
            &mut s,
            &[
                EvalNode::root(3),      // a
                EvalNode::root(7),      // b (sibling of a)
                EvalNode::child(1, 0),  // a -> c
                EvalNode::child(2, 1),  // b -> d
            ],
        )
        .unwrap();

    let close = |x: &[f32], y: &[f32], what: &str| {
        for (a, b) in x.iter().zip(y) {
            assert!((a - b).abs() < 2e-3, "{what}: {a} vs {b}");
        }
    };
    close(&tree_rows[0], &seq_a[2], "node a");
    close(&tree_rows[1], &seq_b[2], "node b");
    close(&tree_rows[2], &seq_a[3], "node c");
    close(&tree_rows[3], &seq_b[3], "node d");
}

/// Zero-copy KV filtering: after commit of one branch, continuing from
/// the accepted path matches a fresh session over the same tokens.
#[test]
fn kv_filter_keeps_accepted_branch_only() {
    let (_rt, target, _draft) = load();
    // session X: tree {a, b} under prefix [6]; accept b; then eval token 9
    let mut sx = target.begin().unwrap();
    target.eval(&mut sx, &[EvalNode::root(6)]).unwrap();
    target.commit(&mut sx, &[0]).unwrap();
    target.eval(&mut sx, &[EvalNode::root(3), EvalNode::root(7)]).unwrap();
    target.commit(&mut sx, &[1]).unwrap(); // accept token 7 (slot of b reused later)
    let rows_x = target.eval(&mut sx, &[EvalNode::root(9)]).unwrap();

    // fresh session Y over [6, 7, 9]
    let mut sy = target.begin().unwrap();
    let rows_y = target
        .eval(
            &mut sy,
            &[EvalNode::root(6), EvalNode::child(7, 0), EvalNode::child(9, 1)],
        )
        .unwrap();

    for (a, b) in rows_x[0].iter().zip(&rows_y[2]) {
        assert!((a - b).abs() < 2e-3, "{a} vs {b}");
    }
}

/// Every decoder runs on the real model and generates the requested
/// number of tokens with sane stats.
#[test]
fn all_decoders_run_on_real_model() {
    let (_rt, target, draft) = load();
    let tok = Tokenizer::new();
    let prompt = tok.encode("he said ");
    let sampling = SamplingConfig::new(0.3, 1.0);
    let mut rng = Rng::seed_from_u64(1);
    for cfg in [
        DecoderConfig::Ar,
        DecoderConfig::Sd { l: 3 },
        DecoderConfig::SpecTr { k: 2, l: 3 },
        DecoderConfig::RsdC { branches: vec![2, 2] },
        DecoderConfig::RsdS { w: 3, l: 3 },
    ] {
        let run =
            generate(&cfg, &sampling, &target, &draft, &prompt, 24, &mut rng).unwrap();
        assert_eq!(run.tokens.len(), 24, "{cfg:?}");
        assert!(run.tokens.iter().all(|&t| t < target.vocab() as u32));
        if cfg != DecoderConfig::Ar {
            assert!(
                run.stats.block_efficiency() > 1.0,
                "{cfg:?}: eff {}",
                run.stats.block_efficiency()
            );
        }
    }
}

/// The trained draft must actually be aligned with the target: the
/// processed next-token distributions should be close on corpus-like
/// context (this is what makes speculative decoding profitable at all).
#[test]
fn draft_is_aligned_with_target() {
    let (_rt, target, draft) = load();
    let tok = Tokenizer::new();
    let prompt = tok.encode("in the ");
    let nodes: Vec<EvalNode> = prompt
        .iter()
        .enumerate()
        .map(|(i, &t)| if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) })
        .collect();
    let mut st = target.begin().unwrap();
    let mut sd = draft.begin().unwrap();
    let qt = target.eval(&mut st, &nodes).unwrap();
    let qd = draft.eval(&mut sd, &nodes).unwrap();
    let q = process_logits(qt.last().unwrap(), 1.0, 1.0).probs();
    let p = process_logits(qd.last().unwrap(), 1.0, 1.0).probs();
    let tv = rsd::sampling::tv_distance(&q, &p);
    assert!(tv < 0.5, "draft/target TV {tv} — distillation failed?");
    assert!(tv > 0.001, "draft identical to target — suspicious");
}
