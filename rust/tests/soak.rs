//! Engine soak/fuzz suite: a seeded-random mix of priorities,
//! deadlines, prompt lengths, stop tokens and decoder modes, pushed
//! through an engine with a deliberately undersized paged KV pool —
//! 200 requests that force admission churn, preemption and resume to
//! interleave continuously.
//!
//! Invariants asserted:
//! * no deadlock — every request reaches a terminal state (a watchdog
//!   timeout per receive turns a hang into a failure);
//! * no starved request — all 200 complete (priorities + aging must let
//!   every class through);
//! * clean terminal states — exactly one `Done` per request, no
//!   `Error`s, stats consistent with the delivered stream;
//! * determinism under churn — per-request token streams bit-identical
//!   to a sequential reference run (dense substrate, per-request eval,
//!   no preemption), so scheduling chaos never leaks into output.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use rsd::bench::harness;
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig, SamplingPatch};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::{Metrics, Snapshot};
use rsd::decode::DecodeStats;
use rsd::kvcache::KvConfig;
use rsd::obs::Analytics;
use rsd::sim::SimLm;
use rsd::trace::export::chrome_trace;
use rsd::trace::{TraceEvent, Tracer};
use rsd::util::json::Json;
use rsd::util::Rng;

const VOCAB: usize = 32;
const N_REQUESTS: u64 = 200;
const SIM_SEED: u64 = 17;
const ENGINE_SEED: u64 = 99;

/// One fuzzed request, pre-generated so the chaos run and the reference
/// run submit byte-identical workloads.
#[derive(Clone)]
struct Spec {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    decoder: Option<DecoderConfig>,
    sampling: Option<SamplingPatch>,
    priority: u8,
    deadline_ms: Option<u64>,
}

/// Seeded-random workload. Adaptive decoders are excluded on purpose:
/// they share the engine-global acceptance estimator, so their tree
/// SHAPES (never their correctness) legitimately depend on scheduling,
/// which would break the bit-identity half of this test.
fn build_workload(seed: u64) -> Vec<Spec> {
    let mut rng = Rng::seed_from_u64(seed);
    let decoders: [Option<DecoderConfig>; 6] = [
        None, // engine default (rsd-s:3x3)
        Some(DecoderConfig::Ar),
        Some(DecoderConfig::Sd { l: 3 }),
        Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
        Some(DecoderConfig::RsdS { w: 3, l: 2 }),
        Some(DecoderConfig::SpecTr { k: 2, l: 2 }),
    ];
    (0..N_REQUESTS)
        .map(|id| {
            let prompt_len = 1 + rng.gen_range(20);
            let prompt: Vec<u32> =
                (0..prompt_len).map(|_| rng.gen_range(VOCAB) as u32).collect();
            let max_new = 1 + rng.gen_range(12);
            let decoder = decoders[rng.gen_range(decoders.len())].clone();
            // 25% of requests carry a stop token (any token id: most
            // never trigger, some truncate mid-stream)
            let sampling = if rng.gen_range(4) == 0 {
                Some(SamplingPatch {
                    stop: Some(vec![rng.gen_range(VOCAB) as u32]),
                    ..Default::default()
                })
            } else {
                None
            };
            let priority = rng.gen_range(3) as u8;
            let deadline_ms = if rng.gen_range(2) == 0 {
                Some(50 + 100 * rng.gen_range(5) as u64)
            } else {
                None
            };
            Spec { id, prompt, max_new, decoder, sampling, priority, deadline_ms }
        })
        .collect()
}

/// Submit the workload, drain every receiver (watchdog per receive) and
/// return per-request (stream, stats) plus the final metrics snapshot,
/// the flight-recorder journal (empty when `cfg.trace_events` is 0) and
/// the speculation-analytics handle (inert when `stats_window_rounds`
/// is 0).
fn run_workload(
    target: SimLm,
    draft: SimLm,
    cfg: EngineConfig,
    specs: &[Spec],
) -> (Vec<(Vec<u32>, DecodeStats)>, Snapshot, Vec<TraceEvent>, Analytics) {
    let trace = Tracer::new(cfg.trace_events);
    let engine =
        Engine::with_telemetry(target, draft, cfg, Arc::new(Metrics::default()), trace.clone());
    let analytics = engine.analytics.clone();
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for s in specs {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: s.id,
            prompt: s.prompt.clone(),
            max_new: s.max_new,
            decoder: s.decoder.clone(),
            sampling: s.sampling.clone(),
            priority: s.priority,
            deadline_ms: s.deadline_ms,
            resp: rtx,
        })
        .unwrap();
        receivers.push((s.id, rrx));
    }
    drop(tx);
    let mut results = Vec::new();
    for (id, rrx) in receivers {
        let mut toks = Vec::new();
        loop {
            match rrx.recv_timeout(Duration::from_secs(180)) {
                Ok(Event::Tokens(t)) => toks.extend(t),
                Ok(Event::Done(r)) => {
                    results.push((toks, r.stats));
                    break;
                }
                Ok(Event::Error(e)) => panic!("request {id} failed: {e}"),
                Err(e) => panic!("request {id} starved or engine deadlocked: {e}"),
            }
        }
    }
    (results, handle.join().unwrap().snapshot(), trace.snapshot(), analytics)
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        max_concurrency: 6,
        max_queue: 256,
        default_max_tokens: 8,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.6, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: ENGINE_SEED,
        fused: true,
        ..EngineConfig::default()
    }
}

/// The soak property (see module docs). The chaos engine runs fused +
/// continuous over an undersized paged pool (24 blocks of 8 across up
/// to 6 concurrent sessions forces preemption mid-run); the reference
/// engine runs the identical workload dense and sequential.
#[test]
fn soak_chaos_is_clean_and_deterministic() {
    let specs = build_workload(2024);

    let kv = KvConfig { num_blocks: 24, block_size: 8, share: true };
    let (t, d) = SimLm::pair_paged(SIM_SEED, 0.8, VOCAB, kv);
    // the chaos run records into a flight-recorder ring AND the
    // speculation-analytics ledger; the reference run turns both off,
    // so the bit-identity assert below doubles as "tracing/analytics
    // on vs off never changes a stream"
    let chaos_cfg = EngineConfig { trace_events: 4096, stats_window_rounds: 16, ..base_cfg() };
    let (chaos, chaos_snap, chaos_events, chaos_stats) = run_workload(t, d, chaos_cfg, &specs);

    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let ref_cfg = EngineConfig { fused: false, stats_window_rounds: 0, ..base_cfg() };
    let (reference, _, ref_events, ref_stats) = run_workload(t, d, ref_cfg, &specs);
    assert!(ref_events.is_empty(), "tracing must stay off by default");
    assert!(!ref_stats.enabled(), "analytics must be off in the reference run");

    // clean terminal states, all 200 of them
    assert_eq!(chaos_snap.completed, N_REQUESTS);
    assert_eq!(chaos_snap.failed, 0);
    assert_eq!(chaos_snap.rejected, 0);
    assert_eq!(chaos_snap.preemptions, chaos_snap.resumes, "every victim resumed");

    // per-request invariants + bit-identity to the sequential reference
    for (i, (spec, ((toks, stats), (ref_toks, _)))) in
        specs.iter().zip(chaos.iter().zip(reference.iter())).enumerate()
    {
        assert_eq!(stats.generated, toks.len(), "request {i}: stats vs stream");
        assert!(toks.len() <= spec.max_new, "request {i}: overlong stream");
        if let Some(patch) = &spec.sampling {
            if let Some(stop) = &patch.stop {
                assert!(
                    !toks.iter().any(|t| stop.contains(t)),
                    "request {i}: stop token leaked into the stream"
                );
            }
        }
        assert_eq!(
            toks, ref_toks,
            "request {i} (id {}): stream differs from sequential reference",
            spec.id
        );
    }

    // the chaos run actually exercised the machinery under test
    assert!(chaos_snap.preemptions > 0, "undersized pool never preempted");
    assert_eq!(chaos_snap.kv_blocks_total, 24);

    // the flight recorder saw the run: the ring holds the newest 4096
    // events in strict sequence order, including preemptions
    assert!(!chaos_events.is_empty(), "tracing was enabled but recorded nothing");
    assert!(chaos_events.windows(2).all(|w| w[1].seq == w[0].seq + 1), "seq gap/tear");
    // the analytics ledger saw the run too: committed tokens reconcile
    // exactly with the delivered streams (the full cross-family
    // reconciliation property lives in tests/stats.rs)
    let totals = chaos_stats.totals();
    let delivered: u64 = chaos.iter().map(|(toks, _)| toks.len() as u64).sum();
    assert_eq!(totals.committed, delivered, "ledger committed vs delivered tokens");
    assert!(totals.target_forwards > 0, "analytics recorded no target forwards");

    // dump the journal as a Chrome trace so CI can archive the soak
    // timeline next to the BENCH_*.json snapshots
    let doc = Json::obj(vec![("trace", chrome_trace(&chaos_events))]);
    let path = harness::snapshot_path("TRACE_soak.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write TRACE_soak.json");
    // and the windowed speculation-analytics report as STATS_soak.json
    let stats_doc = Json::obj(vec![("stats", chaos_stats.stats_json(8))]);
    let stats_path = harness::snapshot_path("STATS_soak.json");
    std::fs::write(&stats_path, format!("{stats_doc}\n")).expect("write STATS_soak.json");
}

/// Continuous batching is token-invisible: requests that join MID-ROUND
/// (at a phase boundary, while the first wave's round is in flight)
/// decode exactly the streams they would get in any other schedule.
/// Dispatch cost makes each model call slow enough (>= tens of ms) that
/// the second wave reliably lands inside the first wave's round 1.
#[test]
fn mid_round_joiners_stream_identically() {
    const OVERHEAD: u64 = 30_000_000;
    let run = |stagger: bool, overhead: u64| {
        let (target, draft) = SimLm::pair(3, 0.8, 64);
        let target = target.with_call_overhead(overhead);
        let draft = draft.with_call_overhead(overhead);
        let cfg = EngineConfig {
            max_concurrency: 4,
            max_queue: 16,
            default_max_tokens: 6,
            sampling: SamplingConfig::new(0.5, 1.0),
            decoder: DecoderConfig::RsdS { w: 3, l: 3 },
            seed: 5,
            fused: true,
            ..EngineConfig::default()
        };
        let engine = Engine::new(target, draft, cfg);
        let (tx, handle) = spawn(engine);
        let mut receivers = Vec::new();
        for id in 0..4u64 {
            if stagger && id == 2 {
                // wave 2: the engine is a few dispatches into wave 1's
                // first round (each dispatch burns >= tens of ms; this
                // sleep is well inside the first draft phase)
                std::thread::sleep(Duration::from_millis(18));
            }
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                id,
                prompt: vec![1 + id as u32, 7],
                max_new: 6,
                decoder: None,
                sampling: None,
                priority: 0,
                deadline_ms: None,
                resp: rtx,
            })
            .unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        let mut streams = Vec::new();
        for rrx in receivers {
            let mut toks = Vec::new();
            loop {
                match rrx.recv_timeout(Duration::from_secs(180)) {
                    Ok(Event::Tokens(t)) => toks.extend(t),
                    Ok(Event::Done(_)) => break,
                    Ok(Event::Error(e)) => panic!("{e}"),
                    Err(e) => panic!("deadlock: {e}"),
                }
            }
            streams.push(toks);
        }
        (streams, handle.join().unwrap().snapshot())
    };

    let (staggered, snap) = run(true, OVERHEAD);
    // the reference schedule: everyone up front, no dispatch cost
    let (upfront, _) = run(false, 0);
    assert_eq!(staggered, upfront, "mid-round joining changed a stream");
    assert!(
        snap.mid_round_admitted >= 1,
        "second wave was expected to join mid-round (got {} mid-round admissions)",
        snap.mid_round_admitted
    );
}
