//! Fused-execution properties on the sim substrate:
//!
//! * fused `eval_batch` stepping vs the per-request fallback path must
//!   produce byte-identical token streams for a mixed batch of decoders
//!   (ar / rsd-c / rsd-s / spectr / adaptive, heterogeneous depths and
//!   budgets);
//! * per-request RNG streams make every (non-adaptive) request's output
//!   independent of admission order and batch composition;
//! * stop tokens truncate the stream at the first occurrence with
//!   consistent stats;
//! * the engine exposes fused-batch telemetry.

use std::sync::mpsc;

use rsd::config::{AdaptiveFamily, DecoderConfig, EngineConfig, SamplingConfig, SamplingPatch};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::Snapshot;
use rsd::decode::generate;
use rsd::sim::SimLm;
use rsd::util::Rng;

/// (id, prompt, max_new, decoder override) of one request.
type Req = (u64, Vec<u32>, usize, Option<DecoderConfig>);

/// The heterogeneous decoder mix exercised by the equivalence property.
fn mixed_decoders() -> Vec<Option<DecoderConfig>> {
    vec![
        None, // engine default
        Some(DecoderConfig::Ar),
        Some(DecoderConfig::RsdC { branches: vec![2, 2, 1] }),
        Some(DecoderConfig::RsdS { w: 4, l: 2 }),
        Some(DecoderConfig::SpecTr { k: 2, l: 3 }),
        Some(DecoderConfig::Adaptive { budget: 6, family: AdaptiveFamily::Auto }),
        Some(DecoderConfig::Adaptive { budget: 20, family: AdaptiveFamily::RsdS }),
        Some(DecoderConfig::Sd { l: 5 }),
    ]
}

fn engine_cfg(seed: u64, fused: bool) -> EngineConfig {
    EngineConfig {
        max_concurrency: 8,
        max_queue: 64,
        default_max_tokens: 24,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.6, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed,
        fused,
        ..EngineConfig::default()
    }
}

/// Run a set of requests to completion; returns their token streams in
/// submission order plus the final metrics snapshot.
fn run_requests(alpha: f64, cfg: EngineConfig, reqs: Vec<Req>) -> (Vec<Vec<u32>>, Snapshot) {
    let (target, draft) = SimLm::pair(9, alpha, 64);
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for (id, prompt, max_new, decoder) in reqs {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id,
            prompt,
            max_new,
            decoder,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    let mut streams = Vec::new();
    for rrx in receivers {
        let mut toks = Vec::new();
        while let Ok(ev) = rrx.recv() {
            match ev {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(_) => break,
                Event::Error(e) => panic!("{e}"),
            }
        }
        streams.push(toks);
    }
    (streams, handle.join().unwrap().snapshot())
}

fn mixed_requests() -> Vec<Req> {
    mixed_decoders()
        .into_iter()
        .enumerate()
        .map(|(i, d)| {
            // heterogeneous prompts and lengths: requests finish at
            // different rounds, so late fused calls run partially filled
            (i as u64, vec![1 + i as u32, 7, 3], 12 + 3 * i, d)
        })
        .collect()
}

/// PROPERTY (acceptance criterion): a mixed batch decodes byte-identical
/// streams under fused `eval_batch` and the per-request fallback path.
#[test]
fn fused_and_sequential_streams_identical() {
    for (alpha_i, seed) in [(0usize, 1u64), (1, 5), (2, 11)] {
        let alpha = [0.5, 0.8, 0.95][alpha_i];
        let (fused, fsnap) = run_requests(alpha, engine_cfg(seed, true), mixed_requests());
        let (seq, _) = run_requests(alpha, engine_cfg(seed, false), mixed_requests());
        assert_eq!(fused, seq, "alpha {alpha} seed {seed}");
        assert!(fused.iter().all(|s| !s.is_empty()));
        // the fused run actually fused: batches larger than one request
        assert!(
            fsnap.fused_batch_hist.iter().any(|&(g, _)| g > 1),
            "{:?}",
            fsnap.fused_batch_hist
        );
    }
}

/// SATELLITE: per-request deterministic RNG — output is independent of
/// admission order and of what else shares the batch (static decoders;
/// `adaptive:B` shapes intentionally share global statistics).
#[test]
fn output_independent_of_batch_composition_and_order() {
    let decoder = Some(DecoderConfig::RsdC { branches: vec![2, 2] });
    let solo = || -> Vec<Req> { vec![(5u64, vec![6, 7, 3], 20, decoder.clone())] };
    let crowd = || {
        let mut reqs: Vec<Req> = (0..4u64)
            .map(|i| (i, vec![1 + i as u32, 2], 16, Some(DecoderConfig::RsdS { w: 3, l: 2 })))
            .collect();
        reqs.push(solo()[0].clone());
        reqs
    };

    let (alone, _) = run_requests(0.8, engine_cfg(7, true), solo());
    let (crowded, _) = run_requests(0.8, engine_cfg(7, true), crowd());
    assert_eq!(alone[0], crowded[4], "batch composition changed request 5's stream");

    // admission order: same requests, reversed submission
    let mut rev = crowd();
    rev.reverse();
    let (fwd, _) = run_requests(0.8, engine_cfg(7, true), crowd());
    let (bwd, _) = run_requests(0.8, engine_cfg(7, true), rev);
    let mut bwd_rev = bwd;
    bwd_rev.reverse();
    assert_eq!(fwd, bwd_rev, "admission order changed some stream");
}

/// SATELLITE: stop tokens end generation at the first occurrence; the
/// stop token is not emitted and stats stay consistent.
#[test]
fn stop_tokens_truncate_streams() {
    let (target, draft) = SimLm::pair(4, 0.8, 48);
    for decoder in [
        DecoderConfig::Ar,
        DecoderConfig::RsdS { w: 3, l: 3 },
        DecoderConfig::RsdC { branches: vec![2, 2] },
    ] {
        let sampling = SamplingConfig::new(0.9, 1.0);
        let mut rng = Rng::seed_from_u64(3);
        let full =
            generate(&decoder, &sampling, &target, &draft, &[1, 2], 32, &mut rng).unwrap();
        assert_eq!(full.tokens.len(), 32);
        // pick a token that first appears mid-stream and use it as stop
        let stop = full.tokens[16];
        let first = full.tokens.iter().position(|&t| t == stop).unwrap();
        let stopped_sampling = sampling.clone().with_stop(vec![stop]);
        let mut rng = Rng::seed_from_u64(3);
        let stopped =
            generate(&decoder, &stopped_sampling, &target, &draft, &[1, 2], 32, &mut rng)
                .unwrap();
        assert_eq!(
            stopped.tokens,
            full.tokens[..first].to_vec(),
            "{decoder:?}: stop must truncate at the first occurrence"
        );
        assert!(!stopped.tokens.contains(&stop));
        assert_eq!(stopped.stats.generated, stopped.tokens.len(), "{decoder:?}");
        assert!(
            stopped.stats.accepted_draft_tokens + stopped.stats.bonus_tokens
                <= full.stats.accepted_draft_tokens + full.stats.bonus_tokens,
            "{decoder:?}: dropped tokens must not inflate acceptance stats"
        );
    }
}

/// Stop tokens work end to end over the engine (wire-level semantics).
#[test]
fn engine_honors_per_request_stop() {
    let (target, draft) = SimLm::pair(2, 0.8, 48);
    let engine = Engine::new(target, draft, engine_cfg(3, true));
    let (tx, handle) = spawn(engine);

    // first: an unstopped probe to learn the stream
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request {
        id: 1,
        prompt: vec![5, 6],
        max_new: 24,
        decoder: None,
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp: rtx,
    })
    .unwrap();
    let mut probe = Vec::new();
    while let Ok(ev) = rrx.recv() {
        match ev {
            Event::Tokens(t) => probe.extend(t),
            Event::Done(_) => break,
            Event::Error(e) => panic!("{e}"),
        }
    }
    let stop = probe[8];
    let first = probe.iter().position(|&t| t == stop).unwrap();

    // same request id + engine seed => same stream; the stop-only patch
    // inherits the engine's temperature/top_p, so streams match exactly
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request {
        id: 1,
        prompt: vec![5, 6],
        max_new: 24,
        decoder: None,
        sampling: Some(SamplingPatch { stop: Some(vec![stop]), ..Default::default() }),
        priority: 0,
        deadline_ms: None,
        resp: rtx,
    })
    .unwrap();
    drop(tx);
    let mut stopped = Vec::new();
    let mut done_stats = None;
    while let Ok(ev) = rrx.recv() {
        match ev {
            Event::Tokens(t) => stopped.extend(t),
            Event::Done(r) => {
                done_stats = Some(r.stats);
                break;
            }
            Event::Error(e) => panic!("{e}"),
        }
    }
    assert_eq!(stopped, probe[..first].to_vec());
    assert_eq!(done_stats.unwrap().generated, first);
    handle.join().unwrap();
}

/// The engine's fused telemetry is populated and self-consistent.
#[test]
fn fused_telemetry_exposed() {
    let (streams, snap) = run_requests(0.8, engine_cfg(2, true), mixed_requests());
    assert_eq!(streams.len(), 8);
    assert!(snap.fused_calls > 0);
    let hist_total: u64 = snap.fused_batch_hist.iter().map(|&(_, c)| c).sum();
    assert_eq!(hist_total, snap.fused_calls);
    let fill_total: u64 = snap.fused_fill_hist.iter().sum();
    assert_eq!(fill_total, snap.fused_calls);
    assert!(snap.fused_mean_batch >= 1.0);
    // full-width calls exist (every request participates in round 1)
    assert!(snap.fused_batch_hist.iter().any(|&(g, _)| g >= 2));
}
