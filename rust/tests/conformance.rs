//! Statistical conformance suite: end-to-end *distribution losslessness*
//! of every decoder mode on the sim substrate.
//!
//! The paper's correctness bar (Theorems 3.1/3.2, and the multi-draft
//! analyses of SpecHub / the Xia et al. survey) is that speculative
//! verification must leave the target model's output distribution
//! untouched — acceleration is only legitimate if the emitted tokens
//! are exactly `q`-distributed. The unit tests in `src/decode/rrs.rs`
//! check the *rules* in isolation; this suite checks the whole pipeline:
//! drafting strategy -> tree construction -> fused phase machine ->
//! verification walk -> commit/emission.
//!
//! Method: for each decoder mode (sd, rsd-c, rsd-s, spectr) run 50k
//! independent single-round decodes (one seed per draw, pinned), record
//! the FIRST emitted token, and compare the empirical distribution
//! against the target model's processed softmax at the prompt context.
//! The first token is the level-0 verification outcome (accepted sibling
//! or residual sample), so by the losslessness theorems its law must be
//! exactly `q(. | prompt)` for every mode.
//!
//! The gate is a pooled Pearson chi-square test (primary, calibrated:
//! threshold df + 8·sqrt(2·df), false-alarm probability < 1e-6 across
//! the whole suite) plus a total-variation bound (secondary, catches
//! mass sitting in pooled low-expectation bins).
//!
//! Statistical power is demonstrated by a NEGATIVE control: the same
//! harness run against a deliberately biased verifier (accept-first,
//! which makes the output follow the DRAFT distribution) must fail —
//! `#[should_panic]` on the exact assertion message.

use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::decode::generate;
use rsd::decode::rrs::{LevelOutcome, VerifyRule};
use rsd::decode::spec::run_spec;
use rsd::decode::strategies::Chain;
use rsd::sampling::{process_logits, LogProbs, VerifyScratch};
use rsd::sim::SimLm;
use rsd::util::Rng;

/// Small vocabulary keeps per-bin expected counts high (chi-square
/// power) while the analytic sim still produces a rugged distribution.
const VOCAB: usize = 24;
/// Draws per decoder mode (~50k, per the suite's design target).
const DRAWS: usize = 50_000;
/// Sim seed 9 at alpha 0.5 puts the draft FAR from the target at this
/// context (TV(p, q) ≈ 0.90), so a biased verifier is unmissable while
/// the lossless ones still face a genuinely adversarial draft.
const SIM_SEED: u64 = 9;
const ALPHA: f64 = 0.5;
const PROMPT: [u32; 3] = [3, 1, 4];
const TEMPERATURE: f32 = 0.7;

fn sim_pair() -> (SimLm, SimLm) {
    SimLm::pair(SIM_SEED, ALPHA, VOCAB)
}

/// The target model's processed next-token law at the prompt context —
/// what every lossless decoder's first token must follow.
fn target_law(target: &SimLm) -> Vec<f64> {
    process_logits(&target.logits(&PROMPT), TEMPERATURE, 1.0).probs()
}

/// Pooled Pearson chi-square + total-variation conformance gate.
/// Bins with expected count < 5 are pooled into one cell (the standard
/// validity condition for the chi-square approximation).
fn assert_conforms(label: &str, hist: &[u64], law: &[f64]) {
    assert_eq!(hist.len(), law.len());
    let n: u64 = hist.iter().sum();
    let nf = n as f64;
    assert!(n > 0, "{label}: empty histogram");
    let mut chi2 = 0.0;
    let mut tv = 0.0;
    let mut cells = 0usize;
    let (mut pooled_obs, mut pooled_exp) = (0.0f64, 0.0f64);
    for (&h, &q) in hist.iter().zip(law) {
        let (obs, exp) = (h as f64, nf * q);
        tv += (obs / nf - q).abs();
        if exp >= 5.0 {
            chi2 += (obs - exp) * (obs - exp) / exp;
            cells += 1;
        } else {
            pooled_obs += obs;
            pooled_exp += exp;
        }
    }
    tv *= 0.5;
    if pooled_exp > 0.0 {
        chi2 += (pooled_obs - pooled_exp) * (pooled_obs - pooled_exp) / pooled_exp;
        cells += 1;
    }
    let df = (cells.max(2) - 1) as f64;
    // mean df, sd sqrt(2 df): 8 sigma keeps the suite-wide false-alarm
    // probability far below 1e-6 while a biased verifier lands 3-5
    // orders of magnitude above
    let chi2_bound = df + 8.0 * (2.0 * df).sqrt();
    let tv_bound = 4.0 * ((VOCAB as f64) / nf).sqrt();
    assert!(
        chi2 <= chi2_bound && tv <= tv_bound,
        "conformance violated for {label}: chi2 {chi2:.1} (bound {chi2_bound:.1}), \
         TV {tv:.4} (bound {tv_bound:.4}), n {n}"
    );
}

/// Empirical first-token histogram of `decoder` over [`DRAWS`] pinned
/// seeds (seed = draw index: fully reproducible, independent draws).
fn first_token_hist(decoder: &DecoderConfig) -> Vec<u64> {
    let (target, draft) = sim_pair();
    let sampling = SamplingConfig::new(TEMPERATURE, 1.0);
    let mut hist = vec![0u64; VOCAB];
    for seed in 0..DRAWS as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let run = generate(decoder, &sampling, &target, &draft, &PROMPT, 1, &mut rng)
            .expect("decode failed");
        hist[run.tokens[0] as usize] += 1;
    }
    hist
}

fn check_mode(label: &str, decoder: DecoderConfig) {
    let (target, _) = sim_pair();
    assert_conforms(label, &first_token_hist(&decoder), &target_law(&target));
}

#[test]
fn sd_is_distribution_lossless() {
    check_mode("sd:3", DecoderConfig::Sd { l: 3 });
}

#[test]
fn rsd_c_is_distribution_lossless() {
    check_mode("rsd-c:2-2", DecoderConfig::RsdC { branches: vec![2, 2] });
}

#[test]
fn rsd_s_is_distribution_lossless() {
    check_mode("rsd-s:3x2", DecoderConfig::RsdS { w: 3, l: 2 });
}

#[test]
fn spectr_is_distribution_lossless() {
    check_mode("spectr:2x2", DecoderConfig::SpecTr { k: 2, l: 2 });
}

/// A deliberately broken verification rule: it accepts the first sibling
/// unconditionally, so emitted tokens follow the DRAFT distribution
/// instead of the target's.
struct AcceptFirst;

impl VerifyRule for AcceptFirst {
    fn verify_with(
        &self,
        _siblings: &[u32],
        _draft: &LogProbs,
        _target: &LogProbs,
        _scratch: &mut VerifyScratch,
        _rng: &mut Rng,
    ) -> LevelOutcome {
        LevelOutcome::Accept { pos: 0 }
    }
}

/// NEGATIVE control (statistical power): the gate must FAIL against a
/// biased verifier. At this context TV(draft, target) ≈ 0.90, so the
/// chi-square lands orders of magnitude above the bound even at a fifth
/// of the positive tests' sample size.
#[test]
#[should_panic(expected = "conformance violated")]
fn biased_verifier_is_detected() {
    let (target, draft) = sim_pair();
    let sampling = SamplingConfig::new(TEMPERATURE, 1.0);
    let law = target_law(&target);
    let mut hist = vec![0u64; VOCAB];
    for seed in 0..(DRAWS / 5) as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let run = run_spec(
            &target,
            &draft,
            Box::new(Chain { depth: 2 }),
            Box::new(AcceptFirst),
            &sampling,
            &PROMPT,
            1,
            &mut rng,
        )
        .expect("decode failed");
        hist[run.tokens[0] as usize] += 1;
    }
    assert_conforms("accept-first (biased)", &hist, &law);
}
